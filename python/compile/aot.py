"""AOT lowering: jax -> HLO text artifacts for the rust PJRT runtime.

Interchange format is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``). The HLO text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (invoked by ``make artifacts``):
    cd python && python -m compile.aot --out-dir ../artifacts

Writes one ``<name>.hlo.txt`` per variant plus ``manifest.json`` describing
argument shapes/dtypes so the rust runtime (runtime/manifest.rs) can select
and pad without re-deriving shape rules.
"""

import argparse
import hashlib
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import variants


def to_hlo_text(lowered) -> str:
    """Lower a jitted function's StableHLO to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(fn, arg_specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*arg_specs))


def build(out_dir: pathlib.Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"format": "hlo-text", "return_tuple": True, "entries": []}
    for name, fn, arg_specs, meta in variants():
        text = lower_variant(fn, arg_specs)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": path.name,
                "args": [
                    {"shape": list(s.shape), "dtype": str(s.dtype)}
                    for s in arg_specs
                ],
                "meta": meta,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"  {name}: {len(text)} chars")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    manifest = build(pathlib.Path(args.out_dir))
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
