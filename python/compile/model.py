"""Layer-2 JAX compute graphs and the AOT variant registry.

Composes the Layer-1 Pallas kernels into the jitted entry points that
``aot.py`` lowers to HLO text. Combined work-request sizes vary at runtime,
but AOT artifacts have static shapes, so each entry point is lowered at a
ladder of batch sizes (powers of two); the rust runtime picks the smallest
variant that fits and pads (see rust/src/runtime/manifest.rs).

Entry points (shapes per DESIGN.md section 3):
  gravity_B{b}            parts (b,P,4), inters (b,I,4), eps2 (1,)
  gravity_gather_B{b}_S{s} pool (s,4), idx (b,P) i32, inters (b,I,4), eps2 (1,)
  ewald_B{b}              parts (b,P,4), ktab (K,4)
  md_force_C{c}           pa (c,N,2), pb (c,N,2), params (3,)
"""

import jax
import jax.numpy as jnp

from .kernels import (
    INTERACTIONS,
    KTABLE,
    PARTS_PER_BUCKET,
    PARTS_PER_PATCH,
    ewald,
    gravity,
    gravity_gather,
    md_force,
)

# Batch ladders. The combiner's maxSize for the force kernel is 104 and for
# Ewald 65 (paper section 4.3), so the ladders cover up to 128 buckets.
GRAVITY_BATCHES = (8, 16, 32, 64, 128)
GATHER_BATCHES = (16, 64, 128)
POOL_SIZES = (2048, 16384)
EWALD_BATCHES = (16, 64, 128)
MD_BATCHES = (4, 16, 64)

P = PARTS_PER_BUCKET
I = INTERACTIONS
K = KTABLE
N = PARTS_PER_PATCH

F32 = jnp.float32
I32 = jnp.int32


def _s(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def gravity_fn(parts, inters, eps2):
    """L2 graph: combined bucket gravity. Thin today; the seam where a
    multipole expansion or a bwd pass would compose with the kernel."""
    return (gravity(parts, inters, eps2),)


def gravity_gather_fn(pool, idx, inters, eps2):
    """L2 graph: reuse-path gravity (gather from the device pool)."""
    return (gravity_gather(pool, idx, inters, eps2),)


def ewald_fn(parts, ktab):
    """L2 graph: combined Ewald k-space correction."""
    return (ewald(parts, ktab),)


def md_force_fn(pa, pb, params):
    """L2 graph: combined patch-pair LJ forces."""
    return (md_force(pa, pb, params),)


def variants():
    """Yield (name, fn, arg_specs, meta) for every AOT artifact.

    meta is embedded in artifacts/manifest.json so the rust runtime can
    select variants without re-deriving shape rules.
    """
    for b in GRAVITY_BATCHES:
        yield (
            f"gravity_B{b}",
            gravity_fn,
            (_s((b, P, 4)), _s((b, I, 4)), _s((1,))),
            {"kernel": "gravity", "batch": b, "parts": P, "inters": I},
        )
    for b in GATHER_BATCHES:
        for s in POOL_SIZES:
            yield (
                f"gravity_gather_B{b}_S{s}",
                gravity_gather_fn,
                (_s((s, 4)), _s((b, P), I32), _s((b, I, 4)), _s((1,))),
                {
                    "kernel": "gravity_gather",
                    "batch": b,
                    "pool": s,
                    "parts": P,
                    "inters": I,
                },
            )
    for b in EWALD_BATCHES:
        yield (
            f"ewald_B{b}",
            ewald_fn,
            (_s((b, P, 4)), _s((K, 4))),
            {"kernel": "ewald", "batch": b, "parts": P, "ktable": K},
        )
    for c in MD_BATCHES:
        yield (
            f"md_force_C{c}",
            md_force_fn,
            (_s((c, N, 2)), _s((c, N, 2)), _s((3,))),
            {"kernel": "md_force", "batch": c, "parts": N},
        )
