"""Layer-1 Pallas kernel for the 2D molecular-dynamics ``interact`` method.

Paper section 4.2: the MD app partitions 2D space into patches; a *compute*
object calculates forces between one pair of patches via the ``interact``
entry method, implemented as a CUDA kernel in G-Charm. Here it is a Pallas
kernel: one grid step per patch pair in the combined work request, with the
(N x N) pair panel as the VMEM tile.

Lennard-Jones with cutoff:
  r2 < rc2:  F = 24 eps (2 (sig2/r2)^6 - (sig2/r2)^3) / r2 * d
Self-pairs (r2 ~ 0, when a patch interacts with itself) and padding
particles (parked at HUGE coordinates, so r2 > rc2) are masked out.

Layouts:
  pa, pb (C, N, 2)  particle positions of the two patches per pair.
  params (3,)       [rc2, sig2, eps].
  out    (C, N, 2)  forces on pa particles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PARTS_PER_PATCH = 64  # N: particle slots per patch (padded)
PAD_POS = 1.0e8       # padding particles parked far outside any cutoff
_R2_MIN = 1e-9        # masks self-pairs when pa is pb


def _lj_panel(pa, pb, rc2, sig2, eps):
    """pa (N,2), pb (M,2) -> forces on pa (N,2)."""
    d = pa[:, None, :] - pb[None, :, :]            # (N, M, 2)
    r2 = jnp.sum(d * d, axis=-1)                   # (N, M)
    mask = (r2 < rc2) & (r2 > _R2_MIN)
    r2s = jnp.where(mask, r2, 1.0)
    s2 = sig2 / r2s
    s6 = s2 * s2 * s2
    f = 24.0 * eps * (2.0 * s6 * s6 - s6) / r2s
    f = jnp.where(mask, f, 0.0)
    return jnp.sum(f[:, :, None] * d, axis=1)      # (N, 2)


def _md_kernel(pa_ref, pb_ref, params_ref, out_ref):
    pa = pa_ref[...][0]            # (N, 2)
    pb = pb_ref[...][0]            # (N, 2)
    rc2 = params_ref[0]
    sig2 = params_ref[1]
    eps = params_ref[2]
    out_ref[...] = _lj_panel(pa, pb, rc2, sig2, eps)[None]


@functools.partial(jax.jit, static_argnames=())
def md_force(pa, pb, params):
    """Combined patch-pair force launch: one grid step per pair.

    pa (C, N, 2), pb (C, N, 2), params (3,) -> (C, N, 2)
    """
    c, n, _ = pa.shape
    return pl.pallas_call(
        _md_kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, n, 2), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, n, 2), lambda g: (g, 0, 0)),
            pl.BlockSpec((3,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n, 2), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, n, 2), jnp.float32),
        interpret=True,
    )(pa, pb, params)
