"""Layer-1 Pallas kernels for the ChaNGa-style bucket gravity force.

The paper (§4.1) computes gravitational forces on *buckets* of particles:
every particle in a bucket interacts with the same list of tree nodes and
particles (the bucket's interaction list). The CUDA scheme (Jetley et al.)
uses a 16x8 thread block staging bucket particles and 8 interactions at a
time through shared memory.

TPU-style rethink (DESIGN.md section "Hardware adaptation"): one Pallas grid
step per bucket; the (P particles x I interactions) panel is the VMEM tile;
the per-thread MAC loop becomes a lane-parallel broadcast/rsqrt/reduce
expression. Two variants:

- ``gravity``         : contiguous particle layout (B, P, 4) -- the paper's
                        "redundant transfer, fully coalesced" configuration.
- ``gravity_gather``  : particles fetched through an index array from a
                        device-resident pool -- the "data reuse" path whose
                        access locality depends on whether the indices are
                        sorted (paper section 3.2, Fig 1 c/d).

Layouts:
  parts  (B, P, 4)  rows are [x, y, z, mass]; padding rows have mass = 0.
  inters (B, I, 4)  interaction entries [x, y, z, mass]; padding mass = 0.
  pool   (S, 4)     device-resident particle pool (gather variant).
  idx    (B, P)     int32 indices into the pool (gather variant).
  eps2   (1,)       Plummer softening squared (> 0 keeps self-terms finite).
  out    (B, P, 4)  [ax, ay, az, potential].

All kernels are lowered with interpret=True: real-TPU Pallas emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PARTS_PER_BUCKET = 16  # P: matches the paper's 16-row CUDA block
INTERACTIONS = 128     # I: interaction-list slots per bucket (padded)


def _bucket_force(pos, mass_src, src, eps2):
    """Softened monopole gravity for one (P, I) panel.

    pos      (P, 3) bucket particle positions
    mass_src (I,)   interaction masses (0 = padding)
    src      (I, 3) interaction positions
    returns  (P, 4) [ax, ay, az, potential]
    """
    d = src[None, :, :] - pos[:, None, :]          # (P, I, 3)
    r2 = jnp.sum(d * d, axis=-1) + eps2            # (P, I)
    inv = jax.lax.rsqrt(r2)
    inv3 = inv * inv * inv
    w = mass_src[None, :] * inv3                   # (P, I)
    acc = jnp.sum(w[:, :, None] * d, axis=1)       # (P, 3)
    pot = -jnp.sum(mass_src[None, :] * inv, axis=1)
    return jnp.concatenate([acc, pot[:, None]], axis=-1)


def _gravity_kernel(parts_ref, inters_ref, eps2_ref, out_ref):
    parts = parts_ref[...][0]       # (P, 4)
    inters = inters_ref[...][0]     # (I, 4)
    eps2 = eps2_ref[0]
    out = _bucket_force(parts[:, :3], inters[:, 3], inters[:, :3], eps2)
    out_ref[...] = out[None]


def _gravity_gather_kernel(pool_ref, idx_ref, inters_ref, eps2_ref, out_ref):
    pool = pool_ref[...]            # (S, 4)
    idx = idx_ref[...][0]           # (P,)
    inters = inters_ref[...][0]     # (I, 4)
    eps2 = eps2_ref[0]
    parts = pool[idx]               # gather: locality depends on idx order
    out = _bucket_force(parts[:, :3], inters[:, 3], inters[:, :3], eps2)
    out_ref[...] = out[None]


@functools.partial(jax.jit, static_argnames=())
def gravity(parts, inters, eps2):
    """Combined bucket-force launch: one grid step per bucket.

    parts (B, P, 4), inters (B, I, 4), eps2 (1,) -> (B, P, 4)
    """
    b, p, _ = parts.shape
    _, i, _ = inters.shape
    return pl.pallas_call(
        _gravity_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, p, 4), lambda k: (k, 0, 0)),
            pl.BlockSpec((1, i, 4), lambda k: (k, 0, 0)),
            pl.BlockSpec((1,), lambda k: (0,)),
        ],
        out_specs=pl.BlockSpec((1, p, 4), lambda k: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, p, 4), jnp.float32),
        interpret=True,
    )(parts, inters, eps2)


@functools.partial(jax.jit, static_argnames=())
def gravity_gather(pool, idx, inters, eps2):
    """Reuse-path bucket force: particles gathered from the device pool.

    pool (S, 4), idx (B, P) int32, inters (B, I, 4), eps2 (1,) -> (B, P, 4)

    Layer-2 structure (EXPERIMENTS.md Perf): the HBM gather `pool[idx]`
    happens *outside* the Pallas grid as a single XLA gather -- streaming
    the whole pool through every grid step's VMEM block was the naive port
    and cost ~1.9x on the CPU executor. The access-locality cost of the
    gather itself (sorted vs random idx) is what the Fig 3 experiment
    measures; it is preserved.
    """
    b, p = idx.shape
    _, i, _ = inters.shape
    parts = pool[idx]  # (B, P, 4) single gather from the device pool
    return pl.pallas_call(
        _gravity_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, p, 4), lambda k: (k, 0, 0)),
            pl.BlockSpec((1, i, 4), lambda k: (k, 0, 0)),
            pl.BlockSpec((1,), lambda k: (0,)),
        ],
        out_specs=pl.BlockSpec((1, p, 4), lambda k: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, p, 4), jnp.float32),
        interpret=True,
    )(parts, inters, eps2)
