"""Pure-jnp correctness oracles for every Layer-1 kernel.

Independent implementations (no shared helpers with the kernels): pytest
and hypothesis compare ``kernels.*`` against these with assert_allclose.
These are also the Layer-2 reference used by the rust integration tests'
golden values.
"""

import jax.numpy as jnp


def gravity_ref(parts, inters, eps2):
    """parts (B,P,4), inters (B,I,4), eps2 (1,) -> (B,P,4)."""
    pos = parts[:, :, None, :3]                     # (B, P, 1, 3)
    src = inters[:, None, :, :3]                    # (B, 1, I, 3)
    m = inters[:, None, :, 3]                       # (B, 1, I)
    d = src - pos                                   # (B, P, I, 3)
    r2 = jnp.sum(d * d, axis=-1) + eps2[0]
    inv = 1.0 / jnp.sqrt(r2)
    w = m * inv ** 3                                # (B, P, I)
    acc = jnp.sum(w[..., None] * d, axis=2)         # (B, P, 3)
    pot = -jnp.sum(m * inv, axis=2)                 # (B, P)
    return jnp.concatenate([acc, pot[..., None]], axis=-1)


def gravity_gather_ref(pool, idx, inters, eps2):
    """pool (S,4), idx (B,P) i32, inters (B,I,4), eps2 (1,) -> (B,P,4)."""
    parts = pool[idx]                               # (B, P, 4)
    return gravity_ref(parts, inters, eps2)


def ewald_ref(parts, ktab):
    """parts (B,P,4), ktab (K,4) -> (B,P,4)."""
    pos = parts[:, :, :3]                           # (B, P, 3)
    mass = parts[:, :, 3]                           # (B, P)
    kvec = ktab[:, :3]                              # (K, 3)
    coef = ktab[:, 3]                               # (K,)
    phase = jnp.einsum("bpd,kd->bpk", pos, kvec)    # (B, P, K)
    force = mass[..., None] * jnp.einsum(
        "bpk,kd->bpd", jnp.sin(phase) * coef, kvec
    )
    pot = mass * jnp.sum(jnp.cos(phase) * coef, axis=-1)
    return jnp.concatenate([force, pot[..., None]], axis=-1)


def md_force_ref(pa, pb, params):
    """pa (C,N,2), pb (C,N,2), params (3,) -> (C,N,2)."""
    rc2, sig2, eps = params[0], params[1], params[2]
    d = pa[:, :, None, :] - pb[:, None, :, :]       # (C, N, N, 2)
    r2 = jnp.sum(d * d, axis=-1)
    mask = (r2 < rc2) & (r2 > 1e-9)
    r2s = jnp.where(mask, r2, 1.0)
    s6 = (sig2 / r2s) ** 3
    f = jnp.where(mask, 24.0 * eps * (2.0 * s6 * s6 - s6) / r2s, 0.0)
    return jnp.sum(f[..., None] * d, axis=2)
