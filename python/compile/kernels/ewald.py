"""Layer-1 Pallas kernel for the k-space Ewald summation correction.

ChaNGa applies periodic boundary conditions with Ewald summation (paper
section 4.1): each particle accumulates a reciprocal-space force/potential
correction over a precomputed table of k-vectors. The paper's framework
measured 31% occupancy for this kernel on Kepler, yielding maxSize = 65
combined work requests (section 4.3); the rust coordinator reproduces that
number from the analytic occupancy model.

Layouts:
  parts (B, P, 4)  [x, y, z, mass]; padding rows have mass = 0.
  ktab  (K, 4)     [kx, ky, kz, coef] reciprocal-space table.
  out   (B, P, 4)  [fx, fy, fz, potential].

Math (standard k-space form, one image box):
  phase_ik = k_vec . r_i
  F_i  += mass_i * coef_k * k_vec * sin(phase_ik)
  pot_i += mass_i * coef_k * cos(phase_ik)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KTABLE = 64  # K: k-vector slots (padded with coef = 0)


def _ewald_panel(pos, mass, kvec, coef):
    """pos (P,3), mass (P,), kvec (K,3), coef (K,) -> (P,4)."""
    phase = pos @ kvec.T                              # (P, K)
    s = jnp.sin(phase) * coef[None, :]                # (P, K)
    c = jnp.cos(phase) * coef[None, :]
    force = mass[:, None] * (s @ kvec)                # (P, 3)
    pot = mass * jnp.sum(c, axis=1)                   # (P,)
    return jnp.concatenate([force, pot[:, None]], axis=-1)


def _ewald_kernel(parts_ref, ktab_ref, out_ref):
    parts = parts_ref[...][0]     # (P, 4)
    ktab = ktab_ref[...]          # (K, 4)
    out = _ewald_panel(parts[:, :3], parts[:, 3], ktab[:, :3], ktab[:, 3])
    out_ref[...] = out[None]


@functools.partial(jax.jit, static_argnames=())
def ewald(parts, ktab):
    """Combined Ewald launch: one grid step per bucket.

    parts (B, P, 4), ktab (K, 4) -> (B, P, 4)
    """
    b, p, _ = parts.shape
    k, _ = ktab.shape
    return pl.pallas_call(
        _ewald_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, p, 4), lambda g: (g, 0, 0)),
            pl.BlockSpec((k, 4), lambda g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p, 4), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, p, 4), jnp.float32),
        interpret=True,
    )(parts, ktab)
