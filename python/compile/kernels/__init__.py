"""Layer-1 Pallas kernels (build-time only; never imported at runtime)."""

from .ewald import KTABLE, ewald
from .gravity import INTERACTIONS, PARTS_PER_BUCKET, gravity, gravity_gather
from .md_force import PAD_POS, PARTS_PER_PATCH, md_force

__all__ = [
    "KTABLE",
    "INTERACTIONS",
    "PARTS_PER_BUCKET",
    "PARTS_PER_PATCH",
    "PAD_POS",
    "ewald",
    "gravity",
    "gravity_gather",
    "md_force",
]
