"""Pallas gravity kernels vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import gravity, gravity_gather
from compile.kernels.ref import gravity_gather_ref, gravity_ref

EPS2 = jnp.array([1e-2], dtype=jnp.float32)


def _rand_parts(rng, b, p):
    pos = rng.uniform(-1.0, 1.0, size=(b, p, 3))
    mass = rng.uniform(0.1, 2.0, size=(b, p, 1))
    return jnp.asarray(np.concatenate([pos, mass], axis=-1), jnp.float32)


def _rand_inters(rng, b, i):
    return _rand_parts(rng, b, i)


def test_gravity_matches_ref():
    rng = np.random.default_rng(0)
    parts = _rand_parts(rng, 8, 16)
    inters = _rand_inters(rng, 8, 128)
    got = gravity(parts, inters, EPS2)
    want = gravity_ref(parts, inters, EPS2)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_gravity_zero_mass_interactions_are_inert():
    rng = np.random.default_rng(1)
    parts = _rand_parts(rng, 4, 16)
    inters = _rand_inters(rng, 4, 128)
    # zero out the mass of half the interaction slots (padding convention)
    padded = inters.at[:, 64:, 3].set(0.0)
    trimmed = gravity_ref(parts, padded[:, :64], EPS2)
    got = gravity(parts, padded, EPS2)
    assert_allclose(np.asarray(got), np.asarray(trimmed), rtol=2e-4, atol=2e-4)


def test_gravity_attracts_toward_mass():
    # single particle at origin, single far mass on +x: acceleration is +x
    parts = jnp.zeros((1, 16, 4), jnp.float32).at[0, 0, 3].set(1.0)
    inters = jnp.zeros((1, 128, 4), jnp.float32)
    inters = inters.at[0, 0].set(jnp.array([2.0, 0.0, 0.0, 5.0]))
    out = np.asarray(gravity(parts, inters, EPS2))
    assert out[0, 0, 0] > 0.0
    assert abs(out[0, 0, 1]) < 1e-6 and abs(out[0, 0, 2]) < 1e-6
    assert out[0, 0, 3] < 0.0  # potential is negative


def test_gravity_newton_pair_magnitude():
    # two unit masses at distance r: |a| ~ 1/(r^2 + eps2)^{3/2} * r
    r = 0.5
    parts = jnp.zeros((1, 16, 4), jnp.float32).at[0, 0, 3].set(1.0)
    inters = jnp.zeros((1, 128, 4), jnp.float32)
    inters = inters.at[0, 0].set(jnp.array([r, 0.0, 0.0, 1.0]))
    out = np.asarray(gravity(parts, inters, EPS2))
    expect = r / (r * r + float(EPS2[0])) ** 1.5
    assert_allclose(out[0, 0, 0], expect, rtol=1e-4)


def test_gravity_gather_matches_ref():
    rng = np.random.default_rng(2)
    pool = jnp.asarray(
        np.concatenate(
            [
                rng.uniform(-1, 1, size=(256, 3)),
                rng.uniform(0.1, 2.0, size=(256, 1)),
            ],
            axis=-1,
        ),
        jnp.float32,
    )
    idx = jnp.asarray(rng.integers(0, 256, size=(8, 16)), jnp.int32)
    inters = _rand_inters(rng, 8, 128)
    got = gravity_gather(pool, idx, inters, EPS2)
    want = gravity_gather_ref(pool, idx, inters, EPS2)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_gather_equals_contiguous_when_identity_indexed():
    """gather(pool, identity) == gravity(pool reshaped): the two code paths
    compute the same physics -- the paper's Fig 3 compares their *speed*."""
    rng = np.random.default_rng(3)
    parts = _rand_parts(rng, 4, 16)
    inters = _rand_inters(rng, 4, 128)
    pool = parts.reshape(-1, 4)
    idx = jnp.arange(64, dtype=jnp.int32).reshape(4, 16)
    a = gravity(parts, inters, EPS2)
    b = gravity_gather(pool, idx, inters, EPS2)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_gather_invariant_under_index_permutation():
    """Sorting the access order (the paper's coalescing strategy) must not
    change the physics, only the locality: permuting rows of idx together
    with output rows is a no-op."""
    rng = np.random.default_rng(4)
    pool = jnp.asarray(rng.uniform(-1, 1, size=(128, 4)), jnp.float32)
    idx = jnp.asarray(rng.permutation(128)[:16].reshape(1, 16), jnp.int32)
    inters = _rand_inters(rng, 1, 128)
    perm = np.argsort(np.asarray(idx[0]))
    sorted_idx = idx[:, perm]
    a = np.asarray(gravity_gather(pool, idx, inters, EPS2))
    b = np.asarray(gravity_gather(pool, sorted_idx, inters, EPS2))
    assert_allclose(a[0, perm], b[0], rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    p=st.sampled_from([4, 8, 16]),
    i=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gravity_hypothesis_shapes(b, p, i, seed):
    rng = np.random.default_rng(seed)
    parts = _rand_parts(rng, b, p)
    inters = _rand_inters(rng, b, i)
    got = gravity(parts, inters, EPS2)
    want = gravity_ref(parts, inters, EPS2)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([64, 256, 1024]),
    b=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_hypothesis_pools(s, b, seed):
    rng = np.random.default_rng(seed)
    pool = jnp.asarray(rng.uniform(-1, 1, size=(s, 4)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, s, size=(b, 16)), jnp.int32)
    inters = _rand_inters(rng, b, 32)
    got = gravity_gather(pool, idx, inters, EPS2)
    want = gravity_gather_ref(pool, idx, inters, EPS2)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)
