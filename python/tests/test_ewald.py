"""Pallas Ewald kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ewald
from compile.kernels.ref import ewald_ref


def _rand(rng, b, p, k):
    pos = rng.uniform(-1.0, 1.0, size=(b, p, 3))
    mass = rng.uniform(0.1, 2.0, size=(b, p, 1))
    parts = jnp.asarray(np.concatenate([pos, mass], -1), jnp.float32)
    kvec = rng.normal(0.0, 2.0, size=(k, 3))
    coef = rng.uniform(0.0, 1.0, size=(k, 1))
    ktab = jnp.asarray(np.concatenate([kvec, coef], -1), jnp.float32)
    return parts, ktab


def test_ewald_matches_ref():
    rng = np.random.default_rng(0)
    parts, ktab = _rand(rng, 8, 16, 64)
    got = ewald(parts, ktab)
    want = ewald_ref(parts, ktab)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_ewald_zero_coef_is_inert():
    rng = np.random.default_rng(1)
    parts, ktab = _rand(rng, 4, 16, 64)
    zeroed = ktab.at[:, 3].set(0.0)
    out = np.asarray(ewald(parts, zeroed))
    assert_allclose(out, np.zeros_like(out), atol=1e-7)


def test_ewald_zero_mass_particle_feels_nothing():
    rng = np.random.default_rng(2)
    parts, ktab = _rand(rng, 2, 16, 64)
    parts = parts.at[:, :, 3].set(0.0)
    out = np.asarray(ewald(parts, ktab))
    assert_allclose(out, np.zeros_like(out), atol=1e-7)


def test_ewald_particle_at_origin_pure_cos():
    # at r = 0: sin term vanishes, potential = mass * sum(coef)
    rng = np.random.default_rng(3)
    _, ktab = _rand(rng, 1, 16, 64)
    parts = jnp.zeros((1, 16, 4), jnp.float32).at[0, 0, 3].set(2.0)
    out = np.asarray(ewald(parts, ktab))
    assert_allclose(out[0, 0, :3], np.zeros(3), atol=1e-5)
    assert_allclose(out[0, 0, 3], 2.0 * float(jnp.sum(ktab[:, 3])), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 2, 8]),
    p=st.sampled_from([4, 16]),
    k=st.sampled_from([8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ewald_hypothesis(b, p, k, seed):
    rng = np.random.default_rng(seed)
    parts, ktab = _rand(rng, b, p, k)
    got = ewald(parts, ktab)
    want = ewald_ref(parts, ktab)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)
