"""Pallas MD force kernel vs the pure-jnp oracle, plus physics sanity."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import PAD_POS, md_force
from compile.kernels.ref import md_force_ref

PARAMS = jnp.array([1.0, 0.04, 1.0], jnp.float32)  # rc2, sig2, eps


def _rand_patch(rng, c, n, lo=0.0, hi=4.0):
    return jnp.asarray(rng.uniform(lo, hi, size=(c, n, 2)), jnp.float32)


def test_md_matches_ref():
    rng = np.random.default_rng(0)
    pa = _rand_patch(rng, 4, 64)
    pb = _rand_patch(rng, 4, 64)
    got = md_force(pa, pb, PARAMS)
    want = md_force_ref(pa, pb, PARAMS)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_md_padding_particles_are_inert():
    rng = np.random.default_rng(1)
    pa = _rand_patch(rng, 2, 64)
    pb = _rand_patch(rng, 2, 64)
    # park the second half of pb at PAD_POS: must not change forces on pa
    padded = pb.at[:, 32:, :].set(PAD_POS)
    trimmed = md_force_ref(pa, pb[:, :32], PARAMS)
    got = md_force(pa, padded, PARAMS)
    assert_allclose(np.asarray(got), np.asarray(trimmed), rtol=2e-4, atol=2e-4)


def test_md_self_patch_no_self_force():
    """Patch interacting with itself: diagonal (r=0) pairs are masked."""
    rng = np.random.default_rng(2)
    pa = _rand_patch(rng, 1, 64)
    out = np.asarray(md_force(pa, pa, PARAMS))
    assert np.all(np.isfinite(out))


def test_md_newton_third_law():
    """Self-patch LJ forces sum to (near) zero -- momentum conservation.

    Particles on a jittered grid (min separation ~ sigma) so magnitudes stay
    O(1-100) and f32 pairwise cancellation is visible above rounding noise.
    """
    rng = np.random.default_rng(3)
    gx, gy = np.meshgrid(np.arange(8) * 0.25, np.arange(8) * 0.25)
    grid = np.stack([gx.ravel(), gy.ravel()], axis=-1)
    grid += rng.uniform(-0.02, 0.02, size=grid.shape)
    pa = jnp.asarray(grid[None], jnp.float32)
    out = np.asarray(md_force(pa, pa, PARAMS))
    scale = np.abs(out).max()
    assert_allclose(out.sum(axis=(0, 1)) / scale, np.zeros(2), atol=1e-3)


def test_md_repulsive_at_short_range():
    # two particles closer than sigma: force on a points away from b
    pa = jnp.zeros((1, 64, 2), jnp.float32) + PAD_POS
    pb = jnp.zeros((1, 64, 2), jnp.float32) + PAD_POS
    pa = pa.at[0, 0].set(jnp.array([0.0, 0.0]))
    pb = pb.at[0, 0].set(jnp.array([0.1, 0.0]))
    out = np.asarray(md_force(pa, pb, PARAMS))
    assert out[0, 0, 0] < 0.0  # pushed in -x, away from the neighbor


def test_md_attractive_in_well():
    # separation between sigma (0.2) and cutoff: attraction
    pa = jnp.zeros((1, 64, 2), jnp.float32) + PAD_POS
    pb = jnp.zeros((1, 64, 2), jnp.float32) + PAD_POS
    pa = pa.at[0, 0].set(jnp.array([0.0, 0.0]))
    pb = pb.at[0, 0].set(jnp.array([0.4, 0.0]))
    out = np.asarray(md_force(pa, pb, PARAMS))
    assert out[0, 0, 0] > 0.0  # pulled in +x, toward the neighbor


def test_md_beyond_cutoff_zero():
    pa = jnp.zeros((1, 64, 2), jnp.float32) + PAD_POS
    pb = jnp.zeros((1, 64, 2), jnp.float32) + PAD_POS
    pa = pa.at[0, 0].set(jnp.array([0.0, 0.0]))
    pb = pb.at[0, 0].set(jnp.array([3.0, 0.0]))  # rc = 1.0
    out = np.asarray(md_force(pa, pb, PARAMS))
    assert_allclose(out, np.zeros_like(out), atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(
    c=st.sampled_from([1, 4, 16]),
    n=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_md_hypothesis(c, n, seed):
    rng = np.random.default_rng(seed)
    pa = _rand_patch(rng, c, n)
    pb = _rand_patch(rng, c, n)
    got = md_force(pa, pb, PARAMS)
    want = md_force_ref(pa, pb, PARAMS)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)
