"""AOT pipeline: lowering, manifest integrity, variant registry."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_variant_names_unique():
    names = [name for name, *_ in model.variants()]
    assert len(names) == len(set(names))


def test_variant_count():
    entries = list(model.variants())
    expected = (
        len(model.GRAVITY_BATCHES)
        + len(model.GATHER_BATCHES) * len(model.POOL_SIZES)
        + len(model.EWALD_BATCHES)
        + len(model.MD_BATCHES)
    )
    assert len(entries) == expected


def test_lower_one_variant_produces_hlo_text():
    name, fn, arg_specs, meta = next(model.variants())
    text = aot.lower_variant(fn, arg_specs)
    assert "HloModule" in text
    assert "ROOT" in text


def test_build_writes_manifest(tmp_path):
    # Build only the cheapest variants by monkeypatching the registry.
    small = [v for v in model.variants()][:2]

    import compile.aot as aot_mod

    orig = aot_mod.variants
    aot_mod.variants = lambda: iter(small)
    try:
        manifest = aot_mod.build(tmp_path)
    finally:
        aot_mod.variants = orig

    assert (tmp_path / "manifest.json").exists()
    loaded = json.loads((tmp_path / "manifest.json").read_text())
    assert loaded["format"] == "hlo-text"
    assert len(loaded["entries"]) == 2
    for e in loaded["entries"]:
        assert (tmp_path / e["file"]).exists()
        assert e["sha256"]
        assert all("shape" in a and "dtype" in a for a in e["args"])


def test_model_entry_points_execute():
    """The jitted L2 graphs run and return 1-tuples (return_tuple contract)."""
    rng = np.random.default_rng(0)
    parts = jnp.asarray(rng.uniform(-1, 1, (8, 16, 4)), jnp.float32)
    inters = jnp.asarray(rng.uniform(-1, 1, (8, 128, 4)), jnp.float32)
    eps2 = jnp.array([1e-2], jnp.float32)
    out = model.gravity_fn(parts, inters, eps2)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (8, 16, 4)

    ktab = jnp.asarray(rng.uniform(-1, 1, (64, 4)), jnp.float32)
    out = model.ewald_fn(parts, ktab)
    assert out[0].shape == (8, 16, 4)

    pa = jnp.asarray(rng.uniform(0, 4, (4, 64, 2)), jnp.float32)
    params = jnp.array([1.0, 0.04, 1.0], jnp.float32)
    out = model.md_force_fn(pa, pa, params)
    assert out[0].shape == (4, 64, 2)
