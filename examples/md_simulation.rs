//! 2D molecular dynamics on the full stack: patches, compute-object work
//! requests, hybrid CPU+GPU execution, particle migration.
//!
//! ```bash
//! make artifacts && cargo run --release --example md_simulation
//! ```

use gcharm::apps::md::{self, MdConfig};
use gcharm::coordinator::{Config, SplitPolicy};

fn main() -> anyhow::Result<()> {
    let mut cfg = MdConfig::new(4096);
    cfg.steps = 8;
    cfg.runtime = Config {
        pes: 4,
        split: SplitPolicy::AdaptiveItems,
        hybrid: true,
        ..Config::default()
    };

    println!(
        "MD: {} particles, {}x{} patches, {} steps, {} PEs, hybrid CPU+GPU",
        cfg.n_particles, cfg.grid, cfg.grid, cfg.steps, cfg.runtime.pes
    );
    let r = md::run(&cfg)?;

    println!("\nkinetic energy per step:");
    for (i, e) in r.energies.iter().enumerate() {
        println!("  step {i:>2}: {e:.4}");
    }
    println!("\nruntime report:\n{}", r.report);
    println!(
        "\nhybrid split: {} items on CPU, {} on GPU ({}% CPU)",
        r.report.cpu_items,
        r.report.gpu_items,
        100 * r.report.cpu_items / (r.report.cpu_items + r.report.gpu_items).max(1)
    );
    println!("wall time: {:.3}s", r.wall);
    Ok(())
}
