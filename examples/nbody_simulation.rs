//! End-to-end driver: the ChaNGa-style N-Body simulation on the full stack.
//!
//! Runs the small (cube300-like) clustered dataset for several iterations
//! through tree build -> walks -> adaptive combining -> reuse+coalescing ->
//! PJRT gravity/Ewald kernels -> integration, and prints the energy curve
//! plus the runtime report. This is the repository's primary end-to-end
//! validation workload (EXPERIMENTS.md section "End-to-end run").
//!
//! ```bash
//! make artifacts && cargo run --release --example nbody_simulation
//! ```

use gcharm::apps::nbody::{self, dataset::DatasetSpec, NbodyConfig};
use gcharm::coordinator::{CombinePolicy, Config, DataPolicy, RoutePolicy};

fn main() -> anyhow::Result<()> {
    let mut cfg = NbodyConfig::new(DatasetSpec::small());
    cfg.iters = 5;
    cfg.runtime = Config {
        pes: 4,
        combine: CombinePolicy::Adaptive,
        data_policy: DataPolicy::ReuseSorted,
        // Sharded GPU pool: 2 simulated devices, chare-affinity routing
        // with idle-steal rebalancing. `devices: 1` reproduces the
        // single-device runtime; the report breaks out per-device stats.
        devices: 2,
        route: RoutePolicy::AffinitySteal,
        ..Config::default()
    };

    println!(
        "N-Body: {} particles ({} clusters), {} iterations, {} PEs, {} devices",
        cfg.dataset.n,
        cfg.dataset.clusters,
        cfg.iters,
        cfg.runtime.pes,
        cfg.runtime.devices
    );
    let r = nbody::run(&cfg)?;

    println!("\nbuckets: {}", r.buckets);
    println!("energy curve (kinetic + potential/2):");
    for (i, e) in r.energies.iter().enumerate() {
        println!("  iter {i:>2}: {e:+.6e}");
    }
    let drift = (r.energies.last().unwrap() - r.energies[0]).abs()
        / r.energies[0].abs();
    println!("relative energy drift over run: {drift:.3e}");

    println!("\nruntime report:\n{}", r.report);
    println!("\nwall time: {:.3}s", r.wall);
    Ok(())
}
