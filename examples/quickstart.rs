//! Quickstart: the G-Charm public API in ~90 lines.
//!
//! Registers the built-in gravity kernel family through the open kernel
//! registry, defines one custom chare that submits a shape-checked tile
//! work request, receives the result through its entry method, and
//! contributes to a reduction the driver waits on. Run with:
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use gcharm::coordinator::{
    force_descriptor, Chare, ChareId, Config, Ctx, GCharm, KernelKindId,
    Msg, Tile, WorkDraft, WrResult, METHOD_RESULT,
};
use gcharm::runtime::shapes::{
    INTERACTIONS, INTER_W, PARTICLE_W, PARTS_PER_BUCKET,
};

const METHOD_GO: u32 = 1;

/// A chare owning one bucket: a unit-mass particle at the origin with a
/// single mass-2 attractor at x = 2.
struct MyBucket {
    id: ChareId,
    force_kind: KernelKindId,
}

impl Chare for MyBucket {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        match msg.method {
            METHOD_GO => {
                // particle buffer: rows of [x, y, z, mass]
                let mut parts = vec![0.0f32; PARTS_PER_BUCKET * PARTICLE_W];
                parts[3] = 1.0; // particle 0: unit mass at origin
                // interaction list: rows of [x, y, z, mass]
                let mut inters = vec![0.0f32; INTERACTIONS * INTER_W];
                inters[0] = 2.0; // attractor at (2, 0, 0)
                inters[3] = 2.0; // with mass 2
                ctx.submit(WorkDraft {
                    chare: self.id,
                    kind: self.force_kind,
                    buffer: Some(0),
                    data_items: 1,
                    tag: 7,
                    payload: Tile::with_entries(
                        vec![parts, inters],
                        vec![0],
                    ),
                })
                .expect("canonical tile shapes");
            }
            METHOD_RESULT => {
                let r: WrResult = msg.take();
                assert_eq!(r.tag, 7);
                // output rows: [ax, ay, az, potential]
                println!(
                    "gravity on particle 0: a = ({:.4}, {:.4}, {:.4}), pot = {:.4}",
                    r.out[0], r.out[1], r.out[2], r.out[3]
                );
                ctx.contribute(r.out[0] as f64);
            }
            _ => unreachable!(),
        }
    }
}

fn main() -> anyhow::Result<()> {
    // 1. configure the runtime (defaults: adaptive combining, sorted reuse)
    let mut rt = GCharm::new(Config { pes: 2, ..Config::default() })?;

    // 2. register the kernel families the app uses (here: the built-in
    //    gravity descriptor with softening eps2 = 0.01)
    let force_kind = rt.register_kernel(force_descriptor(1e-2))?;

    // 3. register chares before start
    let id = ChareId::new(0, 0);
    rt.register(id, 0, Box::new(MyBucket { id, force_kind }));

    // 4. start PEs + coordinator + GPU service (loads AOT artifacts)
    rt.start()?;

    // 5. drive: send a message, await the reduction
    rt.send(id, Msg::new(METHOD_GO, ()));
    let ax = rt.await_reduction(1);
    println!("reduction value (ax) = {ax:.4}");

    // expected: a_x = m*r/(r^2+eps2)^1.5 = 2*2/(4.01)^1.5 ~ 0.4981
    assert!((ax - 0.4981).abs() < 1e-3);

    // 6. shutdown returns the run report
    let report = rt.shutdown();
    println!("\n{report}");
    Ok(())
}
