//! Demonstrates the paper's dynamic hybrid scheduling (section 3.3 / Fig 5):
//! the same MD workload under the static count-split baseline and the
//! adaptive per-data-item split, printing the resulting device shares and
//! wall times side by side.
//!
//! ```bash
//! make artifacts && cargo run --release --example hybrid_scheduling
//! ```

use gcharm::apps::md::{self, MdConfig};
use gcharm::coordinator::{Config, SplitPolicy};

fn run_one(split: SplitPolicy, label: &str) -> anyhow::Result<f64> {
    let mut cfg = MdConfig::new(6144);
    cfg.steps = 6;
    cfg.clustered = true; // uneven patch populations = irregular workloads
    cfg.runtime =
        Config { pes: 4, split, hybrid: true, ..Config::default() };
    let r = md::run(&cfg)?;
    let total = (r.report.cpu_items + r.report.gpu_items).max(1);
    println!(
        "{label:<18} wall {:.3}s | cpu items {:>8} ({:>2}%) | gpu items {:>8} | \
         cpu task wall {:.3}s | kernel wall {:.3}s",
        r.wall,
        r.report.cpu_items,
        100 * r.report.cpu_items / total,
        r.report.gpu_items,
        r.report.cpu_task_wall,
        r.report.kernel_wall,
    );
    Ok(r.wall)
}

fn main() -> anyhow::Result<()> {
    println!("hybrid scheduling: static count-split vs adaptive item-split\n");
    let stat = run_one(SplitPolicy::StaticCount, "static (count)")?;
    let adapt = run_one(SplitPolicy::AdaptiveItems, "adaptive (items)")?;
    println!(
        "\nadaptive vs static: {:+.1}% (paper Fig 5: 10-15% reduction)",
        (stat - adapt) / stat * 100.0
    );
    Ok(())
}
