//! The node session: one local [`Runtime`] joined to a cluster.
//!
//! [`ClusterNode::run`] glues a [`Transport`] to a runtime instance and
//! runs one application job SPMD-style across the mesh:
//!
//! * **Registration handshake** — every node announces its kernel-family
//!   fingerprint in a `Hello`; a mismatch is a hard error, because kind
//!   ids are registration-order indices and must agree across the wire.
//! * **Cross-node reductions** — [`ClusterHandle::reduce`] folds each
//!   node's per-job reduction result up a binary tree (parent
//!   `(i-1)/2`); the root totals the round and broadcasts a `Release`.
//!   A departed child shrinks the expected-contribution count, so a
//!   graceful early exit never wedges the tree.
//! * **Remote chare messages** — [`ClusterHandle::send_remote`] carries
//!   a serialized payload to a chare on another node, delivered through
//!   the public `Router` path like any local message.
//! * **Cross-node batch steal** — the pump advertises queue depth in
//!   heartbeats; a node under the runtime's learned `steal_low`
//!   watermark asks the deepest peer at/above `steal_high` for work.
//!   The home coordinator drains a combiner batch only when the modeled
//!   serialize+transfer cost ([`super::wire_secs`]) beats the queue
//!   time it saves, ships it, and keeps the originals so a vanished
//!   thief's shipment *requeues at home* instead of hanging quiescence.
//!
//! Remote execution rides the public chare API: every node runs a
//! hidden **mule job** whose single chare resubmits shipped requests
//! through `Ctx::submit` and forwards results back to the pump, so the
//! thief side needs no private scheduler hooks at all.
//!
//! Shutdown is collective and ordered: a node's pump sends `Summary`
//! (its steal/byte counters, to the root) and then `Goodbye` as its
//! **last frames ever**, and only exits after collecting `Goodbye` from
//! every peer — which makes the per-node transport byte counters exact
//! at accounting time and gives conservation invariants something to
//! check (`chaos::invariants::cluster_violations`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::job::NetEndpoint;
use crate::coordinator::scheduler::NetAccountDelta;
use crate::coordinator::{
    Chare, ChareId, Config, Ctx, JobId, JobSpec, KernelKindId, Msg,
    PoolReport, Runtime, Tile, WorkDraft, WorkRequest, WrResult,
    METHOD_RESULT,
};

use super::loopback::LoopbackFabric;
use super::wire::{Frame, WirePayload, WireRequest};
use super::{NodeId, Transport};

/// Job token of the application job in `Chare`/`Contribute` frames.
/// Token 0 is the mule job; only these two jobs exist on the wire, so
/// a u64 token (not a name service) suffices.
const TOKEN_APP: u64 = 1;

/// Entry method of the mule chare: "execute this shipment of drafts".
pub(crate) const MULE_EXEC: u32 = 1;

/// The mule job's single chare. `u32::MAX` keeps it out of any app's
/// collection-id space.
const MULE_CHARE: ChareId = ChareId { collection: u32::MAX, index: 0 };

/// Knobs of the cluster session (transport cadence and the steal
/// protocol's timers; the steal *watermarks* come from the runtime
/// [`Config`] so local and remote rebalancing share one learned model).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Heartbeat/liveness + depth-advertisement period.
    pub heartbeat: Duration,
    /// Enable cross-node batch steal (reductions and chare messages
    /// flow regardless).
    pub steal: bool,
    /// Modeled per-item execution seconds used by the home's
    /// ship-or-keep decision until enough completions teach the real
    /// rate (5 us ~ the K20 model's small-batch gravity rate).
    pub est_item_secs: f64,
    /// Home-side deadline on a shipped batch: results not back in time
    /// requeue locally (covers a thief that died without a `Goodbye`).
    pub ship_timeout: Duration,
    /// Thief-side cap on one outstanding `StealRequest` before it may
    /// target a peer again.
    pub steal_expiry: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            heartbeat: Duration::from_millis(2),
            steal: true,
            est_item_secs: 5e-6,
            ship_timeout: Duration::from_secs(10),
            steal_expiry: Duration::from_millis(300),
        }
    }
}

/// What one node's [`ClusterNode::run`] returns: the local pool report
/// (with the `remote_*` cross-node counters), the app job's reduction
/// series (totals on the root, empty elsewhere), and — on the root —
/// every peer's final `Summary` counters for conservation audits.
#[derive(Debug)]
pub struct NodeReport {
    pub node: NodeId,
    /// The app driver's series. Cross-node totals appear only where
    /// [`ClusterHandle::reduce`] returned `Some` — the root.
    pub series: Vec<f64>,
    pub pool: PoolReport,
    /// Root only: `(node, [steals_out, requests_out, steals_in,
    /// requests_in, requeues, requeued_requests, bytes_out, bytes_in])`
    /// from each peer's `Summary` frame.
    pub peer_summaries: Vec<(u32, [u64; 8])>,
}

/// One reduction round's fold state on one node.
#[derive(Debug, Default)]
struct RoundAcc {
    count: u64,
    sum: f64,
    /// Contributions folded in so far (local + direct children).
    got: usize,
    /// The local driver has contributed. Required before advancing:
    /// a child's early contribution plus a shrunken `expected` (other
    /// child departed) must never total a round without us.
    local: bool,
    sent_up: bool,
    released: bool,
    total: Option<(u64, f64)>,
}

struct HandleInner {
    node: NodeId,
    nodes: usize,
    transport: Option<Arc<dyn Transport>>,
    /// Open rounds. Lock order: `rounds` before `alive`, everywhere.
    rounds: Mutex<HashMap<u32, RoundAcc>>,
    cv: Condvar,
    alive: Mutex<Vec<bool>>,
    /// Set by the pump the instant it decides to say goodbye: from
    /// here on [`ClusterHandle::dispatch`] drops every send, upholding
    /// the goodbye-is-last-frame contract even for late reduction
    /// traffic.
    closed: AtomicBool,
}

/// A job driver's window into the cluster: node identity, the blocking
/// cross-node reduction, and remote chare sends. Cheap to clone; the
/// same handle is shared with the pump thread, which feeds it inbound
/// `Contribute`/`Release`/`Goodbye` frames.
#[derive(Clone)]
pub struct ClusterHandle {
    inner: Arc<HandleInner>,
}

impl ClusterHandle {
    pub(crate) fn new(
        node: NodeId,
        nodes: usize,
        transport: Option<Arc<dyn Transport>>,
    ) -> ClusterHandle {
        ClusterHandle {
            inner: Arc::new(HandleInner {
                node,
                nodes,
                transport,
                rounds: Mutex::new(HashMap::new()),
                cv: Condvar::new(),
                alive: Mutex::new(vec![true; nodes.max(1)]),
                closed: AtomicBool::new(false),
            }),
        }
    }

    /// A single-node handle: [`reduce`](ClusterHandle::reduce) returns
    /// its argument immediately, so a spec builder written for the
    /// cluster runs unchanged — and bitwise-identically — on a plain
    /// in-process [`Runtime`].
    pub fn solo() -> ClusterHandle {
        ClusterHandle::new(NodeId(0), 1, None)
    }

    pub fn node(&self) -> NodeId {
        self.inner.node
    }

    pub fn nodes(&self) -> usize {
        self.inner.nodes
    }

    /// Node 0: the reduction root and summary collector.
    pub fn is_root(&self) -> bool {
        self.inner.node.0 == 0
    }

    /// Contribute this node's `(count, sum)` for `round` and block
    /// until the cluster-wide fold resolves. The root returns the
    /// cluster total; every other node returns `None` (the root owns
    /// the series, exactly like a Charm++ reduction client). A node
    /// whose parent or root has departed stops waiting and returns
    /// `None` — a graceful peer exit degrades the series, never hangs
    /// it.
    pub fn reduce(&self, round: u32, count: u64, sum: f64) -> Option<(u64, f64)> {
        if self.inner.nodes <= 1 {
            return Some((count, sum));
        }
        let me = self.inner.node.0 as usize;
        let mut sends = Vec::new();
        {
            let mut rounds =
                self.inner.rounds.lock().expect("rounds poisoned");
            let acc = rounds.entry(round).or_default();
            acc.count += count;
            acc.sum += sum;
            acc.got += 1;
            acc.local = true;
            self.advance_locked(&mut rounds, &mut sends);
        }
        self.inner.cv.notify_all();
        self.dispatch(sends);

        let parent = if me == 0 { 0 } else { (me - 1) / 2 };
        let mut rounds = self.inner.rounds.lock().expect("rounds poisoned");
        loop {
            let done = if me == 0 {
                rounds.get(&round).and_then(|a| a.total).is_some()
            } else {
                let released =
                    rounds.get(&round).map(|a| a.released).unwrap_or(true);
                let escape = {
                    let alive =
                        self.inner.alive.lock().expect("alive poisoned");
                    !alive[parent] || !alive[0]
                };
                released || escape
            };
            if done {
                let acc = rounds.remove(&round);
                return if me == 0 { acc.and_then(|a| a.total) } else { None };
            }
            let (g, _) = self
                .inner
                .cv
                .wait_timeout(rounds, Duration::from_millis(50))
                .expect("rounds poisoned");
            rounds = g;
        }
    }

    /// Send a chare message to `chare` of the app job on node `to`.
    /// Self-sends are a no-op (use `Ctx::send` locally). Delivery is
    /// at-most-once: a departed peer silently drops it.
    pub fn send_remote(
        &self,
        to: NodeId,
        chare: ChareId,
        method: u32,
        payload: WirePayload,
    ) {
        if to == self.inner.node {
            return;
        }
        self.dispatch(vec![(
            to,
            Frame::Chare {
                token: TOKEN_APP,
                chare: (chare.collection, chare.index),
                method,
                payload,
            },
        )]);
    }

    /// Pump: a child's subtree contribution arrived.
    fn on_contribute(&self, round: u32, count: u64, sum: f64) {
        let mut sends = Vec::new();
        {
            let mut rounds =
                self.inner.rounds.lock().expect("rounds poisoned");
            let acc = rounds.entry(round).or_default();
            acc.count += count;
            acc.sum += sum;
            acc.got += 1;
            self.advance_locked(&mut rounds, &mut sends);
        }
        self.inner.cv.notify_all();
        self.dispatch(sends);
    }

    /// Pump: the root released `round`.
    fn on_release(&self, round: u32) {
        {
            let mut rounds =
                self.inner.rounds.lock().expect("rounds poisoned");
            rounds.entry(round).or_default().released = true;
        }
        self.inner.cv.notify_all();
    }

    /// Pump: `peer` departed. Shrinks every open round's expected
    /// contribution count and re-advances — a round waiting only on
    /// the departed subtree resolves right here.
    fn on_goodbye(&self, peer: NodeId) {
        let p = peer.0 as usize;
        let mut sends = Vec::new();
        {
            let mut rounds =
                self.inner.rounds.lock().expect("rounds poisoned");
            {
                let mut alive =
                    self.inner.alive.lock().expect("alive poisoned");
                if p >= alive.len() || !alive[p] {
                    return;
                }
                alive[p] = false;
            }
            self.advance_locked(&mut rounds, &mut sends);
        }
        self.inner.cv.notify_all();
        self.dispatch(sends);
    }

    /// Advance every open round that has its local contribution plus
    /// one per *alive* direct child: the root totals and broadcasts
    /// `Release`, everyone else sends the subtree fold to its parent.
    /// Caller holds `rounds`; `alive` is taken inside (lock order).
    fn advance_locked(
        &self,
        rounds: &mut HashMap<u32, RoundAcc>,
        sends: &mut Vec<(NodeId, Frame)>,
    ) {
        let me = self.inner.node.0 as usize;
        let n = self.inner.nodes;
        let alive = self.inner.alive.lock().expect("alive poisoned");
        let expected = 1 + [2 * me + 1, 2 * me + 2]
            .iter()
            .filter(|&&c| c < n && alive[c])
            .count();
        for (&round, acc) in rounds.iter_mut() {
            if !acc.local
                || acc.got < expected
                || acc.sent_up
                || acc.total.is_some()
            {
                continue;
            }
            if me == 0 {
                acc.total = Some((acc.count, acc.sum));
                acc.released = true;
                for peer in 1..n {
                    if alive[peer] {
                        sends.push((
                            NodeId(peer as u32),
                            Frame::Release { token: TOKEN_APP, round },
                        ));
                    }
                }
            } else {
                acc.sent_up = true;
                sends.push((
                    NodeId(((me - 1) / 2) as u32),
                    Frame::Contribute {
                        token: TOKEN_APP,
                        round,
                        count: acc.count,
                        sum: acc.sum,
                    },
                ));
            }
        }
    }

    /// Send outside every lock; a dead peer's error is liveness's
    /// problem, not the reduction's. After [`close`](Self::close),
    /// sends are dropped: our goodbye was the last frame.
    fn dispatch(&self, sends: Vec<(NodeId, Frame)>) {
        if self.inner.closed.load(Ordering::SeqCst) {
            return;
        }
        if let Some(t) = &self.inner.transport {
            for (to, frame) in sends {
                let _ = t.send(to, frame);
            }
        }
    }

    /// Stop all outbound traffic from this handle (pump, pre-goodbye).
    fn close(&self) {
        self.inner.closed.store(true, Ordering::SeqCst);
    }
}

/// The mule job's chare: remote execution through the public API. A
/// `MULE_EXEC` message carries the shipment's drafts; each result comes
/// back as a normal `METHOD_RESULT` scatter and is forwarded to the
/// pump over a channel.
struct MuleChare {
    done: Sender<WrResult>,
}

impl Chare for MuleChare {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        match msg.method {
            MULE_EXEC => {
                let drafts: Vec<WorkDraft> = msg.take();
                for d in drafts {
                    ctx.submit(d).expect("shipment validated at its home node");
                }
            }
            METHOD_RESULT => {
                let res: WrResult = msg.take();
                // pump gone (post-join drain): drop, the home's
                // ship_timeout already covers the shipment
                let _ = self.done.send(res);
            }
            m => panic!("mule chare got unknown method {m}"),
        }
    }
}

/// Exchange `Hello`s with every peer and verify the SPMD contract
/// (identical kernel-family fingerprints, so kind ids agree on the
/// wire). Non-`Hello` frames racing ahead of a slow peer's `Hello` are
/// buffered and returned as the pump's backlog.
fn hello_barrier(
    t: &dyn Transport,
    families: &[String],
) -> Result<Vec<(NodeId, Frame)>> {
    let n = t.nodes();
    if n <= 1 {
        return Ok(Vec::new());
    }
    let me = t.node();
    for peer in 0..n as u32 {
        if peer != me.0 {
            t.send(
                NodeId(peer),
                Frame::Hello { node: me.0, families: families.to_vec() },
            )
            .with_context(|| format!("hello to node{peer}"))?;
        }
    }
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut seen = vec![false; n];
    seen[me.0 as usize] = true;
    let mut backlog = Vec::new();
    while seen.iter().any(|s| !s) {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            bail!("{me}: hello barrier timed out; missing peers");
        }
        let Some((from, frame)) = t.recv_timeout(left.min(Duration::from_millis(50)))
        else {
            continue;
        };
        match frame {
            Frame::Hello { node, families: theirs } => {
                if theirs != families {
                    bail!(
                        "SPMD kernel-registration mismatch: {me} has \
                         {families:?}, node{node} announced {theirs:?}"
                    );
                }
                seen[node as usize] = true;
            }
            other => backlog.push((from, other)),
        }
    }
    Ok(backlog)
}

/// The pump's own steal/summary counters, folded into the local
/// `PoolReport` through [`NetEndpoint::account`] and shipped to the
/// root in the final `Summary` frame.
#[derive(Debug, Default)]
struct PumpStats {
    steals_out: u64,
    requests_out: u64,
    steals_in: u64,
    requests_in: u64,
    requeues: u64,
    requeued_requests: u64,
    peer_summaries: Vec<(u32, [u64; 8])>,
}

/// A shipment we sent to a thief: the original requests are retained
/// so results rebuild full `WrResult`s — and so a vanished thief's
/// batch can requeue at home instead of hanging quiescence.
struct OutShipment {
    kind: KernelKindId,
    reqs: Vec<WorkRequest>,
    to: NodeId,
    sent: Instant,
}

/// A shipment we are executing for a remote home.
struct InShipment {
    home: NodeId,
    outs: Vec<Option<Vec<f32>>>,
    left: usize,
}

/// The per-node session thread: drains the transport inbox, ticks
/// heartbeats and the steal protocol, and runs the collective
/// shutdown. Exactly one per [`ClusterNode::run`].
struct Pump {
    node: NodeId,
    nodes: usize,
    transport: Arc<dyn Transport>,
    endpoint: NetEndpoint,
    handle: ClusterHandle,
    net: NetConfig,
    steal_low: usize,
    steal_high: usize,
    app_job: JobId,
    mule_job: JobId,
    done_rx: Receiver<WrResult>,
    draining: Arc<AtomicBool>,
    leave: Arc<AtomicBool>,
    alive: Vec<bool>,
    peer_depth: Vec<u64>,
    last_hb: Option<Instant>,
    outbound: HashMap<u64, OutShipment>,
    inbound: HashMap<u64, InShipment>,
    next_shipment: u64,
    /// Deadline of the single outstanding `StealRequest`, if any.
    steal_wait: Option<Instant>,
    /// Our summary+goodbye went out: send NOTHING more (late inbound
    /// frames are dropped or answered by silence — the senders' own
    /// timeouts cover them).
    said_goodbye: bool,
    stats: PumpStats,
}

impl Pump {
    fn run(mut self, backlog: Vec<(NodeId, Frame)>) -> PumpStats {
        for (from, frame) in backlog {
            self.on_frame(from, frame);
        }
        loop {
            while let Ok(res) = self.done_rx.try_recv() {
                self.on_mule_result(res);
            }
            if let Some((from, frame)) =
                self.transport.recv_timeout(Duration::from_millis(1))
            {
                self.on_frame(from, frame);
            }
            self.tick();
            if !self.said_goodbye
                && self.leave.load(Ordering::SeqCst)
                && self.outbound.is_empty()
                && self.inbound.is_empty()
            {
                // summary + goodbye are this node's LAST frames: byte
                // counters are final when read here, and peers can
                // trust that nothing follows our goodbye
                self.handle.close();
                if self.node.0 != 0 {
                    let counters = [
                        self.stats.steals_out,
                        self.stats.requests_out,
                        self.stats.steals_in,
                        self.stats.requests_in,
                        self.stats.requeues,
                        self.stats.requeued_requests,
                        self.transport.bytes_out(),
                        // bytes_in misses frames still queued from
                        // peers that outlive us; the root audits with
                        // its own post-join totals, this is advisory
                        self.transport.bytes_in(),
                    ];
                    let _ = self.transport.send(
                        NodeId(0),
                        Frame::Summary { node: self.node.0, counters },
                    );
                }
                for peer in 0..self.nodes as u32 {
                    if peer != self.node.0 && self.alive[peer as usize] {
                        let _ = self.transport.send(
                            NodeId(peer),
                            Frame::Goodbye { node: self.node.0 },
                        );
                    }
                }
                self.said_goodbye = true;
            }
            if self.said_goodbye {
                let all_gone = (0..self.nodes)
                    .all(|p| p == self.node.0 as usize || !self.alive[p]);
                if all_gone {
                    break;
                }
            }
        }
        self.stats
    }

    fn on_frame(&mut self, from: NodeId, frame: Frame) {
        match frame {
            // late hello (already consumed at the barrier)
            Frame::Hello { .. } => {}
            Frame::Heartbeat { node, depth } => {
                if let Some(d) = self.peer_depth.get_mut(node as usize) {
                    *d = depth;
                }
            }
            Frame::Chare { token, chare, method, payload } => {
                let job = if token == 0 { self.mule_job } else { self.app_job };
                let to = ChareId::new(chare.0, chare.1);
                // placement gone = app already finished here; drop
                let _ = self.endpoint.post(job, to, Msg::new(method, payload));
            }
            Frame::Contribute { round, count, sum, .. } => {
                self.handle.on_contribute(round, count, sum);
            }
            Frame::Release { round, .. } => self.handle.on_release(round),
            Frame::StealRequest { node } => self.on_steal_request(node),
            Frame::StealBatch { shipment, kind, reqs } => {
                self.on_steal_batch(from, shipment, kind, reqs);
            }
            Frame::StealResults { shipment, outs } => {
                self.on_steal_results(shipment, outs);
            }
            Frame::StealDecline { shipment } => self.on_steal_decline(shipment),
            Frame::Summary { node, counters } => {
                if self.node.0 == 0 {
                    self.stats.peer_summaries.push((node, counters));
                }
            }
            Frame::Goodbye { node } => self.on_peer_down(NodeId(node)),
        }
    }

    /// A thief asked for work: consult the coordinator's drain gate
    /// (watermarks + busy + wire-cost model) and ship a batch, keeping
    /// the originals in `outbound` until results or timeout.
    fn on_steal_request(&mut self, thief: u32) {
        let t = thief as usize;
        if self.draining.load(Ordering::SeqCst)
            || t >= self.alive.len()
            || !self.alive[t]
        {
            return;
        }
        let Some(shipment) = self
            .endpoint
            .drain(self.peer_depth[t] as usize, self.net.est_item_secs)
        else {
            return; // gate said keep it local; thief's expiry re-arms it
        };
        debug_assert!(
            shipment.reqs.len() < 1 << 16,
            "result tags pack the request index into 16 bits"
        );
        let id = ((self.node.0 as u64) << 32) | self.next_shipment;
        self.next_shipment += 1;
        let wire: Vec<WireRequest> = shipment
            .reqs
            .iter()
            .map(|wr| WireRequest {
                wr_id: wr.id,
                chare: (wr.chare.collection, wr.chare.index),
                // strip the home's job namespace (upper 16 bits); the
                // thief re-namespaces under its mule job
                buffer: wr.buffer.map(|b| b & ((1u64 << 48) - 1)),
                data_items: wr.data_items as u64,
                tag: wr.tag,
                bufs: wr.payload.bufs.clone(),
                entry_ids: wr.payload.entry_ids.clone(),
            })
            .collect();
        self.stats.steals_out += 1;
        self.stats.requests_out += wire.len() as u64;
        let _ = self.transport.send(
            NodeId(thief),
            Frame::StealBatch {
                shipment: id,
                kind: shipment.kind.0 as u32,
                reqs: wire,
            },
        );
        self.outbound.insert(
            id,
            OutShipment {
                kind: shipment.kind,
                reqs: shipment.reqs,
                to: NodeId(thief),
                sent: Instant::now(),
            },
        );
    }

    /// A home shipped us a batch: resubmit it through the mule chare.
    fn on_steal_batch(
        &mut self,
        from: NodeId,
        shipment: u64,
        kind: u32,
        reqs: Vec<WireRequest>,
    ) {
        self.steal_wait = None;
        if self.said_goodbye {
            return; // silence; the home's ship_timeout requeues it
        }
        if self.draining.load(Ordering::SeqCst) || reqs.is_empty() {
            let _ = self
                .transport
                .send(from, Frame::StealDecline { shipment });
            return;
        }
        let n = reqs.len();
        let drafts: Vec<WorkDraft> = reqs
            .into_iter()
            .enumerate()
            .map(|(i, rq)| WorkDraft {
                chare: MULE_CHARE,
                kind: KernelKindId(kind as usize),
                buffer: rq.buffer,
                data_items: rq.data_items as usize,
                // the result tag routes back to (shipment, index)
                tag: (shipment << 16) | i as u64,
                payload: Tile::with_entries(rq.bufs, rq.entry_ids),
            })
            .collect();
        if !self
            .endpoint
            .post(self.mule_job, MULE_CHARE, Msg::new(MULE_EXEC, drafts))
        {
            let _ = self
                .transport
                .send(from, Frame::StealDecline { shipment });
            return;
        }
        self.inbound.insert(
            shipment,
            InShipment { home: from, outs: vec![None; n], left: n },
        );
    }

    /// One remotely executed request finished on this node.
    fn on_mule_result(&mut self, res: WrResult) {
        let shipment = res.tag >> 16;
        let idx = (res.tag & 0xffff) as usize;
        let Some(ins) = self.inbound.get_mut(&shipment) else {
            return; // duplicate or post-requeue straggler
        };
        if idx >= ins.outs.len() {
            return;
        }
        if ins.outs[idx].is_none() {
            ins.left -= 1;
        }
        ins.outs[idx] = Some(res.out);
        if ins.left > 0 {
            return;
        }
        let ins = self.inbound.remove(&shipment).expect("present");
        let home = ins.home;
        if !self.alive[home.0 as usize] {
            // dead home: results have nowhere to go. Do NOT count them
            // as steals_in — conservation counts a steal only when the
            // results ship, so the home's requeue keeps the books exact.
            return;
        }
        let outs: Vec<Vec<f32>> =
            ins.outs.into_iter().map(|o| o.expect("left hit 0")).collect();
        self.stats.steals_in += 1;
        self.stats.requests_in += outs.len() as u64;
        self.endpoint.account(NetAccountDelta {
            remote_steals_in: 1,
            remote_requests_in: outs.len() as u64,
            ..Default::default()
        });
        let _ = self
            .transport
            .send(home, Frame::StealResults { shipment, outs });
    }

    /// Results came home: rebuild full `WrResult`s from the retained
    /// originals and hand them to the coordinator, which scatters them
    /// to the owning chares and drops the quiescence holds.
    fn on_steal_results(&mut self, shipment: u64, outs: Vec<Vec<f32>>) {
        let Some(out_ship) = self.outbound.remove(&shipment) else {
            // we already requeued (timeout or thief-down): the work ran
            // twice, results are stale. Count them so conservation
            // still balances: steals_in = steals_out - stale_batches...
            self.endpoint.account(NetAccountDelta {
                remote_stale_batches: 1,
                remote_stale_results: outs.len() as u64,
                ..Default::default()
            });
            return;
        };
        if outs.len() != out_ship.reqs.len() {
            // malformed (truncated frame?): requeue rather than zip
            // short and leak quiescence holds
            self.requeue_shipment(out_ship);
            return;
        }
        let kind = out_ship.kind;
        let results: Vec<(JobId, ChareId, WrResult)> = out_ship
            .reqs
            .into_iter()
            .zip(outs)
            .map(|(wr, out)| {
                (
                    wr.job,
                    wr.chare,
                    WrResult { wr_id: wr.id, tag: wr.tag, kind, out },
                )
            })
            .collect();
        self.endpoint.finish(results);
    }

    fn on_steal_decline(&mut self, shipment: u64) {
        if let Some(out_ship) = self.outbound.remove(&shipment) {
            self.requeue_shipment(out_ship);
        }
    }

    fn requeue_shipment(&mut self, out_ship: OutShipment) {
        self.stats.requeues += 1;
        self.stats.requeued_requests += out_ship.reqs.len() as u64;
        self.endpoint.requeue(out_ship.kind, out_ship.reqs);
    }

    /// A peer departed (graceful `Goodbye`, or synthesized by the
    /// transport when a stream died): requeue everything we had shipped
    /// to it, unwedge the reduction tree, and stop heartbeating it.
    fn on_peer_down(&mut self, peer: NodeId) {
        let p = peer.0 as usize;
        if p >= self.alive.len() || p == self.node.0 as usize || !self.alive[p]
        {
            return;
        }
        self.alive[p] = false;
        self.handle.on_goodbye(peer);
        let requeue: Vec<u64> = self
            .outbound
            .iter()
            .filter(|(_, s)| s.to == peer)
            .map(|(&id, _)| id)
            .collect();
        for id in requeue {
            let out_ship = self.outbound.remove(&id).expect("present");
            self.requeue_shipment(out_ship);
        }
        // inbound shipments FROM the dead home keep executing (the mule
        // can't cancel); their results drop uncounted in on_mule_result
    }

    /// Heartbeat-period work: expire overdue shipments, advertise our
    /// depth, and maybe ask the deepest peer for work.
    fn tick(&mut self) {
        if self.said_goodbye {
            return; // nothing follows our goodbye, not even heartbeats
        }
        let now = Instant::now();
        if self
            .last_hb
            .is_some_and(|t| now.duration_since(t) < self.net.heartbeat)
        {
            return;
        }
        self.last_hb = Some(now);
        let overdue: Vec<u64> = self
            .outbound
            .iter()
            .filter(|(_, s)| now.duration_since(s.sent) > self.net.ship_timeout)
            .map(|(&id, _)| id)
            .collect();
        for id in overdue {
            let out_ship = self.outbound.remove(&id).expect("present");
            self.requeue_shipment(out_ship);
        }
        let depth = self.endpoint.depth();
        for peer in 0..self.nodes as u32 {
            if peer != self.node.0 && self.alive[peer as usize] {
                let _ = self.transport.send(
                    NodeId(peer),
                    Frame::Heartbeat { node: self.node.0, depth },
                );
            }
        }
        if !self.net.steal || self.draining.load(Ordering::SeqCst) {
            return;
        }
        if let Some(deadline) = self.steal_wait {
            if now < deadline {
                return; // one outstanding request at a time
            }
            self.steal_wait = None;
        }
        if depth as usize >= self.steal_low {
            return;
        }
        let target = (0..self.nodes)
            .filter(|&p| p != self.node.0 as usize && self.alive[p])
            .max_by_key(|&p| self.peer_depth[p])
            .filter(|&p| self.peer_depth[p] as usize >= self.steal_high);
        if let Some(p) = target {
            let _ = self.transport.send(
                NodeId(p as u32),
                Frame::StealRequest { node: self.node.0 },
            );
            self.steal_wait = Some(now + self.net.steal_expiry);
        }
    }
}

/// One node's session: `Runtime` + transport + pump, run to completion.
pub struct ClusterNode;

impl ClusterNode {
    /// Run the application job built by `build` as this node's share of
    /// the SPMD cluster: handshake, submit, pump until the app job and
    /// every peer have finished, and fold the cross-node counters into
    /// the local [`PoolReport`].
    ///
    /// `build` receives the node's [`ClusterHandle`]; the spec it
    /// returns must register the same kernel families (same names, same
    /// order) on every node.
    pub fn run<F>(
        cfg: Config,
        net: NetConfig,
        transport: Arc<dyn Transport>,
        build: F,
    ) -> Result<NodeReport>
    where
        F: FnOnce(ClusterHandle) -> JobSpec,
    {
        let node = transport.node();
        let nodes = transport.nodes();
        let steal_low = cfg.steal_low;
        let steal_high = cfg.steal_high;
        let rt = Runtime::new(cfg)?;
        let endpoint = rt.net_endpoint();
        let handle = ClusterHandle::new(node, nodes, Some(transport.clone()));
        let spec = build(handle.clone());
        let families: Vec<String> = spec
            .kernel_descs()
            .iter()
            .map(|d| d.kernel.name.to_string())
            .collect();
        let backlog = hello_barrier(transport.as_ref(), &families)?;

        let (done_tx, done_rx) = channel();
        let (stop_tx, stop_rx) = channel::<()>();
        let mule = rt
            .submit_job(
                JobSpec::new("net-mule")
                    .chare(MULE_CHARE, 0, Box::new(MuleChare { done: done_tx }))
                    .driver(move |_| {
                        // alive until the session releases it; remote
                        // work arrives as messages, not driver calls
                        let _ = stop_rx.recv();
                        Ok(Vec::new())
                    }),
            )
            .context("submit mule job")?;
        let app = rt.submit_job(spec).context("submit app job")?;

        let draining = Arc::new(AtomicBool::new(false));
        let leave = Arc::new(AtomicBool::new(false));
        let pump = Pump {
            node,
            nodes,
            transport: transport.clone(),
            endpoint: rt.net_endpoint(),
            handle,
            net,
            steal_low,
            steal_high,
            app_job: app.job(),
            mule_job: mule.job(),
            done_rx,
            draining: draining.clone(),
            leave: leave.clone(),
            alive: vec![true; nodes],
            peer_depth: vec![0; nodes],
            last_hb: None,
            outbound: HashMap::new(),
            inbound: HashMap::new(),
            next_shipment: 0,
            steal_wait: None,
            // a solo node has no one to say goodbye to
            said_goodbye: nodes <= 1,
            stats: PumpStats::default(),
        };
        let pump_thread = thread::Builder::new()
            .name(format!("net-pump-{node}"))
            .spawn(move || pump.run(backlog))
            .context("spawn pump")?;

        let app_result = app.wait();
        // draining: decline new inbound steals but finish the ones in
        // hand; leave: summary+goodbye once both shipment maps empty.
        // The pump still pumps until every peer said goodbye, so an
        // early-finishing node keeps delivering frames for the slow.
        draining.store(true, Ordering::SeqCst);
        leave.store(true, Ordering::SeqCst);
        let stats = pump_thread.join().expect("pump thread panicked");
        drop(stop_tx);
        let _ = mule.wait();
        // transport counters are final: we said goodbye last-frame and
        // every peer's goodbye has been collected
        endpoint.account(NetAccountDelta {
            wire_bytes_out: transport.bytes_out(),
            wire_bytes_in: transport.bytes_in(),
            ..Default::default()
        });
        match app_result {
            Ok(report) => {
                let pool = rt.shutdown();
                Ok(NodeReport {
                    node,
                    series: report.series,
                    pool,
                    peer_summaries: stats.peer_summaries,
                })
            }
            Err(e) => {
                rt.shutdown();
                Err(e).with_context(|| format!("{node}: app job failed"))
            }
        }
    }
}

/// Convenience launcher for in-process clusters (tests, `--nodes N`).
pub struct Cluster;

impl Cluster {
    /// Run `nodes` [`ClusterNode`]s over a [`LoopbackFabric`], one
    /// thread each, and return their reports in node order. `make` is
    /// called once per node (SPMD: it must register identical kernel
    /// families everywhere).
    pub fn loopback<F>(
        nodes: usize,
        cfg: Config,
        net: NetConfig,
        make: F,
    ) -> Result<Vec<NodeReport>>
    where
        F: Fn(NodeId, ClusterHandle) -> JobSpec + Send + Sync + 'static,
    {
        let transports: Vec<Arc<dyn Transport>> = LoopbackFabric::new(nodes)
            .into_iter()
            .map(|t| Arc::new(t) as Arc<dyn Transport>)
            .collect();
        Cluster::over(transports, cfg, net, make)
    }

    /// Same, over caller-supplied transports (the chaos harness passes
    /// a fault-injecting fabric here).
    pub fn over<F>(
        transports: Vec<Arc<dyn Transport>>,
        cfg: Config,
        net: NetConfig,
        make: F,
    ) -> Result<Vec<NodeReport>>
    where
        F: Fn(NodeId, ClusterHandle) -> JobSpec + Send + Sync + 'static,
    {
        let make = Arc::new(make);
        let handles: Vec<_> = transports
            .into_iter()
            .map(|t| {
                let cfg = cfg.clone();
                let net = net.clone();
                let make = make.clone();
                let node = t.node();
                thread::Builder::new()
                    .name(format!("cluster-{node}"))
                    .spawn(move || {
                        ClusterNode::run(cfg, net, t, move |h| make(node, h))
                    })
                    .expect("spawn cluster node")
            })
            .collect();
        let mut reports = Vec::new();
        for h in handles {
            reports.push(h.join().expect("cluster node thread panicked")?);
        }
        reports.sort_by_key(|r| r.node.0);
        Ok(reports)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solo_handle_short_circuits() {
        let h = ClusterHandle::solo();
        assert!(h.is_root());
        assert_eq!(h.nodes(), 1);
        assert_eq!(h.reduce(0, 3, 1.5), Some((3, 1.5)));
        // rounds never accumulate state on the solo path
        assert_eq!(h.reduce(0, 4, 2.5), Some((4, 2.5)));
    }

    fn tiny_cfg() -> Config {
        Config { pes: 1, ..Config::default() }
    }

    /// Driver-only spec: `node` contributes `(node+1) * (round+1)` for
    /// four rounds; the root's series is the cluster totals.
    fn reduce_spec(rounds: u32, node: NodeId, h: ClusterHandle) -> JobSpec {
        JobSpec::new(format!("reduce-{node}")).driver(move |_| {
            let mut series = Vec::new();
            for r in 0..rounds {
                let mine = ((node.0 + 1) * (r + 1)) as f64;
                if let Some((count, sum)) = h.reduce(r, 1, mine) {
                    assert_eq!(count as usize, h.nodes(), "everyone counted");
                    series.push(sum);
                }
            }
            Ok(series)
        })
    }

    #[test]
    fn two_node_reduction_tree_is_exact_and_byte_balanced() {
        let reports = Cluster::loopback(
            2,
            tiny_cfg(),
            NetConfig::default(),
            |node, h| reduce_spec(4, node, h),
        )
        .expect("cluster runs");
        // node n contributes (n+1)*(r+1): totals 3(r+1)
        assert_eq!(reports[0].series, vec![3.0, 6.0, 9.0, 12.0]);
        assert!(reports[1].series.is_empty(), "non-root owns no series");
        // goodbye-is-last-frame makes loopback byte accounting exact
        let out: u64 = reports.iter().map(|r| r.pool.wire_bytes_out).sum();
        let inn: u64 = reports.iter().map(|r| r.pool.wire_bytes_in).sum();
        assert_eq!(out, inn, "every sent byte was received");
        assert_eq!(
            reports[0].peer_summaries.len(),
            1,
            "root collected node1's summary"
        );
    }

    #[test]
    fn four_node_tree_totals_match_flat_sum() {
        let reports = Cluster::loopback(
            4,
            tiny_cfg(),
            NetConfig::default(),
            |node, h| reduce_spec(3, node, h),
        )
        .expect("cluster runs");
        // sum over nodes of (n+1)(r+1) = 10(r+1), exact in f64
        assert_eq!(reports[0].series, vec![10.0, 20.0, 30.0]);
        for r in &reports[1..] {
            assert!(r.series.is_empty());
        }
    }

    #[test]
    fn early_peer_exit_degrades_the_series_without_hanging() {
        // node 1 leaves after 2 of 4 rounds. FIFO per link means its
        // contributions for rounds 0-1 always precede its goodbye, so
        // the root's series is deterministic: full totals for 0-1,
        // root-only for 2-3.
        let reports = Cluster::loopback(
            2,
            tiny_cfg(),
            NetConfig::default(),
            |node, h| {
                let my_rounds = if node.0 == 1 { 2 } else { 4 };
                JobSpec::new(format!("early-{node}")).driver(move |_| {
                    let mut series = Vec::new();
                    for r in 0..my_rounds {
                        let mine = ((node.0 + 1) * (r + 1)) as f64;
                        if let Some((_, sum)) = h.reduce(r, 1, mine) {
                            series.push(sum);
                        }
                    }
                    Ok(series)
                })
            },
        )
        .expect("cluster survives the early exit");
        assert_eq!(
            reports[0].series,
            vec![3.0, 6.0, 3.0, 4.0],
            "rounds 0-1 are cluster totals, 2-3 root-only"
        );
    }
}
