//! Frame format: everything that crosses a node boundary.
//!
//! Hand-rolled little-endian codec (the crate deliberately has no
//! serde). A frame on a byte transport is `u32` body length followed
//! by the body; the body is a one-byte tag and fixed-layout fields.
//! Variable-length sequences carry a `u32` count. [`Frame::encoded_len`]
//! computes the body length arithmetically without serializing — the
//! loopback transport uses it to account `bytes_on_wire` while moving
//! frames zero-copy — and a property test pins it to the real encoding.
//!
//! Frames fall into four groups, mirroring the tentpole's contract:
//!
//! * registration announcements: [`Frame::Hello`] (SPMD family
//!   fingerprint; a mismatch is a hard setup error),
//! * serialized chare messages: [`Frame::Chare`] carrying a
//!   [`WirePayload`] delivered to the target chare as its message
//!   payload,
//! * reduction traffic: [`Frame::Contribute`] / [`Frame::Release`]
//!   for the cross-node reduction tree,
//! * steal traffic: [`Frame::StealRequest`] / [`Frame::StealBatch`] /
//!   [`Frame::StealResults`] / [`Frame::StealDecline`], plus
//!   [`Frame::Heartbeat`] (liveness + advertised queue depth),
//!   [`Frame::Summary`] (final cross-node accounting counters) and
//!   [`Frame::Goodbye`] (graceful departure).

use anyhow::{bail, Result};

/// Payload of a cross-node chare message. The receiving chare gets a
/// `Msg` whose payload downcasts to this enum — concrete `Box<dyn Any>`
/// payloads cannot cross a node boundary, so remote senders pick one
/// of these shapes and the receiver matches on it.
#[derive(Debug, Clone, PartialEq)]
pub enum WirePayload {
    /// Pure signal, no data (e.g. a round GO).
    Empty,
    U32(u32),
    U64(u64),
    F64(f64),
    F32s(Vec<f32>),
    /// Opaque application bytes.
    Bytes(Vec<u8>),
}

impl WirePayload {
    fn encoded_len(&self) -> usize {
        1 + match self {
            WirePayload::Empty => 0,
            WirePayload::U32(_) => 4,
            WirePayload::U64(_) | WirePayload::F64(_) => 8,
            WirePayload::F32s(v) => 4 + 4 * v.len(),
            WirePayload::Bytes(b) => 4 + b.len(),
        }
    }

    fn encode(&self, w: &mut ByteWriter) {
        match self {
            WirePayload::Empty => w.u8(0),
            WirePayload::U32(x) => {
                w.u8(1);
                w.u32(*x);
            }
            WirePayload::U64(x) => {
                w.u8(2);
                w.u64(*x);
            }
            WirePayload::F64(x) => {
                w.u8(3);
                w.f64(*x);
            }
            WirePayload::F32s(v) => {
                w.u8(4);
                w.f32s(v);
            }
            WirePayload::Bytes(b) => {
                w.u8(5);
                w.u32(b.len() as u32);
                w.buf.extend_from_slice(b);
            }
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<WirePayload> {
        Ok(match r.u8()? {
            0 => WirePayload::Empty,
            1 => WirePayload::U32(r.u32()?),
            2 => WirePayload::U64(r.u64()?),
            3 => WirePayload::F64(r.f64()?),
            4 => WirePayload::F32s(r.f32s()?),
            5 => {
                let n = r.u32()? as usize;
                WirePayload::Bytes(r.bytes(n)?.to_vec())
            }
            t => bail!("wire: unknown payload tag {t}"),
        })
    }
}

/// One stolen work request in a [`Frame::StealBatch`]. Carries exactly
/// what the thief's mule job needs to resubmit through the public
/// chare API, plus the home-side `wr_id` so results scatter back to
/// the right chare. `buffer` is the *app-level* residency key — the
/// home strips its job namespace before shipping and the thief's
/// runtime re-namespaces under the mule job, so residency stays
/// isolated per node.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub wr_id: u64,
    pub chare: (u32, u32),
    pub buffer: Option<u64>,
    pub data_items: u64,
    pub tag: u64,
    /// Tile slot buffers, registration order.
    pub bufs: Vec<Vec<f32>>,
    /// Residency keys of the entry-cache argument, if the family has
    /// one (empty otherwise).
    pub entry_ids: Vec<u32>,
}

impl WireRequest {
    fn encoded_len(&self) -> usize {
        8 + 8                                      // wr_id, tag
            + 8                                    // chare
            + 1 + if self.buffer.is_some() { 8 } else { 0 }
            + 8                                    // data_items
            + 4 + self.bufs.iter().map(|b| 4 + 4 * b.len()).sum::<usize>()
            + 4 + 4 * self.entry_ids.len()
    }

    fn encode(&self, w: &mut ByteWriter) {
        w.u64(self.wr_id);
        w.u32(self.chare.0);
        w.u32(self.chare.1);
        match self.buffer {
            Some(b) => {
                w.u8(1);
                w.u64(b);
            }
            None => w.u8(0),
        }
        w.u64(self.data_items);
        w.u64(self.tag);
        w.u32(self.bufs.len() as u32);
        for b in &self.bufs {
            w.f32s(b);
        }
        w.u32s(&self.entry_ids);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<WireRequest> {
        let wr_id = r.u64()?;
        let chare = (r.u32()?, r.u32()?);
        let buffer = match r.u8()? {
            0 => None,
            1 => Some(r.u64()?),
            t => bail!("wire: bad option tag {t}"),
        };
        let data_items = r.u64()?;
        let tag = r.u64()?;
        let nb = r.u32()? as usize;
        let mut bufs = Vec::with_capacity(nb.min(1 << 16));
        for _ in 0..nb {
            bufs.push(r.f32s()?);
        }
        let entry_ids = r.u32s()?;
        Ok(WireRequest { wr_id, chare, buffer, data_items, tag, bufs, entry_ids })
    }
}

/// Everything a node can say to a peer. See the module docs for the
/// grouping; `token` fields name a cluster-wide job slot (the SPMD
/// contract maps each token to a local `JobId` on every node).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// SPMD handshake: the sender's node id and its kernel-family
    /// fingerprint (family names in registration order). Every node
    /// must announce an identical list before any work flows — kind
    /// ids are registration-order indices, so equal lists make them
    /// portable across the wire.
    Hello { node: u32, families: Vec<String> },
    /// Periodic liveness + advertised total queue depth (pending and
    /// in-flight requests across the node's devices). Thieves target
    /// the deepest advertised peer.
    Heartbeat { node: u32, depth: u64 },
    /// A serialized chare message: deliver `payload` to `chare` of the
    /// job bound to `token` on the receiving node.
    Chare { token: u64, chare: (u32, u32), method: u32, payload: WirePayload },
    /// Subtree reduction contribution for `round`, sent child → parent
    /// along the binary tree.
    Contribute { token: u64, round: u32, count: u64, sum: f64 },
    /// Root's release of `round`, forwarded parent → children.
    Release { token: u64, round: u32 },
    /// "I'm under my low watermark — got work?" Sender is the thief.
    StealRequest { node: u32 },
    /// A drained batch shipped home → thief for remote execution.
    StealBatch { shipment: u64, kind: u32, reqs: Vec<WireRequest> },
    /// Outputs of a remotely executed shipment, thief → home, in
    /// request order.
    StealResults { shipment: u64, outs: Vec<Vec<f32>> },
    /// Thief can no longer execute the shipment (it is draining);
    /// the home requeues the batch locally.
    StealDecline { shipment: u64 },
    /// Final cross-node accounting counters, sent before `Goodbye` so
    /// the root can audit conservation:
    /// `[steals_out, requests_out, steals_in, requests_in, requeues,
    ///   requeued_requests, bytes_out, bytes_in]`.
    Summary { node: u32, counters: [u64; 8] },
    /// Graceful departure. A transport synthesizes one when a peer's
    /// stream dies, so departure is observable either way.
    Goodbye { node: u32 },
}

impl Frame {
    /// Exact length of [`encode`](Frame::encode)'s output, computed
    /// without serializing. The loopback transport charges this to
    /// `bytes_on_wire` while handing the frame over zero-copy.
    pub fn encoded_len(&self) -> usize {
        1 + match self {
            Frame::Hello { families, .. } => {
                4 + 4 + families.iter().map(|f| 4 + f.len()).sum::<usize>()
            }
            Frame::Heartbeat { .. } => 4 + 8,
            Frame::Chare { payload, .. } => 8 + 8 + 4 + payload.encoded_len(),
            Frame::Contribute { .. } => 8 + 4 + 8 + 8,
            Frame::Release { .. } => 8 + 4,
            Frame::StealRequest { .. } => 4,
            Frame::StealBatch { reqs, .. } => {
                8 + 4 + 4 + reqs.iter().map(WireRequest::encoded_len).sum::<usize>()
            }
            Frame::StealResults { outs, .. } => {
                8 + 4 + outs.iter().map(|o| 4 + 4 * o.len()).sum::<usize>()
            }
            Frame::StealDecline { .. } => 8,
            Frame::Summary { .. } => 4 + 8 * 8,
            Frame::Goodbye { .. } => 4,
        }
    }

    /// Serialize the frame body (no length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter { buf: Vec::with_capacity(self.encoded_len()) };
        match self {
            Frame::Hello { node, families } => {
                w.u8(1);
                w.u32(*node);
                w.u32(families.len() as u32);
                for f in families {
                    w.str(f);
                }
            }
            Frame::Heartbeat { node, depth } => {
                w.u8(2);
                w.u32(*node);
                w.u64(*depth);
            }
            Frame::Chare { token, chare, method, payload } => {
                w.u8(3);
                w.u64(*token);
                w.u32(chare.0);
                w.u32(chare.1);
                w.u32(*method);
                payload.encode(&mut w);
            }
            Frame::Contribute { token, round, count, sum } => {
                w.u8(4);
                w.u64(*token);
                w.u32(*round);
                w.u64(*count);
                w.f64(*sum);
            }
            Frame::Release { token, round } => {
                w.u8(5);
                w.u64(*token);
                w.u32(*round);
            }
            Frame::StealRequest { node } => {
                w.u8(6);
                w.u32(*node);
            }
            Frame::StealBatch { shipment, kind, reqs } => {
                w.u8(7);
                w.u64(*shipment);
                w.u32(*kind);
                w.u32(reqs.len() as u32);
                for rq in reqs {
                    rq.encode(&mut w);
                }
            }
            Frame::StealResults { shipment, outs } => {
                w.u8(8);
                w.u64(*shipment);
                w.u32(outs.len() as u32);
                for o in outs {
                    w.f32s(o);
                }
            }
            Frame::StealDecline { shipment } => {
                w.u8(9);
                w.u64(*shipment);
            }
            Frame::Summary { node, counters } => {
                w.u8(10);
                w.u32(*node);
                for c in counters {
                    w.u64(*c);
                }
            }
            Frame::Goodbye { node } => {
                w.u8(11);
                w.u32(*node);
            }
        }
        debug_assert_eq!(w.buf.len(), self.encoded_len());
        w.buf
    }

    /// Decode one frame body. Truncated or malformed input is an
    /// error, never a panic — a TCP reader treats it as a dead peer.
    pub fn decode(body: &[u8]) -> Result<Frame> {
        let mut r = ByteReader { buf: body, pos: 0 };
        let frame = match r.u8()? {
            1 => {
                let node = r.u32()?;
                let n = r.u32()? as usize;
                let mut families = Vec::with_capacity(n.min(1 << 12));
                for _ in 0..n {
                    families.push(r.str()?);
                }
                Frame::Hello { node, families }
            }
            2 => Frame::Heartbeat { node: r.u32()?, depth: r.u64()? },
            3 => Frame::Chare {
                token: r.u64()?,
                chare: (r.u32()?, r.u32()?),
                method: r.u32()?,
                payload: WirePayload::decode(&mut r)?,
            },
            4 => Frame::Contribute {
                token: r.u64()?,
                round: r.u32()?,
                count: r.u64()?,
                sum: r.f64()?,
            },
            5 => Frame::Release { token: r.u64()?, round: r.u32()? },
            6 => Frame::StealRequest { node: r.u32()? },
            7 => {
                let shipment = r.u64()?;
                let kind = r.u32()?;
                let n = r.u32()? as usize;
                let mut reqs = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    reqs.push(WireRequest::decode(&mut r)?);
                }
                Frame::StealBatch { shipment, kind, reqs }
            }
            8 => {
                let shipment = r.u64()?;
                let n = r.u32()? as usize;
                let mut outs = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    outs.push(r.f32s()?);
                }
                Frame::StealResults { shipment, outs }
            }
            9 => Frame::StealDecline { shipment: r.u64()? },
            10 => {
                let node = r.u32()?;
                let mut counters = [0u64; 8];
                for c in &mut counters {
                    *c = r.u64()?;
                }
                Frame::Summary { node, counters }
            }
            11 => Frame::Goodbye { node: r.u32()? },
            t => bail!("wire: unknown frame tag {t}"),
        };
        if r.pos != body.len() {
            bail!("wire: {} trailing bytes after frame", body.len() - r.pos);
        }
        Ok(frame)
    }
}

struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }
    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn f64(&mut self, x: f64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn u32s(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("wire: truncated frame (want {n} at {}, have {})", self.pos, self.buf.len());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.bytes(n)?.to_vec())?)
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.bytes(4 * n)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let raw = self.bytes(4 * n)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request() -> WireRequest {
        WireRequest {
            wr_id: 42,
            chare: (1, 7),
            buffer: Some(9),
            data_items: 16,
            tag: (3 << 16) | 5,
            bufs: vec![vec![1.0, 2.0, 3.0], vec![], vec![0.5; 8]],
            entry_ids: vec![9, 10],
        }
    }

    /// Every frame variant (and every payload kind) round-trips, and
    /// the arithmetic `encoded_len` matches the real encoding — the
    /// loopback transport's zero-copy byte accounting depends on it.
    #[test]
    fn every_frame_round_trips_and_encoded_len_is_exact() {
        let frames = vec![
            Frame::Hello {
                node: 3,
                families: vec!["nbody_forces".into(), "spmv_rows".into(), String::new()],
            },
            Frame::Heartbeat { node: 1, depth: 77 },
            Frame::Chare {
                token: 1,
                chare: (0, 4),
                method: 19,
                payload: WirePayload::Empty,
            },
            Frame::Chare {
                token: 1,
                chare: (2, 0),
                method: 20,
                payload: WirePayload::U32(123),
            },
            Frame::Chare {
                token: 2,
                chare: (0, 0),
                method: 21,
                payload: WirePayload::U64(u64::MAX - 1),
            },
            Frame::Chare {
                token: 2,
                chare: (0, 1),
                method: 22,
                payload: WirePayload::F64(-2.5),
            },
            Frame::Chare {
                token: 0,
                chare: (1, 1),
                method: 23,
                payload: WirePayload::F32s(vec![1.0, -1.0, 0.25]),
            },
            Frame::Chare {
                token: 0,
                chare: (1, 2),
                method: 24,
                payload: WirePayload::Bytes(vec![0, 255, 7]),
            },
            Frame::Contribute { token: 1, round: 4, count: 12, sum: 4096.0 },
            Frame::Release { token: 1, round: 4 },
            Frame::StealRequest { node: 2 },
            Frame::StealBatch {
                shipment: 11,
                kind: 1,
                reqs: vec![sample_request(), WireRequest {
                    buffer: None,
                    bufs: vec![],
                    entry_ids: vec![],
                    ..sample_request()
                }],
            },
            Frame::StealResults { shipment: 11, outs: vec![vec![1.5; 4], vec![]] },
            Frame::StealDecline { shipment: 12 },
            Frame::Summary { node: 1, counters: [1, 2, 3, 4, 5, 6, 7, 8] },
            Frame::Goodbye { node: 0 },
        ];
        for f in frames {
            let body = f.encode();
            assert_eq!(body.len(), f.encoded_len(), "encoded_len drifted for {f:?}");
            let back = Frame::decode(&body).expect("decode");
            assert_eq!(back, f);
        }
    }

    #[test]
    fn truncated_and_trailing_bytes_are_errors_not_panics() {
        let body = Frame::Contribute { token: 1, round: 0, count: 3, sum: 9.0 }.encode();
        for cut in 0..body.len() {
            assert!(Frame::decode(&body[..cut]).is_err(), "accepted truncation at {cut}");
        }
        let mut long = body.clone();
        long.push(0);
        assert!(Frame::decode(&long).is_err(), "accepted trailing byte");
        assert!(Frame::decode(&[99]).is_err(), "accepted unknown tag");
    }
}
