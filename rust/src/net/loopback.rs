//! In-process fabric: deterministic, zero-copy, channel-backed.
//!
//! Frames are *moved* between endpoints — never serialized — so a
//! 1-node cluster (and the `--nodes N` CLI mode) adds no copies to the
//! single-process hot path. `bytes_on_wire` accounting still holds:
//! every handoff charges [`Frame::encoded_len`], which the wire tests
//! pin to the real encoding, and delivery is a synchronous handoff so
//! the sender's `bytes_out` and the receiver's `bytes_in` stay equal
//! by construction (the conservation clause the chaos checker audits).
//!
//! Under `--features chaos` (and in unit tests) each directed link can
//! carry a [`LinkFault`]: frames delayed behind later sends, adjacent
//! pairs reordered, every n-th heartbeat dropped (dropped bytes are
//! counted so conservation stays checkable). A `Goodbye` flushes the
//! link's held frames first — a graceful departure drains the link —
//! which keeps reduction and steal traffic causally ordered with the
//! departure itself.

#[cfg(any(test, feature = "chaos"))]
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::wire::Frame;
use super::{NodeId, Transport};

/// Deterministic fault on every directed link of a fabric. `delay`
/// holds each frame back until `delay` later sends push it out;
/// `reorder` swaps adjacent frame pairs; `drop_nth_heartbeat` drops
/// every n-th heartbeat (only heartbeats — they are the only frames
/// whose loss the protocol tolerates by design). Zero/false everywhere
/// means a transparent link.
#[cfg(any(test, feature = "chaos"))]
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LinkFault {
    pub delay: usize,
    pub reorder: bool,
    pub drop_nth_heartbeat: usize,
}

#[cfg(any(test, feature = "chaos"))]
#[derive(Default)]
struct LinkState {
    held: VecDeque<Frame>,
    heartbeats_seen: usize,
}

/// Constructor namespace for loopback endpoint sets.
pub struct LoopbackFabric;

impl LoopbackFabric {
    /// `n` connected endpoints, one per node, transparent links.
    pub fn new(n: usize) -> Vec<Loopback> {
        Self::build(n, None)
    }

    /// Endpoints whose every directed link carries `fault`. The
    /// returned counter accumulates the encoded bytes of dropped
    /// frames so `bytes_out == bytes_in + dropped` stays auditable.
    #[cfg(any(test, feature = "chaos"))]
    pub fn with_faults(n: usize, fault: LinkFault) -> (Vec<Loopback>, Arc<AtomicU64>) {
        let dropped = Arc::new(AtomicU64::new(0));
        let eps = Self::build(n, Some((fault, dropped.clone())));
        (eps, dropped)
    }

    #[cfg(not(any(test, feature = "chaos")))]
    fn build(n: usize, _unused: Option<()>) -> Vec<Loopback> {
        Self::wire_up(n)
    }

    #[cfg(any(test, feature = "chaos"))]
    fn build(n: usize, faults: Option<(LinkFault, Arc<AtomicU64>)>) -> Vec<Loopback> {
        let mut eps = Self::wire_up(n);
        if let Some((fault, dropped)) = faults {
            for ep in &mut eps {
                ep.fault = fault;
                ep.dropped = dropped.clone();
                ep.links = (0..n).map(|_| Mutex::new(LinkState::default())).collect();
            }
        }
        eps
    }

    fn wire_up(n: usize) -> Vec<Loopback> {
        let chans: Vec<(Sender<(NodeId, Frame)>, Receiver<(NodeId, Frame)>)> =
            (0..n).map(|_| channel()).collect();
        let inns: Vec<Arc<AtomicU64>> = (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect();
        let txs: Vec<Sender<(NodeId, Frame)>> = chans.iter().map(|(tx, _)| tx.clone()).collect();
        chans
            .into_iter()
            .enumerate()
            .map(|(i, (_tx, rx))| Loopback {
                node: NodeId(i as u32),
                n,
                peers: (0..n)
                    .map(|j| {
                        (j != i).then(|| Peer { tx: txs[j].clone(), inn: inns[j].clone() })
                    })
                    .collect(),
                rx: Mutex::new(rx),
                inn: inns[i].clone(),
                out: AtomicU64::new(0),
                #[cfg(any(test, feature = "chaos"))]
                fault: Default::default(),
                #[cfg(any(test, feature = "chaos"))]
                dropped: Arc::new(AtomicU64::new(0)),
                #[cfg(any(test, feature = "chaos"))]
                links: Vec::new(),
            })
            .collect()
    }
}

struct Peer {
    tx: Sender<(NodeId, Frame)>,
    /// The *receiving* endpoint's `bytes_in` counter, charged at the
    /// handoff (delivery is synchronous, so out/in never diverge).
    inn: Arc<AtomicU64>,
}

/// One node's endpoint of an in-process fabric.
pub struct Loopback {
    node: NodeId,
    n: usize,
    peers: Vec<Option<Peer>>,
    rx: Mutex<Receiver<(NodeId, Frame)>>,
    inn: Arc<AtomicU64>,
    out: AtomicU64,
    #[cfg(any(test, feature = "chaos"))]
    fault: LinkFault,
    #[cfg(any(test, feature = "chaos"))]
    dropped: Arc<AtomicU64>,
    /// Per-destination held-frame queues; empty when the fabric was
    /// built without faults.
    #[cfg(any(test, feature = "chaos"))]
    links: Vec<Mutex<LinkState>>,
}

impl Loopback {
    fn deliver(&self, to: usize, frame: Frame) {
        if let Some(peer) = &self.peers[to] {
            let len = frame.encoded_len() as u64;
            // a departed peer has dropped its receiver; frames to the
            // dead vanish uncounted, exactly like an unread socket
            if peer.tx.send((self.node, frame)).is_ok() {
                self.out.fetch_add(len, Ordering::Relaxed);
                peer.inn.fetch_add(len, Ordering::Relaxed);
            }
        }
    }
}

impl Transport for Loopback {
    fn node(&self) -> NodeId {
        self.node
    }

    fn nodes(&self) -> usize {
        self.n
    }

    #[cfg(not(any(test, feature = "chaos")))]
    fn send(&self, to: NodeId, frame: Frame) -> Result<()> {
        self.deliver(to.0 as usize, frame);
        Ok(())
    }

    #[cfg(any(test, feature = "chaos"))]
    fn send(&self, to: NodeId, frame: Frame) -> Result<()> {
        let to = to.0 as usize;
        if self.links.is_empty() {
            self.deliver(to, frame);
            return Ok(());
        }
        // faulted link: drop / hold / reorder before real delivery
        let mut ready: Vec<Frame> = Vec::new();
        {
            let mut link = self.links[to].lock().unwrap();
            if matches!(frame, Frame::Heartbeat { .. }) && self.fault.drop_nth_heartbeat > 0 {
                link.heartbeats_seen += 1;
                if link.heartbeats_seen % self.fault.drop_nth_heartbeat == 0 {
                    // the sender did put it on the wire: count it out,
                    // and into `dropped`, so out == in + dropped holds
                    let len = frame.encoded_len() as u64;
                    self.out.fetch_add(len, Ordering::Relaxed);
                    self.dropped.fetch_add(len, Ordering::Relaxed);
                    return Ok(());
                }
            }
            if matches!(frame, Frame::Goodbye { .. }) {
                // graceful departure drains the link before the goodbye
                ready.extend(link.held.drain(..));
                ready.push(frame);
            } else if self.fault.reorder {
                // swap adjacent pairs: deliver the newer frame first
                match link.held.pop_front() {
                    Some(older) => {
                        ready.push(frame);
                        ready.push(older);
                    }
                    None => link.held.push_back(frame),
                }
            } else if self.fault.delay > 0 {
                link.held.push_back(frame);
                while link.held.len() > self.fault.delay {
                    ready.push(link.held.pop_front().unwrap());
                }
            } else {
                ready.push(frame);
            }
        }
        for f in ready {
            self.deliver(to, f);
        }
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<(NodeId, Frame)> {
        self.rx.lock().unwrap().recv_timeout(timeout).ok()
    }

    fn bytes_out(&self) -> u64 {
        self.out.load(Ordering::Relaxed)
    }

    fn bytes_in(&self) -> u64 {
        self.inn.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hb(node: u32) -> Frame {
        Frame::Heartbeat { node, depth: 0 }
    }

    #[test]
    fn frames_flow_between_endpoints_and_bytes_balance() {
        let eps = LoopbackFabric::new(2);
        let f = Frame::Contribute { token: 1, round: 0, count: 2, sum: 8.0 };
        let len = f.encoded_len() as u64;
        eps[0].send(NodeId(1), f.clone()).unwrap();
        let (from, got) = eps[1].recv_timeout(Duration::from_secs(1)).expect("delivered");
        assert_eq!(from, NodeId(0));
        assert_eq!(got, f);
        assert_eq!(eps[0].bytes_out(), len);
        assert_eq!(eps[1].bytes_in(), len);
        assert_eq!(eps[0].bytes_in(), 0);
        assert!(eps[1].recv_timeout(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn reorder_swaps_adjacent_frames_and_goodbye_flushes() {
        let (eps, _) =
            LoopbackFabric::with_faults(2, LinkFault { reorder: true, ..Default::default() });
        eps[0].send(NodeId(1), Frame::Release { token: 1, round: 0 }).unwrap();
        eps[0].send(NodeId(1), Frame::Release { token: 1, round: 1 }).unwrap();
        // the pair arrives swapped
        let a = eps[1].recv_timeout(Duration::from_secs(1)).unwrap().1;
        let b = eps[1].recv_timeout(Duration::from_secs(1)).unwrap().1;
        assert_eq!(a, Frame::Release { token: 1, round: 1 });
        assert_eq!(b, Frame::Release { token: 1, round: 0 });
        // an odd frame held back is drained by the goodbye, in order
        eps[0].send(NodeId(1), Frame::Release { token: 1, round: 2 }).unwrap();
        eps[0].send(NodeId(1), Frame::Goodbye { node: 0 }).unwrap();
        let c = eps[1].recv_timeout(Duration::from_secs(1)).unwrap().1;
        let d = eps[1].recv_timeout(Duration::from_secs(1)).unwrap().1;
        assert_eq!(c, Frame::Release { token: 1, round: 2 });
        assert_eq!(d, Frame::Goodbye { node: 0 });
    }

    #[test]
    fn delay_holds_frames_behind_later_sends() {
        let (eps, _) =
            LoopbackFabric::with_faults(2, LinkFault { delay: 2, ..Default::default() });
        eps[0].send(NodeId(1), Frame::Release { token: 1, round: 0 }).unwrap();
        eps[0].send(NodeId(1), Frame::Release { token: 1, round: 1 }).unwrap();
        assert!(eps[1].recv_timeout(Duration::from_millis(1)).is_none(), "held");
        eps[0].send(NodeId(1), Frame::Release { token: 1, round: 2 }).unwrap();
        let got = eps[1].recv_timeout(Duration::from_secs(1)).unwrap().1;
        assert_eq!(got, Frame::Release { token: 1, round: 0 }, "FIFO despite the delay");
    }

    #[test]
    fn dropped_heartbeats_are_counted_and_only_heartbeats_drop() {
        let (eps, dropped) = LoopbackFabric::with_faults(
            2,
            LinkFault { drop_nth_heartbeat: 2, ..Default::default() },
        );
        eps[0].send(NodeId(1), hb(0)).unwrap();
        eps[0].send(NodeId(1), hb(0)).unwrap(); // second one drops
        eps[0].send(NodeId(1), Frame::Release { token: 1, round: 0 }).unwrap();
        assert_eq!(dropped.load(Ordering::Relaxed), hb(0).encoded_len() as u64);
        let mut got = Vec::new();
        while let Some((_, f)) = eps[1].recv_timeout(Duration::from_millis(5)) {
            got.push(f);
        }
        assert_eq!(got, vec![hb(0), Frame::Release { token: 1, round: 0 }]);
        // conservation: out == in + dropped
        assert_eq!(eps[0].bytes_out(), eps[1].bytes_in() + dropped.load(Ordering::Relaxed));
    }
}
