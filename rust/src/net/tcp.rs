//! TCP transport: length-prefixed frames over localhost/LAN.
//!
//! Full mesh with one connection per unordered pair: the higher node
//! id dials, the lower accepts, and the dialer's first four bytes are
//! its node id (little-endian) so the acceptor can place the stream.
//! Dialing uses bounded retries with exponential backoff plus a
//! deterministic per-node jitter — peers of a cluster rarely start in
//! lockstep, and a thundering-herd reconnect is exactly what the
//! backoff avoids.
//!
//! Each established stream gets a reader thread: `u32` little-endian
//! body length, body, [`Frame::decode`]. Any read or decode error is
//! treated as a dead peer and surfaces as a synthesized
//! [`Frame::Goodbye`] on the inbox, so the session's peer-down
//! draining runs whether the departure was graceful or not. Writes to
//! a dead stream are dropped silently — liveness is the session's job,
//! carried by heartbeats, not the transport's.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::wire::Frame;
use super::{NodeId, Transport};

/// Dial retry bound: ~40 attempts, backoff capped at 1 s, worst case
/// well under a minute — mirroring the chaos shutdown contract that
/// nothing waits unbounded.
const DIAL_ATTEMPTS: u32 = 40;
const DIAL_BACKOFF_BASE_MS: u64 = 20;
const DIAL_BACKOFF_CAP_MS: u64 = 1000;
/// Accept-side bound for the full mesh to form.
const ACCEPT_DEADLINE: Duration = Duration::from_secs(45);
/// Largest frame body we will read; far above any real shipment.
const MAX_FRAME: u32 = 64 << 20;

/// Write one length-prefixed text frame (`u32` little-endian body
/// length, then the UTF-8 body) — the same framing the mesh uses,
/// reused by the serve metrics endpoint so scrapers share one wire
/// format with the cluster.
pub fn write_text_frame(w: &mut impl Write, body: &str) -> std::io::Result<()> {
    let bytes = body.as_bytes();
    w.write_all(&(bytes.len() as u32).to_le_bytes())?;
    w.write_all(bytes)?;
    w.flush()
}

/// Read one length-prefixed text frame written by [`write_text_frame`].
/// Refuses bodies above [`MAX_FRAME`] or invalid UTF-8.
pub fn read_text_frame(r: &mut impl Read) -> std::io::Result<String> {
    let mut lenb = [0u8; 4];
    r.read_exact(&mut lenb)?;
    let len = u32::from_le_bytes(lenb);
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("text frame of {len} bytes exceeds MAX_FRAME"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    String::from_utf8(body)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// One node's endpoint of a TCP mesh.
pub struct Tcp {
    node: NodeId,
    n: usize,
    peers: Vec<Option<Mutex<TcpStream>>>,
    rx: Mutex<Receiver<(NodeId, Frame)>>,
    out: AtomicU64,
    inn: Arc<AtomicU64>,
}

impl Tcp {
    /// Join a mesh of `peers.len()` nodes. `peers[i]` is node i's
    /// listen address; this node binds `peers[node]` and then dials
    /// every lower id while accepting every higher one.
    pub fn connect(node: u32, peers: &[String]) -> Result<Tcp> {
        let me = peers
            .get(node as usize)
            .with_context(|| format!("node {node} has no address among {} peers", peers.len()))?;
        let listener = TcpListener::bind(me.as_str())
            .with_context(|| format!("node {node}: bind {me}"))?;
        Self::with_listener(node, listener, peers)
    }

    /// Same as [`connect`](Tcp::connect) with a pre-bound listener —
    /// tests bind port 0 first to learn their addresses.
    pub fn with_listener(node: u32, listener: TcpListener, peers: &[String]) -> Result<Tcp> {
        let n = peers.len();
        if (node as usize) >= n {
            bail!("node id {node} outside cluster of {n}");
        }
        let mut streams: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();

        // dial every lower id with bounded backoff + jitter
        for (j, addr) in peers.iter().enumerate().take(node as usize) {
            let mut stream = None;
            for attempt in 0..DIAL_ATTEMPTS {
                match TcpStream::connect(addr.as_str()) {
                    Ok(s) => {
                        stream = Some(s);
                        break;
                    }
                    Err(_) if attempt + 1 < DIAL_ATTEMPTS => {
                        let backoff = DIAL_BACKOFF_CAP_MS
                            .min(DIAL_BACKOFF_BASE_MS << attempt.min(6));
                        let jitter =
                            splitmix64(((node as u64) << 32) ^ attempt as u64) % 30;
                        std::thread::sleep(Duration::from_millis(backoff + jitter));
                    }
                    Err(e) => {
                        return Err(e).with_context(|| {
                            format!("node {node}: dialing node {j} at {addr} (final attempt)")
                        });
                    }
                }
            }
            let mut s = stream.unwrap();
            s.write_all(&node.to_le_bytes())
                .with_context(|| format!("node {node}: id preamble to node {j}"))?;
            let _ = s.set_nodelay(true);
            streams[j] = Some(s);
        }

        // accept every higher id, bounded by a deadline
        let expected = n - 1 - node as usize;
        if expected > 0 {
            listener.set_nonblocking(true)?;
            let deadline = Instant::now() + ACCEPT_DEADLINE;
            let mut got = 0;
            while got < expected {
                match listener.accept() {
                    Ok((mut s, _)) => {
                        s.set_nonblocking(false)?;
                        let mut id = [0u8; 4];
                        s.read_exact(&mut id)
                            .with_context(|| format!("node {node}: peer id preamble"))?;
                        let peer = u32::from_le_bytes(id) as usize;
                        if peer <= node as usize || peer >= n {
                            bail!("node {node}: unexpected peer id {peer}");
                        }
                        if streams[peer].is_some() {
                            bail!("node {node}: duplicate connection from node {peer}");
                        }
                        let _ = s.set_nodelay(true);
                        streams[peer] = Some(s);
                        got += 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if Instant::now() > deadline {
                            bail!(
                                "node {node}: only {got}/{expected} peers connected \
                                 within {ACCEPT_DEADLINE:?}"
                            );
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(e).context("accept"),
                }
            }
        }

        // one reader thread per peer, feeding a shared inbox
        let (tx, rx) = channel();
        let inn = Arc::new(AtomicU64::new(0));
        let mut peers_out: Vec<Option<Mutex<TcpStream>>> = (0..n).map(|_| None).collect();
        for (j, s) in streams.into_iter().enumerate() {
            let Some(s) = s else { continue };
            let reader = s.try_clone().context("clone stream for reader")?;
            let tx: Sender<(NodeId, Frame)> = tx.clone();
            let inn = inn.clone();
            std::thread::Builder::new()
                .name(format!("net-rx-{node}-from-{j}"))
                .spawn(move || read_loop(NodeId(j as u32), reader, tx, inn))
                .context("spawn reader")?;
            peers_out[j] = Some(Mutex::new(s));
        }

        Ok(Tcp {
            node: NodeId(node),
            n,
            peers: peers_out,
            rx: Mutex::new(rx),
            out: AtomicU64::new(0),
            inn,
        })
    }
}

fn read_loop(
    peer: NodeId,
    mut stream: TcpStream,
    tx: Sender<(NodeId, Frame)>,
    inn: Arc<AtomicU64>,
) {
    loop {
        let mut lenb = [0u8; 4];
        if stream.read_exact(&mut lenb).is_err() {
            break;
        }
        let len = u32::from_le_bytes(lenb);
        if len > MAX_FRAME {
            break;
        }
        let mut body = vec![0u8; len as usize];
        if stream.read_exact(&mut body).is_err() {
            break;
        }
        match Frame::decode(&body) {
            Ok(frame) => {
                inn.fetch_add(len as u64, Ordering::Relaxed);
                if tx.send((peer, frame)).is_err() {
                    return; // endpoint dropped: nobody to tell
                }
            }
            Err(_) => break, // garbage on the wire: treat as dead
        }
    }
    // surface the departure exactly like a graceful one
    let _ = tx.send((peer, Frame::Goodbye { node: peer.0 }));
}

impl Transport for Tcp {
    fn node(&self) -> NodeId {
        self.node
    }

    fn nodes(&self) -> usize {
        self.n
    }

    fn send(&self, to: NodeId, frame: Frame) -> Result<()> {
        let Some(Some(stream)) = self.peers.get(to.0 as usize) else {
            return Ok(()); // self or out-of-mesh: nothing to do
        };
        let body = frame.encode();
        let mut s = stream.lock().unwrap();
        let mut msg = Vec::with_capacity(4 + body.len());
        msg.extend_from_slice(&(body.len() as u32).to_le_bytes());
        msg.extend_from_slice(&body);
        // a dead stream drops the frame; the reader thread reports the
        // departure, and heartbeat liveness handles the rest
        if s.write_all(&msg).is_ok() {
            self.out.fetch_add(body.len() as u64, Ordering::Relaxed);
        }
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<(NodeId, Frame)> {
        self.rx.lock().unwrap().recv_timeout(timeout).ok()
    }

    fn bytes_out(&self) -> u64 {
        self.out.load(Ordering::Relaxed)
    }

    fn bytes_in(&self) -> u64 {
        self.inn.load(Ordering::Relaxed)
    }
}

impl Drop for Tcp {
    fn drop(&mut self) {
        for p in self.peers.iter().flatten() {
            let _ = p.lock().unwrap().shutdown(Shutdown::Both);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mesh2() -> (Tcp, Tcp) {
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let addrs =
            vec![l0.local_addr().unwrap().to_string(), l1.local_addr().unwrap().to_string()];
        let a1 = addrs.clone();
        let h = std::thread::spawn(move || Tcp::with_listener(1, l1, &a1).unwrap());
        let t0 = Tcp::with_listener(0, l0, &addrs).unwrap();
        (t0, h.join().unwrap())
    }

    #[test]
    fn frames_round_trip_over_real_sockets_both_ways() {
        let (t0, t1) = mesh2();
        let f = Frame::Contribute { token: 1, round: 3, count: 4, sum: 64.0 };
        t1.send(NodeId(0), f.clone()).unwrap();
        let (from, got) = t0.recv_timeout(Duration::from_secs(5)).expect("delivered");
        assert_eq!(from, NodeId(1));
        assert_eq!(got, f);
        let g = Frame::StealRequest { node: 0 };
        t0.send(NodeId(1), g.clone()).unwrap();
        assert_eq!(t1.recv_timeout(Duration::from_secs(5)), Some((NodeId(0), g.clone())));
        assert_eq!(t1.bytes_out(), f.encoded_len() as u64);
        assert_eq!(t0.bytes_in(), f.encoded_len() as u64);
        assert_eq!(t0.bytes_out(), g.encoded_len() as u64);
        assert_eq!(t1.bytes_in(), g.encoded_len() as u64);
    }

    #[test]
    fn text_frames_round_trip() {
        let mut buf = Vec::new();
        write_text_frame(&mut buf, "gcharm_up 1\n").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_text_frame(&mut r).unwrap(), "gcharm_up 1\n");
        // oversized length prefix is refused, not allocated
        let mut bad = Vec::new();
        bad.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        assert!(read_text_frame(&mut &bad[..]).is_err());
    }

    #[test]
    fn vanished_peer_surfaces_as_goodbye() {
        let (t0, t1) = mesh2();
        drop(t1); // shuts the streams down
        let (from, frame) = t0.recv_timeout(Duration::from_secs(5)).expect("synthetic goodbye");
        assert_eq!(from, NodeId(1));
        assert_eq!(frame, Frame::Goodbye { node: 1 });
    }
}
