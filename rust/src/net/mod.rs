//! Multi-node transport: active messages between runtime instances.
//!
//! The G-Charm model is inherently distributed — chares live wherever
//! capacity exists and messages find them (paper section 2; the
//! overdecomposition-on-distributed-memory line of work carries the
//! same combining/reuse strategies across nodes). This module extends
//! the single-process [`Runtime`](crate::coordinator::Runtime) to a
//! set of peer nodes connected by a [`Transport`]:
//!
//! * [`wire`] — the length-prefixed frame format: serialized chare
//!   messages, kernel-registration announcements (`Hello`), reduction
//!   contributions, and batch-steal shipments, all hand-rolled
//!   little-endian (the crate's only dependency is `anyhow`).
//! * [`loopback`] — in-process fabric backed by channels. Frames are
//!   moved, never serialized (zero-copy); `bytes_on_wire` accounting
//!   uses [`wire::Frame::encoded_len`], which a property test pins to
//!   the real encoding. Deterministic, and the substrate for the chaos
//!   harness's node-fault theme.
//! * [`tcp`] — real sockets: `u32`-length-prefixed frames over
//!   localhost/LAN, bounded connect retries with exponential backoff +
//!   jitter, a reader thread per peer, and a synthesized `Goodbye`
//!   when a peer's stream dies so liveness never hangs on a vanished
//!   node.
//! * [`cluster`] — the node session gluing a transport to a local
//!   `Runtime`: SPMD registration handshake, cross-node reduction
//!   trees folding into the per-job reduction counters, and cross-node
//!   batch steal reusing the device pool's learned-rate watermarks.
//!
//! Placement becomes `(NodeId, JobId, ChareId)`:
//! [`rendezvous_node`](crate::coordinator::rendezvous_node) gives every
//! chare a home node by the same highest-random-weight hash the device
//! router uses, and a remote steal pays an explicit
//! serialize+transfer+restage cost ([`wire_secs`]) so it only wins
//! when the model says it does.

pub mod cluster;
pub mod loopback;
pub mod tcp;
pub mod wire;

pub use cluster::{Cluster, ClusterHandle, ClusterNode, NetConfig, NodeReport};
pub use loopback::{Loopback, LoopbackFabric};
pub use tcp::{read_text_frame, write_text_frame, Tcp};
pub use wire::{Frame, WirePayload, WireRequest};

use std::time::Duration;

/// A node in the cluster. Dense ids `0..nodes`; node 0 is the root of
/// the reduction tree and the coordinator of collective shutdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "node{}", self.0)
    }
}

/// Modeled one-way latency of a frame, seconds (localhost-class).
/// The steal cost model charges this per shipment on top of the
/// bandwidth term; see [`wire_secs`].
pub const WIRE_LATENCY: f64 = 30e-6;

/// Modeled wire bandwidth, bytes/second (loopback-class; a LAN would
/// be ~10x slower, which only makes remote steal *more* conservative).
pub const WIRE_BANDWIDTH: f64 = 4e9;

/// Modeled seconds to move `bytes` to a peer: the explicit
/// serialize+transfer half of the remote-steal cost (the restage half
/// is charged by the thief's own staging pipeline when the mule job
/// resubmits). A shipment is only sent when this is smaller than the
/// queue-wait it saves.
pub fn wire_secs(bytes: u64) -> f64 {
    WIRE_LATENCY + bytes as f64 / WIRE_BANDWIDTH
}

/// Point-to-point frame carrier between `nodes` peers.
///
/// Implementations must be usable from several threads at once (the
/// session pump receives while drivers and heartbeat timers send).
/// `recv_timeout` is single-consumer by convention: exactly one pump
/// thread per node drains the inbox.
pub trait Transport: Send + Sync {
    /// This endpoint's node id.
    fn node(&self) -> NodeId;
    /// Cluster size (dense ids `0..nodes`).
    fn nodes(&self) -> usize;
    /// Queue `frame` to `to`. Delivery is FIFO per (sender, receiver)
    /// pair. Sending to a departed peer is not an error — frames to
    /// the dead are dropped silently (liveness is the session's job).
    fn send(&self, to: NodeId, frame: Frame) -> anyhow::Result<()>;
    /// Next inbound frame and its sender, or `None` on timeout.
    fn recv_timeout(&self, timeout: Duration) -> Option<(NodeId, Frame)>;
    /// Total bytes put on the wire by this endpoint (frame bodies, by
    /// [`Frame::encoded_len`]; the 4-byte TCP length prefix is
    /// excluded so loopback and TCP agree).
    fn bytes_out(&self) -> u64;
    /// Total frame-body bytes taken off the wire by this endpoint.
    fn bytes_in(&self) -> u64;
}
