//! Layer-3 coordinator: the G-Charm runtime system.
//!
//! Wires together the message-driven substrate (PEs + chares), the three
//! paper strategies (adaptive combining section 3.1, data reuse + coalescing
//! section 3.2, dynamic hybrid scheduling section 3.3), and the GPU service.
//!
//! The kernel surface is *open*: apps register kernel families at startup
//! (`GCharm::register_kernel`) and submit shape-checked `Tile` payloads
//! tagged with the returned `KernelKindId`. Every scheduling layer —
//! per-device combiner tables, reuse staging, hybrid CPU/GPU rate models,
//! the steal rebalancer, per-kind metrics — is table-driven off the
//! registry; no coordinator code matches on a kernel family.
//!
//! Thread topology:
//!
//! ```text
//!   driver (main)      PE threads (chares)        coordinator thread
//!      |  send/await      |  entry methods            |  combiners,
//!      v                  v  -> effects               v  chare table,
//!   [Router] ---Msg---> [PE queues]                [Coord queue]
//!      |                   \--WorkDraft-------------> |
//!      |                    <--CpuBatch-------------- |   hybrid split
//!      |                                              |--LaunchSpec--> GPU
//!      |                    <---METHOD_RESULT-------- | <--Completion--service
//! ```
//!
//! Python never appears: the GPU service executes AOT artifacts via PJRT.

pub mod chare;
pub mod chare_table;
pub mod coalescing;
pub mod combiner;
pub mod cpu_kernels;
pub mod cpu_pool;
pub mod hybrid;
pub mod metrics;
pub mod registry;
pub mod scheduler;
pub mod work_request;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::runtime::device_sim::CoalescingClass;
use crate::runtime::executor::{Completion, LaunchSpec, Payload};
use crate::runtime::pool::DevicePool;

pub use chare::{Chare, ChareId, Ctx, Msg, WorkDraft, METHOD_RESULT};
pub use chare_table::ChareTable;
pub use combiner::{Batch, CombinePolicy, Combiner, FlushReason, Pending};
pub use cpu_pool::chunk_by_items;
pub use hybrid::{HybridScheduler, SplitPolicy};
pub use metrics::{DeviceStats, KindStats, Report};
pub use registry::{
    builtin_registry, ewald_descriptor, force_descriptor, md_descriptor,
    KernelDescriptor, KernelKindId, KernelRegistry, ShapeError,
};
pub use scheduler::{DeviceRouter, RoutePolicy, Shared};
pub use work_request::{Tile, WorkRequest, WrResult};

use registry::KernelRegistry as Registry;
use scheduler::{pe_loop, CoordMsg, PeMsg, Router};

/// Data-movement policy (paper section 3.2 / Fig 1 / Fig 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPolicy {
    /// Redundant transfers, fully coalesced contiguous packing (Fig 1b).
    NoReuse,
    /// Reuse resident buffers; arrival-order gather (uncoalesced, Fig 1c).
    Reuse,
    /// Reuse + slot-sorted insertion for local coalescing (Fig 1d).
    ReuseSorted,
}

/// Full runtime configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of PE worker threads.
    pub pes: usize,
    pub combine: CombinePolicy,
    pub data_policy: DataPolicy,
    pub split: SplitPolicy,
    /// Enable CPU+GPU hybrid execution for registered families with a CPU
    /// fallback (`KernelDescriptor::cpu_fallback`).
    pub hybrid: bool,
    /// CPU worker-pool size for the hybrid split's CPU batches (>= 1).
    /// Batches are chunked by `data_items` across the pool; per-worker
    /// timings fold into the hybrid scheduler.
    pub cpu_workers: usize,
    /// Number of simulated GPU devices in the sharded pool (>= 1). Each
    /// device gets its own `GpuService` (stager+engine thread pair and
    /// staging arena), chare tables, node cache, and combiner set. `1`
    /// reproduces the single-device runtime bitwise.
    pub devices: usize,
    /// Chare -> device routing policy (ignored when `devices == 1`).
    pub route: RoutePolicy,
    /// Steal when some device's pending depth is below this...
    pub steal_low: usize,
    /// ...while another's is at or above this (must exceed `steal_low`).
    pub steal_high: usize,
    /// Per-device, per-reuse-family pool capacity in buffer slots.
    pub table_slots: usize,
    /// Per-device interaction-entry cache capacity (tree moments /
    /// particle entries, 16 B each). Models ChaNGa's GPU-resident moments
    /// and particle arrays.
    pub node_slots: usize,
    pub artifacts: PathBuf,
    /// Safety drain: force-flush a combiner whose newest request has waited
    /// this long (rescues the static policy at iteration tails).
    pub idle_drain: f64,
    /// Coordinator tick (recv timeout driving combiner polls).
    pub tick: Duration,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            pes: 4,
            combine: CombinePolicy::Adaptive,
            data_policy: DataPolicy::ReuseSorted,
            split: SplitPolicy::AdaptiveItems,
            hybrid: true,
            cpu_workers: 4,
            devices: 1,
            route: RoutePolicy::AffinitySteal,
            steal_low: 4,
            steal_high: 16,
            table_slots: 1024,
            node_slots: 1 << 17,
            artifacts: crate::runtime::default_artifacts_dir(),
            idle_drain: 2e-3,
            tick: Duration::from_micros(200),
        }
    }
}

impl Config {
    /// Reject configurations that would previously have panicked deep in
    /// the pool. Called by `GCharm::new`, so CLI flags and programmatic
    /// configs fail fast with a descriptive error.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.devices >= 1,
            "config: devices must be >= 1 (got {})",
            self.devices
        );
        anyhow::ensure!(
            self.steal_low < self.steal_high,
            "config: steal_low ({}) must be below steal_high ({})",
            self.steal_low,
            self.steal_high
        );
        anyhow::ensure!(
            self.cpu_workers >= 1,
            "config: cpu_workers must be >= 1 (got 0)"
        );
        Ok(())
    }
}

/// One work request recorded inside an in-flight launch.
struct LaunchItem {
    wr_id: u64,
    tag: u64,
    chare: ChareId,
    kind: KernelKindId,
    data_items: usize,
    buffer: Option<u64>,
}

struct LaunchInfo {
    items: Vec<LaunchItem>,
    transfer_bytes: u64,
    /// Pool device the launch was submitted to.
    device: usize,
    /// Registered family the launch belongs to.
    kind: KernelKindId,
    /// Output floats per request slot (from the family's registration).
    out_slot: usize,
}

/// Accumulator folding a hybrid batch's CPU-pool chunk *timings* back
/// together. Results are scattered per chunk as they arrive (no added
/// latency); only the hybrid-rate observation waits for the batch.
struct CpuBatchAcc {
    kind: KernelKindId,
    chunks_left: usize,
    items: usize,
    /// Longest single chunk: the batch makespan (chunks start together),
    /// i.e. the pool's true wall time for the batch.
    max_secs: f64,
    /// Summed per-worker busy time (report accounting).
    sum_secs: f64,
}

/// Per-device coordinator-side state: residency tables and combiners,
/// one entry per registered kind.
struct DeviceState {
    /// Reuse-buffer tables, indexed by kind; `None` for families without
    /// a reuse arg.
    tables: Vec<Option<ChareTable>>,
    /// Residency of interaction entries (tree moments / cached particles),
    /// 16 bytes each. Accounting-level model of the GPU-resident arrays
    /// the interaction lists reference.
    node_table: crate::runtime::DeviceMemory,
    node_saved: u64,
    /// One workGroupList per registered kind, in registry order.
    combiners: Vec<Combiner>,
}

/// The coordinator thread's state.
struct Coord {
    cfg: Config,
    registry: Arc<Registry>,
    router: Router,
    /// Per-device residency + combiner shards (length = pool devices).
    devices: Vec<DeviceState>,
    /// Chare -> device affinity routing and steal accounting.
    dev_router: DeviceRouter,
    hybrid: HybridScheduler,
    report: Report,
    launches: HashMap<u64, LaunchInfo>,
    gpu: DevicePool,
    /// Hybrid CPU worker pool, spawned lazily on the first CPU split so
    /// GPU-only workloads never carry idle worker threads.
    cpu_pool: Option<cpu_pool::CpuPool>,
    cpu_workers: usize,
    cpu_batches: HashMap<u64, CpuBatchAcc>,
    next_wr: u64,
    next_launch: u64,
}

impl Coord {
    fn new(
        cfg: Config,
        router: Router,
        done_tx: Sender<Result<Completion>>,
    ) -> Result<Coord> {
        let registry = router.registry.clone();
        let ndev = cfg.devices.max(1);
        let gpu = DevicePool::spawn(
            &cfg.artifacts,
            registry.kernels(),
            ndev,
            done_tx,
        )?;
        let devices = (0..ndev)
            .map(|_| DeviceState {
                tables: registry
                    .descriptors()
                    .iter()
                    .map(|d| {
                        d.kernel.reuse_arg.map(|ra| {
                            ChareTable::new(
                                cfg.table_slots,
                                d.kernel.args[ra].slot_len(),
                            )
                        })
                    })
                    .collect(),
                node_table: crate::runtime::DeviceMemory::new(cfg.node_slots),
                node_saved: 0,
                combiners: registry
                    .descriptors()
                    .iter()
                    .map(|d| {
                        Combiner::new(
                            d.combine.unwrap_or(cfg.combine),
                            d.kernel.max_combine(),
                            d.sort_by_slot
                                && cfg.data_policy == DataPolicy::ReuseSorted,
                        )
                    })
                    .collect(),
            })
            .collect();
        let mut report = Report {
            device_stats: vec![DeviceStats::default(); ndev],
            ..Report::default()
        };
        for (i, d) in registry.descriptors().iter().enumerate() {
            report.kind_mut(i).name = d.kernel.name.to_string();
        }
        Ok(Coord {
            devices,
            dev_router: DeviceRouter::new(
                cfg.route,
                ndev,
                cfg.steal_low,
                cfg.steal_high,
            ),
            hybrid: HybridScheduler::with_kinds(
                cfg.split,
                registry.len(),
                ndev,
            ),
            report,
            launches: HashMap::new(),
            gpu,
            cpu_pool: None,
            cpu_workers: cfg.cpu_workers.max(1),
            cpu_batches: HashMap::new(),
            next_wr: 0,
            next_launch: 0,
            cfg,
            registry,
            router,
        })
    }

    fn now(&self) -> f64 {
        self.router.shared.timeline.now()
    }

    /// Handle one submitted work request: route it to a device by the
    /// chare affinity map, stage its reuse buffer on that device if the
    /// family declares one, then insert into the device's combiner for
    /// that kind.
    fn on_submit(&mut self, draft: WorkDraft) {
        let now = self.now();
        let id = self.next_wr;
        self.next_wr += 1;
        let device = self.dev_router.route(draft.chare);
        let kind = draft.kind;
        let registry = self.registry.clone();
        let desc = registry.get(kind);
        let wr = WorkRequest {
            id,
            chare: draft.chare,
            kind,
            buffer: draft.buffer,
            data_items: draft.data_items,
            tag: draft.tag,
            arrival: now,
            payload: draft.payload,
        };

        // Reuse staging applies to families with a registered reuse arg
        // and requests that declare a buffer id.
        let mut slot = None;
        let mut staged_bytes = 0;
        if self.cfg.data_policy != DataPolicy::NoReuse {
            if let (Some(ra), Some(buf)) = (desc.kernel.reuse_arg, wr.buffer)
            {
                let table = self.devices[device].tables[kind.0]
                    .as_mut()
                    .expect("reuse family has a table");
                match table.stage_pinned(buf, &wr.payload.bufs[ra]) {
                    Ok(staged) => {
                        slot = Some(staged.slot);
                        staged_bytes = staged.bytes;
                    }
                    Err(_) => {
                        // Pool exhausted by pinned pending launches: fall
                        // back to contiguous transfer for this request.
                        slot = None;
                    }
                }
            }
        }

        let pending = Pending { wr, slot, staged_bytes };
        self.devices[device].combiners[kind.0].insert(pending, now);
        self.dev_router.note_enqueued(device, 1);
        self.poll_combiners();
    }

    /// Poll every device's combiners; dispatch flushed batches, then run
    /// the idle-steal rebalancer.
    fn poll_combiners(&mut self) {
        let now = self.now();
        for d in 0..self.devices.len() {
            for k in 0..self.devices[d].combiners.len() {
                while let Some(batch) = self.devices[d].combiners[k].poll(now)
                {
                    self.dispatch(batch, KernelKindId(k), d);
                }
            }
        }
        self.idle_drain(now);
        self.try_steal();
    }

    /// Safety drain (see Config::idle_drain).
    fn idle_drain(&mut self, now: f64) {
        let gap = self.cfg.idle_drain;
        if gap <= 0.0 {
            return;
        }
        for d in 0..self.devices.len() {
            for k in 0..self.devices[d].combiners.len() {
                let c = &self.devices[d].combiners[k];
                if !c.is_empty() && now - c.last_arrival().unwrap_or(now) > gap
                {
                    while let Some(b) =
                        self.devices[d].combiners[k].force_flush()
                    {
                        self.dispatch(b, KernelKindId(k), d);
                    }
                }
            }
        }
    }

    /// Force-flush everything (shutdown path).
    fn drain_all(&mut self) {
        for d in 0..self.devices.len() {
            for k in 0..self.devices[d].combiners.len() {
                while let Some(b) = self.devices[d].combiners[k].force_flush()
                {
                    self.dispatch(b, KernelKindId(k), d);
                }
            }
        }
    }

    /// Idle-steal rebalancer (section 3.3's adaptive split at device
    /// granularity): while one device's pending depth sits below the low
    /// watermark and another's at or above the high one, migrate a whole
    /// pending batch from the loaded device and dispatch it on the idle
    /// one immediately, paying the restage/transfer cost in the reuse
    /// model. Depths are weighted by the hybrid scheduler's measured
    /// per-device speeds, so a fast idle device pulls work sooner.
    fn try_steal(&mut self) {
        // Allocation-free precondition first: poll_combiners runs per
        // submitted request, and device_shares() allocates.
        if self.cfg.route != RoutePolicy::AffinitySteal
            || !self.dev_router.watermarks_crossed()
        {
            return;
        }
        let shares = self.hybrid.device_shares();
        // Bounded per poll: each iteration moves one batch; stop when the
        // watermarks are satisfied or the loaded device has nothing
        // pending (its depth is all in-flight work).
        for _ in 0..self.devices.len() {
            let Some((from, to)) = self.dev_router.steal_candidate(&shares)
            else {
                break;
            };
            let Some((batch, kind)) = self.steal_batch(from) else {
                break;
            };
            let n = batch.items.len();
            self.dev_router.note_stolen(from, to, n);
            self.report.device_mut(from).steals_out += 1;
            self.report.device_mut(to).steals_in += 1;
            let batch = self.migrate_batch(batch, kind, from, to);
            self.dispatch(batch, kind, to);
        }
    }

    /// Drain one batch from the loaded device's longest pending queue.
    fn steal_batch(&mut self, from: usize) -> Option<(Batch, KernelKindId)> {
        let st = &mut self.devices[from];
        if st.combiners.is_empty() {
            return None;
        }
        // First-registered kind wins ties (stable victim selection).
        let mut k = 0usize;
        for i in 1..st.combiners.len() {
            if st.combiners[i].len() > st.combiners[k].len() {
                k = i;
            }
        }
        if st.combiners[k].is_empty() {
            return None;
        }
        st.combiners[k].steal_flush().map(|b| (b, KernelKindId(k)))
    }

    /// Move a stolen batch's residency from `from` to `to`: release the
    /// source pins, restage into the destination's table (a miss there
    /// re-transfers the buffer — the explicit migration cost), and
    /// re-home the chares so their future requests follow the data.
    fn migrate_batch(
        &mut self,
        mut batch: Batch,
        kind: KernelKindId,
        from: usize,
        to: usize,
    ) -> Batch {
        let registry = self.registry.clone();
        let reuse_arg = registry.get(kind).kernel.reuse_arg;
        for p in &mut batch.items {
            self.dev_router.rehome(p.wr.chare, to);
            if p.slot.is_none() {
                continue;
            }
            let Some(buf) = p.wr.buffer else { continue };
            let Some(ra) = reuse_arg else { continue };
            self.devices[from].tables[kind.0]
                .as_mut()
                .expect("reuse family has a table")
                .release(buf);
            // Bytes staged to the source device were spent whether or not
            // the launch runs there: a migrated launch keeps carrying
            // them, plus whatever the destination restage costs.
            let src_bytes = p.staged_bytes;
            p.slot = None;
            p.staged_bytes = 0;
            let dst = self.devices[to].tables[kind.0]
                .as_mut()
                .expect("reuse family has a table");
            match dst.stage_pinned(buf, &p.wr.payload.bufs[ra]) {
                Ok(staged) => {
                    p.slot = Some(staged.slot);
                    p.staged_bytes = src_bytes + staged.bytes;
                    self.report.migrated_bytes += staged.bytes;
                }
                Err(_) => {
                    // Destination pool exhausted: contiguous fallback
                    // (the full payload is charged at dispatch).
                }
            }
        }
        // The batch was slot-sorted for the *source* pool; restaging
        // scrambled that. Re-sort on the destination slots so the
        // coalescing model's SortedGather claim stays honest.
        if self.cfg.data_policy == DataPolicy::ReuseSorted
            && registry.get(kind).sort_by_slot
        {
            batch
                .items
                .sort_by_key(|p| p.slot.unwrap_or(u32::MAX));
        }
        batch
    }

    /// Build and submit the combined launch for a flushed batch of one
    /// registered kind on one device: hybrid-split if the family has a
    /// CPU fallback, account transfers per the data policy (entry-cache
    /// hits, staged reuse, contiguous payloads), and pick the gather or
    /// contiguous payload form.
    fn dispatch(&mut self, batch: Batch, kind: KernelKindId, device: usize) {
        self.report.record_flush(batch.reason, batch.items.len());
        if batch.items.is_empty() {
            return;
        }
        let registry = self.registry.clone();
        let desc = registry.get(kind);
        let kernel = &desc.kernel;

        let (cpu, gpu) = if desc.cpu_fallback && self.cfg.hybrid {
            self.hybrid.split(kind, batch.items)
        } else {
            (Vec::new(), batch.items)
        };

        if !cpu.is_empty() {
            // The CPU prefix leaves this device's pending queue. Any slots
            // its requests pinned at submission must be released here: the
            // CPU completion path never touches the chare table, so a
            // reuse+hybrid family would otherwise leak pins until the
            // pool is exhausted.
            if kernel.reuse_arg.is_some() {
                let table = self.devices[device].tables[kind.0]
                    .as_mut()
                    .expect("reuse family has a table");
                for p in &cpu {
                    if p.slot.is_some() {
                        if let Some(buf) = p.wr.buffer {
                            table.release(buf);
                        }
                    }
                }
            }
            self.dev_router.note_completed(device, cpu.len());
            let total: usize = cpu.iter().map(|p| p.wr.data_items).sum();
            self.report.cpu_items += total as u64;
            self.report.kind_mut(kind.0).cpu_items += total as u64;
            // Fan the CPU portion across the worker pool (asynchronous
            // executions on all CPU cores, section 3.3), chunked by
            // data_items so each worker gets a similar item load.
            if self.cpu_pool.is_none() {
                let pool = cpu_pool::CpuPool::spawn(
                    self.cpu_workers,
                    self.router.coord.clone(),
                    self.router.shared.clone(),
                    self.registry.clone(),
                )
                .expect("spawning cpu pool");
                self.cpu_pool = Some(pool);
            }
            let pool = self.cpu_pool.as_mut().expect("cpu pool just spawned");
            let (batch_id, chunks) = pool.submit(cpu);
            self.cpu_batches.insert(
                batch_id,
                CpuBatchAcc {
                    kind,
                    chunks_left: chunks,
                    items: 0,
                    max_secs: 0.0,
                    sum_secs: 0.0,
                },
            );
        }

        let n = gpu.len();
        if n == 0 {
            return;
        }

        let mut transfer = 0u64;

        // Entry-cache accounting: the family's entry arg is either fully
        // transferred (NoReuse) or charged per *real* entry against the
        // device-resident entry cache (section 3.2: moments/particle data
        // resident from prior kernels — transfer only the misses).
        if let Some(ea) = kernel.entry_arg {
            let entry_bytes = (kernel.args[ea].width * 4) as u64;
            for p in &gpu {
                if self.cfg.data_policy == DataPolicy::NoReuse {
                    transfer += (p.wr.payload.bufs[ea].len() * 4) as u64;
                } else {
                    let st = &mut self.devices[device];
                    for &eid in &p.wr.payload.entry_ids {
                        match st.node_table.acquire(eid as u64) {
                            Some(r) if r.is_hit() => {
                                st.node_saved += entry_bytes;
                            }
                            _ => transfer += entry_bytes,
                        }
                    }
                }
            }
        }

        let use_gather = kernel.reuse_arg.is_some()
            && self.cfg.data_policy != DataPolicy::NoReuse
            && gpu.iter().all(|p| p.slot.is_some());

        let (payload, pattern) = if use_gather {
            let ra = kernel.reuse_arg.expect("gather requires a reuse arg");
            let rows = kernel.args[ra].rows;
            let mut idx = Vec::with_capacity(n * rows);
            for p in &gpu {
                let base = p.slot.expect("all staged") as i32 * rows as i32;
                idx.extend((0..rows as i32).map(|j| base + j));
                transfer += p.staged_bytes;
            }
            transfer += (idx.len() * 4) as u64; // the index buffer itself
            let mut bufs = Vec::with_capacity(kernel.args.len() - 1);
            for (i, spec) in kernel.args.iter().enumerate() {
                if i == ra {
                    continue; // resident: addressed through the gather
                }
                let mut v = Vec::with_capacity(n * spec.slot_len());
                for p in &gpu {
                    v.extend_from_slice(&p.wr.payload.bufs[i]);
                    // the entry arg's transfer was charged per real entry
                    // against the entry cache above
                    if Some(i) != kernel.entry_arg {
                        transfer += (p.wr.payload.bufs[i].len() * 4) as u64;
                    }
                }
                bufs.push(v);
            }
            let pattern = match self.cfg.data_policy {
                DataPolicy::ReuseSorted if desc.sort_by_slot => {
                    CoalescingClass::SortedGather
                }
                _ => CoalescingClass::RandomGather,
            };
            let pool = self.devices[device].tables[kind.0]
                .as_ref()
                .expect("reuse family has a table")
                .pool_arc();
            (
                Payload::TileGather {
                    kernel: kernel.clone(),
                    pool,
                    idx,
                    bufs,
                    batch: n,
                },
                pattern,
            )
        } else {
            let mut bufs = Vec::with_capacity(kernel.args.len());
            for (i, spec) in kernel.args.iter().enumerate() {
                let mut v = Vec::with_capacity(n * spec.slot_len());
                for p in &gpu {
                    v.extend_from_slice(&p.wr.payload.bufs[i]);
                    if Some(i) != kernel.entry_arg {
                        transfer += (p.wr.payload.bufs[i].len() * 4) as u64;
                    }
                }
                bufs.push(v);
            }
            (
                Payload::Tile { kernel: kernel.clone(), bufs, batch: n },
                CoalescingClass::Contiguous,
            )
        };
        self.submit_launch(gpu, kind, payload, transfer, pattern, device);
    }

    fn submit_launch(
        &mut self,
        items: Vec<Pending>,
        kind: KernelKindId,
        payload: Payload,
        transfer_bytes: u64,
        pattern: CoalescingClass,
        device: usize,
    ) {
        let id = self.next_launch;
        self.next_launch += 1;
        let info = LaunchInfo {
            items: items
                .iter()
                .map(|p| LaunchItem {
                    wr_id: p.wr.id,
                    tag: p.wr.tag,
                    chare: p.wr.chare,
                    kind: p.wr.kind,
                    data_items: p.wr.data_items,
                    buffer: if p.slot.is_some() { p.wr.buffer } else { None },
                })
                .collect(),
            transfer_bytes,
            device,
            kind,
            out_slot: self.registry.kernel(kind).out_slot_len(),
        };
        self.launches.insert(id, info);
        self.gpu
            .submit(device, LaunchSpec { id, payload, transfer_bytes, pattern })
            .expect("gpu service is down");
    }

    /// Scatter a completed launch's outputs back to the owning chares.
    fn on_gpu_done(&mut self, completion: Result<Completion>) {
        let c = completion.expect("GPU launch failed");
        let info = self
            .launches
            .remove(&c.id)
            .expect("completion for unknown launch");
        let device = info.device;
        let kind = info.kind;
        debug_assert_eq!(c.device, device, "completion from wrong device");

        self.report.launches += 1;
        self.report.gpu_requests += info.items.len() as u64;
        self.report.kernel_wall += c.wall;
        self.report.kernel_modeled += c.modeled.kernel;
        self.report.transfer_modeled += c.modeled.transfer;
        self.report.transfer_bytes += info.transfer_bytes;
        self.router.shared.timeline.record(
            crate::util::timeline::SpanKind::Kernel,
            "combined-kernel",
            self.now() - c.wall,
            c.wall,
            c.modeled.kernel,
            info.items.len() as u64,
        );

        let slot_len = info.out_slot;
        let mut gpu_items = 0u64;
        for (i, item) in info.items.iter().enumerate() {
            gpu_items += item.data_items as u64;
            let out = c.out[i * slot_len..(i + 1) * slot_len].to_vec();
            self.router.send_msg(
                item.chare,
                Msg::new(
                    METHOD_RESULT,
                    WrResult {
                        wr_id: item.wr_id,
                        tag: item.tag,
                        kind: item.kind,
                        out,
                    },
                ),
            );
            if let Some(buf) = item.buffer {
                // item.buffer is only retained when the request was staged
                // (slot.is_some()), which implies the family has a table;
                // stay graceful regardless.
                if let Some(table) =
                    self.devices[device].tables[kind.0].as_mut()
                {
                    table.release(buf);
                }
            }
        }
        self.report.gpu_items += gpu_items;
        {
            let ks = self.report.kind_mut(kind.0);
            ks.launches += 1;
            ks.gpu_requests += info.items.len() as u64;
            ks.gpu_items += gpu_items;
        }
        {
            let dev = self.report.device_mut(device);
            dev.launches += 1;
            dev.requests += info.items.len() as u64;
            dev.items += gpu_items;
            dev.busy_wall += c.wall;
            dev.busy_modeled += c.modeled.kernel + c.modeled.transfer;
        }
        self.dev_router.note_completed(device, info.items.len());
        // Per-device rate (all kinds): the steal rebalancer's weights.
        self.hybrid.record_device(device, gpu_items as usize, c.wall);
        if self.registry.get(kind).cpu_fallback {
            self.hybrid.record_gpu(kind, gpu_items as usize, c.wall);
        }

        // Release the work-request holds.
        self.router
            .shared
            .outstanding
            .fetch_sub(info.items.len() as i64, Ordering::SeqCst);
    }

    /// Scatter one CPU-pool chunk's results immediately (a slow sibling
    /// chunk must not delay finished work), and fold its timing into the
    /// batch accumulator; when the last chunk lands, record the batch
    /// makespan with the hybrid scheduler (total items over the longest
    /// chunk: the pool's true per-item rate).
    fn on_cpu_chunk(
        &mut self,
        batch: u64,
        items: usize,
        secs: f64,
        results: Vec<(ChareId, WrResult)>,
    ) {
        let acc = self
            .cpu_batches
            .get_mut(&batch)
            .expect("chunk for unknown cpu batch");
        acc.chunks_left -= 1;
        acc.items += items;
        acc.max_secs = acc.max_secs.max(secs);
        acc.sum_secs += secs;
        let kind = acc.kind;
        let batch_done = acc.chunks_left == 0;

        self.report.cpu_requests += results.len() as u64;
        self.report.kind_mut(kind.0).cpu_requests += results.len() as u64;
        let n = results.len() as i64;
        for (chare, res) in results {
            self.router.send_msg(chare, Msg::new(METHOD_RESULT, res));
        }
        // Release this chunk's work-request holds, then the chunk hold.
        self.router
            .shared
            .outstanding
            .fetch_sub(n + 1, Ordering::SeqCst);

        if batch_done {
            let acc = self.cpu_batches.remove(&batch).unwrap();
            self.hybrid.record_cpu(kind, acc.items, acc.max_secs);
            self.report.cpu_task_wall += acc.sum_secs;
        }
    }

    fn on_cpu_done(
        &mut self,
        items: usize,
        secs: f64,
        results: Vec<(ChareId, WrResult)>,
    ) {
        if let Some(kind) = results.first().map(|(_, r)| r.kind) {
            self.hybrid.record_cpu(kind, items, secs);
            self.report.kind_mut(kind.0).cpu_requests +=
                results.len() as u64;
        }
        self.report.cpu_task_wall += secs;
        self.report.cpu_requests += results.len() as u64;
        let n = results.len() as i64;
        for (chare, res) in results {
            self.router
                .send_msg(chare, Msg::new(METHOD_RESULT, res));
        }
        // Release the work-request holds, then the CpuDone hold.
        self.router
            .shared
            .outstanding
            .fetch_sub(n + 1, Ordering::SeqCst);
    }

    /// The coordinator event loop.
    fn run(mut self, rx: Receiver<CoordMsg>) -> Report {
        loop {
            match rx.recv_timeout(self.cfg.tick) {
                Ok(CoordMsg::Submit(draft)) => self.on_submit(draft),
                Ok(CoordMsg::GpuDone(c)) => {
                    self.on_gpu_done(c);
                    self.poll_combiners();
                }
                Ok(CoordMsg::CpuDone { items, secs, results }) => {
                    self.on_cpu_done(items, secs, results);
                    self.poll_combiners();
                }
                Ok(CoordMsg::CpuChunk { batch, items, secs, results }) => {
                    self.on_cpu_chunk(batch, items, secs, results);
                    self.poll_combiners();
                }
                Ok(CoordMsg::InvalidateAll) => {
                    for st in &mut self.devices {
                        for t in st.tables.iter_mut().flatten() {
                            t.invalidate_all();
                        }
                        st.node_table.invalidate_all();
                    }
                }
                Ok(CoordMsg::Stop) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    self.poll_combiners();
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        self.drain_all();
        // Wait for in-flight launches and CPU-pool batches so their holds
        // are released and the final stats are complete.
        // (Completions still arrive on rx via the forwarder.)
        while !self.launches.is_empty() || !self.cpu_batches.is_empty() {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(CoordMsg::GpuDone(c)) => self.on_gpu_done(c),
                Ok(CoordMsg::CpuDone { items, secs, results }) => {
                    self.on_cpu_done(items, secs, results)
                }
                Ok(CoordMsg::CpuChunk { batch, items, secs, results }) => {
                    self.on_cpu_chunk(batch, items, secs, results)
                }
                Ok(_) => {}
                Err(_) => break,
            }
        }
        self.report.steals = self.dev_router.steals();
        self.report.migrated_requests = self.dev_router.migrated_requests();
        self.report.table_hits = 0;
        self.report.table_misses = 0;
        self.report.saved_bytes = 0;
        for d in 0..self.devices.len() {
            let st = &self.devices[d];
            let mut hits = st.node_table.hits();
            let mut misses = st.node_table.misses();
            let mut saved = st.node_saved;
            for t in st.tables.iter().flatten() {
                hits += t.hits();
                misses += t.misses();
                saved += t.saved_bytes();
            }
            self.report.table_hits += hits;
            self.report.table_misses += misses;
            self.report.saved_bytes += saved;
            let dev = self.report.device_mut(d);
            dev.hits = hits;
            dev.misses = misses;
        }
        self.report
    }
}

/// The user-facing runtime: build, register kernels and chares, start,
/// drive, shutdown.
pub struct GCharm {
    cfg: Config,
    kernels: Registry,
    placement: HashMap<ChareId, usize>,
    chares: Vec<HashMap<ChareId, Box<dyn Chare>>>,
    running: Option<RunningState>,
}

struct RunningState {
    router: Router,
    pe_handles: Vec<JoinHandle<()>>,
    coord_handle: JoinHandle<Report>,
    forwarder: JoinHandle<()>,
}

impl GCharm {
    /// Build a runtime over a validated configuration (see
    /// [`Config::validate`] for what is rejected).
    pub fn new(cfg: Config) -> Result<GCharm> {
        cfg.validate()?;
        let pes = cfg.pes.max(1);
        Ok(GCharm {
            cfg: Config { pes, ..cfg },
            kernels: Registry::new(),
            placement: HashMap::new(),
            chares: (0..pes).map(|_| HashMap::new()).collect(),
            running: None,
        })
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Register a kernel family (must happen before `start`). Returns the
    /// kind id work drafts are tagged with. The paper's built-in families
    /// are available as [`force_descriptor`], [`ewald_descriptor`], and
    /// [`md_descriptor`]; new workloads register their own descriptors
    /// through this same call — see PERF.md, "Adding a workload".
    pub fn register_kernel(
        &mut self,
        desc: KernelDescriptor,
    ) -> Result<KernelKindId> {
        anyhow::ensure!(
            self.running.is_none(),
            "register kernels before start"
        );
        self.kernels.register(desc)
    }

    /// The registered kernel families so far.
    pub fn kernel_registry(&self) -> &KernelRegistry {
        &self.kernels
    }

    /// Register a chare on a PE (must happen before `start`).
    pub fn register(&mut self, id: ChareId, pe: usize, chare: Box<dyn Chare>) {
        assert!(self.running.is_none(), "register before start");
        let pe = pe % self.cfg.pes;
        let prev = self.placement.insert(id, pe);
        assert!(prev.is_none(), "chare {id:?} registered twice");
        self.chares[pe].insert(id, chare);
    }

    /// Spawn PE threads, the coordinator, and the GPU service.
    pub fn start(&mut self) -> Result<()> {
        anyhow::ensure!(self.running.is_none(), "already started");
        let shared = Shared::new();
        let registry = Arc::new(self.kernels.clone());
        let (coord_tx, coord_rx) = channel::<CoordMsg>();
        let mut pe_txs = Vec::new();
        let mut pe_rxs = Vec::new();
        for _ in 0..self.cfg.pes {
            let (tx, rx) = channel::<PeMsg>();
            pe_txs.push(tx);
            pe_rxs.push(rx);
        }
        let router = Router {
            pes: pe_txs,
            coord: coord_tx.clone(),
            placement: Arc::new(std::mem::take(&mut self.placement)),
            shared: shared.clone(),
            registry,
        };

        // GPU completion forwarder: GpuService -> coordinator queue.
        let (done_tx, done_rx) = channel::<Result<Completion>>();
        let fwd_coord = coord_tx.clone();
        let forwarder = std::thread::Builder::new()
            .name("gpu-forwarder".into())
            .spawn(move || {
                while let Ok(c) = done_rx.recv() {
                    if fwd_coord.send(CoordMsg::GpuDone(c)).is_err() {
                        break;
                    }
                }
            })?;

        let coord = Coord::new(self.cfg.clone(), router.clone(), done_tx)
            .context("starting coordinator")?;
        let coord_handle = std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || coord.run(coord_rx))?;

        let mut pe_handles = Vec::new();
        for (pe, rx) in pe_rxs.into_iter().enumerate() {
            let chares = std::mem::take(&mut self.chares[pe]);
            let r = router.clone();
            pe_handles.push(
                std::thread::Builder::new()
                    .name(format!("pe-{pe}"))
                    .spawn(move || pe_loop(pe, rx, chares, r))?,
            );
        }

        self.running = Some(RunningState {
            router,
            pe_handles,
            coord_handle,
            forwarder,
        });
        Ok(())
    }

    fn running(&self) -> &RunningState {
        self.running.as_ref().expect("runtime not started")
    }

    /// Driver-side message send.
    pub fn send(&self, to: ChareId, msg: Msg) {
        self.running().router.send_msg(to, msg);
    }

    /// Timeline seconds since start.
    pub fn now(&self) -> f64 {
        self.running().router.shared.timeline.now()
    }

    pub fn shared(&self) -> Arc<Shared> {
        self.running().router.shared.clone()
    }

    /// Block until the system is quiescent: no queued messages, no pending
    /// or in-flight work requests.
    pub fn await_quiescence(&self) {
        let shared = &self.running().router.shared;
        loop {
            if shared.outstanding.load(Ordering::SeqCst) == 0 {
                return;
            }
            std::thread::sleep(Duration::from_micros(50));
        }
    }

    /// Block until `n` contributions have arrived; returns their sum and
    /// resets the reduction.
    pub fn await_reduction(&self, n: u64) -> f64 {
        let shared = &self.running().router.shared;
        let mut guard = shared.reduction.lock().unwrap();
        while guard.count < n {
            guard = shared.reduction_cv.wait(guard).unwrap();
        }
        let sum = guard.sum;
        guard.count = 0;
        guard.sum = 0.0;
        sum
    }

    /// Invalidate all device-resident buffers. Call only at quiescence
    /// (iteration boundary): pinned slots back in-flight launches.
    pub fn invalidate_device_buffers(&self) {
        self.running()
            .router
            .coord
            .send(CoordMsg::InvalidateAll)
            .expect("coordinator is down");
    }

    /// Stop all threads and return the run report.
    pub fn shutdown(mut self) -> Report {
        let state = self.running.take().expect("runtime not started");
        state.router.coord.send(CoordMsg::Stop).ok();
        let report = state.coord_handle.join().expect("coordinator panicked");
        for tx in &state.router.pes {
            tx.send(PeMsg::Stop).ok();
        }
        for h in state.pe_handles {
            h.join().expect("pe panicked");
        }
        drop(state.router); // closes the forwarder's target
        state.forwarder.join().ok();
        report
    }
}
