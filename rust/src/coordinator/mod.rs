//! Layer-3 coordinator: the G-Charm runtime system.
//!
//! Wires together the message-driven substrate (PEs + chares), the three
//! paper strategies (adaptive combining section 3.1, data reuse + coalescing
//! section 3.2, dynamic hybrid scheduling section 3.3), and the GPU service.
//!
//! Thread topology:
//!
//! ```text
//!   driver (main)      PE threads (chares)        coordinator thread
//!      |  send/await      |  entry methods            |  combiners,
//!      v                  v  -> effects               v  chare table,
//!   [Router] ---Msg---> [PE queues]                [Coord queue]
//!      |                   \--WorkDraft-------------> |
//!      |                    <--CpuBatch-------------- |   hybrid split
//!      |                                              |--LaunchSpec--> GPU
//!      |                    <---METHOD_RESULT-------- | <--Completion--service
//! ```
//!
//! Python never appears: the GPU service executes AOT artifacts via PJRT.

pub mod chare;
pub mod chare_table;
pub mod coalescing;
pub mod combiner;
pub mod cpu_kernels;
pub mod cpu_pool;
pub mod hybrid;
pub mod metrics;
pub mod scheduler;
pub mod work_request;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::runtime::device_sim::CoalescingClass;
use crate::runtime::executor::{
    Completion, ExecutorConfig, LaunchSpec, Payload,
};
use crate::runtime::pool::DevicePool;
use crate::runtime::shapes::{
    INTERACTIONS, INTER_W, OUT_W, PARTICLE_W, PARTS_PER_BUCKET,
    PARTS_PER_PATCH, MD_W,
};
use crate::runtime::{occupancy, GpuSpec, KernelResources};

pub use chare::{Chare, ChareId, Ctx, Msg, WorkDraft, METHOD_RESULT};
pub use chare_table::ChareTable;
pub use combiner::{Batch, CombinePolicy, Combiner, FlushReason, Pending};
pub use cpu_pool::chunk_by_items;
pub use hybrid::{HybridScheduler, SplitPolicy};
pub use metrics::{DeviceStats, Report};
pub use scheduler::{DeviceRouter, RoutePolicy, Shared};
pub use work_request::{WorkKind, WorkRequest, WrPayload, WrResult};

use scheduler::{pe_loop, CoordMsg, PeMsg, Router};

/// Data-movement policy (paper section 3.2 / Fig 1 / Fig 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPolicy {
    /// Redundant transfers, fully coalesced contiguous packing (Fig 1b).
    NoReuse,
    /// Reuse resident buffers; arrival-order gather (uncoalesced, Fig 1c).
    Reuse,
    /// Reuse + slot-sorted insertion for local coalescing (Fig 1d).
    ReuseSorted,
}

/// Full runtime configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of PE worker threads.
    pub pes: usize,
    pub combine: CombinePolicy,
    pub data_policy: DataPolicy,
    pub split: SplitPolicy,
    /// Enable CPU+GPU hybrid execution for MD interact requests.
    pub hybrid_md: bool,
    /// CPU worker-pool size for the hybrid split's CPU batches
    /// (0 = match `pes`). Batches are chunked by `data_items` across the
    /// pool; per-worker timings fold into the hybrid scheduler.
    pub cpu_workers: usize,
    /// Number of simulated GPU devices in the sharded pool. Each device
    /// gets its own `GpuService` (stager+engine thread pair and staging
    /// arena), chare table, node cache, and combiner set. `1` reproduces
    /// the single-device runtime bitwise.
    pub devices: usize,
    /// Chare -> device routing policy (ignored when `devices == 1`).
    pub route: RoutePolicy,
    /// Steal when some device's pending depth is below this...
    pub steal_low: usize,
    /// ...while another's is at or above this.
    pub steal_high: usize,
    /// Per-device pool capacity in bucket-buffer slots.
    pub table_slots: usize,
    /// Per-device interaction-entry cache capacity (tree moments /
    /// particle entries, 16 B each). Models ChaNGa's GPU-resident moments
    /// and particle arrays.
    pub node_slots: usize,
    pub executor: ExecutorConfig,
    pub artifacts: PathBuf,
    /// Safety drain: force-flush a combiner whose newest request has waited
    /// this long (rescues the static policy at iteration tails).
    pub idle_drain: f64,
    /// Coordinator tick (recv timeout driving combiner polls).
    pub tick: Duration,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            pes: 4,
            combine: CombinePolicy::Adaptive,
            data_policy: DataPolicy::ReuseSorted,
            split: SplitPolicy::AdaptiveItems,
            hybrid_md: true,
            cpu_workers: 0,
            devices: 1,
            route: RoutePolicy::AffinitySteal,
            steal_low: 4,
            steal_high: 16,
            table_slots: 1024,
            node_slots: 1 << 17,
            executor: ExecutorConfig::default(),
            artifacts: crate::runtime::default_artifacts_dir(),
            idle_drain: 2e-3,
            tick: Duration::from_micros(200),
        }
    }
}

/// One work request recorded inside an in-flight launch.
struct LaunchItem {
    wr_id: u64,
    tag: u64,
    chare: ChareId,
    kind: WorkKind,
    data_items: usize,
    buffer: Option<u64>,
}

struct LaunchInfo {
    items: Vec<LaunchItem>,
    transfer_bytes: u64,
    /// Pool device the launch was submitted to.
    device: usize,
}

/// Accumulator folding a hybrid batch's CPU-pool chunk *timings* back
/// together. Results are scattered per chunk as they arrive (no added
/// latency); only the hybrid-rate observation waits for the batch.
struct CpuBatchAcc {
    chunks_left: usize,
    items: usize,
    /// Longest single chunk: the batch makespan (chunks start together),
    /// i.e. the pool's true wall time for the batch.
    max_secs: f64,
    /// Summed per-worker busy time (report accounting).
    sum_secs: f64,
}

/// Per-device coordinator-side state: residency tables and combiners.
/// One instance per pool device, so reuse decisions and combining are
/// local to the device the requests will execute on.
struct DeviceState {
    table: ChareTable,
    /// Residency of interaction entries (tree moments / cached particles),
    /// 16 bytes each. Accounting-level model of the GPU-resident arrays
    /// the interaction lists reference.
    node_table: crate::runtime::DeviceMemory,
    node_saved: u64,
    force: Combiner,
    ewald: Combiner,
    md: Combiner,
}

/// The coordinator thread's state.
struct Coord {
    cfg: Config,
    router: Router,
    /// Per-device residency + combiner shards (length = pool devices).
    devices: Vec<DeviceState>,
    /// Chare -> device affinity routing and steal accounting.
    dev_router: DeviceRouter,
    hybrid: HybridScheduler,
    report: Report,
    launches: HashMap<u64, LaunchInfo>,
    gpu: DevicePool,
    /// Hybrid CPU worker pool, spawned lazily on the first CPU split so
    /// GPU-only workloads (all N-body runs, `hybrid_md: false`) never
    /// carry idle worker threads.
    cpu_pool: Option<cpu_pool::CpuPool>,
    cpu_workers: usize,
    cpu_batches: HashMap<u64, CpuBatchAcc>,
    next_wr: u64,
    next_launch: u64,
}

impl Coord {
    fn new(cfg: Config, router: Router, done_tx: Sender<Result<Completion>>) -> Result<Coord> {
        let spec = GpuSpec::kepler_k20();
        let force_max = occupancy(&spec, &KernelResources::force_kernel()).max_size as usize;
        let ewald_max = occupancy(&spec, &KernelResources::ewald_kernel()).max_size as usize;
        let md_max = occupancy(&spec, &KernelResources::md_kernel()).max_size as usize;
        let sort = cfg.data_policy == DataPolicy::ReuseSorted;
        let ndev = cfg.devices.max(1);
        let gpu =
            DevicePool::spawn(&cfg.artifacts, cfg.executor.clone(), ndev, done_tx)?;
        let devices = (0..ndev)
            .map(|_| DeviceState {
                table: ChareTable::new(cfg.table_slots),
                node_table: crate::runtime::DeviceMemory::new(cfg.node_slots),
                node_saved: 0,
                force: Combiner::new(cfg.combine, force_max, sort),
                ewald: Combiner::new(cfg.combine, ewald_max, false),
                md: Combiner::new(cfg.combine, md_max, false),
            })
            .collect();
        let cpu_workers =
            if cfg.cpu_workers == 0 { cfg.pes } else { cfg.cpu_workers };
        let report = Report {
            device_stats: vec![DeviceStats::default(); ndev],
            ..Report::default()
        };
        Ok(Coord {
            devices,
            dev_router: DeviceRouter::new(
                cfg.route,
                ndev,
                cfg.steal_low,
                cfg.steal_high,
            ),
            hybrid: HybridScheduler::with_devices(cfg.split, ndev),
            report,
            launches: HashMap::new(),
            gpu,
            cpu_pool: None,
            cpu_workers,
            cpu_batches: HashMap::new(),
            next_wr: 0,
            next_launch: 0,
            cfg,
            router,
        })
    }

    fn now(&self) -> f64 {
        self.router.shared.timeline.now()
    }

    /// Handle one submitted work request: route it to a device by the
    /// chare affinity map, stage for reuse on that device if configured,
    /// then insert into the device's matching combiner.
    fn on_submit(&mut self, draft: WorkDraft) {
        let now = self.now();
        let id = self.next_wr;
        self.next_wr += 1;
        let device = self.dev_router.route(draft.chare);
        let wr = WorkRequest {
            id,
            chare: draft.chare,
            kind: draft.kind,
            buffer: draft.buffer,
            data_items: draft.data_items,
            tag: draft.tag,
            arrival: now,
            payload: draft.payload,
        };

        // Reuse staging applies to Force requests with a declared buffer;
        // Ewald uses the contiguous path (no gather variant) and MD patch
        // data changes every step.
        let mut slot = None;
        let mut staged_bytes = 0;
        if self.cfg.data_policy != DataPolicy::NoReuse
            && wr.kind == WorkKind::Force
        {
            if let (Some(buf), WrPayload::Force { parts, .. }) =
                (wr.buffer, &wr.payload)
            {
                match self.devices[device].table.stage_pinned(buf, parts) {
                    Ok(staged) => {
                        slot = Some(staged.slot);
                        staged_bytes = staged.bytes;
                    }
                    Err(_) => {
                        // Pool exhausted by pinned pending launches: fall
                        // back to contiguous transfer for this request.
                        slot = None;
                    }
                }
            }
        }

        let pending = Pending { wr, slot, staged_bytes };
        let st = &mut self.devices[device];
        match pending.wr.kind {
            WorkKind::Force => st.force.insert(pending, now),
            WorkKind::Ewald => st.ewald.insert(pending, now),
            WorkKind::MdInteract => st.md.insert(pending, now),
        }
        self.dev_router.note_enqueued(device, 1);
        self.poll_combiners();
    }

    /// Poll every device's combiners; dispatch flushed batches, then run
    /// the idle-steal rebalancer.
    fn poll_combiners(&mut self) {
        let now = self.now();
        for d in 0..self.devices.len() {
            while let Some(batch) = self.devices[d].force.poll(now) {
                self.dispatch_force(batch, d);
            }
            while let Some(batch) = self.devices[d].ewald.poll(now) {
                self.dispatch_ewald(batch, d);
            }
            while let Some(batch) = self.devices[d].md.poll(now) {
                self.dispatch_md(batch, d);
            }
        }
        self.idle_drain(now);
        self.try_steal();
    }

    /// Safety drain (see Config::idle_drain).
    fn idle_drain(&mut self, now: f64) {
        let gap = self.cfg.idle_drain;
        if gap <= 0.0 {
            return;
        }
        for d in 0..self.devices.len() {
            let st = &mut self.devices[d];
            if !st.force.is_empty()
                && now - st.force.last_arrival().unwrap_or(now) > gap
            {
                while let Some(b) = self.devices[d].force.force_flush() {
                    self.dispatch_force(b, d);
                }
            }
            let st = &mut self.devices[d];
            if !st.ewald.is_empty()
                && now - st.ewald.last_arrival().unwrap_or(now) > gap
            {
                while let Some(b) = self.devices[d].ewald.force_flush() {
                    self.dispatch_ewald(b, d);
                }
            }
            let st = &mut self.devices[d];
            if !st.md.is_empty()
                && now - st.md.last_arrival().unwrap_or(now) > gap
            {
                while let Some(b) = self.devices[d].md.force_flush() {
                    self.dispatch_md(b, d);
                }
            }
        }
    }

    /// Force-flush everything (shutdown path).
    fn drain_all(&mut self) {
        for d in 0..self.devices.len() {
            while let Some(b) = self.devices[d].force.force_flush() {
                self.dispatch_force(b, d);
            }
            while let Some(b) = self.devices[d].ewald.force_flush() {
                self.dispatch_ewald(b, d);
            }
            while let Some(b) = self.devices[d].md.force_flush() {
                self.dispatch_md(b, d);
            }
        }
    }

    /// Idle-steal rebalancer (section 3.3's adaptive split at device
    /// granularity): while one device's pending depth sits below the low
    /// watermark and another's at or above the high one, migrate a whole
    /// pending batch from the loaded device and dispatch it on the idle
    /// one immediately, paying the restage/transfer cost in the reuse
    /// model. Depths are weighted by the hybrid scheduler's measured
    /// per-device speeds, so a fast idle device pulls work sooner.
    fn try_steal(&mut self) {
        // Allocation-free precondition first: poll_combiners runs per
        // submitted request, and device_shares() allocates.
        if self.cfg.route != RoutePolicy::AffinitySteal
            || !self.dev_router.watermarks_crossed()
        {
            return;
        }
        let shares = self.hybrid.device_shares();
        // Bounded per poll: each iteration moves one batch; stop when the
        // watermarks are satisfied or the loaded device has nothing
        // pending (its depth is all in-flight work).
        for _ in 0..self.devices.len() {
            let Some((from, to)) = self.dev_router.steal_candidate(&shares)
            else {
                break;
            };
            let Some((batch, kind)) = self.steal_batch(from) else {
                break;
            };
            let n = batch.items.len();
            self.dev_router.note_stolen(from, to, n);
            self.report.device_mut(from).steals_out += 1;
            self.report.device_mut(to).steals_in += 1;
            let batch = self.migrate_batch(batch, from, to);
            match kind {
                WorkKind::Force => self.dispatch_force(batch, to),
                WorkKind::Ewald => self.dispatch_ewald(batch, to),
                WorkKind::MdInteract => self.dispatch_md(batch, to),
            }
        }
    }

    /// Drain one batch from the loaded device's longest pending queue.
    fn steal_batch(&mut self, from: usize) -> Option<(Batch, WorkKind)> {
        let st = &mut self.devices[from];
        let (lf, le, lm) = (st.force.len(), st.ewald.len(), st.md.len());
        if lf == 0 && le == 0 && lm == 0 {
            return None;
        }
        if lf >= le && lf >= lm {
            st.force.steal_flush().map(|b| (b, WorkKind::Force))
        } else if le >= lm {
            st.ewald.steal_flush().map(|b| (b, WorkKind::Ewald))
        } else {
            st.md.steal_flush().map(|b| (b, WorkKind::MdInteract))
        }
    }

    /// Move a stolen batch's residency from `from` to `to`: release the
    /// source pins, restage into the destination's chare table (a miss
    /// there re-transfers the buffer — the explicit migration cost), and
    /// re-home the chares so their future requests follow the data.
    fn migrate_batch(&mut self, mut batch: Batch, from: usize, to: usize) -> Batch {
        for p in &mut batch.items {
            self.dev_router.rehome(p.wr.chare, to);
            if p.slot.is_none() {
                continue;
            }
            let Some(buf) = p.wr.buffer else { continue };
            self.devices[from].table.release(buf);
            // Bytes staged to the source device were spent whether or not
            // the launch runs there: a migrated launch keeps carrying
            // them, plus whatever the destination restage costs.
            let src_bytes = p.staged_bytes;
            p.slot = None;
            p.staged_bytes = 0;
            let WrPayload::Force { parts, .. } = &p.wr.payload else {
                continue;
            };
            match self.devices[to].table.stage_pinned(buf, parts) {
                Ok(staged) => {
                    p.slot = Some(staged.slot);
                    p.staged_bytes = src_bytes + staged.bytes;
                    self.report.migrated_bytes += staged.bytes;
                }
                Err(_) => {
                    // Destination pool exhausted: contiguous fallback
                    // (the full payload is charged at dispatch).
                }
            }
        }
        // The batch was slot-sorted for the *source* pool; restaging
        // scrambled that. Re-sort on the destination slots so the
        // coalescing model's SortedGather claim stays honest.
        if self.cfg.data_policy == DataPolicy::ReuseSorted {
            batch
                .items
                .sort_by_key(|p| p.slot.unwrap_or(u32::MAX));
        }
        batch
    }

    /// Build and submit the combined force launch for a flushed batch on
    /// one device.
    fn dispatch_force(&mut self, batch: Batch, device: usize) {
        self.report.record_flush(batch.reason, batch.items.len());
        let n = batch.items.len();
        if n == 0 {
            return;
        }
        let all_staged = batch.items.iter().all(|p| p.slot.is_some());
        let use_gather = self.cfg.data_policy != DataPolicy::NoReuse && all_staged;

        let mut inters = Vec::with_capacity(n * INTERACTIONS * INTER_W);
        let mut transfer = 0u64;
        const ENTRY_BYTES: u64 = (INTER_W * 4) as u64;
        for p in &batch.items {
            let WrPayload::Force { inters: i, inter_ids, .. } = &p.wr.payload
            else {
                unreachable!("force combiner holds only Force requests")
            };
            inters.extend_from_slice(i);
            if self.cfg.data_policy == DataPolicy::NoReuse {
                transfer += (i.len() * 4) as u64;
            } else {
                // interaction entries (moments/particles) are resident on
                // the device from prior kernels: transfer only the misses
                let st = &mut self.devices[device];
                for &eid in inter_ids {
                    match st.node_table.acquire(eid as u64) {
                        Some(r) if r.is_hit() => {
                            st.node_saved += ENTRY_BYTES;
                        }
                        _ => transfer += ENTRY_BYTES,
                    }
                }
            }
        }

        let (payload, pattern) = if use_gather {
            let mut idx = Vec::with_capacity(n * PARTS_PER_BUCKET);
            for p in &batch.items {
                let base = p.slot.unwrap() as i32 * PARTS_PER_BUCKET as i32;
                idx.extend((0..PARTS_PER_BUCKET as i32).map(|j| base + j));
                transfer += p.staged_bytes;
            }
            transfer += (idx.len() * 4) as u64; // the index buffer itself
            let pattern = match self.cfg.data_policy {
                DataPolicy::ReuseSorted => CoalescingClass::SortedGather,
                _ => CoalescingClass::RandomGather,
            };
            (
                Payload::GravityGather {
                    pool: self.devices[device].table.pool_arc(),
                    idx,
                    inters,
                    batch: n,
                },
                pattern,
            )
        } else {
            let mut parts = Vec::with_capacity(n * PARTS_PER_BUCKET * PARTICLE_W);
            for p in &batch.items {
                let WrPayload::Force { parts: pp, .. } = &p.wr.payload else {
                    unreachable!()
                };
                parts.extend_from_slice(pp);
                transfer += (pp.len() * 4) as u64;
            }
            (
                Payload::Gravity { parts, inters, batch: n },
                CoalescingClass::Contiguous,
            )
        };
        self.submit_launch(batch.items, payload, transfer, pattern, device);
    }

    fn dispatch_ewald(&mut self, batch: Batch, device: usize) {
        self.report.record_flush(batch.reason, batch.items.len());
        let n = batch.items.len();
        if n == 0 {
            return;
        }
        let mut parts = Vec::with_capacity(n * PARTS_PER_BUCKET * PARTICLE_W);
        let mut transfer = 0u64;
        for p in &batch.items {
            let WrPayload::Ewald { parts: pp } = &p.wr.payload else {
                unreachable!("ewald combiner holds only Ewald requests")
            };
            parts.extend_from_slice(pp);
            transfer += (pp.len() * 4) as u64;
        }
        self.submit_launch(
            batch.items,
            Payload::Ewald { parts, batch: n },
            transfer,
            CoalescingClass::Contiguous,
            device,
        );
    }

    /// MD: hybrid-split the flushed batch, CPU prefix to the worker pool,
    /// GPU suffix to a combined launch on `device`.
    fn dispatch_md(&mut self, batch: Batch, device: usize) {
        self.report.record_flush(batch.reason, batch.items.len());
        if batch.items.is_empty() {
            return;
        }
        let (cpu, gpu) = if self.cfg.hybrid_md {
            self.hybrid.split(batch.items)
        } else {
            (Vec::new(), batch.items)
        };

        if !cpu.is_empty() {
            // The CPU prefix leaves this device's pending queue.
            self.dev_router.note_completed(device, cpu.len());
            let total: usize =
                cpu.iter().map(|p| p.wr.data_items).sum();
            self.report.cpu_items += total as u64;
            // Fan the CPU portion across the worker pool (asynchronous
            // executions on all CPU cores, section 3.3), chunked by
            // data_items so each worker gets a similar item load.
            if self.cpu_pool.is_none() {
                let pool = cpu_pool::CpuPool::spawn(
                    self.cpu_workers,
                    self.router.coord.clone(),
                    self.router.shared.clone(),
                    self.cfg.executor.clone(),
                )
                .expect("spawning cpu pool");
                self.cpu_pool = Some(pool);
            }
            let pool = self.cpu_pool.as_mut().expect("cpu pool just spawned");
            let (batch_id, chunks) = pool.submit(cpu);
            self.cpu_batches.insert(
                batch_id,
                CpuBatchAcc {
                    chunks_left: chunks,
                    items: 0,
                    max_secs: 0.0,
                    sum_secs: 0.0,
                },
            );
        }

        let n = gpu.len();
        if n == 0 {
            return;
        }
        let mut pa = Vec::with_capacity(n * PARTS_PER_PATCH * MD_W);
        let mut pb = Vec::with_capacity(n * PARTS_PER_PATCH * MD_W);
        let mut transfer = 0u64;
        for p in &gpu {
            let WrPayload::MdPair { pa: a, pb: b } = &p.wr.payload else {
                unreachable!("md combiner holds only MdPair requests")
            };
            pa.extend_from_slice(a);
            pb.extend_from_slice(b);
            transfer += ((a.len() + b.len()) * 4) as u64;
        }
        self.submit_launch(
            gpu,
            Payload::MdForce { pa, pb, batch: n },
            transfer,
            CoalescingClass::Contiguous,
            device,
        );
    }

    fn submit_launch(
        &mut self,
        items: Vec<Pending>,
        payload: Payload,
        transfer_bytes: u64,
        pattern: CoalescingClass,
        device: usize,
    ) {
        let id = self.next_launch;
        self.next_launch += 1;
        let info = LaunchInfo {
            items: items
                .iter()
                .map(|p| LaunchItem {
                    wr_id: p.wr.id,
                    tag: p.wr.tag,
                    chare: p.wr.chare,
                    kind: p.wr.kind,
                    data_items: p.wr.data_items,
                    buffer: if p.slot.is_some() { p.wr.buffer } else { None },
                })
                .collect(),
            transfer_bytes,
            device,
        };
        self.launches.insert(id, info);
        self.gpu
            .submit(device, LaunchSpec { id, payload, transfer_bytes, pattern })
            .expect("gpu service is down");
    }

    /// Scatter a completed launch's outputs back to the owning chares.
    fn on_gpu_done(&mut self, completion: Result<Completion>) {
        let c = completion.expect("GPU launch failed");
        let info = self
            .launches
            .remove(&c.id)
            .expect("completion for unknown launch");
        let device = info.device;
        debug_assert_eq!(c.device, device, "completion from wrong device");

        self.report.launches += 1;
        self.report.gpu_requests += info.items.len() as u64;
        self.report.kernel_wall += c.wall;
        self.report.kernel_modeled += c.modeled.kernel;
        self.report.transfer_modeled += c.modeled.transfer;
        self.report.transfer_bytes += info.transfer_bytes;
        self.router.shared.timeline.record(
            crate::util::timeline::SpanKind::Kernel,
            "combined-kernel",
            self.now() - c.wall,
            c.wall,
            c.modeled.kernel,
            info.items.len() as u64,
        );

        let slot_len = match info.items.first().map(|i| i.kind) {
            Some(WorkKind::MdInteract) => PARTS_PER_PATCH * MD_W,
            _ => PARTS_PER_BUCKET * OUT_W,
        };

        let mut gpu_items = 0u64;
        for (i, item) in info.items.iter().enumerate() {
            gpu_items += item.data_items as u64;
            let out = c.out[i * slot_len..(i + 1) * slot_len].to_vec();
            self.router.send_msg(
                item.chare,
                Msg::new(
                    METHOD_RESULT,
                    WrResult {
                        wr_id: item.wr_id,
                        tag: item.tag,
                        kind: item.kind,
                        out,
                    },
                ),
            );
            if let Some(buf) = item.buffer {
                self.devices[device].table.release(buf);
            }
        }
        self.report.gpu_items += gpu_items;
        {
            let dev = self.report.device_mut(device);
            dev.launches += 1;
            dev.requests += info.items.len() as u64;
            dev.items += gpu_items;
            dev.busy_wall += c.wall;
            dev.busy_modeled += c.modeled.kernel + c.modeled.transfer;
        }
        self.dev_router.note_completed(device, info.items.len());
        // Per-device rate (all kinds): the steal rebalancer's weights.
        self.hybrid.record_device(device, gpu_items as usize, c.wall);
        if matches!(
            info.items.first().map(|i| i.kind),
            Some(WorkKind::MdInteract)
        ) {
            self.hybrid.record_gpu(gpu_items as usize, c.wall);
        }

        // Release the work-request holds.
        self.router
            .shared
            .outstanding
            .fetch_sub(info.items.len() as i64, Ordering::SeqCst);
    }

    /// Scatter one CPU-pool chunk's results immediately (a slow sibling
    /// chunk must not delay finished work), and fold its timing into the
    /// batch accumulator; when the last chunk lands, record the batch
    /// makespan with the hybrid scheduler (total items over the longest
    /// chunk: the pool's true per-item rate).
    fn on_cpu_chunk(
        &mut self,
        batch: u64,
        items: usize,
        secs: f64,
        results: Vec<(ChareId, WrResult)>,
    ) {
        let acc = self
            .cpu_batches
            .get_mut(&batch)
            .expect("chunk for unknown cpu batch");
        acc.chunks_left -= 1;
        acc.items += items;
        acc.max_secs = acc.max_secs.max(secs);
        acc.sum_secs += secs;
        let batch_done = acc.chunks_left == 0;

        self.report.cpu_requests += results.len() as u64;
        let n = results.len() as i64;
        for (chare, res) in results {
            self.router.send_msg(chare, Msg::new(METHOD_RESULT, res));
        }
        // Release this chunk's work-request holds, then the chunk hold.
        self.router
            .shared
            .outstanding
            .fetch_sub(n + 1, Ordering::SeqCst);

        if batch_done {
            let acc = self.cpu_batches.remove(&batch).unwrap();
            self.hybrid.record_cpu(acc.items, acc.max_secs);
            self.report.cpu_task_wall += acc.sum_secs;
        }
    }

    fn on_cpu_done(
        &mut self,
        items: usize,
        secs: f64,
        results: Vec<(ChareId, WrResult)>,
    ) {
        self.hybrid.record_cpu(items, secs);
        self.report.cpu_task_wall += secs;
        self.report.cpu_requests += results.len() as u64;
        let n = results.len() as i64;
        for (chare, res) in results {
            self.router
                .send_msg(chare, Msg::new(METHOD_RESULT, res));
        }
        // Release the work-request holds, then the CpuDone hold.
        self.router
            .shared
            .outstanding
            .fetch_sub(n + 1, Ordering::SeqCst);
    }

    /// The coordinator event loop.
    fn run(mut self, rx: Receiver<CoordMsg>) -> Report {
        loop {
            match rx.recv_timeout(self.cfg.tick) {
                Ok(CoordMsg::Submit(draft)) => self.on_submit(draft),
                Ok(CoordMsg::GpuDone(c)) => {
                    self.on_gpu_done(c);
                    self.poll_combiners();
                }
                Ok(CoordMsg::CpuDone { items, secs, results }) => {
                    self.on_cpu_done(items, secs, results);
                    self.poll_combiners();
                }
                Ok(CoordMsg::CpuChunk { batch, items, secs, results }) => {
                    self.on_cpu_chunk(batch, items, secs, results);
                    self.poll_combiners();
                }
                Ok(CoordMsg::InvalidateAll) => {
                    for st in &mut self.devices {
                        st.table.invalidate_all();
                        st.node_table.invalidate_all();
                    }
                }
                Ok(CoordMsg::Stop) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    self.poll_combiners();
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        self.drain_all();
        // Wait for in-flight launches and CPU-pool batches so their holds
        // are released and the final stats are complete.
        // (Completions still arrive on rx via the forwarder.)
        while !self.launches.is_empty() || !self.cpu_batches.is_empty() {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(CoordMsg::GpuDone(c)) => self.on_gpu_done(c),
                Ok(CoordMsg::CpuDone { items, secs, results }) => {
                    self.on_cpu_done(items, secs, results)
                }
                Ok(CoordMsg::CpuChunk { batch, items, secs, results }) => {
                    self.on_cpu_chunk(batch, items, secs, results)
                }
                Ok(_) => {}
                Err(_) => break,
            }
        }
        self.report.steals = self.dev_router.steals();
        self.report.migrated_requests = self.dev_router.migrated_requests();
        self.report.table_hits = 0;
        self.report.table_misses = 0;
        self.report.saved_bytes = 0;
        for d in 0..self.devices.len() {
            let hits =
                self.devices[d].table.hits() + self.devices[d].node_table.hits();
            let misses = self.devices[d].table.misses()
                + self.devices[d].node_table.misses();
            let saved =
                self.devices[d].table.saved_bytes() + self.devices[d].node_saved;
            self.report.table_hits += hits;
            self.report.table_misses += misses;
            self.report.saved_bytes += saved;
            let dev = self.report.device_mut(d);
            dev.hits = hits;
            dev.misses = misses;
        }
        self.report
    }
}

/// The user-facing runtime: build, register chares, start, drive, shutdown.
pub struct GCharm {
    cfg: Config,
    placement: HashMap<ChareId, usize>,
    registry: Vec<HashMap<ChareId, Box<dyn Chare>>>,
    running: Option<RunningState>,
}

struct RunningState {
    router: Router,
    pe_handles: Vec<JoinHandle<()>>,
    coord_handle: JoinHandle<Report>,
    forwarder: JoinHandle<()>,
}

impl GCharm {
    pub fn new(cfg: Config) -> GCharm {
        let pes = cfg.pes.max(1);
        GCharm {
            cfg: Config { pes, ..cfg },
            placement: HashMap::new(),
            registry: (0..pes).map(|_| HashMap::new()).collect(),
            running: None,
        }
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Register a chare on a PE (must happen before `start`).
    pub fn register(&mut self, id: ChareId, pe: usize, chare: Box<dyn Chare>) {
        assert!(self.running.is_none(), "register before start");
        let pe = pe % self.cfg.pes;
        let prev = self.placement.insert(id, pe);
        assert!(prev.is_none(), "chare {id:?} registered twice");
        self.registry[pe].insert(id, chare);
    }

    /// Spawn PE threads, the coordinator, and the GPU service.
    pub fn start(&mut self) -> Result<()> {
        anyhow::ensure!(self.running.is_none(), "already started");
        let shared = Shared::new();
        let (coord_tx, coord_rx) = channel::<CoordMsg>();
        let mut pe_txs = Vec::new();
        let mut pe_rxs = Vec::new();
        for _ in 0..self.cfg.pes {
            let (tx, rx) = channel::<PeMsg>();
            pe_txs.push(tx);
            pe_rxs.push(rx);
        }
        let router = Router {
            pes: pe_txs,
            coord: coord_tx.clone(),
            placement: Arc::new(std::mem::take(&mut self.placement)),
            shared: shared.clone(),
        };

        // GPU completion forwarder: GpuService -> coordinator queue.
        let (done_tx, done_rx) = channel::<Result<Completion>>();
        let fwd_coord = coord_tx.clone();
        let forwarder = std::thread::Builder::new()
            .name("gpu-forwarder".into())
            .spawn(move || {
                while let Ok(c) = done_rx.recv() {
                    if fwd_coord.send(CoordMsg::GpuDone(c)).is_err() {
                        break;
                    }
                }
            })?;

        let coord = Coord::new(self.cfg.clone(), router.clone(), done_tx)
            .context("starting coordinator")?;
        let coord_handle = std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || coord.run(coord_rx))?;

        let mut pe_handles = Vec::new();
        for (pe, rx) in pe_rxs.into_iter().enumerate() {
            let chares = std::mem::take(&mut self.registry[pe]);
            let r = router.clone();
            let exec_cfg = self.cfg.executor.clone();
            pe_handles.push(
                std::thread::Builder::new()
                    .name(format!("pe-{pe}"))
                    .spawn(move || pe_loop(pe, rx, chares, r, exec_cfg))?,
            );
        }

        self.running = Some(RunningState {
            router,
            pe_handles,
            coord_handle,
            forwarder,
        });
        Ok(())
    }

    fn running(&self) -> &RunningState {
        self.running.as_ref().expect("runtime not started")
    }

    /// Driver-side message send.
    pub fn send(&self, to: ChareId, msg: Msg) {
        self.running().router.send_msg(to, msg);
    }

    /// Timeline seconds since start.
    pub fn now(&self) -> f64 {
        self.running().router.shared.timeline.now()
    }

    pub fn shared(&self) -> Arc<Shared> {
        self.running().router.shared.clone()
    }

    /// Block until the system is quiescent: no queued messages, no pending
    /// or in-flight work requests.
    pub fn await_quiescence(&self) {
        let shared = &self.running().router.shared;
        loop {
            if shared.outstanding.load(Ordering::SeqCst) == 0 {
                return;
            }
            std::thread::sleep(Duration::from_micros(50));
        }
    }

    /// Block until `n` contributions have arrived; returns their sum and
    /// resets the reduction.
    pub fn await_reduction(&self, n: u64) -> f64 {
        let shared = &self.running().router.shared;
        let mut guard = shared.reduction.lock().unwrap();
        while guard.count < n {
            guard = shared.reduction_cv.wait(guard).unwrap();
        }
        let sum = guard.sum;
        guard.count = 0;
        guard.sum = 0.0;
        sum
    }

    /// Invalidate all device-resident buffers. Call only at quiescence
    /// (iteration boundary): pinned slots back in-flight launches.
    pub fn invalidate_device_buffers(&self) {
        self.running()
            .router
            .coord
            .send(CoordMsg::InvalidateAll)
            .expect("coordinator is down");
    }

    /// Stop all threads and return the run report.
    pub fn shutdown(mut self) -> Report {
        let state = self.running.take().expect("runtime not started");
        state.router.coord.send(CoordMsg::Stop).ok();
        let report = state.coord_handle.join().expect("coordinator panicked");
        for tx in &state.router.pes {
            tx.send(PeMsg::Stop).ok();
        }
        for h in state.pe_handles {
            h.join().expect("pe panicked");
        }
        drop(state.router); // closes the forwarder's target
        state.forwarder.join().ok();
        report
    }
}
