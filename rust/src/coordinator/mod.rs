//! Layer-3 coordinator: the G-Charm runtime system.
//!
//! Wires together the message-driven substrate (PEs + chares), the three
//! paper strategies (adaptive combining section 3.1, data reuse + coalescing
//! section 3.2, dynamic hybrid scheduling section 3.3), and the GPU service.
//!
//! The runtime is **persistent and multi-tenant**: a [`Runtime`] owns the
//! device pool, the append-only kernel registry, and the PE worker threads
//! for its whole lifetime, and concurrent jobs join it through
//! [`Runtime::submit_job`] with a [`JobSpec`]. Requests of the same kernel
//! family from *different* jobs may be combined into one launch
//! (cross-job combining), with per-job accounting split back out on
//! completion; a weighted-fair share keeps one heavy job from starving
//! its co-tenants. The one-shot [`GCharm`] API survives as a thin shim:
//! one job on a private runtime.
//!
//! The kernel surface is *open*: jobs register kernel families in their
//! specs and submit shape-checked `Tile` payloads tagged with the
//! returned `KernelKindId`. Every scheduling layer — per-device combiner
//! tables, reuse staging, hybrid CPU/GPU rate models, the steal
//! rebalancer, per-kind metrics — is table-driven off the registry; no
//! coordinator code matches on a kernel family.
//!
//! Thread topology:
//!
//! ```text
//!   job drivers        PE threads (chares)        coordinator thread
//!      |  send/await      |  entry methods            |  combiners,
//!      v                  v  -> effects               v  chare tables,
//!   [Router] ---Msg---> [PE queues]                [Coord queue]
//!      |                   \--WorkDraft-------------> |
//!      |                    <--CpuBatch-------------- |   hybrid split
//!      |                                              |--LaunchSpec--> GPU
//!      |                    <---METHOD_RESULT-------- | <--Completion--pool
//! ```

pub mod chare;
pub mod chare_table;
pub mod coalescing;
pub mod combiner;
pub mod cpu_kernels;
pub mod cpu_pool;
pub mod hybrid;
pub mod job;
pub mod metrics;
pub mod registry;
pub mod residency;
pub mod scheduler;
pub mod work_request;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::runtime::device_sim::{CoalescingClass, GpuSpec};
use crate::runtime::executor::{Completion, LaunchSpec, Payload};
use crate::runtime::pool::{DevicePool, InFlightGuard};
use crate::runtime::workqueue::{WorkQueue, DEFAULT_QUEUE_DEPTH};

pub use chare::{Chare, ChareId, Ctx, JobId, Msg, WorkDraft, METHOD_RESULT};
pub use chare_table::ChareTable;
pub use combiner::{Batch, CombinePolicy, Combiner, FlushReason, Pending};
pub use cpu_pool::chunk_by_items;
pub use hybrid::{HybridScheduler, SplitPolicy};
pub use job::{
    GCharm, JobCtx, JobDriver, JobHandle, JobSpec, PoolSnapshotHandle, Runtime,
};
pub use metrics::{
    DeviceStats, JobMetricsSnapshot, JobReport, KindStats, PoolReport, Report,
};
pub use registry::{
    builtin_registry, ewald_descriptor, force_descriptor, md_descriptor,
    KernelDescriptor, KernelKindId, KernelRegistry, ShapeError,
    SharedRegistry,
};
pub use crate::runtime::memory::ResidencyPolicy;
pub use crate::runtime::workqueue::LaunchMode;
pub use residency::ReuseScorer;
pub use scheduler::{
    rendezvous_node, DeviceRouter, JobState, JobStatus, RoutePolicy, Shared,
};
pub use work_request::{Tile, WorkRequest, WrResult};

use scheduler::{CoordMsg, NetAccountDelta, NetShipment, Router};

/// Data-movement policy (paper section 3.2 / Fig 1 / Fig 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPolicy {
    /// Redundant transfers, fully coalesced contiguous packing (Fig 1b).
    NoReuse,
    /// Reuse resident buffers; arrival-order gather (uncoalesced, Fig 1c).
    Reuse,
    /// Reuse + slot-sorted insertion for local coalescing (Fig 1d).
    ReuseSorted,
}

/// Pool-wide launch-mode policy (ISSUE 8) for families whose descriptor
/// does not pin a [`LaunchMode`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LaunchModePolicy {
    /// Every combined batch pays a host kernel launch (the seed path).
    PerBatch,
    /// Every family runs a resident megakernel loop fed by a work queue.
    Persistent,
    /// Watch each family's flush-reason stream and switch it between
    /// modes at the modeled break-even idle-flush share (the paper's
    /// adaptive-over-static thesis applied to launch strategy). Static
    /// modes above are the ablation baselines.
    #[default]
    Adaptive,
}

/// Full runtime configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of PE worker threads.
    pub pes: usize,
    pub combine: CombinePolicy,
    pub data_policy: DataPolicy,
    pub split: SplitPolicy,
    /// Enable CPU+GPU hybrid execution for registered families with a CPU
    /// fallback (`KernelDescriptor::cpu_fallback`).
    pub hybrid: bool,
    /// CPU worker-pool size for the hybrid split's CPU batches (>= 1).
    /// Batches are chunked by `data_items` across the pool; per-worker
    /// timings fold into the hybrid scheduler.
    pub cpu_workers: usize,
    /// Number of simulated GPU devices in the sharded pool (>= 1). Each
    /// device gets its own `GpuService` (stager+engine thread pair and
    /// staging arena), chare tables, node cache, and combiner set. `1`
    /// reproduces the single-device runtime bitwise.
    pub devices: usize,
    /// Chare -> device routing policy (ignored when `devices == 1`).
    pub route: RoutePolicy,
    /// Steal when some device's pending depth is below this...
    pub steal_low: usize,
    /// ...while another's is at or above this (must exceed `steal_low`).
    pub steal_high: usize,
    /// Per-device, per-reuse-family pool capacity in buffer slots.
    pub table_slots: usize,
    /// Eviction + prefetch policy of the per-family device pools
    /// (ISSUE 7). [`ResidencyPolicy::Lru`] is the seed behavior:
    /// least-recently-used eviction, no lookahead, no prefetch.
    /// [`ResidencyPolicy::ReuseGraph`] builds a per-`(job, kind)` reuse
    /// graph from the pending request stream and (a) evicts the buffer
    /// with the *farthest predicted next use* (never-revisited streaming
    /// scans first — which also keeps one tenant's scan from flushing a
    /// co-tenant's hot set, since keys are job-namespaced), (b)
    /// prefetch-stages soon-to-be-used evicted buffers into free slots
    /// while a combined batch executes, and (c) makes steal decisions
    /// residency-aware, shrinking `migrated_bytes`.
    pub residency: ResidencyPolicy,
    /// Per-device interaction-entry cache capacity (tree moments /
    /// particle entries, 16 B each). Models ChaNGa's GPU-resident moments
    /// and particle arrays.
    pub node_slots: usize,
    pub artifacts: PathBuf,
    /// Safety drain: force-flush a combiner whose newest request has waited
    /// this long (rescues the static policy at iteration tails).
    pub idle_drain: f64,
    /// Coordinator tick (recv timeout driving combiner polls).
    pub tick: Duration,
    /// How combined batches reach the device (ISSUE 8) for families
    /// without a [`KernelDescriptor::launch_mode`] pin. The default
    /// `Adaptive` learner starts every family per-batch (the seed
    /// behavior) and promotes it to a persistent loop only once its
    /// flush stream proves dense enough to win; outputs are
    /// bit-identical in every mode — only the modeled cost moves.
    pub launch_mode: LaunchModePolicy,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            pes: 4,
            combine: CombinePolicy::Adaptive,
            data_policy: DataPolicy::ReuseSorted,
            split: SplitPolicy::AdaptiveItems,
            hybrid: true,
            cpu_workers: 4,
            devices: 1,
            route: RoutePolicy::AffinitySteal,
            steal_low: 4,
            steal_high: 16,
            table_slots: 1024,
            residency: ResidencyPolicy::ReuseGraph,
            node_slots: 1 << 17,
            artifacts: crate::runtime::default_artifacts_dir(),
            idle_drain: 2e-3,
            tick: Duration::from_micros(200),
            launch_mode: LaunchModePolicy::Adaptive,
        }
    }
}

impl Config {
    /// Reject configurations that would previously have panicked deep in
    /// the pool. Called by `Runtime::new` (and the `GCharm` shim), so CLI
    /// flags and programmatic configs fail fast with an error naming the
    /// offending field.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.devices >= 1,
            "config: devices must be >= 1 (got {})",
            self.devices
        );
        anyhow::ensure!(
            self.steal_low < self.steal_high,
            "config: steal_low ({}) must be below steal_high ({})",
            self.steal_low,
            self.steal_high
        );
        anyhow::ensure!(
            self.cpu_workers >= 1,
            "config: cpu_workers must be >= 1 (got 0)"
        );
        Ok(())
    }
}

/// Compose a job-namespaced residency key. The runtime is multi-tenant:
/// two jobs may use the same app-level buffer or entry ids, so every key
/// entering the shared chare tables and entry caches carries its job in
/// the upper 16 bits (app ids must fit in 48).
pub(crate) fn job_key(job: JobId, k: u64) -> u64 {
    debug_assert!(k < 1 << 48, "buffer/entry id {k} exceeds 48 bits");
    debug_assert!(job.0 < 1 << 16, "job id {} exceeds 16 bits", job.0);
    (job.0 << 48) | (k & ((1u64 << 48) - 1))
}

/// The job half of a job-namespaced residency key.
pub(crate) fn key_job(key: u64) -> u64 {
    key >> 48
}

/// Prefetch stagings attempted per submitted launch (ReuseGraph).
const PREFETCH_MAX: usize = 8;
/// Forecast window for prefetch candidacy, in request-stream positions:
/// only buffers predicted to be demanded this soon are worth a slot.
const PREFETCH_HORIZON: u64 = 256;

/// EWMA step for the adaptive launch-mode learner's idle-flush share.
const MODE_EWMA_ALPHA: f64 = 0.25;
/// Enter persistent mode when a family's idle-flush share falls below
/// this. The modeled break-even share is
/// `(launch_overhead - queue_poll_cost) / poll_idle_cost` (~0.38 on the
/// K20 spec: below it the per-batch savings outrun the idle-poll burn);
/// entering under 0.30 and leaving above 0.50 brackets it with
/// hysteresis so a family cannot flap on one flush.
const MODE_ENTER_PERSISTENT: f64 = 0.30;
/// Leave persistent mode when the idle-flush share climbs above this.
const MODE_EXIT_PERSISTENT: f64 = 0.50;

/// Adaptive launch-mode learner state for one kernel family: an EWMA of
/// how often the family's flushes were *time-sparse* (`IdleTimeout` /
/// `Forced` — the resident loop would have idled before them), and the
/// mode the family currently runs in.
struct LaunchModeState {
    /// EWMA of sparse flushes (1.0 = every flush idles first). Starts
    /// pessimistic: a family is per-batch until proven dense.
    idle_share: f64,
    mode: LaunchMode,
}

impl Default for LaunchModeState {
    fn default() -> LaunchModeState {
        LaunchModeState { idle_share: 1.0, mode: LaunchMode::PerBatch }
    }
}

/// One work request recorded inside an in-flight launch.
struct LaunchItem {
    wr_id: u64,
    tag: u64,
    job: JobId,
    chare: ChareId,
    kind: KernelKindId,
    data_items: usize,
    /// Job-namespaced buffer key to release on completion, if staged.
    buffer: Option<u64>,
    /// PCIe bytes attributed to this request (payload + staging + its
    /// slice of shared launch overheads). Per-item attribution is exact:
    /// the items of a launch sum to its `transfer_bytes`.
    bytes: u64,
}

struct LaunchInfo {
    items: Vec<LaunchItem>,
    transfer_bytes: u64,
    /// Pool device the launch was submitted to.
    device: usize,
    /// Registered family the launch belongs to.
    kind: KernelKindId,
    /// Output floats per request slot (from the family's registration).
    out_slot: usize,
    /// Mode the coordinator resolved for the launch. `Persistent` means
    /// the batch's descriptor was queued on the family's work ring (the
    /// engine may still demote it if the backend cannot keep a resident
    /// loop — `Completion::mode` is the effective answer).
    mode: LaunchMode,
    /// Modeled device time the resident loop burned spin-polling before
    /// this batch arrived (time-sparse flushes only; 0 per-batch).
    idle_penalty: f64,
    /// Holds the device's in-flight gauge up until the launch completes
    /// (dropped with this struct on the completion path).
    _in_flight: InFlightGuard,
}

/// Accumulator folding a hybrid batch's CPU-pool chunk *timings* back
/// together. Results are scattered per chunk as they arrive (no added
/// latency); only the hybrid-rate observation waits for the batch.
struct CpuBatchAcc {
    kind: KernelKindId,
    chunks_left: usize,
    items: usize,
    /// Longest single chunk: the batch makespan (chunks start together),
    /// i.e. the pool's true wall time for the batch.
    max_secs: f64,
    /// Summed per-worker busy time (report accounting).
    sum_secs: f64,
}

/// Per-device coordinator-side state: residency tables and combiners,
/// one entry per registered kind. Rows are appended as the shared
/// registry grows (jobs may bring new families to a live runtime).
struct DeviceState {
    /// Reuse-buffer tables, indexed by kind; `None` for families without
    /// a reuse arg.
    tables: Vec<Option<ChareTable>>,
    /// Reuse-graph scorers, parallel to `tables` (one per reuse family;
    /// `None` for families without a reuse arg). Populated only under
    /// `ResidencyPolicy::ReuseGraph`; each observes its own device's
    /// request stream for that kind.
    scorers: Vec<Option<ReuseScorer>>,
    /// Residency of interaction entries (tree moments / cached particles),
    /// 16 bytes each, keyed per job. Accounting-level model of the
    /// GPU-resident arrays the interaction lists reference.
    node_table: crate::runtime::DeviceMemory,
    node_saved: u64,
    /// One workGroupList per registered kind, in registry order.
    combiners: Vec<Combiner>,
}

/// The coordinator thread's state.
pub(crate) struct Coord {
    cfg: Config,
    /// Local, append-only copy of the registered descriptors (grown by
    /// `KindsAdded`; avoids registry locks on the hot path).
    kinds: Vec<KernelDescriptor>,
    router: Router,
    /// Per-device residency + combiner shards (length = pool devices).
    devices: Vec<DeviceState>,
    /// Chare -> device affinity routing and steal accounting.
    dev_router: DeviceRouter,
    hybrid: HybridScheduler,
    report: PoolReport,
    launches: HashMap<u64, LaunchInfo>,
    gpu: DevicePool,
    /// Hybrid CPU worker pool, spawned lazily on the first CPU split so
    /// GPU-only workloads never carry idle worker threads.
    cpu_pool: Option<cpu_pool::CpuPool>,
    cpu_workers: usize,
    cpu_batches: HashMap<u64, CpuBatchAcc>,
    next_wr: u64,
    next_launch: u64,
    /// Persistent-kernel descriptor rings, keyed by `(device, kind)`,
    /// created lazily on a family's first persistent launch on a device.
    queues: HashMap<(usize, usize), Arc<WorkQueue>>,
    /// Chaos override for ring capacity (applied to existing rings and
    /// used for rings created afterwards). `None` = `DEFAULT_QUEUE_DEPTH`.
    queue_cap_override: Option<usize>,
    /// Chaos-forced launch mode: when set, every resolution uses it,
    /// overriding descriptor pins and the configured policy. Written only
    /// by the chaos injection path; `None` in production runs.
    chaos_forced_mode: Option<LaunchMode>,
    /// Adaptive launch-mode learner, one row per registered kind.
    mode_states: Vec<LaunchModeState>,
    /// QoS class per job (serve front end, ISSUE 10). Jobs submitted
    /// outside a serve front end have no entry and behave exactly as
    /// before (multiplier 1.0, steal-eligible, no deadline).
    job_qos: HashMap<u64, crate::serve::QosClass>,
    /// Deadline budget (timeline seconds) per latency-sensitive job:
    /// arms the deadline flush trigger in `poll_combiners`.
    job_deadline: HashMap<u64, f64>,
}

/// Fraction of a latency job's deadline budget its oldest queued
/// request may age in a combiner before the queue drains early (below
/// maxSize): half the budget is left for the launch itself.
const DEADLINE_FLUSH_FRACTION: f64 = 0.5;

impl Coord {
    pub(crate) fn new(
        cfg: Config,
        router: Router,
        done_tx: Sender<Result<Completion>>,
    ) -> Result<Coord> {
        let ndev = cfg.devices.max(1);
        // The pool spawns before any job arrives; families are taught to
        // the live services as jobs register them (`KindsAdded`).
        let gpu = DevicePool::spawn(&cfg.artifacts, Vec::new(), ndev, done_tx)?;
        let devices = (0..ndev)
            .map(|_| DeviceState {
                tables: Vec::new(),
                scorers: Vec::new(),
                node_table: crate::runtime::DeviceMemory::new(cfg.node_slots),
                node_saved: 0,
                combiners: Vec::new(),
            })
            .collect();
        let report = PoolReport {
            device_stats: vec![DeviceStats::default(); ndev],
            ..PoolReport::default()
        };
        Ok(Coord {
            kinds: Vec::new(),
            devices,
            dev_router: DeviceRouter::new(
                cfg.route,
                ndev,
                cfg.steal_low,
                cfg.steal_high,
            ),
            hybrid: HybridScheduler::with_kinds(cfg.split, 1, ndev),
            report,
            launches: HashMap::new(),
            gpu,
            cpu_pool: None,
            cpu_workers: cfg.cpu_workers.max(1),
            cpu_batches: HashMap::new(),
            next_wr: 0,
            next_launch: 0,
            queues: HashMap::new(),
            queue_cap_override: None,
            chaos_forced_mode: None,
            mode_states: Vec::new(),
            job_qos: HashMap::new(),
            job_deadline: HashMap::new(),
            cfg,
            router,
        })
    }

    fn now(&self) -> f64 {
        self.router.shared.timeline.now()
    }

    /// The shared registry grew: append per-device combiner/table rows
    /// for the new families, grow the hybrid models, label the per-kind
    /// stats, and teach every pool device the new kernels. Ordered ahead
    /// of any submission of the new kinds (same queue).
    fn on_kinds_added(&mut self, added: Vec<KernelDescriptor>) {
        let table_slots = self.cfg.table_slots;
        let residency = self.cfg.residency;
        let default_combine = self.cfg.combine;
        let sorted = self.cfg.data_policy == DataPolicy::ReuseSorted;
        let mut kernels = Vec::with_capacity(added.len());
        for desc in added {
            let k = self.kinds.len();
            for st in &mut self.devices {
                st.tables.push(desc.kernel.reuse_arg.map(|ra| {
                    ChareTable::with_policy(
                        table_slots,
                        desc.kernel.args[ra].slot_len(),
                        residency,
                    )
                }));
                st.scorers.push(
                    (residency == ResidencyPolicy::ReuseGraph)
                        .then(|| desc.kernel.reuse_arg.map(|_| ReuseScorer::new()))
                        .flatten(),
                );
                st.combiners.push(Combiner::new(
                    desc.combine.unwrap_or(default_combine),
                    desc.kernel.max_combine(),
                    desc.sort_by_slot && sorted,
                ));
            }
            self.report.kind_mut(k).name = desc.kernel.name.to_string();
            kernels.push(desc.kernel.clone());
            self.mode_states.push(LaunchModeState::default());
            self.kinds.push(desc);
        }
        self.hybrid.ensure_kinds(self.kinds.len());
        self.gpu.add_kernels(&kernels).expect("gpu pool is down");
    }

    /// Handle one submitted work request: route it to a device by the
    /// job-scoped chare affinity map, stage its reuse buffer on that
    /// device if the family declares one (under a job-namespaced key),
    /// then insert into the device's combiner for that kind.
    fn on_submit(&mut self, job: JobId, draft: WorkDraft) {
        let now = self.now();
        let id = self.next_wr;
        self.next_wr += 1;
        let device = self.dev_router.route(job, draft.chare);
        let kind = draft.kind;
        let reuse_arg = self.kinds[kind.0].kernel.reuse_arg;
        let wr = WorkRequest {
            id,
            job,
            chare: draft.chare,
            kind,
            buffer: draft.buffer.map(|b| job_key(job, b)),
            data_items: draft.data_items,
            tag: draft.tag,
            arrival: now,
            payload: draft.payload,
        };

        // Reuse staging applies to families with a registered reuse arg
        // and requests that declare a buffer id.
        let mut slot = None;
        let mut staged_bytes = 0;
        if self.cfg.data_policy != DataPolicy::NoReuse {
            if let (Some(ra), Some(buf)) = (reuse_arg, wr.buffer) {
                // Under ReuseGraph the scorer observes every reference
                // and forecasts this buffer's next use; the forecast
                // rides into the table as the slot's eviction priority.
                let predicted = match self.devices[device].scorers[kind.0]
                    .as_mut()
                {
                    Some(s) => s.note(buf),
                    None => u64::MAX,
                };
                let table = self.devices[device].tables[kind.0]
                    .as_mut()
                    .expect("reuse family has a table");
                match table.stage_pinned_predicted(
                    buf,
                    &wr.payload.bufs[ra],
                    predicted,
                ) {
                    Ok(staged) => {
                        slot = Some(staged.slot);
                        staged_bytes = staged.bytes;
                    }
                    Err(_) => {
                        // Pool exhausted by pinned pending launches: fall
                        // back to contiguous transfer for this request.
                        slot = None;
                    }
                }
            }
        }

        let pending = Pending { wr, slot, staged_bytes };
        self.devices[device].combiners[kind.0].insert(pending, now);
        self.dev_router.note_enqueued(device, job, 1);
        if let Some(js) = self.router.shared.job(job) {
            js.metrics.queued.fetch_add(1, Ordering::SeqCst);
        }
        self.poll_combiners();
    }

    /// Poll every device's combiners; dispatch flushed batches, run the
    /// deadline flush trigger for latency-class jobs, then the
    /// idle-steal rebalancer.
    fn poll_combiners(&mut self) {
        let now = self.now();
        for d in 0..self.devices.len() {
            for k in 0..self.devices[d].combiners.len() {
                while let Some(batch) = self.devices[d].combiners[k].poll(now)
                {
                    self.dispatch(batch, KernelKindId(k), d);
                }
            }
        }
        self.deadline_flush(now);
        self.idle_drain(now);
        self.try_steal();
    }

    /// Deadline-aware flushing (serve front end, ISSUE 10): when a
    /// latency-class job's oldest queued request has aged past
    /// [`DEADLINE_FLUSH_FRACTION`] of that job's deadline budget, drain
    /// the combiner holding it even below `maxSize` — trading launch
    /// occupancy for tail latency. The flush reason is
    /// [`FlushReason::Deadline`]: it counts as a *dense* observation for
    /// the adaptive launch-mode learner (the arrival stream is hot, the
    /// drain is policy) and charges no persistent-loop idle penalty.
    fn deadline_flush(&mut self, now: f64) {
        if self.job_deadline.is_empty() {
            return;
        }
        for d in 0..self.devices.len() {
            for k in 0..self.devices[d].combiners.len() {
                let due = self.job_deadline.iter().any(|(&j, &dl)| {
                    self.devices[d].combiners[k]
                        .oldest_arrival_of(JobId(j))
                        .is_some_and(|a| {
                            now - a >= dl * DEADLINE_FLUSH_FRACTION
                        })
                });
                if due {
                    while let Some(b) =
                        self.devices[d].combiners[k].deadline_flush()
                    {
                        self.dispatch(b, KernelKindId(k), d);
                    }
                }
            }
        }
    }

    /// The combine-weight multiplier of a job's QoS class (1.0 for jobs
    /// with no class, i.e. everything outside a serve front end).
    fn qos_mult(&self, job: JobId) -> f64 {
        self.job_qos
            .get(&job.0)
            .map_or(1.0, |c| c.weight_multiplier())
    }

    /// The serve front end classified a job: remember its class and
    /// deadline budget, and push the class multiplier into every
    /// combiner's fair-share weight immediately — a latency job must
    /// get its enlarged quota before its first completion refreshes the
    /// learned per-(job, kind) weight.
    fn on_set_job_qos(
        &mut self,
        job: JobId,
        class: crate::serve::QosClass,
        deadline: Option<f64>,
    ) {
        self.job_qos.insert(job.0, class);
        match deadline {
            Some(d) if d > 0.0 => {
                self.job_deadline.insert(job.0, d);
            }
            _ => {
                self.job_deadline.remove(&job.0);
            }
        }
        for k in 0..self.kinds.len() {
            let w = self.hybrid.job_weight(job, KernelKindId(k))
                * self.qos_mult(job);
            for st in &mut self.devices {
                st.combiners[k].set_job_weight(job, w);
            }
        }
    }

    /// Safety drain (see Config::idle_drain).
    fn idle_drain(&mut self, now: f64) {
        let gap = self.cfg.idle_drain;
        if gap <= 0.0 {
            return;
        }
        for d in 0..self.devices.len() {
            for k in 0..self.devices[d].combiners.len() {
                let c = &self.devices[d].combiners[k];
                if !c.is_empty() && now - c.last_arrival().unwrap_or(now) > gap
                {
                    while let Some(b) =
                        self.devices[d].combiners[k].force_flush()
                    {
                        self.dispatch(b, KernelKindId(k), d);
                    }
                }
            }
        }
    }

    /// Force-flush everything (shutdown path).
    fn drain_all(&mut self) {
        for d in 0..self.devices.len() {
            for k in 0..self.devices[d].combiners.len() {
                while let Some(b) = self.devices[d].combiners[k].force_flush()
                {
                    self.dispatch(b, KernelKindId(k), d);
                }
            }
        }
    }

    /// Idle-steal rebalancer (section 3.3's adaptive split at device
    /// granularity): while one device's pending depth sits below the low
    /// watermark and another's at or above the high one, migrate a whole
    /// pending batch from the loaded device and dispatch it on the idle
    /// one immediately, paying the restage/transfer cost in the reuse
    /// model. Depths are weighted by the hybrid scheduler's measured
    /// per-device speeds, so a fast idle device pulls work sooner.
    fn try_steal(&mut self) {
        // Allocation-free precondition first: poll_combiners runs per
        // submitted request, and device_shares() allocates.
        if self.cfg.route != RoutePolicy::AffinitySteal
            || !self.dev_router.watermarks_crossed()
        {
            return;
        }
        let shares = self.hybrid.device_shares();
        // Bounded per poll: each iteration moves one batch; stop when the
        // watermarks are satisfied or the loaded device has nothing
        // pending (its depth is all in-flight work).
        for _ in 0..self.devices.len() {
            // Under ReuseGraph, a victim's stealable batch is discounted
            // by the residency it would forfeit (each resident request
            // restages on the thief), so cold batches migrate first and
            // `migrated_bytes` shrinks. Recomputed per iteration: each
            // steal drains a queue and re-ranks the rest.
            let restage: Vec<usize> =
                if self.cfg.residency == ResidencyPolicy::ReuseGraph {
                    self.devices
                        .iter()
                        .map(|st| Self::stealable_resident(st))
                        .collect()
                } else {
                    Vec::new()
                };
            let Some((from, to)) = self
                .dev_router
                .steal_candidate_with_cost(&shares, &restage)
            else {
                break;
            };
            let Some((batch, kind)) = self.steal_batch(from) else {
                break;
            };
            let n = batch.items.len();
            self.dev_router.note_stolen(from, to, n);
            self.report.device_mut(from).steals_out += 1;
            self.report.device_mut(to).steals_in += 1;
            let batch = self.migrate_batch(batch, kind, from, to);
            self.dispatch(batch, kind, to);
        }
    }

    /// Drain one batch from the loaded device's longest pending queue.
    fn steal_batch(&mut self, from: usize) -> Option<(Batch, KernelKindId)> {
        let st = &mut self.devices[from];
        let k = Self::steal_kind(st)?;
        st.combiners[k].steal_flush().map(|b| (b, KernelKindId(k)))
    }

    /// The kind `steal_batch` would drain from this device (its longest
    /// pending queue; first-registered kind wins ties — stable victim
    /// selection). `None` when nothing is pending.
    fn steal_kind(st: &DeviceState) -> Option<usize> {
        let mut k = 0usize;
        for i in 1..st.combiners.len() {
            if st.combiners[i].len() > st.combiners[k].len() {
                k = i;
            }
        }
        (!st.combiners.is_empty() && !st.combiners[k].is_empty())
            .then_some(k)
    }

    /// Device-resident requests in the batch a steal from this device
    /// would take: the restage cost `steal_candidate_with_cost` subtracts
    /// from the victim's depth.
    fn stealable_resident(st: &DeviceState) -> usize {
        Self::steal_kind(st)
            .map_or(0, |k| st.combiners[k].resident_slots())
    }

    /// Move a stolen batch's residency from `from` to `to`: release the
    /// source pins, restage into the destination's table (a miss there
    /// re-transfers the buffer — the explicit migration cost), and
    /// re-home the chares so their future requests follow the data.
    fn migrate_batch(
        &mut self,
        mut batch: Batch,
        kind: KernelKindId,
        from: usize,
        to: usize,
    ) -> Batch {
        let reuse_arg = self.kinds[kind.0].kernel.reuse_arg;
        for p in &mut batch.items {
            self.dev_router.rehome(p.wr.job, p.wr.chare, to);
            if p.slot.is_none() {
                continue;
            }
            let Some(buf) = p.wr.buffer else { continue };
            let Some(ra) = reuse_arg else { continue };
            self.devices[from].tables[kind.0]
                .as_mut()
                .expect("reuse family has a table")
                .release(buf);
            // Bytes staged to the source device were spent whether or not
            // the launch runs there: a migrated launch keeps carrying
            // them, plus whatever the destination restage costs.
            let src_bytes = p.staged_bytes;
            p.slot = None;
            p.staged_bytes = 0;
            // A migration is not a fresh reference: carry the
            // destination scorer's existing forecast (UNSCORED until the
            // chare's re-homed stream builds one there).
            let predicted = self.devices[to].scorers[kind.0]
                .as_ref()
                .map_or(u64::MAX, |s| s.predicted_next(buf));
            let dst = self.devices[to].tables[kind.0]
                .as_mut()
                .expect("reuse family has a table");
            match dst.stage_pinned_predicted(
                buf,
                &p.wr.payload.bufs[ra],
                predicted,
            ) {
                Ok(staged) => {
                    p.slot = Some(staged.slot);
                    p.staged_bytes = src_bytes + staged.bytes;
                    self.report.migrated_bytes += staged.bytes;
                }
                Err(_) => {
                    // Destination pool exhausted: contiguous fallback
                    // (the full payload is charged at dispatch).
                }
            }
        }
        // The batch was slot-sorted for the *source* pool; restaging
        // scrambled that. Re-sort on the destination slots so the
        // coalescing model's SortedGather claim stays honest.
        if self.cfg.data_policy == DataPolicy::ReuseSorted
            && self.kinds[kind.0].sort_by_slot
        {
            batch
                .items
                .sort_by_key(|p| p.slot.unwrap_or(u32::MAX));
        }
        batch
    }

    /// Build and submit the combined launch for a flushed batch of one
    /// registered kind on one device: hybrid-split if the family has a
    /// CPU fallback, account transfers per the data policy (entry-cache
    /// hits, staged reuse, contiguous payloads) with exact per-item
    /// attribution for the per-job reports, and pick the gather or
    /// contiguous payload form.
    fn dispatch(&mut self, batch: Batch, kind: KernelKindId, device: usize) {
        self.report.record_flush(batch.reason, batch.items.len());
        let reason = batch.reason;
        self.note_flush(kind, reason);
        if batch.items.is_empty() {
            return;
        }
        let desc = self.kinds[kind.0].clone();
        let kernel = &desc.kernel;

        let (cpu, gpu) = if desc.cpu_fallback && self.cfg.hybrid {
            self.hybrid.split(kind, batch.items)
        } else {
            (Vec::new(), batch.items)
        };

        if !cpu.is_empty() {
            // The CPU prefix leaves this device's pending queue. Any slots
            // its requests pinned at submission must be released here: the
            // CPU completion path never touches the chare table, so a
            // reuse+hybrid family would otherwise leak pins until the
            // pool is exhausted.
            if kernel.reuse_arg.is_some() {
                let table = self.devices[device].tables[kind.0]
                    .as_mut()
                    .expect("reuse family has a table");
                for p in &cpu {
                    if p.slot.is_some() {
                        if let Some(buf) = p.wr.buffer {
                            table.release(buf);
                        }
                    }
                }
            }
            let total: usize = cpu.iter().map(|p| p.wr.data_items).sum();
            self.report.cpu_items += total as u64;
            self.report.kind_mut(kind.0).cpu_items += total as u64;
            // Per-job device-depth and live-metric accounting for the
            // prefix that leaves the GPU queue.
            for p in &cpu {
                self.dev_router.note_completed(device, p.wr.job, 1);
                if let Some(js) = self.router.shared.job(p.wr.job) {
                    js.metrics
                        .cpu_items
                        .fetch_add(p.wr.data_items as u64, Ordering::SeqCst);
                }
            }
            // Fan the CPU portion across the worker pool (asynchronous
            // executions on all CPU cores, section 3.3), chunked by
            // data_items so each worker gets a similar item load.
            if self.cpu_pool.is_none() {
                let pool = cpu_pool::CpuPool::spawn(
                    self.cpu_workers,
                    self.router.coord.clone(),
                    self.router.shared.clone(),
                    self.router.registry.clone(),
                )
                .expect("spawning cpu pool");
                self.cpu_pool = Some(pool);
            }
            let pool = self.cpu_pool.as_mut().expect("cpu pool just spawned");
            let (batch_id, chunks) = pool.submit(cpu);
            self.cpu_batches.insert(
                batch_id,
                CpuBatchAcc {
                    kind,
                    chunks_left: chunks,
                    items: 0,
                    max_secs: 0.0,
                    sum_secs: 0.0,
                },
            );
        }

        let n = gpu.len();
        if n == 0 {
            return;
        }

        // Per-item PCIe byte attribution. Every charge below lands on
        // exactly one item, so `transfer` (the launch total) equals the
        // sum over items — which is what lets the per-job byte counters
        // in JobReport sum exactly back to the pool totals.
        let mut item_bytes = vec![0u64; n];

        // Entry-cache accounting: the family's entry arg is either fully
        // transferred (NoReuse) or charged per *real* entry against the
        // device-resident entry cache (section 3.2: moments/particle data
        // resident from prior kernels — transfer only the misses). Entry
        // keys are namespaced per job.
        if let Some(ea) = kernel.entry_arg {
            let entry_bytes = (kernel.args[ea].width * 4) as u64;
            for (i, p) in gpu.iter().enumerate() {
                if self.cfg.data_policy == DataPolicy::NoReuse {
                    item_bytes[i] +=
                        (p.wr.payload.bufs[ea].len() * 4) as u64;
                } else {
                    let st = &mut self.devices[device];
                    for &eid in &p.wr.payload.entry_ids {
                        let key = job_key(p.wr.job, eid as u64);
                        match st.node_table.acquire(key) {
                            Some(r) if r.is_hit() => {
                                st.node_saved += entry_bytes;
                            }
                            _ => item_bytes[i] += entry_bytes,
                        }
                    }
                }
            }
        }

        let use_gather = kernel.reuse_arg.is_some()
            && self.cfg.data_policy != DataPolicy::NoReuse
            && gpu.iter().all(|p| p.slot.is_some());

        let (payload, pattern) = if use_gather {
            let ra = kernel.reuse_arg.expect("gather requires a reuse arg");
            let rows = kernel.args[ra].rows;
            let mut idx = Vec::with_capacity(n * rows);
            for (i, p) in gpu.iter().enumerate() {
                let base = p.slot.expect("all staged") as i32 * rows as i32;
                idx.extend((0..rows as i32).map(|j| base + j));
                item_bytes[i] += p.staged_bytes;
                // this item's slice of the gather-index buffer
                item_bytes[i] += (rows * 4) as u64;
            }
            let mut bufs = Vec::with_capacity(kernel.args.len() - 1);
            for (argi, _spec) in kernel.args.iter().enumerate() {
                if argi == ra {
                    continue; // resident: addressed through the gather
                }
                let mut v =
                    Vec::with_capacity(n * kernel.args[argi].slot_len());
                for (i, p) in gpu.iter().enumerate() {
                    v.extend_from_slice(&p.wr.payload.bufs[argi]);
                    // the entry arg's transfer was charged per real entry
                    // against the entry cache above
                    if Some(argi) != kernel.entry_arg {
                        item_bytes[i] +=
                            (p.wr.payload.bufs[argi].len() * 4) as u64;
                    }
                }
                bufs.push(v);
            }
            let pattern = match self.cfg.data_policy {
                DataPolicy::ReuseSorted if desc.sort_by_slot => {
                    CoalescingClass::SortedGather
                }
                _ => CoalescingClass::RandomGather,
            };
            let pool = self.devices[device].tables[kind.0]
                .as_ref()
                .expect("reuse family has a table")
                .pool_arc();
            (
                Payload::TileGather {
                    kernel: kernel.clone(),
                    pool,
                    idx,
                    bufs,
                    batch: n,
                },
                pattern,
            )
        } else {
            let mut bufs = Vec::with_capacity(kernel.args.len());
            for (argi, spec) in kernel.args.iter().enumerate() {
                let mut v = Vec::with_capacity(n * spec.slot_len());
                for (i, p) in gpu.iter().enumerate() {
                    v.extend_from_slice(&p.wr.payload.bufs[argi]);
                    if Some(argi) != kernel.entry_arg {
                        item_bytes[i] +=
                            (p.wr.payload.bufs[argi].len() * 4) as u64;
                    }
                }
                bufs.push(v);
            }
            (
                Payload::Tile { kernel: kernel.clone(), bufs, batch: n },
                CoalescingClass::Contiguous,
            )
        };
        let transfer: u64 = item_bytes.iter().sum();
        self.submit_launch(
            gpu, item_bytes, kind, payload, transfer, pattern, device, reason,
        );
    }

    /// Feed the adaptive launch-mode learner one flush observation:
    /// `IdleTimeout`/`Forced` flushes are the deterministic shadow of a
    /// sparse arrival stream (the resident loop would have spin-polled
    /// before them), everything else arrived dense. The EWMA'd sparse
    /// share drives a hysteresis switch around the modeled break-even.
    fn note_flush(&mut self, kind: KernelKindId, reason: FlushReason) {
        let st = &mut self.mode_states[kind.0];
        let sparse = matches!(
            reason,
            FlushReason::IdleTimeout | FlushReason::Forced
        );
        let sample = if sparse { 1.0 } else { 0.0 };
        st.idle_share += MODE_EWMA_ALPHA * (sample - st.idle_share);
        match st.mode {
            LaunchMode::PerBatch
                if st.idle_share < MODE_ENTER_PERSISTENT =>
            {
                st.mode = LaunchMode::Persistent;
            }
            LaunchMode::Persistent
                if st.idle_share > MODE_EXIT_PERSISTENT =>
            {
                st.mode = LaunchMode::PerBatch;
            }
            _ => {}
        }
    }

    /// Resolve the launch mode for one batch of `kind`, with priority:
    /// chaos-forced mode > descriptor pin > configured policy (where
    /// `Adaptive` reads the per-kind learner).
    fn requested_mode(&self, kind: KernelKindId) -> LaunchMode {
        if let Some(m) = self.chaos_forced_mode {
            return m;
        }
        if let Some(m) = self.kinds[kind.0].launch_mode {
            return m;
        }
        match self.cfg.launch_mode {
            LaunchModePolicy::PerBatch => LaunchMode::PerBatch,
            LaunchModePolicy::Persistent => LaunchMode::Persistent,
            LaunchModePolicy::Adaptive => self.mode_states[kind.0].mode,
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn submit_launch(
        &mut self,
        items: Vec<Pending>,
        item_bytes: Vec<u64>,
        kind: KernelKindId,
        payload: Payload,
        transfer_bytes: u64,
        pattern: CoalescingClass,
        device: usize,
        reason: FlushReason,
    ) {
        let id = self.next_launch;
        self.next_launch += 1;
        // Persistent launches enqueue a descriptor on the family's ring;
        // a full ring is backpressure, not an error — the batch falls
        // back to a plain host launch and the ring counts the rejection.
        let mut mode = self.requested_mode(kind);
        let mut idle_penalty = 0.0;
        if mode == LaunchMode::Persistent {
            let cap =
                self.queue_cap_override.unwrap_or(DEFAULT_QUEUE_DEPTH);
            let queue = self
                .queues
                .entry((device, kind.0))
                .or_insert_with(|| Arc::new(WorkQueue::new(cap)));
            match queue.push(id) {
                Ok(_) => {
                    // A time-sparse flush means the resident loop idled
                    // before this batch: charge the modeled spin-poll burn.
                    if matches!(
                        reason,
                        FlushReason::IdleTimeout | FlushReason::Forced
                    ) {
                        idle_penalty = GpuSpec::kepler_k20().poll_idle_cost;
                    }
                }
                Err(()) => mode = LaunchMode::PerBatch,
            }
        }
        let guard = self
            .gpu
            .submit(
                device,
                LaunchSpec { id, payload, transfer_bytes, pattern, mode },
            )
            .expect("gpu service is down");
        let info = LaunchInfo {
            items: items
                .iter()
                .zip(&item_bytes)
                .map(|(p, &bytes)| LaunchItem {
                    wr_id: p.wr.id,
                    tag: p.wr.tag,
                    job: p.wr.job,
                    chare: p.wr.chare,
                    kind: p.wr.kind,
                    data_items: p.wr.data_items,
                    buffer: if p.slot.is_some() { p.wr.buffer } else { None },
                    bytes,
                })
                .collect(),
            transfer_bytes,
            device,
            kind,
            out_slot: self.kinds[kind.0].kernel.out_slot_len(),
            mode,
            idle_penalty,
            _in_flight: guard,
        };
        self.launches.insert(id, info);
        self.prefetch_ahead(device, kind);
    }

    /// Ahead-of-flush prefetch staging (ISSUE 7): while this device is
    /// executing at least one combined batch, restage the
    /// highest-scoring soon-to-be-demanded evicted buffers of this kind
    /// into *free* slots, so the transfer overlaps compute instead of
    /// stalling the next flush. Free-slots-only (never evicts a resident
    /// buffer, scored or not), bounded per launch, and charged exactly
    /// like demand staging: pool `transfer_bytes` + `prefetch_bytes`,
    /// plus the owning job's byte counter (keys are job-namespaced).
    fn prefetch_ahead(&mut self, device: usize, kind: KernelKindId) {
        if self.cfg.residency != ResidencyPolicy::ReuseGraph
            || self.gpu.in_flight(device) == 0
        {
            return;
        }
        let Some(scorer) = self.devices[device].scorers[kind.0].as_ref()
        else {
            return;
        };
        let candidates =
            scorer.hot_candidates(PREFETCH_MAX, PREFETCH_HORIZON);
        if candidates.is_empty() {
            return;
        }
        let Some(table) = self.devices[device].tables[kind.0].as_mut()
        else {
            return;
        };
        for (key, predicted) in candidates {
            if !table.prefetchable(key) {
                continue;
            }
            let Some(bytes) = table.prefetch(key, predicted) else {
                break; // no free slot: later candidates cannot fit either
            };
            self.report.transfer_bytes += bytes;
            self.report.prefetch_bytes += bytes;
            if let Some(js) = self.router.shared.job(JobId(key_job(key))) {
                js.metrics
                    .transfer_bytes
                    .fetch_add(bytes, Ordering::SeqCst);
            }
        }
    }

    /// Scatter a completed launch's outputs back to the owning chares,
    /// splitting the shared launch's accounting back out per job.
    fn on_gpu_done(&mut self, completion: Result<Completion>) {
        let c = completion.expect("GPU launch failed");
        let info = self
            .launches
            .remove(&c.id)
            .expect("completion for unknown launch");
        let device = info.device;
        let kind = info.kind;
        debug_assert_eq!(c.device, device, "completion from wrong device");
        // `info._in_flight` drops at the end of this fn, releasing the
        // device's in-flight gauge.

        // Count by the *effective* mode: the engine may have demoted a
        // queued persistent batch (backend without a resident loop), and
        // the partition `persistent + per_batch == launches` is over what
        // was actually charged.
        let idle_penalty = if c.mode == LaunchMode::Persistent {
            self.report.persistent_batches += 1;
            self.report.kind_mut(kind.0).persistent_batches += 1;
            info.idle_penalty
        } else {
            self.report.per_batch_launches += 1;
            self.report.kind_mut(kind.0).per_batch_launches += 1;
            0.0
        };
        if info.mode == LaunchMode::Persistent {
            // Retire the ring descriptor even when the engine demoted the
            // batch — the queue tracked the submission either way.
            if let Some(q) = self.queues.get(&(device, kind.0)) {
                q.complete(c.id);
            }
        }

        self.report.launches += 1;
        self.report.gpu_requests += info.items.len() as u64;
        self.report.kernel_wall += c.wall;
        self.report.kernel_modeled += c.modeled.kernel + idle_penalty;
        self.report.transfer_modeled += c.modeled.transfer;
        self.report.transfer_bytes += info.transfer_bytes;
        self.router.shared.timeline.record(
            crate::util::timeline::SpanKind::Kernel,
            "combined-kernel",
            self.now() - c.wall,
            c.wall,
            c.modeled.kernel,
            info.items.len() as u64,
        );

        let slot_len = info.out_slot;
        let mut gpu_items = 0u64;
        // Per-job split of the launch: (job, requests, items, bytes),
        // first-seen order.
        let mut per_job: Vec<(JobId, u64, u64, u64)> = Vec::new();
        for (i, item) in info.items.iter().enumerate() {
            gpu_items += item.data_items as u64;
            match per_job.iter_mut().find(|(j, ..)| *j == item.job) {
                Some((_, reqs, items, bytes)) => {
                    *reqs += 1;
                    *items += item.data_items as u64;
                    *bytes += item.bytes;
                }
                None => per_job.push((
                    item.job,
                    1,
                    item.data_items as u64,
                    item.bytes,
                )),
            }
            let out = c.out[i * slot_len..(i + 1) * slot_len].to_vec();
            self.router.send_msg(
                item.job,
                item.chare,
                Msg::new(
                    METHOD_RESULT,
                    WrResult {
                        wr_id: item.wr_id,
                        tag: item.tag,
                        kind: item.kind,
                        out,
                    },
                ),
            );
            if let Some(buf) = item.buffer {
                // item.buffer is only retained when the request was staged
                // (slot.is_some()), which implies the family has a table;
                // stay graceful regardless.
                if let Some(table) =
                    self.devices[device].tables[kind.0].as_mut()
                {
                    table.release(buf);
                }
            }
        }
        let cross_job = per_job.len() >= 2;
        if cross_job {
            self.report.cross_job_launches += 1;
        }
        self.report.gpu_items += gpu_items;
        {
            let ks = self.report.kind_mut(kind.0);
            ks.launches += 1;
            ks.gpu_requests += info.items.len() as u64;
            ks.gpu_items += gpu_items;
        }
        {
            let dev = self.report.device_mut(device);
            dev.launches += 1;
            dev.requests += info.items.len() as u64;
            dev.items += gpu_items;
            dev.busy_wall += c.wall;
            dev.busy_modeled +=
                c.modeled.kernel + c.modeled.transfer + idle_penalty;
        }
        // Per-job accounting: live metrics, learned per-(job, kind)
        // heaviness, the combiners' fair-share weights, depths, and the
        // work-request holds.
        for &(job, reqs, items, bytes) in &per_job {
            self.dev_router.note_completed(device, job, reqs as usize);
            if let Some(js) = self.router.shared.job(job) {
                let m = &js.metrics;
                m.launches.fetch_add(1, Ordering::SeqCst);
                if cross_job {
                    m.cross_job_launches.fetch_add(1, Ordering::SeqCst);
                }
                m.gpu_requests.fetch_add(reqs, Ordering::SeqCst);
                m.gpu_items.fetch_add(items, Ordering::SeqCst);
                m.transfer_bytes.fetch_add(bytes, Ordering::SeqCst);
                m.queued.fetch_sub(reqs as i64, Ordering::SeqCst);
            }
            self.hybrid
                .record_job(job, kind, reqs as usize, items as usize);
            // Learned per-(job, kind) heaviness composed with the QoS
            // class multiplier: a latency-class tenant holds an enlarged
            // share of oversubscribed flushes, best-effort a reduced one.
            let w = self.hybrid.job_weight(job, kind) * self.qos_mult(job);
            for st in &mut self.devices {
                st.combiners[kind.0].set_job_weight(job, w);
            }
            // Release the work-request holds (global + per job).
            self.router.release(job, reqs as i64);
        }
        // Per-device rate (all kinds): the steal rebalancer's weights.
        self.hybrid.record_device(device, gpu_items as usize, c.wall);
        if self.kinds[kind.0].cpu_fallback {
            self.hybrid.record_gpu(kind, gpu_items as usize, c.wall);
        }
    }

    /// Scatter one CPU-pool chunk's results immediately (a slow sibling
    /// chunk must not delay finished work), and fold its timing into the
    /// batch accumulator; when the last chunk lands, record the batch
    /// makespan with the hybrid scheduler (total items over the longest
    /// chunk: the pool's true per-item rate).
    fn on_cpu_chunk(
        &mut self,
        batch: u64,
        items: usize,
        secs: f64,
        results: Vec<(JobId, ChareId, WrResult)>,
    ) {
        let acc = self
            .cpu_batches
            .get_mut(&batch)
            .expect("chunk for unknown cpu batch");
        acc.chunks_left -= 1;
        acc.items += items;
        acc.max_secs = acc.max_secs.max(secs);
        acc.sum_secs += secs;
        let kind = acc.kind;
        let batch_done = acc.chunks_left == 0;

        self.report.cpu_requests += results.len() as u64;
        self.report.kind_mut(kind.0).cpu_requests += results.len() as u64;
        for (job, chare, res) in results {
            self.router
                .send_msg(job, chare, Msg::new(METHOD_RESULT, res));
            if let Some(js) = self.router.shared.job(job) {
                js.metrics.cpu_requests.fetch_add(1, Ordering::SeqCst);
                js.metrics.queued.fetch_sub(1, Ordering::SeqCst);
            }
            // Release this result's work-request hold.
            self.router.release(job, 1);
        }
        // Release the chunk hold (global only).
        self.router
            .shared
            .outstanding
            .fetch_sub(1, Ordering::SeqCst);

        if batch_done {
            let acc = self.cpu_batches.remove(&batch).unwrap();
            self.hybrid.record_cpu(kind, acc.items, acc.max_secs);
            self.report.cpu_task_wall += acc.sum_secs;
        }
    }

    fn on_cpu_done(
        &mut self,
        items: usize,
        secs: f64,
        results: Vec<(JobId, ChareId, WrResult)>,
    ) {
        if let Some(kind) = results.first().map(|(_, _, r)| r.kind) {
            self.hybrid.record_cpu(kind, items, secs);
            self.report.kind_mut(kind.0).cpu_requests +=
                results.len() as u64;
        }
        self.report.cpu_task_wall += secs;
        self.report.cpu_requests += results.len() as u64;
        for (job, chare, res) in results {
            self.router
                .send_msg(job, chare, Msg::new(METHOD_RESULT, res));
            if let Some(js) = self.router.shared.job(job) {
                js.metrics.cpu_requests.fetch_add(1, Ordering::SeqCst);
                js.metrics.queued.fetch_sub(1, Ordering::SeqCst);
            }
            self.router.release(job, 1);
        }
        // Release the CpuDone hold (global only).
        self.router
            .shared
            .outstanding
            .fetch_sub(1, Ordering::SeqCst);
    }

    /// Invalidate one job's device-resident buffers (its iteration
    /// boundary). Co-tenant residency is untouched: keys are
    /// job-namespaced.
    fn on_invalidate_job(&mut self, job: JobId) {
        for st in &mut self.devices {
            for t in st.tables.iter_mut().flatten() {
                t.invalidate_where(|k| key_job(k) == job.0);
            }
            for s in st.scorers.iter_mut().flatten() {
                // Forecasts must not outlive the residency they score:
                // the job's buffers were just rewritten or dropped.
                s.forget_job(job.0);
            }
            st.node_table.invalidate_where(|k| key_job(k) == job.0);
        }
    }

    /// A job's report was sealed: drop its residency, routing affinity,
    /// rate models, and fair-share weights.
    fn on_job_ended(&mut self, job: JobId) {
        self.on_invalidate_job(job);
        self.dev_router.forget_job(job);
        self.hybrid.forget_job(job);
        self.job_qos.remove(&job.0);
        self.job_deadline.remove(&job.0);
        for st in &mut self.devices {
            for c in &mut st.combiners {
                c.clear_job_weight(job);
            }
        }
    }

    /// Exact wire size of the [`Frame::StealBatch`](crate::net::Frame)
    /// a shipment of these requests would encode to, mirroring the
    /// codec's arithmetic (pinned there by a property test). Drives the
    /// serialize+transfer cost gate and the modeled `remote_wire_secs`
    /// in the report without the coordinator ever serializing anything.
    fn ship_bytes(items: &[Pending]) -> u64 {
        let mut bytes = 17u64; // tag, shipment id, kind, count
        for p in items {
            bytes += 41; // wr_id, chare, tag, data_items, option tag, counts
            if p.wr.buffer.is_some() {
                bytes += 8;
            }
            bytes += 4 * p.wr.payload.entry_ids.len() as u64;
            for b in &p.wr.payload.bufs {
                bytes += 4 + 4 * b.len() as u64;
            }
        }
        bytes
    }

    /// A peer under its low watermark asked for work (cross-node batch
    /// steal). Give away the deepest pending combiner batch when (a)
    /// this node's own backlog is at or past the high watermark while
    /// the thief reports at most the low one — the same hysteresis pair
    /// the intra-node rebalancer uses, (b) our pipeline is actually
    /// executing (`in_flight_total > 0`; an idle pipeline means the
    /// backlog is about to dispatch locally and shipping it would only
    /// add wire time), and (c) the modeled serialize+transfer cost is
    /// beaten by the work's modeled execution time at `est_item_secs`
    /// per item. A decline reinserts the drained batch untouched, so a
    /// refused steal is invisible to every counter.
    ///
    /// On success the shipment's requests leave this node's queue
    /// accounting (`note_completed`) and release their staged slots —
    /// but their work-request *holds stay up*: quiescence must not
    /// drop while results are on the wire. The holds release in
    /// [`Coord::on_net_finish`] (results home) or survive a requeue
    /// ([`Coord::on_net_requeue`]) unchanged.
    fn on_net_drain(
        &mut self,
        peer_depth: usize,
        est_item_secs: f64,
        reply: Sender<Option<NetShipment>>,
    ) {
        let total: usize =
            (0..self.devices.len()).map(|d| self.dev_router.depth(d)).sum();
        if total < self.cfg.steal_high
            || peer_depth > self.cfg.steal_low
            || self.gpu.in_flight_total() == 0
        {
            let _ = reply.send(None);
            return;
        }
        // Victim: the deepest pending combiner across all devices.
        let mut best: Option<(usize, usize, usize)> = None;
        for (d, st) in self.devices.iter().enumerate() {
            if let Some(k) = Self::steal_kind(st) {
                let len = st.combiners[k].len();
                if best.is_none_or(|(_, _, b)| len > b) {
                    best = Some((d, k, len));
                }
            }
        }
        let Some((device, k, _)) = best else {
            let _ = reply.send(None);
            return;
        };
        let Some(batch) = self.devices[device].combiners[k].steal_flush()
        else {
            let _ = reply.send(None);
            return;
        };
        // QoS steal eligibility (ISSUE 10): latency-class work never
        // ships over the wire — a remote round trip adds wire latency
        // exactly where the deadline budget is tightest. Intra-node
        // steals (cheap migration between local devices) stay allowed.
        if batch.items.iter().any(|p| {
            self.job_qos.get(&p.wr.job.0)
                == Some(&crate::serve::QosClass::LatencySensitive)
        }) {
            let now = self.now();
            for p in batch.items {
                self.devices[device].combiners[k].insert(p, now);
            }
            let _ = reply.send(None);
            return;
        }
        let items: usize = batch.items.iter().map(|p| p.wr.data_items).sum();
        let bytes = Self::ship_bytes(&batch.items);
        let wire = crate::net::wire_secs(bytes);
        if wire >= items as f64 * est_item_secs {
            // Not worth the wire. Reinsert at the queue tail: the set of
            // pending requests is unchanged, only intra-kind order moved,
            // which perturbs batching but never results.
            let now = self.now();
            for p in batch.items {
                self.devices[device].combiners[k].insert(p, now);
            }
            let _ = reply.send(None);
            return;
        }
        let reuse_arg = self.kinds[k].kernel.reuse_arg;
        let mut reqs = Vec::with_capacity(batch.items.len());
        for p in batch.items {
            if p.slot.is_some() {
                if let (Some(_), Some(buf)) = (reuse_arg, p.wr.buffer) {
                    self.devices[device].tables[k]
                        .as_mut()
                        .expect("reuse family has a table")
                        .release(buf);
                }
            }
            self.dev_router.note_completed(device, p.wr.job, 1);
            if let Some(js) = self.router.shared.job(p.wr.job) {
                js.metrics.remote_requests.fetch_add(1, Ordering::SeqCst);
            }
            reqs.push(p.wr);
        }
        self.report.remote_steals_out += 1;
        self.report.remote_requests_out += reqs.len() as u64;
        self.report.remote_wire_secs += wire;
        let _ = reply.send(Some(NetShipment { kind: KernelKindId(k), reqs }));
    }

    /// Results of a remotely executed shipment returned home: scatter
    /// them to the owning chares exactly like a local completion and
    /// release the holds that kept quiescence up while the work was on
    /// the wire. The remote node's pool counted the execution itself
    /// (launches, items, transfer bytes, under its mule job); home
    /// counts only what it can see — the per-job `remote_requests`
    /// already recorded at drain time.
    fn on_net_finish(&mut self, results: Vec<(JobId, ChareId, WrResult)>) {
        for (job, chare, res) in results {
            self.router.send_msg(job, chare, Msg::new(METHOD_RESULT, res));
            if let Some(js) = self.router.shared.job(job) {
                js.metrics.queued.fetch_sub(1, Ordering::SeqCst);
            }
            self.router.release(job, 1);
        }
    }

    /// A shipment could not complete remotely — the thief vanished, is
    /// draining, or the ship timed out — so its requests come back to
    /// the local pending queues. Their holds never dropped, so
    /// quiescence was safe the whole time; staging restarts cold
    /// (`slot: None`) because the drain released the source pins.
    fn on_net_requeue(&mut self, kind: KernelKindId, reqs: Vec<WorkRequest>) {
        let now = self.now();
        self.report.remote_requeues += 1;
        self.report.remote_requeued_requests += reqs.len() as u64;
        for wr in reqs {
            let device = self.dev_router.route(wr.job, wr.chare);
            self.dev_router.note_enqueued(device, wr.job, 1);
            let pending = Pending { wr, slot: None, staged_bytes: 0 };
            self.devices[device].combiners[kind.0].insert(pending, now);
        }
        self.poll_combiners();
    }

    /// Fold one cluster-session accounting delta (thief-side steal
    /// counters, wire bytes) into the pool report.
    fn on_net_account(&mut self, d: NetAccountDelta) {
        self.report.remote_steals_in += d.remote_steals_in;
        self.report.remote_requests_in += d.remote_requests_in;
        self.report.remote_stale_batches += d.remote_stale_batches;
        self.report.remote_stale_results += d.remote_stale_results;
        self.report.wire_bytes_out += d.wire_bytes_out;
        self.report.wire_bytes_in += d.wire_bytes_in;
    }

    /// Apply one chaos-harness injection (test/chaos builds only; see
    /// [`scheduler::ChaosCmd`]). Kept beside the real handlers so the
    /// injections perturb exactly the state a hostile schedule would.
    #[cfg(any(test, feature = "chaos"))]
    fn on_chaos(&mut self, cmd: scheduler::ChaosCmd) {
        use scheduler::ChaosCmd;
        match cmd {
            ChaosCmd::SetWatermarks { low, high } => {
                self.dev_router.set_watermarks(low, high);
                // A storm must not wait for the next submission to bite.
                self.poll_combiners();
            }
            ChaosCmd::FlushJitter => {
                // One forced flush per combiner — deliberately NOT looped
                // to empty: capped-off leftovers must drain through the
                // regular poll path (the residual-debt contract of
                // `Combiner::take`, which this injection found broken).
                for d in 0..self.devices.len() {
                    for k in 0..self.devices[d].combiners.len() {
                        if let Some(b) =
                            self.devices[d].combiners[k].force_flush()
                        {
                            self.dispatch(b, KernelKindId(k), d);
                        }
                    }
                }
            }
            ChaosCmd::LaunchModeFlip { queue_cap } => {
                // Shrink (or grow) every persistent ring mid-flight and
                // flip the forced mode: first injection forces Persistent,
                // the next forces PerBatch (quiescing rings that still
                // hold descriptors), and so on. Exercises backpressure
                // fallback and the drain-under-mode-change path.
                self.queue_cap_override = Some(queue_cap);
                for q in self.queues.values() {
                    q.set_capacity(queue_cap);
                }
                self.chaos_forced_mode = Some(match self.chaos_forced_mode {
                    Some(m) => m.flipped(),
                    None => LaunchMode::Persistent,
                });
                self.poll_combiners();
            }
            ChaosCmd::AuditResidency(reply) => {
                let mut jobs: Vec<u64> = Vec::new();
                for st in &self.devices {
                    for t in st.tables.iter().flatten() {
                        jobs.extend(
                            t.resident_keys().into_iter().map(key_job),
                        );
                    }
                    jobs.extend(
                        st.node_table.resident_keys().into_iter().map(key_job),
                    );
                }
                jobs.sort_unstable();
                jobs.dedup();
                let _ = reply.send(jobs);
            }
        }
    }

    /// The pool-wide report with the residency and steal counters folded
    /// in (end-of-run sealing and live `Snapshot` replies share this).
    fn sealed_report(&self) -> PoolReport {
        let mut report = self.report.clone();
        report.steals = self.dev_router.steals();
        report.migrated_requests = self.dev_router.migrated_requests();
        report.table_hits = 0;
        report.table_misses = 0;
        report.saved_bytes = 0;
        report.prefetch_hits = 0;
        report.prefetch_wasted = 0;
        // Per-kind residency counters re-fold from the live tables each
        // time (Snapshot replies and the sealed report share this path).
        for ks in &mut report.kind_stats {
            ks.table_hits = 0;
            ks.table_misses = 0;
            ks.prefetch_hits = 0;
            ks.prefetch_wasted = 0;
        }
        for d in 0..self.devices.len() {
            let st = &self.devices[d];
            let mut hits = st.node_table.hits();
            let mut misses = st.node_table.misses();
            let mut saved = st.node_saved;
            for (k, t) in st.tables.iter().enumerate() {
                let Some(t) = t else { continue };
                hits += t.hits();
                misses += t.misses();
                saved += t.saved_bytes();
                // The node entry cache never prefetches, so the pool
                // prefetch totals are exactly the kind sums (the
                // consistency the chaos invariants check).
                report.prefetch_hits += t.prefetch_hits();
                report.prefetch_wasted += t.prefetch_wasted();
                let ks = report.kind_mut(k);
                ks.table_hits += t.hits();
                ks.table_misses += t.misses();
                ks.prefetch_hits += t.prefetch_hits();
                ks.prefetch_wasted += t.prefetch_wasted();
            }
            report.table_hits += hits;
            report.table_misses += misses;
            report.saved_bytes += saved;
            let dev = report.device_mut(d);
            dev.hits = hits;
            dev.misses = misses;
        }
        report
    }

    /// The coordinator event loop.
    pub(crate) fn run(mut self, rx: Receiver<CoordMsg>) -> PoolReport {
        loop {
            match rx.recv_timeout(self.cfg.tick) {
                Ok(CoordMsg::Submit { job, draft }) => {
                    self.on_submit(job, draft)
                }
                Ok(CoordMsg::GpuDone(c)) => {
                    self.on_gpu_done(c);
                    self.poll_combiners();
                }
                Ok(CoordMsg::CpuDone { items, secs, results }) => {
                    self.on_cpu_done(items, secs, results);
                    self.poll_combiners();
                }
                Ok(CoordMsg::CpuChunk { batch, items, secs, results }) => {
                    self.on_cpu_chunk(batch, items, secs, results);
                    self.poll_combiners();
                }
                Ok(CoordMsg::KindsAdded(descs)) => self.on_kinds_added(descs),
                Ok(CoordMsg::JobEnded(job)) => self.on_job_ended(job),
                Ok(CoordMsg::InvalidateJob(job)) => {
                    self.on_invalidate_job(job)
                }
                Ok(CoordMsg::InvalidateAll) => {
                    for st in &mut self.devices {
                        for t in st.tables.iter_mut().flatten() {
                            t.invalidate_all();
                        }
                        for s in st.scorers.iter_mut().flatten() {
                            *s = ReuseScorer::new();
                        }
                        st.node_table.invalidate_all();
                    }
                }
                Ok(CoordMsg::Snapshot(reply)) => {
                    let _ = reply.send(self.sealed_report());
                }
                Ok(CoordMsg::NetDrain { peer_depth, est_item_secs, reply }) => {
                    self.on_net_drain(peer_depth, est_item_secs, reply)
                }
                Ok(CoordMsg::NetFinish { results }) => {
                    self.on_net_finish(results);
                    self.poll_combiners();
                }
                Ok(CoordMsg::NetRequeue { kind, reqs }) => {
                    self.on_net_requeue(kind, reqs)
                }
                Ok(CoordMsg::NetDepth(reply)) => {
                    let d: u64 = (0..self.devices.len())
                        .map(|d| self.dev_router.depth(d) as u64)
                        .sum();
                    let _ = reply.send(d);
                }
                Ok(CoordMsg::NetAccount(d)) => self.on_net_account(d),
                Ok(CoordMsg::SetJobQos { job, class, deadline }) => {
                    self.on_set_job_qos(job, class, deadline)
                }
                Ok(CoordMsg::ServeAccount {
                    offered,
                    admitted,
                    rejected,
                    shed,
                }) => {
                    self.report.serve_offered += offered;
                    self.report.serve_admitted += admitted;
                    self.report.serve_rejected += rejected;
                    self.report.serve_shed += shed;
                }
                #[cfg(any(test, feature = "chaos"))]
                Ok(CoordMsg::Chaos(cmd)) => self.on_chaos(cmd),
                Ok(CoordMsg::Stop) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    self.poll_combiners();
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        self.drain_all();
        // Wait for in-flight launches and CPU-pool batches so their holds
        // are released and the final stats are complete.
        // (Completions still arrive on rx via the forwarder.)
        while !self.launches.is_empty() || !self.cpu_batches.is_empty() {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(CoordMsg::GpuDone(c)) => self.on_gpu_done(c),
                Ok(CoordMsg::CpuDone { items, secs, results }) => {
                    self.on_cpu_done(items, secs, results)
                }
                Ok(CoordMsg::CpuChunk { batch, items, secs, results }) => {
                    self.on_cpu_chunk(batch, items, secs, results)
                }
                // Late result deliveries must still release their holds;
                // a late depth probe must not wedge a cluster pump.
                Ok(CoordMsg::NetFinish { results }) => {
                    self.on_net_finish(results)
                }
                Ok(CoordMsg::NetDepth(reply)) => {
                    let _ = reply.send(0);
                }
                Ok(CoordMsg::NetAccount(d)) => self.on_net_account(d),
                // Late admission-ledger deltas must not be lost: the
                // ledger equality is an exact invariant.
                Ok(CoordMsg::ServeAccount {
                    offered,
                    admitted,
                    rejected,
                    shed,
                }) => {
                    self.report.serve_offered += offered;
                    self.report.serve_admitted += admitted;
                    self.report.serve_rejected += rejected;
                    self.report.serve_shed += shed;
                }
                Ok(_) => {}
                Err(_) => break,
            }
        }
        // Quiesce and close every persistent ring: all launches are
        // retired above, so the rings must already be empty — `quiesce`
        // is the proof (the chaos watchdog leans on this terminating).
        for q in self.queues.values() {
            q.close();
            debug_assert!(
                q.quiesce(Duration::from_secs(5)),
                "persistent ring drained at shutdown"
            );
        }
        self.sealed_report()
    }
}
