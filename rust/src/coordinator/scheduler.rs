//! Processing elements (PEs), the message router, and shared run state.
//!
//! Each PE is a worker thread owning a disjoint set of chares and draining
//! an MPSC queue -- the message-driven scheduler of section 2.1: dequeue a
//! message, invoke the target chare's entry method, dispatch the effects it
//! produced. PEs also execute the CPU side of hybrid scheduling
//! (`CpuBatch`): the native kernels from `cpu_kernels.rs`, timed per batch
//! so the coordinator can maintain the per-data-item running averages.
//!
//! Quiescence: every in-flight unit (queued message, pending work request,
//! CPU batch, coordinator message) holds +1 on `Shared::outstanding`;
//! handoffs increment the successor before decrementing, so the counter
//! only reaches 0 when the system is globally idle.

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::runtime::executor::ExecutorConfig;
use crate::util::timeline::Timeline;

use super::chare::{Chare, ChareId, Ctx, Effect, Msg, WorkDraft};
use super::combiner::Pending;
use super::work_request::WrResult;

/// Messages a PE thread consumes.
pub(crate) enum PeMsg {
    /// Deliver a message to a chare owned by this PE.
    Deliver { to: ChareId, msg: Msg },
    /// Execute a batch of work requests on the CPU (hybrid path).
    CpuBatch(Vec<Pending>),
    Stop,
}

/// Messages the coordinator thread consumes.
pub(crate) enum CoordMsg {
    /// A chare submitted a work request.
    Submit(WorkDraft),
    /// The GPU service finished a combined launch.
    GpuDone(anyhow::Result<crate::runtime::executor::Completion>),
    /// A PE finished a CPU batch: measured seconds, data items, results.
    CpuDone { items: usize, secs: f64, results: Vec<(ChareId, WrResult)> },
    /// A CPU-pool worker finished one chunk of hybrid batch `batch`; the
    /// coordinator folds the chunks back into one hybrid observation.
    CpuChunk {
        batch: u64,
        items: usize,
        secs: f64,
        results: Vec<(ChareId, WrResult)>,
    },
    /// Invalidate all device-resident buffers (iteration boundary).
    InvalidateAll,
    Stop,
}

/// Reduction accumulator (Charm++-style `contribute`).
#[derive(Debug, Default)]
pub(crate) struct ReductionState {
    pub count: u64,
    pub sum: f64,
}

/// State shared by every thread in a run.
pub struct Shared {
    /// In-flight unit count; 0 <=> quiescent.
    pub(crate) outstanding: AtomicI64,
    pub(crate) reduction: Mutex<ReductionState>,
    pub(crate) reduction_cv: Condvar,
    pub timeline: Timeline,
}

impl Shared {
    pub(crate) fn new() -> Arc<Shared> {
        Arc::new(Shared {
            outstanding: AtomicI64::new(0),
            reduction: Mutex::new(ReductionState::default()),
            reduction_cv: Condvar::new(),
            timeline: Timeline::new(),
        })
    }

    pub fn outstanding(&self) -> i64 {
        self.outstanding.load(Ordering::SeqCst)
    }
}

/// Routes messages and work requests between PEs and the coordinator.
#[derive(Clone)]
pub(crate) struct Router {
    pub pes: Vec<Sender<PeMsg>>,
    pub coord: Sender<CoordMsg>,
    pub placement: Arc<HashMap<ChareId, usize>>,
    pub shared: Arc<Shared>,
}

impl Router {
    /// Asynchronously invoke an entry method (+1 outstanding until the PE
    /// has processed it).
    pub fn send_msg(&self, to: ChareId, msg: Msg) {
        self.shared.outstanding.fetch_add(1, Ordering::SeqCst);
        let pe = *self
            .placement
            .get(&to)
            .unwrap_or_else(|| panic!("chare {to:?} is not registered"));
        self.pes[pe]
            .send(PeMsg::Deliver { to, msg })
            .expect("pe thread is down");
    }

    /// Submit a work request to the coordinator (+1 outstanding until its
    /// result message has been dispatched).
    pub fn submit(&self, draft: WorkDraft) {
        self.shared.outstanding.fetch_add(1, Ordering::SeqCst);
        self.coord
            .send(CoordMsg::Submit(draft))
            .expect("coordinator is down");
    }

    /// Contribute to the run's reduction.
    pub fn contribute(&self, value: f64) {
        let mut r = self.shared.reduction.lock().unwrap();
        r.count += 1;
        r.sum += value;
        self.shared.reduction_cv.notify_all();
    }

    /// Dispatch the effects an entry method produced.
    pub fn dispatch(&self, effects: Vec<Effect>) {
        for e in effects {
            match e {
                Effect::Send(to, msg) => self.send_msg(to, msg),
                Effect::Work(draft) => self.submit(draft),
                Effect::Contribute(v) => self.contribute(v),
            }
        }
    }
}

/// The PE worker loop. Owns this PE's chares for the lifetime of the run.
pub(crate) fn pe_loop(
    pe: usize,
    rx: Receiver<PeMsg>,
    mut chares: HashMap<ChareId, Box<dyn Chare>>,
    router: Router,
    exec_cfg: ExecutorConfig,
) {
    while let Ok(m) = rx.recv() {
        match m {
            PeMsg::Deliver { to, msg } => {
                let mut chare = chares
                    .remove(&to)
                    .unwrap_or_else(|| panic!("chare {to:?} not on pe {pe}"));
                let mut ctx = Ctx::new(pe);
                chare.receive(msg, &mut ctx);
                chares.insert(to, chare);
                router.dispatch(ctx.drain());
                router.shared.outstanding.fetch_sub(1, Ordering::SeqCst);
            }
            PeMsg::CpuBatch(batch) => {
                let t0 = Instant::now();
                let (items, results) =
                    super::cpu_pool::execute_pending(&batch, &exec_cfg);
                let secs = t0.elapsed().as_secs_f64();
                router.shared.timeline.record(
                    crate::util::timeline::SpanKind::CpuTask,
                    "cpu-batch",
                    router.shared.timeline.now() - secs,
                    secs,
                    0.0,
                    items as u64,
                );
                // CpuDone holds +1 until the coordinator processes it; the
                // work-request holds stay with the coordinator.
                router.shared.outstanding.fetch_add(1, Ordering::SeqCst);
                router
                    .coord
                    .send(CoordMsg::CpuDone { items, secs, results })
                    .expect("coordinator is down");
                router.shared.outstanding.fetch_sub(1, Ordering::SeqCst);
            }
            PeMsg::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    struct Echo {
        got: Vec<u32>,
        reply_to: Option<ChareId>,
    }

    impl Chare for Echo {
        fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
            self.got.push(msg.method);
            if let Some(to) = self.reply_to.take() {
                ctx.send(to, Msg::new(99, ()));
            }
            ctx.contribute(1.0);
        }
    }

    fn harness(
        nchares: u32,
    ) -> (Router, Receiver<CoordMsg>, Vec<Receiver<PeMsg>>) {
        let (coord_tx, coord_rx) = channel();
        let (pe_tx, pe_rx) = channel();
        let placement: HashMap<ChareId, usize> =
            (0..nchares).map(|i| (ChareId::new(0, i), 0)).collect();
        let router = Router {
            pes: vec![pe_tx],
            coord: coord_tx,
            placement: Arc::new(placement),
            shared: Shared::new(),
        };
        (router, coord_rx, vec![pe_rx])
    }

    #[test]
    fn send_msg_increments_outstanding() {
        let (router, _crx, _prx) = harness(1);
        router.send_msg(ChareId::new(0, 0), Msg::new(1, ()));
        assert_eq!(router.shared.outstanding(), 1);
    }

    #[test]
    fn pe_loop_processes_and_decrements() {
        let (router, _crx, mut prx) = harness(2);
        let rx = prx.pop().unwrap();
        let mut chares: HashMap<ChareId, Box<dyn Chare>> = HashMap::new();
        chares.insert(
            ChareId::new(0, 0),
            Box::new(Echo { got: vec![], reply_to: Some(ChareId::new(0, 1)) }),
        );
        chares.insert(
            ChareId::new(0, 1),
            Box::new(Echo { got: vec![], reply_to: None }),
        );

        router.send_msg(ChareId::new(0, 0), Msg::new(7, ()));
        router.pes[0].send(PeMsg::Stop).unwrap();
        // process: chare 0 replies to chare 1, but Stop is already queued,
        // so deliver the reply manually through another loop run
        let r2 = router.clone();
        pe_loop(0, rx, chares, r2, ExecutorConfig::default());
        // chare 0 processed (-1), its reply enqueued (+1): net 1
        assert_eq!(router.shared.outstanding(), 1);
        let red = router.shared.reduction.lock().unwrap();
        assert_eq!(red.count, 1);
    }

    #[test]
    fn contribute_accumulates() {
        let (router, _crx, _prx) = harness(1);
        router.contribute(2.0);
        router.contribute(3.0);
        let r = router.shared.reduction.lock().unwrap();
        assert_eq!(r.count, 2);
        assert_eq!(r.sum, 5.0);
    }

    #[test]
    fn cpu_batch_computes_and_reports() {
        use crate::coordinator::work_request::{
            WorkKind, WorkRequest, WrPayload,
        };
        let (router, crx, mut prx) = harness(1);
        let rx = prx.pop().unwrap();
        let batch = vec![Pending {
            wr: WorkRequest {
                id: 5,
                chare: ChareId::new(0, 0),
                kind: WorkKind::MdInteract,
                buffer: None,
                data_items: 2,
                tag: 0,
                arrival: 0.0,
                payload: WrPayload::MdPair {
                    pa: vec![0.0, 0.0],
                    pb: vec![0.1, 0.0],
                },
            },
            slot: None,
            staged_bytes: 0,
        }];
        router.pes[0].send(PeMsg::CpuBatch(batch)).unwrap();
        router.pes[0].send(PeMsg::Stop).unwrap();
        pe_loop(0, rx, HashMap::new(), router.clone(), ExecutorConfig::default());
        match crx.try_recv().unwrap() {
            CoordMsg::CpuDone { items, secs, results } => {
                assert_eq!(items, 2);
                assert!(secs >= 0.0);
                assert_eq!(results.len(), 1);
                assert_eq!(results[0].1.wr_id, 5);
                assert!(results[0].1.out[0] < 0.0); // repulsion in -x
            }
            _ => panic!("expected CpuDone"),
        }
        assert_eq!(router.shared.outstanding(), 0);
    }
}
