//! Processing elements (PEs), the message router, and shared run state.
//!
//! Each PE is a worker thread owning a disjoint set of chares and draining
//! an MPSC queue -- the message-driven scheduler of section 2.1: dequeue a
//! message, invoke the target chare's entry method, dispatch the effects it
//! produced. PEs also execute the CPU side of hybrid scheduling
//! (`CpuBatch`): the native kernels from `cpu_kernels.rs`, timed per batch
//! so the coordinator can maintain the per-data-item running averages.
//!
//! The runtime is multi-tenant: chares, messages, and work requests all
//! carry a [`JobId`], the placement map is keyed by `(JobId, ChareId)`,
//! and jobs join and leave a live PE set through `AddChares`/`RemoveJob`
//! messages. Quiescence and reductions are *per job* ([`JobState`]): every
//! in-flight unit holds +1 on the global counter **and** on its job's
//! counter; handoffs increment the successor before decrementing, so a
//! job's counter reaches 0 exactly when that job is idle, regardless of
//! what its co-tenants are doing.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Instant;

use crate::util::timeline::Timeline;

use super::chare::{Chare, ChareId, Ctx, Effect, JobId, Msg, WorkDraft};
use super::combiner::Pending;
use super::metrics::{JobMetricsSnapshot, PoolReport};
use super::registry::{KernelDescriptor, KernelKindId, SharedRegistry};
use super::work_request::{WorkRequest, WrResult};

/// Messages a PE thread consumes.
pub(crate) enum PeMsg {
    /// Deliver a message to a chare owned by this PE.
    Deliver { job: JobId, to: ChareId, msg: Msg },
    /// Execute a batch of work requests on the CPU (hybrid path).
    CpuBatch(Vec<Pending>),
    /// A new job placed these chares on this PE.
    AddChares { job: JobId, chares: Vec<(ChareId, Box<dyn Chare>)> },
    /// A job finished: drop its chares.
    RemoveJob(JobId),
    Stop,
}

/// Messages the coordinator thread consumes.
pub(crate) enum CoordMsg {
    /// A chare of `job` submitted a work request.
    Submit { job: JobId, draft: WorkDraft },
    /// The GPU service finished a combined launch.
    GpuDone(anyhow::Result<crate::runtime::executor::Completion>),
    /// A PE finished a CPU batch: measured seconds, data items, results.
    CpuDone {
        items: usize,
        secs: f64,
        results: Vec<(JobId, ChareId, WrResult)>,
    },
    /// A CPU-pool worker finished one chunk of hybrid batch `batch`; the
    /// coordinator folds the chunks back into one hybrid observation.
    CpuChunk {
        batch: u64,
        items: usize,
        secs: f64,
        results: Vec<(JobId, ChareId, WrResult)>,
    },
    /// The shared registry grew: extend the per-device combiner/table
    /// rows and teach the device pool the new families.
    KindsAdded(Vec<KernelDescriptor>),
    /// A job finished: drop its residency and rate models.
    JobEnded(JobId),
    /// Invalidate one job's device-resident buffers (its iteration
    /// boundary; co-tenant residency is untouched).
    InvalidateJob(JobId),
    /// Invalidate all device-resident buffers (runtime-wide reset).
    InvalidateAll,
    /// Reply with a live snapshot of the pool-wide report.
    Snapshot(Sender<PoolReport>),
    /// Cross-node steal, home side: drain one stealable batch for a
    /// remote peer, or reply `None` when the local backlog is below the
    /// high watermark or the wire cost model says the move loses. The
    /// drained requests *keep* their home-side quiescence holds — the
    /// home job stays non-quiescent until `NetFinish` settles the
    /// shipment (or `NetRequeue` bounces it).
    NetDrain {
        /// The thief's advertised pending depth (its last heartbeat).
        peer_depth: usize,
        /// Learned seconds-per-request of remote round trips, for the
        /// cost model (generous on first contact).
        est_item_secs: f64,
        reply: Sender<Option<NetShipment>>,
    },
    /// Results of a remotely executed shipment returned home: scatter
    /// each output to its owning chare and release the retained holds.
    NetFinish { results: Vec<(JobId, ChareId, WrResult)> },
    /// A peer vanished (or declined) while holding a shipment: re-inject
    /// the requests into the combiners, unstaged — dispatch restages
    /// them through the contiguous fallback, charging the full bytes a
    /// failed steal honestly costs.
    NetRequeue { kind: KernelKindId, reqs: Vec<WorkRequest> },
    /// Reply with this node's total pending depth (combiner queues plus
    /// in-flight), advertised to peers via heartbeats.
    NetDepth(Sender<u64>),
    /// Fold cluster-layer counters (thief-side executions, wire bytes,
    /// stale results) into the pool report.
    NetAccount(NetAccountDelta),
    /// The serve front end classified a job (ISSUE 10): QoS class plus,
    /// for latency-sensitive jobs, a deadline budget in timeline
    /// seconds. The coordinator folds the class into the weighted-fair
    /// combine quotas, gates cross-node steal eligibility on it, and
    /// arms the deadline flush trigger.
    SetJobQos {
        job: JobId,
        class: crate::serve::QosClass,
        deadline: Option<f64>,
    },
    /// Admission-ledger deltas from the serve front end (offered /
    /// admitted / rejected / shed), folded into the pool report so the
    /// ledger closes exactly in `PoolReport`.
    ServeAccount {
        offered: u64,
        admitted: u64,
        rejected: u64,
        shed: u64,
    },
    /// A chaos-harness injection (test/chaos builds only); the release
    /// hot path never constructs or matches this variant.
    #[cfg(any(test, feature = "chaos"))]
    Chaos(ChaosCmd),
    Stop,
}

/// Fault injections the chaos harness (`crate::chaos`) feeds a live
/// coordinator. Compiled only under `#[cfg(any(test, feature =
/// "chaos"))]`; each command perturbs scheduler state the way a hostile
/// schedule would, without any schedule-dependent sleeps.
#[cfg(any(test, feature = "chaos"))]
pub(crate) enum ChaosCmd {
    /// Overwrite the live router's steal watermarks. A huge `low` plus a
    /// tiny `high` makes every device pair a steal candidate (steal
    /// storm: back-to-back `steal_flush` migrations); restoring the
    /// configured values ends the storm.
    SetWatermarks { low: usize, high: usize },
    /// Force one (single-shot, NOT drained-to-empty) flush of every
    /// combiner on every device — flush-timing jitter. Capped flushes
    /// deliberately leave residuals behind to exercise the
    /// residual-drain path.
    FlushJitter,
    /// Reply with the job halves (`key >> 48`) of every buffer resident
    /// on any device, for the no-sealed-job-residency invariant. Queued
    /// after a job's `JobEnded`, the reply cannot race its teardown
    /// (one FIFO coordinator queue).
    AuditResidency(Sender<Vec<u64>>),
    /// Jitter every persistent work ring down (or up) to `queue_cap`
    /// slots and flip the forced launch mode (first injection forces
    /// `Persistent`, the next `PerBatch`, alternating) — exercises
    /// backpressure fallback, quiesce-while-nonempty, and the
    /// mode-partition accounting under mid-job mode changes.
    LaunchModeFlip { queue_cap: usize },
}

/// A batch drained from the combiners for remote execution
/// ([`CoordMsg::NetDrain`]). All requests share one kernel family (they
/// came from one combiner) but may span jobs — cross-job combining
/// survives the node boundary. Each request still holds +1 on its
/// job's quiescence counter; the cluster session settles the shipment
/// with `NetFinish` (results home) or `NetRequeue` (peer down).
#[derive(Debug)]
pub(crate) struct NetShipment {
    pub kind: KernelKindId,
    pub reqs: Vec<WorkRequest>,
}

/// Thief-side and wire-level counter deltas folded into the pool
/// report by [`CoordMsg::NetAccount`]. The home-side counters
/// (`remote_steals_out` etc.) are incremented directly by the drain /
/// requeue handlers; these are the halves only the cluster session
/// observes.
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct NetAccountDelta {
    /// Shipments this node executed for peers (counted when the
    /// results ship back, so a dying thief never counts one).
    pub remote_steals_in: u64,
    pub remote_requests_in: u64,
    /// Results that arrived for a shipment the home had already
    /// requeued (peer presumed dead, then spoke): dropped, counted.
    pub remote_stale_batches: u64,
    pub remote_stale_results: u64,
    pub wire_bytes_out: u64,
    pub wire_bytes_in: u64,
}

/// Chare -> device routing policy for the sharded GPU pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Static round-robin over devices per submitted request (the static
    /// baseline: ignores residency and load).
    RoundRobin,
    /// Rendezvous-hash-seeded chare affinity (maximizes per-device reuse
    /// hits) plus idle-steal rebalancing between the watermarks — the
    /// paper's section 3.3 idle-minimization re-instantiated at device
    /// granularity.
    AffinitySteal,
}

/// Routes work requests to pool devices and tracks per-device pending
/// depth for the idle-steal rebalancer. Multi-tenant: affinity is keyed
/// by `(job, chare)`, and per-job pending depth is tracked alongside the
/// per-device depths so the runtime can observe (and report) when one
/// job's backlog dominates the pool.
#[derive(Debug)]
pub struct DeviceRouter {
    policy: RoutePolicy,
    /// (job, chare) -> device affinity. Seeded by rendezvous hash on
    /// first sight; rewritten when a steal migrates the chare's pending
    /// work (reuse-driven: future requests follow the resident data).
    affinity: HashMap<(JobId, ChareId), usize>,
    rr: usize,
    /// Per-device pending depth: requests queued in combiners plus
    /// requests in flight on the device.
    depth: Vec<usize>,
    /// Per-job pending depth across all devices (the learned per-job
    /// load the fairness layer and live metrics read).
    job_depth: HashMap<u64, usize>,
    /// Steal when some device's depth is below `low` while another's is
    /// at or above `high`.
    low: usize,
    high: usize,
    steals: u64,
    migrated_requests: u64,
}

impl DeviceRouter {
    pub fn new(
        policy: RoutePolicy,
        devices: usize,
        low: usize,
        high: usize,
    ) -> DeviceRouter {
        DeviceRouter {
            policy,
            affinity: HashMap::new(),
            rr: 0,
            depth: vec![0; devices.max(1)],
            job_depth: HashMap::new(),
            low,
            high,
            steals: 0,
            migrated_requests: 0,
        }
    }

    pub fn devices(&self) -> usize {
        self.depth.len()
    }

    pub fn depth(&self, device: usize) -> usize {
        self.depth[device]
    }

    /// Pending depth of one job across the whole pool.
    pub fn job_depth(&self, job: JobId) -> usize {
        self.job_depth.get(&job.0).copied().unwrap_or(0)
    }

    pub fn steals(&self) -> u64 {
        self.steals
    }

    pub fn migrated_requests(&self) -> u64 {
        self.migrated_requests
    }

    /// Route one request to a device per the policy.
    pub fn route(&mut self, job: JobId, chare: ChareId) -> usize {
        let n = self.depth.len();
        if n == 1 {
            return 0;
        }
        match self.policy {
            RoutePolicy::RoundRobin => {
                let d = self.rr % n;
                self.rr += 1;
                d
            }
            RoutePolicy::AffinitySteal => *self
                .affinity
                .entry((job, chare))
                .or_insert_with(|| rendezvous_device(job, chare, n)),
        }
    }

    /// Re-home a chare after its pending batch migrated: subsequent
    /// requests follow the data to the new device.
    pub fn rehome(&mut self, job: JobId, chare: ChareId, device: usize) {
        if self.policy == RoutePolicy::AffinitySteal {
            self.affinity.insert((job, chare), device);
        }
    }

    /// Drop a finished job's affinity and depth records.
    pub fn forget_job(&mut self, job: JobId) {
        self.affinity.retain(|(j, _), _| *j != job);
        self.job_depth.remove(&job.0);
    }

    pub fn note_enqueued(&mut self, device: usize, job: JobId, n: usize) {
        self.depth[device] += n;
        *self.job_depth.entry(job.0).or_insert(0) += n;
    }

    pub fn note_completed(&mut self, device: usize, job: JobId, n: usize) {
        self.depth[device] = self.depth[device].saturating_sub(n);
        if let Some(d) = self.job_depth.get_mut(&job.0) {
            *d = d.saturating_sub(n);
        }
    }

    /// Account a stolen batch of `n` requests moving `from` -> `to`
    /// (device depths only; the requests stay pending for their jobs).
    pub fn note_stolen(&mut self, from: usize, to: usize, n: usize) {
        self.depth[from] = self.depth[from].saturating_sub(n);
        self.depth[to] += n;
        self.steals += 1;
        self.migrated_requests += n as u64;
    }

    /// Cheap allocation-free precondition for `steal_candidate`: is some
    /// device below the low watermark while another is at or above the
    /// high one? Callers use this to skip computing device shares on the
    /// per-request hot path when no steal is possible.
    pub fn watermarks_crossed(&self) -> bool {
        self.policy == RoutePolicy::AffinitySteal
            && self.depth.len() >= 2
            && self.depth.iter().any(|&d| d < self.low)
            && self.depth.iter().any(|&d| d >= self.high)
    }

    /// Chaos-harness override of the steal watermarks on a live router
    /// (see [`ChaosCmd::SetWatermarks`]). Test/chaos builds only.
    #[cfg(any(test, feature = "chaos"))]
    pub fn set_watermarks(&mut self, low: usize, high: usize) {
        self.low = low;
        self.high = high;
    }

    /// Steal decision: among the devices below the low watermark pick the
    /// idlest by share-weighted depth (`shares` are the hybrid
    /// scheduler's measured per-device speed shares — a fast idle device
    /// pulls first; uniform when unmeasured), among those at or above
    /// the high watermark pick the most loaded, and return `(from, to)`.
    /// Residency-blind: equivalent to `steal_candidate_with_cost` at
    /// zero restage cost everywhere.
    pub fn steal_candidate(&self, shares: &[f64]) -> Option<(usize, usize)> {
        self.steal_candidate_with_cost(shares, &[])
    }

    /// Reuse-aware steal decision: `restage[d]` is the number of
    /// device-resident buffers the stealable batch on device `d` would
    /// forfeit if migrated (`Combiner::resident_slots`, summed over the
    /// device's combiners). The victim is the eligible device with the
    /// greatest share-weighted depth *net of* that cost, so the
    /// rebalancer prefers migrating cold batches and a hot, fully
    /// resident backlog can lose the steal to a slightly shallower cold
    /// one — shrinking `migrated_bytes`. Watermark eligibility is
    /// unchanged: cost only reorders devices already at or above the
    /// high mark. A missing entry means zero cost (the residency-blind
    /// seed behavior).
    pub fn steal_candidate_with_cost(
        &self,
        shares: &[f64],
        restage: &[usize],
    ) -> Option<(usize, usize)> {
        let n = self.depth.len();
        if self.policy != RoutePolicy::AffinitySteal || n < 2 {
            return None;
        }
        let share = |d: usize| {
            shares.get(d).copied().unwrap_or(1.0 / n as f64).max(1e-9)
        };
        let weighted = |d: usize| self.depth[d] as f64 / share(d);
        let to = (0..n).filter(|&d| self.depth[d] < self.low).min_by(
            |&a, &b| weighted(a).partial_cmp(&weighted(b)).unwrap(),
        )?;
        // Net value of stealing from d: its weighted depth minus the
        // (equally weighted) requests whose residency the move forfeits.
        let value = |d: usize| {
            let cost = restage.get(d).copied().unwrap_or(0) as f64;
            (self.depth[d] as f64 - cost) / share(d)
        };
        let from = (0..n).filter(|&d| self.depth[d] >= self.high).max_by(
            |&a, &b| value(a).partial_cmp(&value(b)).unwrap(),
        )?;
        (from != to).then_some((from, to))
    }
}

/// Rendezvous (highest-random-weight) hash of a job-scoped chare over `n`
/// devices: stable per chare, uniform across chares, no coordination
/// needed. The job id participates so co-tenant jobs with identical chare
/// ids still spread independently.
fn rendezvous_device(job: JobId, chare: ChareId, n: usize) -> usize {
    let key = splitmix64(job.0)
        ^ (((chare.collection as u64) << 32) | chare.index as u64);
    (0..n)
        .max_by_key(|&d| splitmix64(key ^ (0x9e37_79b9_7f4a_7c15u64
            .wrapping_mul(d as u64 + 1))))
        .unwrap_or(0)
}

/// Rendezvous-hashed *home node* for a job-scoped chare over a cluster
/// of `nodes` — the same highest-random-weight construction as
/// [`rendezvous_device`], one level up. Placement is effectively
/// `(NodeId, JobId, ChareId)`: this picks the node coordinate (every
/// node computes the same answer with no coordination, which is what
/// lets SPMD job setup shard chares without a directory service), and
/// the home node's `DeviceRouter` picks the device coordinate. Domain-
/// separated from the device hash so a chare's node and device draws
/// are independent.
pub fn rendezvous_node(job: JobId, chare: ChareId, nodes: usize) -> usize {
    const NODE_SALT: u64 = 0x6e6f_6465_5f68_6f6d; // "node_hom"
    let key = splitmix64(job.0 ^ NODE_SALT)
        ^ (((chare.collection as u64) << 32) | chare.index as u64);
    (0..nodes)
        .max_by_key(|&d| splitmix64(key ^ (0x9e37_79b9_7f4a_7c15u64
            .wrapping_mul(d as u64 + 1))))
        .unwrap_or(0)
}

/// SplitMix64 finalizer: cheap, well-mixed 64-bit hash.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Reduction accumulator (Charm++-style `contribute`), per job.
#[derive(Debug, Default)]
pub(crate) struct ReductionState {
    pub count: u64,
    pub sum: f64,
}

/// Lifecycle status of a job on the runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    /// Submitted and executing (or draining).
    Running,
    /// Driver returned successfully; report available.
    Done,
    /// Driver returned an error.
    Failed,
    /// `JobHandle::cancel` was observed; the job drained and stopped.
    Cancelled,
}

/// Live counters of one job, updated lock-free by the coordinator as
/// launches and CPU batches complete. `JobHandle::metrics_snapshot` reads
/// them while the job runs; the final values seed the job's
/// [`crate::coordinator::JobReport`].
#[derive(Debug, Default)]
pub(crate) struct JobMetrics {
    pub launches: AtomicU64,
    pub cross_job_launches: AtomicU64,
    pub gpu_requests: AtomicU64,
    pub cpu_requests: AtomicU64,
    pub gpu_items: AtomicU64,
    pub cpu_items: AtomicU64,
    pub transfer_bytes: AtomicU64,
    /// Requests drained off this node for remote execution (cross-node
    /// steal; includes shipments later bounced back by a peer-down
    /// requeue — the drain happened either way).
    pub remote_requests: AtomicU64,
    /// Requests submitted but not yet completed (queue + in flight).
    pub queued: AtomicI64,
}

/// Per-job shared state: quiescence counter, reduction, cancellation,
/// and the live metrics. One `Arc` is held by the runtime's shared map
/// (while the job lives), one by the job's `JobHandle` (for
/// `metrics_snapshot`/`poll` after completion).
#[derive(Debug)]
pub struct JobState {
    job: JobId,
    pub(crate) outstanding: AtomicI64,
    pub(crate) reduction: Mutex<ReductionState>,
    pub(crate) reduction_cv: Condvar,
    pub(crate) cancelled: AtomicBool,
    status: AtomicU8,
    pub(crate) metrics: JobMetrics,
}

impl JobState {
    pub(crate) fn new(job: JobId) -> Arc<JobState> {
        Arc::new(JobState {
            job,
            outstanding: AtomicI64::new(0),
            reduction: Mutex::new(ReductionState::default()),
            reduction_cv: Condvar::new(),
            cancelled: AtomicBool::new(false),
            status: AtomicU8::new(0),
            metrics: JobMetrics::default(),
        })
    }

    pub fn job(&self) -> JobId {
        self.job
    }

    /// In-flight units (messages + work requests) of this job.
    pub fn outstanding(&self) -> i64 {
        self.outstanding.load(Ordering::SeqCst)
    }

    pub fn cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }

    /// Request cancellation: wakes a driver blocked in `await_reduction`.
    pub(crate) fn cancel(&self) {
        self.cancelled.store(true, Ordering::SeqCst);
        let _guard = self.reduction.lock().unwrap();
        self.reduction_cv.notify_all();
    }

    pub fn status(&self) -> JobStatus {
        match self.status.load(Ordering::SeqCst) {
            0 => JobStatus::Running,
            1 => JobStatus::Done,
            2 => JobStatus::Failed,
            _ => JobStatus::Cancelled,
        }
    }

    pub(crate) fn set_status(&self, status: JobStatus) {
        let v = match status {
            JobStatus::Running => 0,
            JobStatus::Done => 1,
            JobStatus::Failed => 2,
            JobStatus::Cancelled => 3,
        };
        self.status.store(v, Ordering::SeqCst);
    }

    /// Point-in-time copy of the live metrics.
    pub fn metrics_snapshot(&self) -> JobMetricsSnapshot {
        let m = &self.metrics;
        JobMetricsSnapshot {
            launches: m.launches.load(Ordering::SeqCst),
            cross_job_launches: m.cross_job_launches.load(Ordering::SeqCst),
            gpu_requests: m.gpu_requests.load(Ordering::SeqCst),
            cpu_requests: m.cpu_requests.load(Ordering::SeqCst),
            gpu_items: m.gpu_items.load(Ordering::SeqCst),
            cpu_items: m.cpu_items.load(Ordering::SeqCst),
            transfer_bytes: m.transfer_bytes.load(Ordering::SeqCst),
            remote_requests: m.remote_requests.load(Ordering::SeqCst),
            queued_requests: m.queued.load(Ordering::SeqCst).max(0),
            outstanding: self.outstanding(),
        }
    }
}

/// State shared by every thread of a runtime: the global in-flight
/// counter, the live-job table, and the timeline.
pub struct Shared {
    /// In-flight unit count across all jobs; 0 <=> globally quiescent.
    pub(crate) outstanding: AtomicI64,
    /// Live jobs by id. Entries are removed when a job's report is
    /// sealed; its `JobHandle` keeps its own `Arc<JobState>`.
    jobs: RwLock<HashMap<u64, Arc<JobState>>>,
    pub timeline: Timeline,
}

impl Shared {
    pub(crate) fn new() -> Arc<Shared> {
        Arc::new(Shared {
            outstanding: AtomicI64::new(0),
            jobs: RwLock::new(HashMap::new()),
            timeline: Timeline::new(),
        })
    }

    pub fn outstanding(&self) -> i64 {
        self.outstanding.load(Ordering::SeqCst)
    }

    pub(crate) fn add_job(&self, job: JobId) -> Arc<JobState> {
        let state = JobState::new(job);
        self.jobs
            .write()
            .expect("job table poisoned")
            .insert(job.0, state.clone());
        state
    }

    pub(crate) fn job(&self, job: JobId) -> Option<Arc<JobState>> {
        self.jobs
            .read()
            .expect("job table poisoned")
            .get(&job.0)
            .cloned()
    }

    pub(crate) fn remove_job(&self, job: JobId) {
        self.jobs
            .write()
            .expect("job table poisoned")
            .remove(&job.0);
    }

    /// Ids of the jobs currently live on the runtime.
    pub fn live_jobs(&self) -> Vec<JobId> {
        let mut out: Vec<JobId> = self
            .jobs
            .read()
            .expect("job table poisoned")
            .keys()
            .map(|&j| JobId(j))
            .collect();
        out.sort();
        out
    }
}

/// Routes messages and work requests between PEs and the coordinator.
/// Every route carries the owning job: placement is `(job, chare)`-keyed
/// and both the global and the job's quiescence counters are maintained.
#[derive(Clone)]
pub(crate) struct Router {
    pub pes: Vec<Sender<PeMsg>>,
    pub coord: Sender<CoordMsg>,
    /// (job, chare) -> PE. Written at job submission/teardown, read on
    /// every send.
    pub placement: Arc<RwLock<HashMap<(JobId, ChareId), usize>>>,
    pub shared: Arc<Shared>,
    /// The append-only kernel registry: entry-method contexts validate
    /// submissions against it, and the PE/pool CPU paths execute through
    /// its slot functions.
    pub registry: Arc<SharedRegistry>,
}

impl Router {
    /// Asynchronously invoke an entry method (+1 outstanding, global and
    /// job, until the PE has processed it).
    pub fn send_msg(&self, job: JobId, to: ChareId, msg: Msg) {
        self.hold(job, 1);
        let pe = *self
            .placement
            .read()
            .expect("placement poisoned")
            .get(&(job, to))
            .unwrap_or_else(|| {
                panic!("chare {to:?} of {job} is not registered")
            });
        self.pes[pe]
            .send(PeMsg::Deliver { job, to, msg })
            .expect("pe thread is down");
    }

    /// Best-effort delivery for cross-node senders: like `send_msg`,
    /// but a chare that is no longer placed (its job sealed between
    /// the frame leaving the wire and arriving here) drops the message
    /// and reports `false` instead of panicking — a remote peer cannot
    /// check placement first the way a local caller can.
    pub fn try_send_msg(&self, job: JobId, to: ChareId, msg: Msg) -> bool {
        let pe = match self
            .placement
            .read()
            .expect("placement poisoned")
            .get(&(job, to))
        {
            Some(&pe) => pe,
            None => return false,
        };
        self.hold(job, 1);
        self.pes[pe]
            .send(PeMsg::Deliver { job, to, msg })
            .expect("pe thread is down");
        true
    }

    /// Submit a work request to the coordinator (+1 outstanding until its
    /// result message has been dispatched).
    pub fn submit(&self, job: JobId, draft: WorkDraft) {
        self.hold(job, 1);
        self.coord
            .send(CoordMsg::Submit { job, draft })
            .expect("coordinator is down");
    }

    /// Take `n` in-flight holds for `job` (global + per-job).
    pub fn hold(&self, job: JobId, n: i64) {
        self.shared.outstanding.fetch_add(n, Ordering::SeqCst);
        if let Some(js) = self.shared.job(job) {
            js.outstanding.fetch_add(n, Ordering::SeqCst);
        }
    }

    /// Release `n` in-flight holds for `job` (global + per-job).
    pub fn release(&self, job: JobId, n: i64) {
        self.shared.outstanding.fetch_sub(n, Ordering::SeqCst);
        if let Some(js) = self.shared.job(job) {
            js.outstanding.fetch_sub(n, Ordering::SeqCst);
        }
    }

    /// Contribute to `job`'s reduction.
    pub fn contribute(&self, job: JobId, value: f64) {
        let Some(js) = self.shared.job(job) else {
            return; // job already sealed: late contribution is dropped
        };
        let mut r = js.reduction.lock().unwrap();
        r.count += 1;
        r.sum += value;
        js.reduction_cv.notify_all();
    }

    /// Dispatch the effects an entry method of `job` produced.
    pub fn dispatch(&self, job: JobId, effects: Vec<Effect>) {
        for e in effects {
            match e {
                Effect::Send(to, msg) => self.send_msg(job, to, msg),
                Effect::Work(draft) => self.submit(job, draft),
                Effect::Contribute(v) => self.contribute(job, v),
            }
        }
    }
}

/// The PE worker loop. Chares arrive with their jobs (`AddChares`) and
/// leave when the job ends (`RemoveJob`); the loop itself lives for the
/// whole runtime.
pub(crate) fn pe_loop(pe: usize, rx: Receiver<PeMsg>, router: Router) {
    let mut chares: HashMap<(JobId, ChareId), Box<dyn Chare>> =
        HashMap::new();
    while let Ok(m) = rx.recv() {
        match m {
            PeMsg::AddChares { job, chares: added } => {
                for (id, chare) in added {
                    let prev = chares.insert((job, id), chare);
                    assert!(
                        prev.is_none(),
                        "chare {id:?} of {job} already on pe {pe}"
                    );
                }
            }
            PeMsg::RemoveJob(job) => {
                chares.retain(|(j, _), _| *j != job);
            }
            PeMsg::Deliver { job, to, msg } => {
                let mut chare =
                    chares.remove(&(job, to)).unwrap_or_else(|| {
                        panic!("chare {to:?} of {job} not on pe {pe}")
                    });
                let mut ctx = Ctx::new(pe, job, router.registry.clone());
                chare.receive(msg, &mut ctx);
                chares.insert((job, to), chare);
                router.dispatch(job, ctx.drain());
                router.release(job, 1);
            }
            PeMsg::CpuBatch(batch) => {
                let t0 = Instant::now();
                let (items, results) =
                    super::cpu_pool::execute_pending(&router.registry, &batch);
                let secs = t0.elapsed().as_secs_f64();
                router.shared.timeline.record(
                    crate::util::timeline::SpanKind::CpuTask,
                    "cpu-batch",
                    router.shared.timeline.now() - secs,
                    secs,
                    0.0,
                    items as u64,
                );
                // CpuDone holds +1 (global) until the coordinator
                // processes it; the work-request holds stay with the
                // coordinator.
                router
                    .shared
                    .outstanding
                    .fetch_add(1, Ordering::SeqCst);
                router
                    .coord
                    .send(CoordMsg::CpuDone { items, secs, results })
                    .expect("coordinator is down");
                router
                    .shared
                    .outstanding
                    .fetch_sub(1, Ordering::SeqCst);
            }
            PeMsg::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    const JOB: JobId = JobId(0);

    struct Echo {
        got: Vec<u32>,
        reply_to: Option<ChareId>,
    }

    impl Chare for Echo {
        fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
            self.got.push(msg.method);
            if let Some(to) = self.reply_to.take() {
                ctx.send(to, Msg::new(99, ()));
            }
            ctx.contribute(1.0);
        }
    }

    fn harness(
        nchares: u32,
    ) -> (Router, Receiver<CoordMsg>, Vec<Receiver<PeMsg>>, Arc<JobState>)
    {
        let (coord_tx, coord_rx) = channel();
        let (pe_tx, pe_rx) = channel();
        let placement: HashMap<(JobId, ChareId), usize> = (0..nchares)
            .map(|i| ((JOB, ChareId::new(0, i)), 0))
            .collect();
        let registry = SharedRegistry::new();
        registry
            .register(crate::coordinator::registry::md_descriptor([
                1.0, 0.04, 1.0,
            ]))
            .unwrap();
        let shared = Shared::new();
        let state = shared.add_job(JOB);
        let router = Router {
            pes: vec![pe_tx],
            coord: coord_tx,
            placement: Arc::new(RwLock::new(placement)),
            shared,
            registry: Arc::new(registry),
        };
        (router, coord_rx, vec![pe_rx], state)
    }

    #[test]
    fn send_msg_increments_outstanding_globally_and_per_job() {
        let (router, _crx, _prx, state) = harness(1);
        router.send_msg(JOB, ChareId::new(0, 0), Msg::new(1, ()));
        assert_eq!(router.shared.outstanding(), 1);
        assert_eq!(state.outstanding(), 1);
    }

    #[test]
    fn pe_loop_processes_and_decrements() {
        let (router, _crx, mut prx, state) = harness(2);
        let rx = prx.pop().unwrap();
        router.pes[0]
            .send(PeMsg::AddChares {
                job: JOB,
                chares: vec![
                    (
                        ChareId::new(0, 0),
                        Box::new(Echo {
                            got: vec![],
                            reply_to: Some(ChareId::new(0, 1)),
                        }) as Box<dyn Chare>,
                    ),
                    (
                        ChareId::new(0, 1),
                        Box::new(Echo { got: vec![], reply_to: None }),
                    ),
                ],
            })
            .unwrap();

        router.send_msg(JOB, ChareId::new(0, 0), Msg::new(7, ()));
        router.pes[0].send(PeMsg::Stop).unwrap();
        // process: chare 0 replies to chare 1, but Stop is already queued,
        // so deliver the reply manually through another loop run
        let r2 = router.clone();
        pe_loop(0, rx, r2);
        // chare 0 processed (-1), its reply enqueued (+1): net 1
        assert_eq!(router.shared.outstanding(), 1);
        assert_eq!(state.outstanding(), 1);
        let red = state.reduction.lock().unwrap();
        assert_eq!(red.count, 1);
    }

    #[test]
    fn contribute_accumulates_per_job() {
        let (router, _crx, _prx, state) = harness(1);
        router.contribute(JOB, 2.0);
        router.contribute(JOB, 3.0);
        // a contribution to an unknown job is dropped, not a panic
        router.contribute(JobId(99), 5.0);
        let r = state.reduction.lock().unwrap();
        assert_eq!(r.count, 2);
        assert_eq!(r.sum, 5.0);
    }

    #[test]
    fn remove_job_drops_chares() {
        let (router, _crx, mut prx, _state) = harness(1);
        let rx = prx.pop().unwrap();
        router.pes[0]
            .send(PeMsg::AddChares {
                job: JOB,
                chares: vec![(
                    ChareId::new(0, 0),
                    Box::new(Echo { got: vec![], reply_to: None })
                        as Box<dyn Chare>,
                )],
            })
            .unwrap();
        router.pes[0].send(PeMsg::RemoveJob(JOB)).unwrap();
        router.pes[0].send(PeMsg::Stop).unwrap();
        // would panic on Deliver-after-Remove; plain drain must not
        pe_loop(0, rx, router.clone());
    }

    #[test]
    fn job_state_cancel_and_status() {
        let state = JobState::new(JobId(3));
        assert_eq!(state.status(), JobStatus::Running);
        assert!(!state.cancelled());
        state.cancel();
        assert!(state.cancelled());
        state.set_status(JobStatus::Cancelled);
        assert_eq!(state.status(), JobStatus::Cancelled);
        let snap = state.metrics_snapshot();
        assert_eq!(snap.launches, 0);
        assert_eq!(snap.outstanding, 0);
    }

    #[test]
    fn shared_job_table_add_lookup_remove() {
        let shared = Shared::new();
        let a = shared.add_job(JobId(1));
        shared.add_job(JobId(2));
        assert_eq!(shared.live_jobs(), vec![JobId(1), JobId(2)]);
        assert!(Arc::ptr_eq(&shared.job(JobId(1)).unwrap(), &a));
        shared.remove_job(JobId(1));
        assert!(shared.job(JobId(1)).is_none());
        assert_eq!(shared.live_jobs(), vec![JobId(2)]);
    }

    #[test]
    fn router_single_device_always_zero() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 1, 1, 4);
        for i in 0..10 {
            assert_eq!(r.route(JOB, ChareId::new(0, i)), 0);
        }
        let mut rr = DeviceRouter::new(RoutePolicy::RoundRobin, 1, 1, 4);
        assert_eq!(rr.route(JOB, ChareId::new(0, 0)), 0);
    }

    #[test]
    fn round_robin_cycles_devices() {
        let mut r = DeviceRouter::new(RoutePolicy::RoundRobin, 3, 1, 4);
        let seq: Vec<usize> =
            (0..6).map(|i| r.route(JOB, ChareId::new(0, i))).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn affinity_is_stable_and_spreads() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 4, 1, 4);
        let mut seen = std::collections::HashSet::new();
        for i in 0..64 {
            let c = ChareId::new(1, i);
            let d = r.route(JOB, c);
            assert!(d < 4);
            assert_eq!(r.route(JOB, c), d, "affinity must be stable");
            seen.insert(d);
        }
        assert!(
            seen.len() >= 3,
            "rendezvous hash must spread 64 chares over the devices, got {seen:?}"
        );
    }

    #[test]
    fn rendezvous_node_is_stable_spreads_and_differs_from_device_hash() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..64 {
            let c = ChareId::new(0, i);
            let n = rendezvous_node(JOB, c, 4);
            assert!(n < 4);
            assert_eq!(rendezvous_node(JOB, c, 4), n, "home must be stable");
            seen.insert(n);
        }
        assert!(seen.len() >= 3, "64 chares must spread over 4 nodes: {seen:?}");
        assert_eq!(rendezvous_node(JOB, ChareId::new(0, 0), 1), 0);
        // domain separation: the node draw is not just the device draw
        let differs = (0..64).any(|i| {
            let c = ChareId::new(0, i);
            rendezvous_node(JOB, c, 4) != rendezvous_device(JOB, c, 4)
        });
        assert!(differs, "node and device hashes must be independent");
    }

    #[test]
    fn cotenant_jobs_spread_independently() {
        // identical chare ids under different jobs must not all land on
        // the same device
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 4, 1, 4);
        let mut differs = false;
        for i in 0..32 {
            let c = ChareId::new(0, i);
            if r.route(JobId(1), c) != r.route(JobId(2), c) {
                differs = true;
            }
        }
        assert!(differs, "job id must participate in placement");
    }

    #[test]
    fn rehome_redirects_future_requests() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 4, 1, 4);
        let c = ChareId::new(0, 9);
        let d0 = r.route(JOB, c);
        let d1 = (d0 + 1) % 4;
        r.rehome(JOB, c, d1);
        assert_eq!(r.route(JOB, c), d1);
    }

    #[test]
    fn job_depths_track_enqueue_and_completion() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 2, 2, 6);
        r.note_enqueued(0, JobId(1), 5);
        r.note_enqueued(1, JobId(2), 2);
        assert_eq!(r.job_depth(JobId(1)), 5);
        assert_eq!(r.job_depth(JobId(2)), 2);
        r.note_completed(0, JobId(1), 3);
        assert_eq!(r.job_depth(JobId(1)), 2);
        r.forget_job(JobId(1));
        assert_eq!(r.job_depth(JobId(1)), 0);
    }

    #[test]
    fn steal_candidate_respects_watermarks() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 2, 2, 6);
        let shares = vec![0.5, 0.5];
        assert!(r.steal_candidate(&shares).is_none(), "both idle: no steal");
        r.note_enqueued(0, JOB, 6);
        assert_eq!(
            r.steal_candidate(&shares),
            Some((0, 1)),
            "0 loaded, 1 idle"
        );
        // destination fills past the low watermark: no steal
        r.note_enqueued(1, JOB, 2);
        assert!(r.steal_candidate(&shares).is_none());
        // completions drain the destination below the watermark again
        r.note_completed(1, JOB, 1);
        assert_eq!(r.steal_candidate(&shares), Some((0, 1)));
        // accounting moves depth with the stolen batch
        r.note_stolen(0, 1, 4);
        assert_eq!(r.depth(0), 2);
        assert_eq!(r.depth(1), 5);
        assert_eq!(r.steals(), 1);
        assert_eq!(r.migrated_requests(), 4);
        assert!(r.steal_candidate(&shares).is_none());
    }

    #[test]
    fn round_robin_never_steals() {
        let mut r = DeviceRouter::new(RoutePolicy::RoundRobin, 2, 2, 4);
        r.note_enqueued(0, JOB, 100);
        assert!(!r.watermarks_crossed());
        assert!(r.steal_candidate(&[0.5, 0.5]).is_none());
    }

    #[test]
    fn watermarks_crossed_tracks_candidate_existence() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 2, 2, 6);
        assert!(!r.watermarks_crossed(), "both idle");
        r.note_enqueued(0, JOB, 6);
        assert!(r.watermarks_crossed());
        r.note_enqueued(1, JOB, 2);
        assert!(!r.watermarks_crossed(), "no device below the low mark");
    }

    #[test]
    fn weighted_steal_prefers_fast_idle_device() {
        // devices 0 and 1 both idle (depth 1 < low), device 2 loaded;
        // device 1 is much faster (share 0.8), so equal raw depth weighs
        // lighter on it and it pulls the stolen batch first
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 3, 2, 8);
        r.note_enqueued(0, JOB, 1);
        r.note_enqueued(1, JOB, 1);
        r.note_enqueued(2, JOB, 10);
        let got = r.steal_candidate(&[0.1, 0.8, 0.1]);
        assert_eq!(got, Some((2, 1)));
    }

    #[test]
    fn watermark_eligibility_overrides_weighting() {
        // share-weighting must only rank *eligible* devices: device 1 has
        // the lightest weighted depth but is not below the low mark, so
        // the truly idle device 0 is the destination
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 3, 4, 16);
        r.note_enqueued(0, JOB, 2);
        r.note_enqueued(1, JOB, 6);
        r.note_enqueued(2, JOB, 30);
        let got = r.steal_candidate(&[0.05, 0.9, 0.05]);
        assert_eq!(got, Some((2, 0)));
    }

    #[test]
    fn restage_cost_redirects_steal_to_cold_victim() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 3, 2, 6);
        r.note_enqueued(0, JOB, 1); // idle destination
        r.note_enqueued(1, JOB, 8);
        r.note_enqueued(2, JOB, 7);
        let shares = vec![1.0 / 3.0; 3];
        // residency-blind: the deepest device is the victim
        assert_eq!(r.steal_candidate(&shares), Some((1, 0)));
        // device 1's batch is fully resident, device 2's is cold: the
        // cold batch wins the steal despite its shallower backlog
        assert_eq!(
            r.steal_candidate_with_cost(&shares, &[0, 8, 0]),
            Some((2, 0))
        );
        // empty cost slice reproduces the blind decision
        assert_eq!(r.steal_candidate_with_cost(&shares, &[]), Some((1, 0)));
    }

    #[test]
    fn restage_cost_does_not_change_eligibility() {
        // a huge cost on the only device above the high watermark cannot
        // promote a below-mark device into the victim set
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 2, 2, 6);
        r.note_enqueued(0, JOB, 6);
        assert_eq!(
            r.steal_candidate_with_cost(&[0.5, 0.5], &[100, 0]),
            Some((0, 1))
        );
    }

    #[test]
    fn cpu_batch_computes_and_reports() {
        use crate::coordinator::registry::KernelKindId;
        use crate::coordinator::work_request::{Tile, WorkRequest};
        let (router, crx, mut prx, _state) = harness(1);
        let rx = prx.pop().unwrap();
        let batch = vec![Pending {
            wr: WorkRequest {
                id: 5,
                job: JOB,
                chare: ChareId::new(0, 0),
                kind: KernelKindId(0),
                buffer: None,
                data_items: 2,
                tag: 0,
                arrival: 0.0,
                payload: Tile::new(vec![
                    vec![0.0, 0.0],
                    vec![0.1, 0.0],
                ]),
            },
            slot: None,
            staged_bytes: 0,
        }];
        router.pes[0].send(PeMsg::CpuBatch(batch)).unwrap();
        router.pes[0].send(PeMsg::Stop).unwrap();
        pe_loop(0, rx, router.clone());
        match crx.try_recv().unwrap() {
            CoordMsg::CpuDone { items, secs, results } => {
                assert_eq!(items, 2);
                assert!(secs >= 0.0);
                assert_eq!(results.len(), 1);
                assert_eq!(results[0].0, JOB, "result carries its job");
                assert_eq!(results[0].2.wr_id, 5);
                assert!(results[0].2.out[0] < 0.0); // repulsion in -x
            }
            _ => panic!("expected CpuDone"),
        }
        assert_eq!(router.shared.outstanding(), 0);
    }
}
