//! Processing elements (PEs), the message router, and shared run state.
//!
//! Each PE is a worker thread owning a disjoint set of chares and draining
//! an MPSC queue -- the message-driven scheduler of section 2.1: dequeue a
//! message, invoke the target chare's entry method, dispatch the effects it
//! produced. PEs also execute the CPU side of hybrid scheduling
//! (`CpuBatch`): the native kernels from `cpu_kernels.rs`, timed per batch
//! so the coordinator can maintain the per-data-item running averages.
//!
//! Quiescence: every in-flight unit (queued message, pending work request,
//! CPU batch, coordinator message) holds +1 on `Shared::outstanding`;
//! handoffs increment the successor before decrementing, so the counter
//! only reaches 0 when the system is globally idle.

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::util::timeline::Timeline;

use super::chare::{Chare, ChareId, Ctx, Effect, Msg, WorkDraft};
use super::combiner::Pending;
use super::registry::KernelRegistry;
use super::work_request::WrResult;

/// Messages a PE thread consumes.
pub(crate) enum PeMsg {
    /// Deliver a message to a chare owned by this PE.
    Deliver { to: ChareId, msg: Msg },
    /// Execute a batch of work requests on the CPU (hybrid path).
    CpuBatch(Vec<Pending>),
    Stop,
}

/// Messages the coordinator thread consumes.
pub(crate) enum CoordMsg {
    /// A chare submitted a work request.
    Submit(WorkDraft),
    /// The GPU service finished a combined launch.
    GpuDone(anyhow::Result<crate::runtime::executor::Completion>),
    /// A PE finished a CPU batch: measured seconds, data items, results.
    CpuDone { items: usize, secs: f64, results: Vec<(ChareId, WrResult)> },
    /// A CPU-pool worker finished one chunk of hybrid batch `batch`; the
    /// coordinator folds the chunks back into one hybrid observation.
    CpuChunk {
        batch: u64,
        items: usize,
        secs: f64,
        results: Vec<(ChareId, WrResult)>,
    },
    /// Invalidate all device-resident buffers (iteration boundary).
    InvalidateAll,
    Stop,
}

/// Chare -> device routing policy for the sharded GPU pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Static round-robin over devices per submitted request (the static
    /// baseline: ignores residency and load).
    RoundRobin,
    /// Rendezvous-hash-seeded chare affinity (maximizes per-device reuse
    /// hits) plus idle-steal rebalancing between the watermarks — the
    /// paper's section 3.3 idle-minimization re-instantiated at device
    /// granularity.
    AffinitySteal,
}

/// Routes work requests to pool devices and tracks per-device pending
/// depth for the idle-steal rebalancer.
#[derive(Debug)]
pub struct DeviceRouter {
    policy: RoutePolicy,
    /// Chare -> device affinity. Seeded by rendezvous hash on first
    /// sight; rewritten when a steal migrates the chare's pending work
    /// (reuse-driven: future requests follow the chare's resident data).
    affinity: HashMap<ChareId, usize>,
    rr: usize,
    /// Per-device pending depth: requests queued in combiners plus
    /// requests in flight on the device.
    depth: Vec<usize>,
    /// Steal when some device's depth is below `low` while another's is
    /// at or above `high`.
    low: usize,
    high: usize,
    steals: u64,
    migrated_requests: u64,
}

impl DeviceRouter {
    pub fn new(
        policy: RoutePolicy,
        devices: usize,
        low: usize,
        high: usize,
    ) -> DeviceRouter {
        DeviceRouter {
            policy,
            affinity: HashMap::new(),
            rr: 0,
            depth: vec![0; devices.max(1)],
            low,
            high,
            steals: 0,
            migrated_requests: 0,
        }
    }

    pub fn devices(&self) -> usize {
        self.depth.len()
    }

    pub fn depth(&self, device: usize) -> usize {
        self.depth[device]
    }

    pub fn steals(&self) -> u64 {
        self.steals
    }

    pub fn migrated_requests(&self) -> u64 {
        self.migrated_requests
    }

    /// Route one request to a device per the policy.
    pub fn route(&mut self, chare: ChareId) -> usize {
        let n = self.depth.len();
        if n == 1 {
            return 0;
        }
        match self.policy {
            RoutePolicy::RoundRobin => {
                let d = self.rr % n;
                self.rr += 1;
                d
            }
            RoutePolicy::AffinitySteal => *self
                .affinity
                .entry(chare)
                .or_insert_with(|| rendezvous_device(chare, n)),
        }
    }

    /// Re-home a chare after its pending batch migrated: subsequent
    /// requests follow the data to the new device.
    pub fn rehome(&mut self, chare: ChareId, device: usize) {
        if self.policy == RoutePolicy::AffinitySteal {
            self.affinity.insert(chare, device);
        }
    }

    pub fn note_enqueued(&mut self, device: usize, n: usize) {
        self.depth[device] += n;
    }

    pub fn note_completed(&mut self, device: usize, n: usize) {
        self.depth[device] = self.depth[device].saturating_sub(n);
    }

    /// Account a stolen batch of `n` requests moving `from` -> `to`.
    pub fn note_stolen(&mut self, from: usize, to: usize, n: usize) {
        self.depth[from] = self.depth[from].saturating_sub(n);
        self.depth[to] += n;
        self.steals += 1;
        self.migrated_requests += n as u64;
    }

    /// Cheap allocation-free precondition for `steal_candidate`: is some
    /// device below the low watermark while another is at or above the
    /// high one? Callers use this to skip computing device shares on the
    /// per-request hot path when no steal is possible.
    pub fn watermarks_crossed(&self) -> bool {
        self.policy == RoutePolicy::AffinitySteal
            && self.depth.len() >= 2
            && self.depth.iter().any(|&d| d < self.low)
            && self.depth.iter().any(|&d| d >= self.high)
    }

    /// Steal decision: among the devices below the low watermark pick the
    /// idlest by share-weighted depth (`shares` are the hybrid
    /// scheduler's measured per-device speed shares — a fast idle device
    /// pulls first; uniform when unmeasured), among those at or above
    /// the high watermark pick the most loaded, and return `(from, to)`.
    pub fn steal_candidate(&self, shares: &[f64]) -> Option<(usize, usize)> {
        let n = self.depth.len();
        if self.policy != RoutePolicy::AffinitySteal || n < 2 {
            return None;
        }
        let weighted = |d: usize| {
            let s = shares.get(d).copied().unwrap_or(1.0 / n as f64);
            self.depth[d] as f64 / s.max(1e-9)
        };
        let to = (0..n).filter(|&d| self.depth[d] < self.low).min_by(
            |&a, &b| weighted(a).partial_cmp(&weighted(b)).unwrap(),
        )?;
        let from = (0..n).filter(|&d| self.depth[d] >= self.high).max_by(
            |&a, &b| weighted(a).partial_cmp(&weighted(b)).unwrap(),
        )?;
        (from != to).then_some((from, to))
    }
}

/// Rendezvous (highest-random-weight) hash of a chare over `n` devices:
/// stable per chare, uniform across chares, no coordination needed.
fn rendezvous_device(chare: ChareId, n: usize) -> usize {
    let key = ((chare.collection as u64) << 32) | chare.index as u64;
    (0..n)
        .max_by_key(|&d| splitmix64(key ^ (0x9e37_79b9_7f4a_7c15u64
            .wrapping_mul(d as u64 + 1))))
        .unwrap_or(0)
}

/// SplitMix64 finalizer: cheap, well-mixed 64-bit hash.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Reduction accumulator (Charm++-style `contribute`).
#[derive(Debug, Default)]
pub(crate) struct ReductionState {
    pub count: u64,
    pub sum: f64,
}

/// State shared by every thread in a run.
pub struct Shared {
    /// In-flight unit count; 0 <=> quiescent.
    pub(crate) outstanding: AtomicI64,
    pub(crate) reduction: Mutex<ReductionState>,
    pub(crate) reduction_cv: Condvar,
    pub timeline: Timeline,
}

impl Shared {
    pub(crate) fn new() -> Arc<Shared> {
        Arc::new(Shared {
            outstanding: AtomicI64::new(0),
            reduction: Mutex::new(ReductionState::default()),
            reduction_cv: Condvar::new(),
            timeline: Timeline::new(),
        })
    }

    pub fn outstanding(&self) -> i64 {
        self.outstanding.load(Ordering::SeqCst)
    }
}

/// Routes messages and work requests between PEs and the coordinator.
#[derive(Clone)]
pub(crate) struct Router {
    pub pes: Vec<Sender<PeMsg>>,
    pub coord: Sender<CoordMsg>,
    pub placement: Arc<HashMap<ChareId, usize>>,
    pub shared: Arc<Shared>,
    /// The frozen kernel registry: entry-method contexts validate
    /// submissions against it, and the PE CpuBatch path executes through
    /// its slot functions.
    pub registry: Arc<KernelRegistry>,
}

impl Router {
    /// Asynchronously invoke an entry method (+1 outstanding until the PE
    /// has processed it).
    pub fn send_msg(&self, to: ChareId, msg: Msg) {
        self.shared.outstanding.fetch_add(1, Ordering::SeqCst);
        let pe = *self
            .placement
            .get(&to)
            .unwrap_or_else(|| panic!("chare {to:?} is not registered"));
        self.pes[pe]
            .send(PeMsg::Deliver { to, msg })
            .expect("pe thread is down");
    }

    /// Submit a work request to the coordinator (+1 outstanding until its
    /// result message has been dispatched).
    pub fn submit(&self, draft: WorkDraft) {
        self.shared.outstanding.fetch_add(1, Ordering::SeqCst);
        self.coord
            .send(CoordMsg::Submit(draft))
            .expect("coordinator is down");
    }

    /// Contribute to the run's reduction.
    pub fn contribute(&self, value: f64) {
        let mut r = self.shared.reduction.lock().unwrap();
        r.count += 1;
        r.sum += value;
        self.shared.reduction_cv.notify_all();
    }

    /// Dispatch the effects an entry method produced.
    pub fn dispatch(&self, effects: Vec<Effect>) {
        for e in effects {
            match e {
                Effect::Send(to, msg) => self.send_msg(to, msg),
                Effect::Work(draft) => self.submit(draft),
                Effect::Contribute(v) => self.contribute(v),
            }
        }
    }
}

/// The PE worker loop. Owns this PE's chares for the lifetime of the run.
pub(crate) fn pe_loop(
    pe: usize,
    rx: Receiver<PeMsg>,
    mut chares: HashMap<ChareId, Box<dyn Chare>>,
    router: Router,
) {
    while let Ok(m) = rx.recv() {
        match m {
            PeMsg::Deliver { to, msg } => {
                let mut chare = chares
                    .remove(&to)
                    .unwrap_or_else(|| panic!("chare {to:?} not on pe {pe}"));
                let mut ctx = Ctx::new(pe, router.registry.clone());
                chare.receive(msg, &mut ctx);
                chares.insert(to, chare);
                router.dispatch(ctx.drain());
                router.shared.outstanding.fetch_sub(1, Ordering::SeqCst);
            }
            PeMsg::CpuBatch(batch) => {
                let t0 = Instant::now();
                let (items, results) =
                    super::cpu_pool::execute_pending(&router.registry, &batch);
                let secs = t0.elapsed().as_secs_f64();
                router.shared.timeline.record(
                    crate::util::timeline::SpanKind::CpuTask,
                    "cpu-batch",
                    router.shared.timeline.now() - secs,
                    secs,
                    0.0,
                    items as u64,
                );
                // CpuDone holds +1 until the coordinator processes it; the
                // work-request holds stay with the coordinator.
                router.shared.outstanding.fetch_add(1, Ordering::SeqCst);
                router
                    .coord
                    .send(CoordMsg::CpuDone { items, secs, results })
                    .expect("coordinator is down");
                router.shared.outstanding.fetch_sub(1, Ordering::SeqCst);
            }
            PeMsg::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    struct Echo {
        got: Vec<u32>,
        reply_to: Option<ChareId>,
    }

    impl Chare for Echo {
        fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
            self.got.push(msg.method);
            if let Some(to) = self.reply_to.take() {
                ctx.send(to, Msg::new(99, ()));
            }
            ctx.contribute(1.0);
        }
    }

    fn harness(
        nchares: u32,
    ) -> (Router, Receiver<CoordMsg>, Vec<Receiver<PeMsg>>) {
        let (coord_tx, coord_rx) = channel();
        let (pe_tx, pe_rx) = channel();
        let placement: HashMap<ChareId, usize> =
            (0..nchares).map(|i| (ChareId::new(0, i), 0)).collect();
        let mut registry = KernelRegistry::new();
        registry
            .register(crate::coordinator::registry::md_descriptor([
                1.0, 0.04, 1.0,
            ]))
            .unwrap();
        let router = Router {
            pes: vec![pe_tx],
            coord: coord_tx,
            placement: Arc::new(placement),
            shared: Shared::new(),
            registry: Arc::new(registry),
        };
        (router, coord_rx, vec![pe_rx])
    }

    #[test]
    fn send_msg_increments_outstanding() {
        let (router, _crx, _prx) = harness(1);
        router.send_msg(ChareId::new(0, 0), Msg::new(1, ()));
        assert_eq!(router.shared.outstanding(), 1);
    }

    #[test]
    fn pe_loop_processes_and_decrements() {
        let (router, _crx, mut prx) = harness(2);
        let rx = prx.pop().unwrap();
        let mut chares: HashMap<ChareId, Box<dyn Chare>> = HashMap::new();
        chares.insert(
            ChareId::new(0, 0),
            Box::new(Echo { got: vec![], reply_to: Some(ChareId::new(0, 1)) }),
        );
        chares.insert(
            ChareId::new(0, 1),
            Box::new(Echo { got: vec![], reply_to: None }),
        );

        router.send_msg(ChareId::new(0, 0), Msg::new(7, ()));
        router.pes[0].send(PeMsg::Stop).unwrap();
        // process: chare 0 replies to chare 1, but Stop is already queued,
        // so deliver the reply manually through another loop run
        let r2 = router.clone();
        pe_loop(0, rx, chares, r2);
        // chare 0 processed (-1), its reply enqueued (+1): net 1
        assert_eq!(router.shared.outstanding(), 1);
        let red = router.shared.reduction.lock().unwrap();
        assert_eq!(red.count, 1);
    }

    #[test]
    fn contribute_accumulates() {
        let (router, _crx, _prx) = harness(1);
        router.contribute(2.0);
        router.contribute(3.0);
        let r = router.shared.reduction.lock().unwrap();
        assert_eq!(r.count, 2);
        assert_eq!(r.sum, 5.0);
    }

    #[test]
    fn router_single_device_always_zero() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 1, 1, 4);
        for i in 0..10 {
            assert_eq!(r.route(ChareId::new(0, i)), 0);
        }
        let mut rr = DeviceRouter::new(RoutePolicy::RoundRobin, 1, 1, 4);
        assert_eq!(rr.route(ChareId::new(0, 0)), 0);
    }

    #[test]
    fn round_robin_cycles_devices() {
        let mut r = DeviceRouter::new(RoutePolicy::RoundRobin, 3, 1, 4);
        let seq: Vec<usize> =
            (0..6).map(|i| r.route(ChareId::new(0, i))).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn affinity_is_stable_and_spreads() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 4, 1, 4);
        let mut seen = std::collections::HashSet::new();
        for i in 0..64 {
            let c = ChareId::new(1, i);
            let d = r.route(c);
            assert!(d < 4);
            assert_eq!(r.route(c), d, "affinity must be stable");
            seen.insert(d);
        }
        assert!(
            seen.len() >= 3,
            "rendezvous hash must spread 64 chares over the devices, got {seen:?}"
        );
    }

    #[test]
    fn rehome_redirects_future_requests() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 4, 1, 4);
        let c = ChareId::new(0, 9);
        let d0 = r.route(c);
        let d1 = (d0 + 1) % 4;
        r.rehome(c, d1);
        assert_eq!(r.route(c), d1);
    }

    #[test]
    fn steal_candidate_respects_watermarks() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 2, 2, 6);
        let shares = vec![0.5, 0.5];
        assert!(r.steal_candidate(&shares).is_none(), "both idle: no steal");
        r.note_enqueued(0, 6);
        assert_eq!(
            r.steal_candidate(&shares),
            Some((0, 1)),
            "0 loaded, 1 idle"
        );
        // destination fills past the low watermark: no steal
        r.note_enqueued(1, 2);
        assert!(r.steal_candidate(&shares).is_none());
        // completions drain the destination below the watermark again
        r.note_completed(1, 1);
        assert_eq!(r.steal_candidate(&shares), Some((0, 1)));
        // accounting moves depth with the stolen batch
        r.note_stolen(0, 1, 4);
        assert_eq!(r.depth(0), 2);
        assert_eq!(r.depth(1), 5);
        assert_eq!(r.steals(), 1);
        assert_eq!(r.migrated_requests(), 4);
        assert!(r.steal_candidate(&shares).is_none());
    }

    #[test]
    fn round_robin_never_steals() {
        let mut r = DeviceRouter::new(RoutePolicy::RoundRobin, 2, 2, 4);
        r.note_enqueued(0, 100);
        assert!(!r.watermarks_crossed());
        assert!(r.steal_candidate(&[0.5, 0.5]).is_none());
    }

    #[test]
    fn watermarks_crossed_tracks_candidate_existence() {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 2, 2, 6);
        assert!(!r.watermarks_crossed(), "both idle");
        r.note_enqueued(0, 6);
        assert!(r.watermarks_crossed());
        r.note_enqueued(1, 2);
        assert!(!r.watermarks_crossed(), "no device below the low mark");
    }

    #[test]
    fn weighted_steal_prefers_fast_idle_device() {
        // devices 0 and 1 both idle (depth 1 < low), device 2 loaded;
        // device 1 is much faster (share 0.8), so equal raw depth weighs
        // lighter on it and it pulls the stolen batch first
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 3, 2, 8);
        r.note_enqueued(0, 1);
        r.note_enqueued(1, 1);
        r.note_enqueued(2, 10);
        let got = r.steal_candidate(&[0.1, 0.8, 0.1]);
        assert_eq!(got, Some((2, 1)));
    }

    #[test]
    fn watermark_eligibility_overrides_weighting() {
        // share-weighting must only rank *eligible* devices: device 1 has
        // the lightest weighted depth but is not below the low mark, so
        // the truly idle device 0 is the destination
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 3, 4, 16);
        r.note_enqueued(0, 2);
        r.note_enqueued(1, 6);
        r.note_enqueued(2, 30);
        let got = r.steal_candidate(&[0.05, 0.9, 0.05]);
        assert_eq!(got, Some((2, 0)));
    }

    #[test]
    fn cpu_batch_computes_and_reports() {
        use crate::coordinator::registry::KernelKindId;
        use crate::coordinator::work_request::{Tile, WorkRequest};
        let (router, crx, mut prx) = harness(1);
        let rx = prx.pop().unwrap();
        let batch = vec![Pending {
            wr: WorkRequest {
                id: 5,
                chare: ChareId::new(0, 0),
                kind: KernelKindId(0),
                buffer: None,
                data_items: 2,
                tag: 0,
                arrival: 0.0,
                payload: Tile::new(vec![
                    vec![0.0, 0.0],
                    vec![0.1, 0.0],
                ]),
            },
            slot: None,
            staged_bytes: 0,
        }];
        router.pes[0].send(PeMsg::CpuBatch(batch)).unwrap();
        router.pes[0].send(PeMsg::Stop).unwrap();
        pe_loop(0, rx, HashMap::new(), router.clone());
        match crx.try_recv().unwrap() {
            CoordMsg::CpuDone { items, secs, results } => {
                assert_eq!(items, 2);
                assert!(secs >= 0.0);
                assert_eq!(results.len(), 1);
                assert_eq!(results[0].1.wr_id, 5);
                assert!(results[0].1.out[0] < 0.0); // repulsion in -x
            }
            _ => panic!("expected CpuDone"),
        }
        assert_eq!(router.shared.outstanding(), 0);
    }
}
