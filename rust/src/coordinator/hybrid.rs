//! Dynamic hybrid CPU/GPU scheduling (paper section 3.3).
//!
//! For registered kernel families with both CPU and GPU kernels
//! (`KernelDescriptor::cpu_fallback`), the runtime executes initial tasks
//! on both devices, maintains *running averages of the time per input
//! data item* on each — per family, so an MD pair item and a sparse-row
//! item never pollute each other's model — and splits the work-request
//! queue by the resulting performance ratio: the queue is scanned front to
//! back, accumulating data items, and cut where the cumulative sum crosses
//! the CPU's share. The static baseline splits by request *count* only,
//! ignoring per-request workloads.

use std::collections::HashMap;

use crate::util::RunningAverage;

use super::chare::JobId;
use super::combiner::Pending;
use super::registry::KernelKindId;

/// Queue-splitting policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitPolicy {
    /// Split by request count only (regular-application baseline).
    StaticCount,
    /// Split by cumulative data items using the measured per-item
    /// performance ratio (section 3.3).
    AdaptiveItems,
}

/// Per-kind, per-device running averages and the splitting logic.
///
/// Two observation streams fold into this scheduler: the per-family
/// CPU/GPU split rates (`record_cpu` / `record_gpu`, hybrid-eligible
/// kinds only) and the per-GPU-device rates (`record_device`, every
/// completed launch on every device). The second stream is what the
/// sharded pool's steal rebalancer weighs pending depths by, so the
/// hybrid split and the device shares come from the same measurements.
#[derive(Debug)]
pub struct HybridScheduler {
    policy: SplitPolicy,
    /// Per-kind CPU seconds-per-item averages.
    cpu_per_item: Vec<RunningAverage>,
    /// Per-kind GPU seconds-per-item averages.
    gpu_per_item: Vec<RunningAverage>,
    /// Per-GPU-device seconds-per-item averages (all kernel kinds).
    device_per_item: Vec<RunningAverage>,
    /// Per-(job, kind) mean data items per request: the measured
    /// "heaviness" of one job's requests within a family. Feeds the
    /// combiners' weighted-fair share on a multi-tenant runtime, so a
    /// job submitting oversized requests is throttled to an items-fair
    /// slice of shared launches instead of a requests-fair one.
    job_items_per_req: HashMap<(u64, usize), RunningAverage>,
    /// Bootstrap split until both devices have at least one sample.
    bootstrap_cpu_share: f64,
}

impl HybridScheduler {
    /// Single-kind, single-device scheduler (unit tests / simple setups).
    pub fn new(policy: SplitPolicy) -> HybridScheduler {
        HybridScheduler::with_kinds(policy, 1, 1)
    }

    /// Scheduler over `kinds` registered families and `devices` GPU
    /// devices (both clamped to >= 1).
    pub fn with_kinds(
        policy: SplitPolicy,
        kinds: usize,
        devices: usize,
    ) -> HybridScheduler {
        HybridScheduler {
            policy,
            cpu_per_item: vec![RunningAverage::new(); kinds.max(1)],
            gpu_per_item: vec![RunningAverage::new(); kinds.max(1)],
            device_per_item: vec![RunningAverage::new(); devices.max(1)],
            job_items_per_req: HashMap::new(),
            bootstrap_cpu_share: 0.5,
        }
    }

    /// Grow the per-kind models to at least `kinds` entries (the shared
    /// registry is append-only: jobs may bring new families to a live
    /// runtime).
    pub fn ensure_kinds(&mut self, kinds: usize) {
        while self.cpu_per_item.len() < kinds {
            self.cpu_per_item.push(RunningAverage::new());
            self.gpu_per_item.push(RunningAverage::new());
        }
    }

    pub fn policy(&self) -> SplitPolicy {
        self.policy
    }

    pub fn devices(&self) -> usize {
        self.device_per_item.len()
    }

    /// Registered kinds this scheduler models.
    pub fn kinds(&self) -> usize {
        self.cpu_per_item.len()
    }

    /// Record a CPU execution of one family: `items` data items in `secs`
    /// seconds.
    ///
    /// The coordinator folds a worker-pool batch into a single
    /// observation -- total items over the batch *makespan* (longest
    /// chunk) -- so with W concurrent workers the learned per-item rate
    /// reflects the pool's true throughput, not a single worker's.
    pub fn record_cpu(&mut self, kind: KernelKindId, items: usize, secs: f64) {
        if items > 0 {
            if let Some(avg) = self.cpu_per_item.get_mut(kind.0) {
                avg.update(secs / items as f64);
            }
        }
    }

    /// Record a GPU execution of one family (kernel time for the combined
    /// batch).
    pub fn record_gpu(&mut self, kind: KernelKindId, items: usize, secs: f64) {
        if items > 0 {
            if let Some(avg) = self.gpu_per_item.get_mut(kind.0) {
                avg.update(secs / items as f64);
            }
        }
    }

    /// Record a completed launch on one GPU device (any kernel kind).
    /// Feeds the per-device rate the steal rebalancer weighs by; does not
    /// touch the CPU/GPU split averages.
    pub fn record_device(&mut self, device: usize, items: usize, secs: f64) {
        if items > 0 {
            if let Some(avg) = self.device_per_item.get_mut(device) {
                avg.update(secs / items as f64);
            }
        }
    }

    /// Measured seconds-per-item on one device, if observed.
    pub fn device_rate(&self, device: usize) -> Option<f64> {
        self.device_per_item.get(device).and_then(|a| a.mean())
    }

    /// Record one job's slice of a completed batch of one family:
    /// `requests` work requests carrying `items` data items. Maintains
    /// the per-(job, kind) items-per-request running average behind
    /// [`HybridScheduler::job_weight`].
    pub fn record_job(
        &mut self,
        job: JobId,
        kind: KernelKindId,
        requests: usize,
        items: usize,
    ) {
        if requests > 0 {
            self.job_items_per_req
                .entry((job.0, kind.0))
                .or_default()
                .update(items as f64 / requests as f64);
        }
    }

    /// Measured mean data items per request for one (job, kind), if
    /// observed.
    pub fn job_rate(&self, job: JobId, kind: KernelKindId) -> Option<f64> {
        self.job_items_per_req
            .get(&(job.0, kind.0))
            .and_then(|a| a.mean())
    }

    /// Weighted-fair combine weight of one job within one family:
    /// inverse measured heaviness, normalized by the family's mean across
    /// jobs, so equal weights share launch *items* rather than request
    /// slots and one heavy job cannot starve its co-tenants. 1.0 until
    /// the job (or the family) has observations.
    pub fn job_weight(&self, job: JobId, kind: KernelKindId) -> f64 {
        let Some(mine) = self.job_rate(job, kind) else {
            return 1.0;
        };
        let rates: Vec<f64> = self
            .job_items_per_req
            .iter()
            .filter(|((_, k), _)| *k == kind.0)
            .filter_map(|(_, a)| a.mean())
            .collect();
        if rates.is_empty() || mine <= 0.0 {
            return 1.0;
        }
        let mean = rates.iter().sum::<f64>() / rates.len() as f64;
        (mean / mine).clamp(0.05, 20.0)
    }

    /// Drop a finished job's rate models.
    pub fn forget_job(&mut self, job: JobId) {
        self.job_items_per_req.retain(|&(j, _), _| j != job.0);
    }

    /// Per-device work shares from the measured rates: share_d is
    /// proportional to 1/rate_d. Devices without samples yet assume the
    /// mean measured rate (uniform shares before any observation), so the
    /// shares always sum to 1 and never zero out an unmeasured device.
    pub fn device_shares(&self) -> Vec<f64> {
        let n = self.device_per_item.len();
        let rates: Vec<Option<f64>> = self
            .device_per_item
            .iter()
            .map(|a| a.mean().filter(|&m| m > 0.0))
            .collect();
        let measured: Vec<f64> = rates.iter().filter_map(|r| *r).collect();
        if measured.is_empty() {
            return vec![1.0 / n as f64; n];
        }
        let fallback = measured.iter().sum::<f64>() / measured.len() as f64;
        let speeds: Vec<f64> =
            rates.iter().map(|r| 1.0 / r.unwrap_or(fallback)).collect();
        let total: f64 = speeds.iter().sum();
        speeds.iter().map(|s| s / total).collect()
    }

    /// CPU time-per-item / GPU time-per-item for one family, once both
    /// are measured.
    pub fn perf_ratio(&self, kind: KernelKindId) -> Option<f64> {
        let c = self.cpu_per_item.get(kind.0).and_then(|a| a.mean());
        let g = self.gpu_per_item.get(kind.0).and_then(|a| a.mean());
        match (c, g) {
            (Some(c), Some(g)) if g > 0.0 => Some(c / g),
            _ => None,
        }
    }

    /// Fraction of one family's work the CPU should take:
    /// share = (1/c)/(1/c+1/g) = g / (c + g). Falls back to the bootstrap
    /// share before both devices have samples (paper: run initial tasks on
    /// both).
    pub fn cpu_share(&self, kind: KernelKindId) -> f64 {
        let c = self.cpu_per_item.get(kind.0).and_then(|a| a.mean());
        let g = self.gpu_per_item.get(kind.0).and_then(|a| a.mean());
        match (c, g) {
            (Some(c), Some(g)) if c + g > 0.0 => g / (c + g),
            _ => self.bootstrap_cpu_share,
        }
    }

    /// Split one family's drained queue into (cpu, gpu) sets per the
    /// policy. Order is preserved: the CPU takes a prefix, the GPU the
    /// suffix (the paper scans from the queue head, cutting at the
    /// cumulative-sum crossing).
    pub fn split(
        &self,
        kind: KernelKindId,
        queue: Vec<Pending>,
    ) -> (Vec<Pending>, Vec<Pending>) {
        if queue.is_empty() {
            return (Vec::new(), Vec::new());
        }
        let share = self.cpu_share(kind);
        let cut = match self.policy {
            SplitPolicy::StaticCount => {
                // count-based: first share-of-count requests to CPU
                (queue.len() as f64 * share).round() as usize
            }
            SplitPolicy::AdaptiveItems => {
                let total: usize = queue.iter().map(|p| p.wr.data_items).sum();
                let cpu_target = total as f64 * share;
                let mut cum = 0usize;
                let mut cut = 0usize;
                for (i, p) in queue.iter().enumerate() {
                    if (cum + p.wr.data_items) as f64 > cpu_target {
                        cut = i;
                        break;
                    }
                    cum += p.wr.data_items;
                    cut = i + 1;
                }
                cut
            }
        };
        let mut queue = queue;
        let gpu = queue.split_off(cut.min(queue.len()));
        (queue, gpu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::chare::ChareId;
    use crate::coordinator::work_request::{Tile, WorkRequest};

    const K0: KernelKindId = KernelKindId(0);

    fn pending(id: u64, items: usize) -> Pending {
        Pending {
            wr: WorkRequest {
                id,
                job: JobId(0),
                chare: ChareId::new(0, id as u32),
                kind: K0,
                buffer: None,
                data_items: items,
                tag: 0,
                arrival: 0.0,
                payload: Tile::default(),
            },
            slot: None,
            staged_bytes: 0,
        }
    }

    #[test]
    fn bootstrap_splits_half() {
        let h = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        assert_eq!(h.cpu_share(K0), 0.5);
        let q: Vec<Pending> = (0..4).map(|i| pending(i, 10)).collect();
        let (cpu, gpu) = h.split(K0, q);
        assert_eq!(cpu.len(), 2);
        assert_eq!(gpu.len(), 2);
    }

    #[test]
    fn pool_makespan_fold_learns_pool_rate() {
        // 2 workers, 100 items each, 0.1 s concurrently: the fold records
        // (200 items, 0.1 s makespan) -> 0.5 ms/item, half the per-worker
        // rate. Per-chunk recording would have learned 1 ms/item.
        let mut pooled = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        pooled.record_cpu(K0, 200, 0.1);
        let mut per_chunk = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        per_chunk.record_cpu(K0, 100, 0.1);
        per_chunk.record_cpu(K0, 100, 0.1);
        pooled.record_gpu(K0, 100, 0.05);
        per_chunk.record_gpu(K0, 100, 0.05);
        assert!((pooled.perf_ratio(K0).unwrap() - 1.0).abs() < 1e-9);
        assert!((per_chunk.perf_ratio(K0).unwrap() - 2.0).abs() < 1e-9);
        // the pool-aware fold hands the CPU a larger share
        assert!(pooled.cpu_share(K0) > per_chunk.cpu_share(K0));
    }

    #[test]
    fn ratio_tracks_running_averages() {
        let mut h = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        h.record_cpu(K0, 100, 0.4); // 4 ms/item
        h.record_gpu(K0, 100, 0.1); // 1 ms/item
        assert!((h.perf_ratio(K0).unwrap() - 4.0).abs() < 1e-9);
        // gpu 4x faster: cpu takes 1/(1+4) = 20%
        assert!((h.cpu_share(K0) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn kinds_keep_independent_rate_models() {
        let k1 = KernelKindId(1);
        let mut h = HybridScheduler::with_kinds(SplitPolicy::AdaptiveItems, 2, 1);
        // kind 0: CPU hopeless; kind 1: CPU competitive
        h.record_cpu(K0, 10, 1.0);
        h.record_gpu(K0, 10, 0.001);
        h.record_cpu(k1, 10, 0.01);
        h.record_gpu(k1, 10, 0.01);
        assert!(h.cpu_share(K0) < 0.01);
        assert!((h.cpu_share(k1) - 0.5).abs() < 1e-9);
        // out-of-range kind records are ignored, shares fall back
        h.record_cpu(KernelKindId(9), 10, 0.01);
        assert_eq!(h.cpu_share(KernelKindId(9)), 0.5);
    }

    #[test]
    fn averages_fold_multiple_samples() {
        let mut h = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        h.record_cpu(K0, 10, 0.02); // 2 ms/item
        h.record_cpu(K0, 10, 0.04); // 4 ms/item -> mean 3 ms
        h.record_gpu(K0, 10, 0.01); // 1 ms/item
        assert!((h.perf_ratio(K0).unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn adaptive_split_follows_data_items_not_count() {
        let mut h = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        h.record_cpu(K0, 10, 0.01);
        h.record_gpu(K0, 10, 0.01); // equal speed: 50% of items each
        // queue: one huge request then many small
        let mut q = vec![pending(0, 90)];
        q.extend((1..11).map(|i| pending(i, 1)));
        let (cpu, gpu) = h.split(K0, q);
        // 100 items total, cpu target 50: the huge request alone would
        // overshoot, so the cut lands before it
        let cpu_items: usize = cpu.iter().map(|p| p.wr.data_items).sum();
        assert!(cpu_items <= 50, "cpu got {cpu_items} items");
        assert_eq!(cpu.len() + gpu.len(), 11);
    }

    #[test]
    fn static_split_ignores_item_weights() {
        let mut h = HybridScheduler::new(SplitPolicy::StaticCount);
        h.record_cpu(K0, 10, 0.01);
        h.record_gpu(K0, 10, 0.01);
        let mut q = vec![pending(0, 90)];
        q.extend((1..11).map(|i| pending(i, 1)));
        let (cpu, gpu) = h.split(K0, q);
        // count split: ~half the requests regardless of weight, so the
        // huge request (at the head) goes to the CPU
        assert!((5..=6).contains(&cpu.len()));
        let cpu_items: usize = cpu.iter().map(|p| p.wr.data_items).sum();
        assert!(cpu_items >= 90, "static split should take the heavy head");
        assert_eq!(cpu.len() + gpu.len(), 11);
    }

    #[test]
    fn split_conserves_requests_and_order() {
        let mut h = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        h.record_cpu(K0, 10, 0.03);
        h.record_gpu(K0, 10, 0.01);
        let q: Vec<Pending> =
            (0..20).map(|i| pending(i, (i % 5 + 1) as usize)).collect();
        let (cpu, gpu) = h.split(K0, q);
        let ids: Vec<u64> = cpu.iter().chain(&gpu).map(|p| p.wr.id).collect();
        assert_eq!(ids, (0..20).collect::<Vec<u64>>());
    }

    #[test]
    fn all_to_gpu_when_cpu_is_hopeless() {
        let mut h = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        h.record_cpu(K0, 1, 1.0); // 1 s/item
        h.record_gpu(K0, 1000, 0.001); // 1 us/item
        let q: Vec<Pending> = (0..10).map(|i| pending(i, 10)).collect();
        let (cpu, gpu) = h.split(K0, q);
        assert!(cpu.len() <= 1);
        assert!(gpu.len() >= 9);
    }

    #[test]
    fn empty_queue_splits_empty() {
        let h = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        let (cpu, gpu) = h.split(K0, Vec::new());
        assert!(cpu.is_empty() && gpu.is_empty());
    }

    #[test]
    fn job_weights_throttle_heavy_jobs() {
        let mut h = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        assert_eq!(h.job_weight(JobId(1), K0), 1.0, "unmeasured job");
        // job 1's requests carry 3x the items of job 2's
        h.record_job(JobId(1), K0, 10, 300);
        h.record_job(JobId(2), K0, 10, 100);
        let w1 = h.job_weight(JobId(1), K0);
        let w2 = h.job_weight(JobId(2), K0);
        assert!(w1 < w2, "heavy job weighs less: {w1} vs {w2}");
        assert!((w1 * 3.0 - w2).abs() < 1e-9, "inverse-rate weighting");
        h.forget_job(JobId(1));
        assert_eq!(h.job_weight(JobId(1), K0), 1.0);
    }

    #[test]
    fn ensure_kinds_grows_models() {
        let mut h = HybridScheduler::with_kinds(SplitPolicy::AdaptiveItems, 1, 1);
        assert_eq!(h.kinds(), 1);
        h.ensure_kinds(3);
        assert_eq!(h.kinds(), 3);
        let k2 = KernelKindId(2);
        h.record_cpu(k2, 10, 0.01);
        h.record_gpu(k2, 10, 0.01);
        assert!((h.cpu_share(k2) - 0.5).abs() < 1e-9);
        h.ensure_kinds(2); // never shrinks
        assert_eq!(h.kinds(), 3);
    }

    #[test]
    fn device_shares_uniform_before_observations() {
        let h = HybridScheduler::with_kinds(SplitPolicy::AdaptiveItems, 1, 4);
        let s = h.device_shares();
        assert_eq!(s.len(), 4);
        for v in &s {
            assert!((v - 0.25).abs() < 1e-12);
        }
        assert!(h.device_rate(0).is_none());
    }

    #[test]
    fn device_shares_follow_measured_speeds() {
        let mut h = HybridScheduler::with_kinds(SplitPolicy::AdaptiveItems, 1, 2);
        h.record_device(0, 100, 0.1); // 1 ms/item
        h.record_device(1, 100, 0.3); // 3 ms/item: 3x slower
        let s = h.device_shares();
        assert!((s[0] - 0.75).abs() < 1e-9, "fast device takes 3/4");
        assert!((s[1] - 0.25).abs() < 1e-9);
        assert!((s[0] + s[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn unmeasured_device_assumes_mean_rate() {
        let mut h = HybridScheduler::with_kinds(SplitPolicy::AdaptiveItems, 1, 3);
        h.record_device(0, 10, 0.01);
        h.record_device(1, 10, 0.01);
        let s = h.device_shares();
        // device 2 is unmeasured: assumes the 1 ms/item mean, so thirds
        for v in &s {
            assert!((v - 1.0 / 3.0).abs() < 1e-9);
        }
    }

    #[test]
    fn device_stream_does_not_touch_split_averages() {
        let mut h = HybridScheduler::with_kinds(SplitPolicy::AdaptiveItems, 1, 2);
        h.record_device(0, 100, 0.5);
        h.record_device(1, 100, 0.5);
        assert!(h.perf_ratio(K0).is_none(), "split averages still unsampled");
        assert_eq!(h.cpu_share(K0), 0.5, "bootstrap split unchanged");
    }

    #[test]
    fn out_of_range_device_record_is_ignored() {
        let mut h = HybridScheduler::with_kinds(SplitPolicy::AdaptiveItems, 1, 2);
        h.record_device(7, 100, 0.5);
        assert!(h.device_rate(0).is_none());
        assert!(h.device_rate(7).is_none());
    }
}
