//! Chares: message-driven objects with entry methods (paper section 2.1).
//!
//! A parallel application divides its data among arrays of chares; entry
//! methods are invoked by messages from chares on the same or other PEs.
//! The runtime over-decomposes: many more chares than PEs. Chares here are
//! `Box<dyn Chare>` owned by one PE thread; messages carry a method id and
//! an `Any` payload (apps downcast to their message types).

use std::any::Any;
use std::sync::Arc;

use super::registry::{KernelKindId, SharedRegistry, ShapeError};
use super::work_request::{Tile, WrResult};
use crate::runtime::memory::BufferId;

/// Identity of one job on a persistent [`crate::coordinator::Runtime`].
///
/// Every routed message, work request, and residency key carries a job
/// dimension: chare ids are namespaced per job (two jobs may both use
/// collection 0 index 0), reductions and quiescence are per job, and the
/// per-job halves of shared combined launches are split back out into
/// [`crate::coordinator::JobReport`]s.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job{}", self.0)
    }
}

/// Identity of a chare: (collection, index) -- like a Charm++ chare-array
/// element. Scoped to its job: the runtime routes on `(JobId, ChareId)`,
/// so concurrent jobs may reuse collection ids freely.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChareId {
    pub collection: u32,
    pub index: u32,
}

impl ChareId {
    pub fn new(collection: u32, index: u32) -> ChareId {
        ChareId { collection, index }
    }
}

/// Reserved method id: delivery of a work-request result. Apps must route
/// this to their result handling.
pub const METHOD_RESULT: u32 = u32::MAX;

/// A message to a chare entry method.
pub struct Msg {
    pub method: u32,
    pub payload: Box<dyn Any + Send>,
    /// Type name of the payload, captured at construction so routing bugs
    /// (e.g. a cross-job misdelivery) report what was actually sent.
    payload_type: &'static str,
}

impl Msg {
    pub fn new<T: Any + Send>(method: u32, payload: T) -> Msg {
        Msg {
            method,
            payload: Box::new(payload),
            payload_type: std::any::type_name::<T>(),
        }
    }

    /// Type name of the payload this message carries.
    pub fn payload_type(&self) -> &'static str {
        self.payload_type
    }

    /// Downcast the payload, panicking with a useful message on mismatch
    /// (a mismatch is always an app bug). The panic names the method id
    /// and both the expected and the actual payload type, so a cross-job
    /// or cross-collection routing bug is debuggable from the message
    /// alone.
    pub fn take<T: Any>(self) -> T {
        let method = self.method;
        let actual = self.payload_type;
        *self.payload.downcast::<T>().unwrap_or_else(|_| {
            panic!(
                "Msg::take: payload type mismatch on method {method}: \
                 expected {}, got {actual}",
                std::any::type_name::<T>()
            )
        })
    }
}

impl std::fmt::Debug for Msg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Msg").field("method", &self.method).finish()
    }
}

/// Draft of a work request an entry method submits; the runtime assigns the
/// id and arrival timestamp.
#[derive(Debug, Clone)]
pub struct WorkDraft {
    pub chare: ChareId,
    /// Registered kernel family (from `GCharm::register_kernel`).
    pub kind: KernelKindId,
    pub buffer: Option<BufferId>,
    pub data_items: usize,
    /// Correlation tag echoed in the result (e.g. bucket index).
    pub tag: u64,
    pub payload: Tile,
}

/// Effects an entry method can produce. Collected by the context during
/// `receive` and dispatched by the PE loop afterwards (so entry methods
/// never block).
pub enum Effect {
    /// Send a message to another chare.
    Send(ChareId, Msg),
    /// Submit a work request to the runtime scheduler.
    Work(WorkDraft),
    /// Contribute to the current reduction (quiescence/iteration barrier).
    Contribute(f64),
}

/// Execution context handed to entry methods. Scoped to the delivering
/// job: sends, work requests, and contributions all stay inside the job
/// that owns the receiving chare.
pub struct Ctx {
    pub pe: usize,
    /// The job that owns the receiving chare.
    pub job: JobId,
    registry: Arc<SharedRegistry>,
    pub(crate) effects: Vec<Effect>,
}

impl Ctx {
    pub(crate) fn new(
        pe: usize,
        job: JobId,
        registry: Arc<SharedRegistry>,
    ) -> Ctx {
        Ctx { pe, job, registry, effects: Vec::new() }
    }

    /// The shared, append-only kernel registry (shape lookups,
    /// name -> kind).
    pub fn registry(&self) -> &SharedRegistry {
        &self.registry
    }

    /// Invoke an entry method on another chare (asynchronous).
    pub fn send(&mut self, to: ChareId, msg: Msg) {
        self.effects.push(Effect::Send(to, msg));
    }

    /// Submit GPU/hybrid work to the runtime (G-Charm's
    /// `gcharm_insert_request`). The payload is validated against the
    /// registered tile shapes here, so a malformed buffer is rejected at
    /// the submission site — with the offending argument named — instead
    /// of corrupting a combined launch downstream.
    pub fn submit(&mut self, draft: WorkDraft) -> Result<(), ShapeError> {
        self.registry.check(draft.kind, &draft.payload)?;
        self.effects.push(Effect::Work(draft));
        Ok(())
    }

    /// Contribute `value` to the run's reduction; the driver's
    /// `await_reduction(n)` completes after n contributions.
    pub fn contribute(&mut self, value: f64) {
        self.effects.push(Effect::Contribute(value));
    }

    pub(crate) fn drain(&mut self) -> Vec<Effect> {
        std::mem::take(&mut self.effects)
    }
}

/// A message-driven object. `receive` must not block; long-running work
/// belongs in work requests.
pub trait Chare: Send {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx);
}

/// Convenience: the payload type of METHOD_RESULT messages.
pub type ResultMsg = WrResult;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::builtin_registry;
    use crate::runtime::shapes::{
        INTERACTIONS, INTER_W, KTABLE, KTAB_W, PARTICLE_W, PARTS_PER_BUCKET,
    };

    fn ctx(pe: usize) -> Ctx {
        let reg = builtin_registry(
            1e-2,
            vec![0.0; KTABLE * KTAB_W],
            [1.0, 0.04, 1.0],
        );
        Ctx::new(pe, JobId(0), Arc::new(SharedRegistry::from_registry(reg)))
    }

    #[test]
    fn msg_roundtrip() {
        let m = Msg::new(3, vec![1u32, 2, 3]);
        assert_eq!(m.method, 3);
        let v: Vec<u32> = m.take();
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "type mismatch")]
    fn msg_wrong_type_panics() {
        let m = Msg::new(0, 42u32);
        let _: String = m.take();
    }

    #[test]
    fn msg_mismatch_panic_names_method_and_both_types() {
        let m = Msg::new(7, 42u32);
        assert_eq!(m.payload_type(), "u32");
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || -> String { m.take() },
        ))
        .expect_err("mismatched take must panic");
        let text = err
            .downcast_ref::<String>()
            .cloned()
            .expect("panic payload is a string");
        assert!(text.contains("method 7"), "missing method id: {text}");
        assert!(
            text.contains("expected alloc::string::String"),
            "missing expected type: {text}"
        );
        assert!(text.contains("got u32"), "missing actual type: {text}");
    }

    #[test]
    fn ctx_collects_effects_in_order() {
        let mut ctx = ctx(2);
        ctx.send(ChareId::new(0, 1), Msg::new(0, ()));
        ctx.contribute(1.5);
        let effects = ctx.drain();
        assert_eq!(effects.len(), 2);
        assert!(matches!(effects[0], Effect::Send(..)));
        assert!(matches!(effects[1], Effect::Contribute(v) if v == 1.5));
        assert!(ctx.drain().is_empty());
    }

    #[test]
    fn submit_validates_shapes_at_the_submission_site() {
        let mut ctx = ctx(0);
        let good = WorkDraft {
            chare: ChareId::new(0, 0),
            kind: KernelKindId(0),
            buffer: None,
            data_items: 1,
            tag: 0,
            payload: Tile::new(vec![
                vec![0.0; PARTS_PER_BUCKET * PARTICLE_W],
                vec![0.0; INTERACTIONS * INTER_W],
            ]),
        };
        assert!(ctx.submit(good).is_ok());
        let bad = WorkDraft {
            chare: ChareId::new(0, 0),
            kind: KernelKindId(0),
            buffer: None,
            data_items: 1,
            tag: 0,
            payload: Tile::new(vec![vec![0.0; 5], vec![]]),
        };
        let e = ctx.submit(bad).unwrap_err();
        assert_eq!(e.arg, "parts");
        // only the valid draft became an effect
        assert_eq!(ctx.drain().len(), 1);
    }

    #[test]
    fn chare_id_ordering() {
        let a = ChareId::new(0, 5);
        let b = ChareId::new(1, 0);
        assert!(a < b);
    }
}
