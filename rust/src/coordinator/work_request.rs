//! Work requests: the unit of GPU work a chare submits to the runtime.
//!
//! When a chare needs a kernel, it creates a `WorkRequest` carrying a
//! [`Tile`] payload tagged with the registered [`KernelKindId`] and hands
//! it to the runtime scheduler (paper section 2.2). The runtime combines
//! several into one `CombinedLaunch` (section 3.1), decides the
//! data-movement policy (section 3.2), or routes them to CPU workers
//! (section 3.3). Payload shapes are validated against the registry at
//! submission (`Ctx::submit`), so a malformed tile is rejected with a
//! `ShapeError` naming the offending argument instead of corrupting a
//! combined launch.

use crate::runtime::memory::BufferId;

use super::chare::{ChareId, JobId};
use super::registry::KernelKindId;

/// Kernel input data carried by one work request: one buffer per
/// registered tile argument (in registration order), each exactly one
/// request slot (`rows * width` floats of the registered shape).
#[derive(Debug, Clone, Default)]
pub struct Tile {
    /// Per-arg slot buffers, registration order.
    pub bufs: Vec<Vec<f32>>,
    /// Residency keys of the *real* (unpadded) entries of the family's
    /// entry-cache argument, if it has one. The runtime keys
    /// interaction-data residency on them (section 3.2: moments/particle
    /// data resident on the device from prior kernels). Empty otherwise.
    pub entry_ids: Vec<u32>,
}

impl Tile {
    /// Payload without entry-cache keys.
    pub fn new(bufs: Vec<Vec<f32>>) -> Tile {
        Tile { bufs, entry_ids: Vec::new() }
    }

    /// Payload with residency keys for the family's entry-cache argument.
    pub fn with_entries(bufs: Vec<Vec<f32>>, entry_ids: Vec<u32>) -> Tile {
        Tile { bufs, entry_ids }
    }

    /// Total payload floats across every tile buffer.
    pub fn floats(&self) -> usize {
        self.bufs.iter().map(Vec::len).sum()
    }
}

/// One unit of device work, created by a chare entry method.
#[derive(Debug, Clone)]
pub struct WorkRequest {
    /// Unique id assigned by the runtime at submission.
    pub id: u64,
    /// The job that submitted the request. Requests of the same kernel
    /// family from *different* jobs may share one combined launch
    /// (cross-job combining); accounting is split back out per job when
    /// the launch completes.
    pub job: JobId,
    /// Chare to notify with the results (scoped to `job`).
    pub chare: ChareId,
    /// Registered kernel family this request belongs to.
    pub kind: KernelKindId,
    /// Chare data buffer this request reads; the chare table uses it for
    /// residency/reuse decisions (section 3.2). `None` for payloads with no
    /// reusable buffer. App-chosen ids must fit in 48 bits: the runtime
    /// namespaces residency keys by job in the upper bits.
    pub buffer: Option<BufferId>,
    /// Workload model: number of input data items (section 3.3 models a
    /// request's cost by the amount of input data it accesses).
    pub data_items: usize,
    /// Opaque correlation tag chosen by the submitting chare, echoed in
    /// `WrResult` (e.g. the bucket index the request belongs to).
    pub tag: u64,
    /// Timeline seconds when the request reached the runtime.
    pub arrival: f64,
    pub payload: Tile,
}

impl WorkRequest {
    /// Payload bytes that would cross PCIe if nothing were resident.
    pub fn payload_bytes(&self) -> u64 {
        (self.payload.floats() * 4) as u64
    }
}

/// Results scattered back to one chare after a combined launch completes.
#[derive(Debug, Clone)]
pub struct WrResult {
    pub wr_id: u64,
    /// The submitting chare's correlation tag.
    pub tag: u64,
    /// Registered kernel family the result belongs to.
    pub kind: KernelKindId,
    /// Output rows for this request's slot
    /// (`out_rows * out_width` floats of the registered shape).
    pub out: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::shapes::{
        INTERACTIONS, INTER_W, PARTICLE_W, PARTS_PER_BUCKET,
    };

    fn force_wr() -> WorkRequest {
        WorkRequest {
            id: 1,
            job: JobId(0),
            chare: ChareId::new(0, 0),
            kind: KernelKindId(0),
            buffer: Some(42),
            data_items: 128,
            tag: 0,
            arrival: 0.0,
            payload: Tile::with_entries(
                vec![
                    vec![0.0; PARTS_PER_BUCKET * PARTICLE_W],
                    vec![0.0; INTERACTIONS * INTER_W],
                ],
                vec![0; 8],
            ),
        }
    }

    #[test]
    fn byte_accounting() {
        let wr = force_wr();
        let parts_bytes = (PARTS_PER_BUCKET * PARTICLE_W * 4) as u64;
        let inter_bytes = (INTERACTIONS * INTER_W * 4) as u64;
        assert_eq!(wr.payload_bytes(), parts_bytes + inter_bytes);
    }

    #[test]
    fn tile_constructors() {
        let t = Tile::new(vec![vec![1.0, 2.0], vec![3.0]]);
        assert_eq!(t.floats(), 3);
        assert!(t.entry_ids.is_empty());
        let e = Tile::with_entries(vec![vec![0.0]], vec![7, 8]);
        assert_eq!(e.entry_ids, vec![7, 8]);
    }
}
