//! Work requests: the unit of GPU work a chare submits to the runtime.
//!
//! When a chare needs a kernel, it creates a `WorkRequest` and hands it to
//! the runtime scheduler (paper section 2.2). The runtime combines several
//! into one `CombinedLaunch` (section 3.1), decides the data-movement policy
//! (section 3.2), or routes them to CPU workers (section 3.3).

use crate::runtime::memory::BufferId;
use crate::runtime::shapes::{
    INTERACTIONS, INTER_W, MD_W, PARTICLE_W, PARTS_PER_BUCKET,
    PARTS_PER_PATCH,
};

use super::chare::ChareId;

/// Which kernel family a work request belongs to. Each family has its own
/// workGroupList/combiner because occupancy-derived maxSize differs
/// (section 4.3: force 104, Ewald 65).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkKind {
    /// Bucket gravity force (N-Body).
    Force,
    /// Ewald periodic correction (N-Body).
    Ewald,
    /// Patch-pair interaction (MD). Has both CPU and GPU kernels, so it is
    /// eligible for hybrid scheduling.
    MdInteract,
}

/// Kernel input data carried by one work request.
#[derive(Debug, Clone)]
pub enum WrPayload {
    /// Bucket particles (P x 4) + interaction list (I x 4, zero-padded).
    /// `inter_ids` are the stable ids of the *real* (unpadded) entries;
    /// the runtime keys interaction-data residency on them (section 3.2:
    /// moments/particle data resident on the device from prior kernels).
    Force { parts: Vec<f32>, inters: Vec<f32>, inter_ids: Vec<u32> },
    /// Bucket particles (P x 4).
    Ewald { parts: Vec<f32> },
    /// Two patch particle sets (N x 2 each).
    MdPair { pa: Vec<f32>, pb: Vec<f32> },
}

impl WrPayload {
    /// Validate buffer lengths against the canonical tile shapes.
    pub fn check(&self) -> bool {
        match self {
            WrPayload::Force { parts, inters, inter_ids } => {
                parts.len() == PARTS_PER_BUCKET * PARTICLE_W
                    && inters.len() == INTERACTIONS * INTER_W
                    && inter_ids.len() <= INTERACTIONS
            }
            WrPayload::Ewald { parts } => {
                parts.len() == PARTS_PER_BUCKET * PARTICLE_W
            }
            WrPayload::MdPair { pa, pb } => {
                pa.len() == PARTS_PER_PATCH * MD_W
                    && pb.len() == PARTS_PER_PATCH * MD_W
            }
        }
    }
}

/// One unit of device work, created by a chare entry method.
#[derive(Debug, Clone)]
pub struct WorkRequest {
    /// Unique id assigned by the runtime at submission.
    pub id: u64,
    /// Chare to notify with the results.
    pub chare: ChareId,
    pub kind: WorkKind,
    /// Chare data buffer this request reads; the chare table uses it for
    /// residency/reuse decisions (section 3.2). `None` for payloads with no
    /// reusable buffer.
    pub buffer: Option<BufferId>,
    /// Workload model: number of input data items (section 3.3 models a
    /// request's cost by the amount of input data it accesses).
    pub data_items: usize,
    /// Opaque correlation tag chosen by the submitting chare, echoed in
    /// `WrResult` (e.g. the bucket index the request belongs to).
    pub tag: u64,
    /// Timeline seconds when the request reached the runtime.
    pub arrival: f64,
    pub payload: WrPayload,
}

impl WorkRequest {
    /// Payload bytes that would cross PCIe if nothing were resident.
    pub fn payload_bytes(&self) -> u64 {
        let floats = match &self.payload {
            WrPayload::Force { parts, inters, .. } => {
                parts.len() + inters.len()
            }
            WrPayload::Ewald { parts } => parts.len(),
            WrPayload::MdPair { pa, pb } => pa.len() + pb.len(),
        };
        (floats * 4) as u64
    }

    /// Bytes of the reusable buffer (the part residency can save).
    pub fn reusable_bytes(&self) -> u64 {
        let floats = match &self.payload {
            WrPayload::Force { parts, .. } => parts.len(),
            WrPayload::Ewald { parts } => parts.len(),
            WrPayload::MdPair { .. } => 0,
        };
        (floats * 4) as u64
    }
}

/// Results scattered back to one chare after a combined launch completes.
#[derive(Debug, Clone)]
pub struct WrResult {
    pub wr_id: u64,
    /// The submitting chare's correlation tag.
    pub tag: u64,
    pub kind: WorkKind,
    /// Output rows for this request's slot (P x 4 for gravity/Ewald,
    /// N x 2 for MD).
    pub out: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn force_wr() -> WorkRequest {
        WorkRequest {
            id: 1,
            chare: ChareId::new(0, 0),
            kind: WorkKind::Force,
            buffer: Some(42),
            data_items: 128,
            tag: 0,
            arrival: 0.0,
            payload: WrPayload::Force {
                parts: vec![0.0; PARTS_PER_BUCKET * PARTICLE_W],
                inters: vec![0.0; INTERACTIONS * INTER_W],
                inter_ids: vec![0; 8],
            },
        }
    }

    #[test]
    fn payload_check_accepts_canonical_shapes() {
        assert!(force_wr().payload.check());
        let e = WrPayload::Ewald { parts: vec![0.0; PARTS_PER_BUCKET * PARTICLE_W] };
        assert!(e.check());
        let m = WrPayload::MdPair {
            pa: vec![0.0; PARTS_PER_PATCH * MD_W],
            pb: vec![0.0; PARTS_PER_PATCH * MD_W],
        };
        assert!(m.check());
    }

    #[test]
    fn payload_check_rejects_wrong_shapes() {
        let bad = WrPayload::Force {
            parts: vec![0.0; 3],
            inters: vec![],
            inter_ids: vec![],
        };
        assert!(!bad.check());
    }

    #[test]
    fn byte_accounting() {
        let wr = force_wr();
        let parts_bytes = (PARTS_PER_BUCKET * PARTICLE_W * 4) as u64;
        let inter_bytes = (INTERACTIONS * INTER_W * 4) as u64;
        assert_eq!(wr.payload_bytes(), parts_bytes + inter_bytes);
        assert_eq!(wr.reusable_bytes(), parts_bytes);
    }
}
