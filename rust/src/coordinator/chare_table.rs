//! The chare table: chare buffer -> device slot mapping and reuse decisions.
//!
//! G-Charm "keeps track of the mapping of chare buffers to slots in the
//! device memory using a chare table" (paper section 3.2): on work-request
//! creation, the buffer indices are looked up, already-resident buffers are
//! not re-transferred, and missing buffers are staged into free slots.
//!
//! Here the device pool is mirrored on the host (`pool`): on a miss the
//! buffer payload is written into the mirror at the assigned slot and the
//! transferred byte count grows; on a hit no bytes move. The mirror is what
//! the gather kernels receive as their `pool` argument -- physically the
//! whole mirror accompanies each PJRT call (the CPU client is the simulated
//! device), but the *accounted* PCIe bytes follow the paper's model.
//!
//! Slot size is per table: each registered kernel family with a reuse arg
//! gets tables shaped to that arg's `rows * width` tile, so the table
//! serves any registered family, not just bucket buffers.
//!
//! Under `ResidencyPolicy::ReuseGraph` (ISSUE 7) the table also keeps a
//! bounded host-side *victim cache* of recently evicted buffers and can
//! [`ChareTable::prefetch`] them back into free slots ahead of the flush
//! that will demand them — while a combined batch executes on the device,
//! so the restage overlaps compute. Prefetch never evicts: only genuinely
//! free slots are used, so a prefetched buffer can never displace one a
//! scorer rates hotter (anything resident outranks "not resident").

use std::collections::{HashMap, VecDeque};

use anyhow::{bail, Result};

use crate::runtime::memory::{BufferId, DeviceMemory, ResidencyPolicy};
use crate::runtime::staging::write_slot;

/// Evicted-buffer copies retained per table for prefetch restaging.
const VICTIM_CACHE_SLOTS: usize = 64;

/// Chare-buffer residency manager over the simulated device pool.
#[derive(Debug)]
pub struct ChareTable {
    mem: DeviceMemory,
    /// Floats per slot (the registered reuse tile's `rows * width`).
    slot_floats: usize,
    /// Host mirror of the device pool: `slots * slot_floats` floats.
    /// Shared (Arc) with in-flight launches; staging uses copy-on-write so
    /// a launch never copies the pool unless one is concurrently in
    /// flight.
    pool: std::sync::Arc<Vec<f32>>,
    /// Accounted PCIe bytes actually transferred (misses).
    transferred: u64,
    /// Accounted PCIe bytes saved by reuse (hits).
    saved: u64,
    /// Of `transferred`, the bytes moved by prefetch staging.
    prefetch_bytes: u64,
    /// Host-side copies of recently evicted buffers (ReuseGraph only):
    /// the data source for prefetch restaging. Bounded FIFO.
    victims: HashMap<BufferId, Vec<f32>>,
    victim_order: VecDeque<BufferId>,
}

/// Result of staging one buffer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Staged {
    /// Device slot holding the buffer.
    pub slot: u32,
    /// Bytes that crossed the (modeled) bus for this staging (0 on a hit).
    pub bytes: u64,
}

impl ChareTable {
    /// `slots`: device pool capacity in buffer slots; `slot_floats`: the
    /// float count of one buffer (one reuse-arg tile). Seed-identical
    /// LRU eviction; see [`ChareTable::with_policy`] for lookahead.
    pub fn new(slots: usize, slot_floats: usize) -> ChareTable {
        ChareTable::with_policy(slots, slot_floats, ResidencyPolicy::Lru)
    }

    /// A table with an explicit residency policy (`Config::residency`).
    pub fn with_policy(
        slots: usize,
        slot_floats: usize,
        policy: ResidencyPolicy,
    ) -> ChareTable {
        ChareTable {
            mem: DeviceMemory::with_policy(slots, policy),
            slot_floats,
            pool: std::sync::Arc::new(vec![0.0; slots * slot_floats]),
            transferred: 0,
            saved: 0,
            prefetch_bytes: 0,
            victims: HashMap::new(),
            victim_order: VecDeque::new(),
        }
    }

    pub fn slots(&self) -> usize {
        self.mem.capacity()
    }

    /// Floats in one slot of this table.
    pub fn slot_floats(&self) -> usize {
        self.slot_floats
    }

    pub fn pool(&self) -> &[f32] {
        &self.pool
    }

    /// Shared handle to the mirror (zero-copy launch argument).
    pub fn pool_arc(&self) -> std::sync::Arc<Vec<f32>> {
        self.pool.clone()
    }

    /// Stage `data` (one buffer, `slot_floats` floats) for `id` and pin its
    /// slot until `release` -- pending combined launches must not lose
    /// their slots to eviction.
    pub fn stage_pinned(&mut self, id: BufferId, data: &[f32]) -> Result<Staged> {
        self.stage_pinned_predicted(id, data, u64::MAX)
    }

    /// [`ChareTable::stage_pinned`] with the reuse scorer's prediction of
    /// this buffer's next reference attached (ignored under `Lru`). Under
    /// `ReuseGraph` the eviction victim's data is retained in the
    /// host-side victim cache so a later [`ChareTable::prefetch`] can
    /// restage it without the original payload.
    pub fn stage_pinned_predicted(
        &mut self,
        id: BufferId,
        data: &[f32],
        predicted_next: u64,
    ) -> Result<Staged> {
        let slot_floats = self.slot_floats;
        if data.len() != slot_floats {
            bail!("buffer {id}: expected {slot_floats} floats, got {}", data.len());
        }
        let reuse_graph = self.mem.policy() == ResidencyPolicy::ReuseGraph;
        let Some((res, evicted)) =
            self.mem.acquire_predicted(id, predicted_next)
        else {
            bail!("device pool exhausted: all {} slots pinned", self.mem.capacity());
        };
        let slot = res.slot();
        let bytes = if res.is_hit() {
            self.saved += (data.len() * 4) as u64;
            0
        } else {
            if let Some(old) = evicted.filter(|_| reuse_graph) {
                // The victim's data still sits in the mirror slot we are
                // about to overwrite: copy it out for later prefetch.
                let off = slot * slot_floats;
                self.cache_victim(
                    old,
                    self.pool[off..off + slot_floats].to_vec(),
                );
            }
            let pool = std::sync::Arc::make_mut(&mut self.pool);
            write_slot(pool, slot, slot_floats, data);
            self.victims.remove(&id);
            let b = (data.len() * 4) as u64;
            self.transferred += b;
            b
        };
        self.mem.pin(id);
        Ok(Staged { slot: slot as u32, bytes })
    }

    /// Restage a previously evicted buffer into a *free* slot ahead of
    /// demand, from the victim cache. Returns the bytes moved, or `None`
    /// when prefetch cannot help: not a `ReuseGraph` table, `id` already
    /// resident, no cached copy, or no free slot (prefetch never
    /// evicts). The bytes are real transfers — the caller accounts them
    /// exactly like demand staging (pool + owning job).
    pub fn prefetch(
        &mut self,
        id: BufferId,
        predicted_next: u64,
    ) -> Option<u64> {
        if self.mem.policy() != ResidencyPolicy::ReuseGraph
            || !self.victims.contains_key(&id)
        {
            return None;
        }
        let slot = self.mem.prefetch(id, predicted_next)?;
        let data = self.victims.remove(&id).expect("checked above");
        let slot_floats = self.slot_floats;
        let pool = std::sync::Arc::make_mut(&mut self.pool);
        write_slot(pool, slot, slot_floats, &data);
        let b = (slot_floats * 4) as u64;
        self.transferred += b;
        self.prefetch_bytes += b;
        Some(b)
    }

    /// Could [`ChareTable::prefetch`] restage `id` right now?
    pub fn prefetchable(&self, id: BufferId) -> bool {
        self.mem.policy() == ResidencyPolicy::ReuseGraph
            && self.mem.peek(id).is_none()
            && self.victims.contains_key(&id)
    }

    fn cache_victim(&mut self, id: BufferId, data: Vec<f32>) {
        if self.victims.insert(id, data).is_none() {
            self.victim_order.push_back(id);
        }
        while self.victim_order.len() > VICTIM_CACHE_SLOTS {
            let old = self.victim_order.pop_front().expect("non-empty");
            self.victims.remove(&old);
        }
    }

    /// Release the pin taken by `stage_pinned`.
    pub fn release(&mut self, id: BufferId) {
        self.mem.unpin(id);
    }

    /// Invalidate one buffer (its chare rewrote the data). Also drops any
    /// victim-cache copy: restaging pre-rewrite data would corrupt the
    /// buffer on its next (pre-fetched) hit.
    pub fn invalidate(&mut self, id: BufferId) {
        self.mem.invalidate(id);
        self.victims.remove(&id);
    }

    /// Invalidate everything (iteration boundary with full rewrites).
    pub fn invalidate_all(&mut self) {
        self.mem.invalidate_all();
        self.victims.clear();
        self.victim_order.clear();
    }

    /// Invalidate the resident buffers matching `pred` (one job's slice
    /// of a multi-tenant pool; co-tenant residency is untouched). The
    /// victim cache drops the job's entries too — a sealed or advancing
    /// job must not be restageable from stale host copies.
    pub fn invalidate_where(&mut self, pred: impl Fn(BufferId) -> bool) {
        self.mem.invalidate_where(&pred);
        self.victims.retain(|&id, _| !pred(id));
        let victims = &self.victims;
        self.victim_order.retain(|id| victims.contains_key(id));
    }

    /// Ids of every resident buffer (chaos-harness residency audit).
    #[cfg(any(test, feature = "chaos"))]
    pub fn resident_keys(&self) -> Vec<BufferId> {
        self.mem.resident_keys()
    }

    pub fn hits(&self) -> u64 {
        self.mem.hits()
    }

    pub fn misses(&self) -> u64 {
        self.mem.misses()
    }

    pub fn transferred_bytes(&self) -> u64 {
        self.transferred
    }

    pub fn saved_bytes(&self) -> u64 {
        self.saved
    }

    /// Prefetched buffers later demanded (counted once at first demand).
    pub fn prefetch_hits(&self) -> u64 {
        self.mem.prefetch_hits()
    }

    /// Prefetched buffers evicted or invalidated before any demand.
    pub fn prefetch_wasted(&self) -> u64 {
        self.mem.prefetch_wasted()
    }

    /// Of `transferred_bytes`, the bytes moved by prefetch staging.
    pub fn prefetch_transferred_bytes(&self) -> u64 {
        self.prefetch_bytes
    }

    /// Hit rate over all stagings so far (0 if none).
    pub fn hit_rate(&self) -> f64 {
        let total = self.mem.hits() + self.mem.misses();
        if total == 0 {
            0.0
        } else {
            self.mem.hits() as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::shapes::{PARTICLE_W, PARTS_PER_BUCKET};

    const SLOT: usize = PARTS_PER_BUCKET * PARTICLE_W;

    fn buf(v: f32) -> Vec<f32> {
        vec![v; SLOT]
    }

    fn table(slots: usize) -> ChareTable {
        ChareTable::new(slots, SLOT)
    }

    #[test]
    fn miss_then_hit_accounting() {
        let mut t = table(8);
        let a = t.stage_pinned(1, &buf(1.0)).unwrap();
        assert!(a.bytes > 0);
        t.release(1);
        let b = t.stage_pinned(1, &buf(1.0)).unwrap();
        assert_eq!(b.bytes, 0);
        assert_eq!(a.slot, b.slot);
        assert_eq!(t.hits(), 1);
        assert_eq!(t.misses(), 1);
        assert_eq!(t.saved_bytes(), a.bytes);
        assert_eq!(t.transferred_bytes(), a.bytes);
        t.release(1);
    }

    #[test]
    fn pool_mirror_holds_staged_data() {
        let mut t = table(4);
        let s = t.stage_pinned(9, &buf(3.5)).unwrap();
        let off = s.slot as usize * SLOT;
        assert!(t.pool()[off..off + 4].iter().all(|&x| x == 3.5));
        t.release(9);
    }

    #[test]
    fn wrong_size_rejected() {
        let mut t = table(4);
        assert!(t.stage_pinned(1, &[0.0; 3]).is_err());
    }

    #[test]
    fn custom_slot_size_is_respected() {
        // a 3x2-tile family gets a 6-float slot table
        let mut t = ChareTable::new(4, 6);
        assert_eq!(t.slot_floats(), 6);
        assert_eq!(t.pool().len(), 24);
        assert!(t.stage_pinned(1, &[1.0; 6]).is_ok());
        assert!(t.stage_pinned(2, &[1.0; SLOT]).is_err());
    }

    #[test]
    fn exhaustion_when_all_pinned() {
        let mut t = table(2);
        t.stage_pinned(1, &buf(1.0)).unwrap();
        t.stage_pinned(2, &buf(2.0)).unwrap();
        assert!(t.stage_pinned(3, &buf(3.0)).is_err());
        t.release(1);
        assert!(t.stage_pinned(3, &buf(3.0)).is_ok());
    }

    #[test]
    fn invalidate_forces_retransfer() {
        let mut t = table(4);
        t.stage_pinned(5, &buf(1.0)).unwrap();
        t.release(5);
        t.invalidate(5);
        let s = t.stage_pinned(5, &buf(2.0)).unwrap();
        assert!(s.bytes > 0, "invalidated buffer must re-transfer");
        t.release(5);
    }

    #[test]
    fn victim_cache_feeds_prefetch_with_exact_data() {
        let mut t = ChareTable::with_policy(2, 4, ResidencyPolicy::ReuseGraph);
        t.stage_pinned_predicted(1, &[1.5; 4], 10).unwrap();
        t.release(1);
        t.stage_pinned_predicted(2, &[2.5; 4], 5).unwrap();
        t.release(2);
        // 1 has the farther next use: staging 3 evicts it into the cache
        t.stage_pinned_predicted(3, &[3.5; 4], 6).unwrap();
        t.release(3);
        assert!(t.prefetchable(1));
        // free a slot, then prefetch 1 back without its payload
        t.invalidate(2);
        let b = t.prefetch(1, 12).expect("cached victim, free slot");
        assert_eq!(b, 16);
        assert_eq!(t.prefetch_transferred_bytes(), 16);
        // the demanded hit pays no bytes and counts the prefetch hit
        let s = t.stage_pinned_predicted(1, &[1.5; 4], 20).unwrap();
        assert_eq!(s.bytes, 0);
        assert_eq!(t.prefetch_hits(), 1);
        let off = s.slot as usize * 4;
        assert!(t.pool()[off..off + 4].iter().all(|&x| x == 1.5));
        t.release(1);
    }

    #[test]
    fn prefetch_never_evicts() {
        let mut t = ChareTable::with_policy(2, 4, ResidencyPolicy::ReuseGraph);
        t.stage_pinned_predicted(1, &[1.0; 4], 100).unwrap();
        t.release(1);
        t.stage_pinned_predicted(2, &[2.0; 4], 5).unwrap();
        t.release(2);
        t.stage_pinned_predicted(3, &[3.0; 4], 6).unwrap(); // evicts 1
        t.release(3);
        // pool full: the cached victim must NOT displace anyone
        assert!(t.prefetch(1, 1).is_none());
        assert!(t.prefetchable(1), "cache copy survives a refused prefetch");
    }

    #[test]
    fn invalidation_purges_victim_cache() {
        let mut t = ChareTable::with_policy(1, 4, ResidencyPolicy::ReuseGraph);
        t.stage_pinned_predicted(1, &[1.0; 4], 10).unwrap();
        t.release(1);
        t.stage_pinned_predicted(2, &[2.0; 4], 5).unwrap(); // evicts 1
        t.release(2);
        assert!(t.prefetchable(1));
        // 1's chare rewrote its data: the cached copy is stale
        t.invalidate_where(|id| id == 1);
        t.invalidate(2);
        assert!(!t.prefetchable(1), "stale victim copy must not restage");
        assert!(t.prefetch(1, 3).is_none());
    }

    #[test]
    fn lru_table_never_prefetches() {
        let mut t = table(2);
        t.stage_pinned(1, &buf(1.0)).unwrap();
        t.release(1);
        t.stage_pinned(2, &buf(2.0)).unwrap();
        t.release(2);
        t.stage_pinned(3, &buf(3.0)).unwrap(); // evicts under LRU
        t.release(3);
        assert!(!t.prefetchable(1), "Lru keeps no victim cache");
        t.invalidate(2);
        assert!(t.prefetch(1, 1).is_none());
        assert_eq!(t.prefetch_transferred_bytes(), 0);
    }

    #[test]
    fn hit_rate_tracks() {
        let mut t = table(4);
        assert_eq!(t.hit_rate(), 0.0);
        t.stage_pinned(1, &buf(1.0)).unwrap();
        t.release(1);
        t.stage_pinned(1, &buf(1.0)).unwrap();
        t.release(1);
        assert!((t.hit_rate() - 0.5).abs() < 1e-12);
    }
}
