//! The chare table: chare buffer -> device slot mapping and reuse decisions.
//!
//! G-Charm "keeps track of the mapping of chare buffers to slots in the
//! device memory using a chare table" (paper section 3.2): on work-request
//! creation, the buffer indices are looked up, already-resident buffers are
//! not re-transferred, and missing buffers are staged into free slots.
//!
//! Here the device pool is mirrored on the host (`pool`): on a miss the
//! buffer payload is written into the mirror at the assigned slot and the
//! transferred byte count grows; on a hit no bytes move. The mirror is what
//! the gather kernels receive as their `pool` argument -- physically the
//! whole mirror accompanies each PJRT call (the CPU client is the simulated
//! device), but the *accounted* PCIe bytes follow the paper's model.
//!
//! Slot size is per table: each registered kernel family with a reuse arg
//! gets tables shaped to that arg's `rows * width` tile, so the table
//! serves any registered family, not just bucket buffers.

use anyhow::{bail, Result};

use crate::runtime::memory::{BufferId, DeviceMemory};

/// Chare-buffer residency manager over the simulated device pool.
#[derive(Debug)]
pub struct ChareTable {
    mem: DeviceMemory,
    /// Floats per slot (the registered reuse tile's `rows * width`).
    slot_floats: usize,
    /// Host mirror of the device pool: `slots * slot_floats` floats.
    /// Shared (Arc) with in-flight launches; staging uses copy-on-write so
    /// a launch never copies the pool unless one is concurrently in
    /// flight.
    pool: std::sync::Arc<Vec<f32>>,
    /// Accounted PCIe bytes actually transferred (misses).
    transferred: u64,
    /// Accounted PCIe bytes saved by reuse (hits).
    saved: u64,
}

/// Result of staging one buffer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Staged {
    /// Device slot holding the buffer.
    pub slot: u32,
    /// Bytes that crossed the (modeled) bus for this staging (0 on a hit).
    pub bytes: u64,
}

impl ChareTable {
    /// `slots`: device pool capacity in buffer slots; `slot_floats`: the
    /// float count of one buffer (one reuse-arg tile).
    pub fn new(slots: usize, slot_floats: usize) -> ChareTable {
        ChareTable {
            mem: DeviceMemory::new(slots),
            slot_floats,
            pool: std::sync::Arc::new(vec![0.0; slots * slot_floats]),
            transferred: 0,
            saved: 0,
        }
    }

    pub fn slots(&self) -> usize {
        self.mem.capacity()
    }

    /// Floats in one slot of this table.
    pub fn slot_floats(&self) -> usize {
        self.slot_floats
    }

    pub fn pool(&self) -> &[f32] {
        &self.pool
    }

    /// Shared handle to the mirror (zero-copy launch argument).
    pub fn pool_arc(&self) -> std::sync::Arc<Vec<f32>> {
        self.pool.clone()
    }

    /// Stage `data` (one buffer, `slot_floats` floats) for `id` and pin its
    /// slot until `release` -- pending combined launches must not lose
    /// their slots to eviction.
    pub fn stage_pinned(&mut self, id: BufferId, data: &[f32]) -> Result<Staged> {
        let slot_floats = self.slot_floats;
        if data.len() != slot_floats {
            bail!("buffer {id}: expected {slot_floats} floats, got {}", data.len());
        }
        let Some(res) = self.mem.acquire(id) else {
            bail!("device pool exhausted: all {} slots pinned", self.mem.capacity());
        };
        let slot = res.slot();
        let bytes = if res.is_hit() {
            self.saved += (data.len() * 4) as u64;
            0
        } else {
            let off = slot * slot_floats;
            let pool = std::sync::Arc::make_mut(&mut self.pool);
            pool[off..off + slot_floats].copy_from_slice(data);
            let b = (data.len() * 4) as u64;
            self.transferred += b;
            b
        };
        self.mem.pin(id);
        Ok(Staged { slot: slot as u32, bytes })
    }

    /// Release the pin taken by `stage_pinned`.
    pub fn release(&mut self, id: BufferId) {
        self.mem.unpin(id);
    }

    /// Invalidate one buffer (its chare rewrote the data).
    pub fn invalidate(&mut self, id: BufferId) {
        self.mem.invalidate(id);
    }

    /// Invalidate everything (iteration boundary with full rewrites).
    pub fn invalidate_all(&mut self) {
        self.mem.invalidate_all();
    }

    /// Invalidate the resident buffers matching `pred` (one job's slice
    /// of a multi-tenant pool; co-tenant residency is untouched).
    pub fn invalidate_where(&mut self, pred: impl Fn(BufferId) -> bool) {
        self.mem.invalidate_where(pred);
    }

    /// Ids of every resident buffer (chaos-harness residency audit).
    #[cfg(any(test, feature = "chaos"))]
    pub fn resident_keys(&self) -> Vec<BufferId> {
        self.mem.resident_keys()
    }

    pub fn hits(&self) -> u64 {
        self.mem.hits()
    }

    pub fn misses(&self) -> u64 {
        self.mem.misses()
    }

    pub fn transferred_bytes(&self) -> u64 {
        self.transferred
    }

    pub fn saved_bytes(&self) -> u64 {
        self.saved
    }

    /// Hit rate over all stagings so far (0 if none).
    pub fn hit_rate(&self) -> f64 {
        let total = self.mem.hits() + self.mem.misses();
        if total == 0 {
            0.0
        } else {
            self.mem.hits() as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::shapes::{PARTICLE_W, PARTS_PER_BUCKET};

    const SLOT: usize = PARTS_PER_BUCKET * PARTICLE_W;

    fn buf(v: f32) -> Vec<f32> {
        vec![v; SLOT]
    }

    fn table(slots: usize) -> ChareTable {
        ChareTable::new(slots, SLOT)
    }

    #[test]
    fn miss_then_hit_accounting() {
        let mut t = table(8);
        let a = t.stage_pinned(1, &buf(1.0)).unwrap();
        assert!(a.bytes > 0);
        t.release(1);
        let b = t.stage_pinned(1, &buf(1.0)).unwrap();
        assert_eq!(b.bytes, 0);
        assert_eq!(a.slot, b.slot);
        assert_eq!(t.hits(), 1);
        assert_eq!(t.misses(), 1);
        assert_eq!(t.saved_bytes(), a.bytes);
        assert_eq!(t.transferred_bytes(), a.bytes);
        t.release(1);
    }

    #[test]
    fn pool_mirror_holds_staged_data() {
        let mut t = table(4);
        let s = t.stage_pinned(9, &buf(3.5)).unwrap();
        let off = s.slot as usize * SLOT;
        assert!(t.pool()[off..off + 4].iter().all(|&x| x == 3.5));
        t.release(9);
    }

    #[test]
    fn wrong_size_rejected() {
        let mut t = table(4);
        assert!(t.stage_pinned(1, &[0.0; 3]).is_err());
    }

    #[test]
    fn custom_slot_size_is_respected() {
        // a 3x2-tile family gets a 6-float slot table
        let mut t = ChareTable::new(4, 6);
        assert_eq!(t.slot_floats(), 6);
        assert_eq!(t.pool().len(), 24);
        assert!(t.stage_pinned(1, &[1.0; 6]).is_ok());
        assert!(t.stage_pinned(2, &[1.0; SLOT]).is_err());
    }

    #[test]
    fn exhaustion_when_all_pinned() {
        let mut t = table(2);
        t.stage_pinned(1, &buf(1.0)).unwrap();
        t.stage_pinned(2, &buf(2.0)).unwrap();
        assert!(t.stage_pinned(3, &buf(3.0)).is_err());
        t.release(1);
        assert!(t.stage_pinned(3, &buf(3.0)).is_ok());
    }

    #[test]
    fn invalidate_forces_retransfer() {
        let mut t = table(4);
        t.stage_pinned(5, &buf(1.0)).unwrap();
        t.release(5);
        t.invalidate(5);
        let s = t.stage_pinned(5, &buf(2.0)).unwrap();
        assert!(s.bytes > 0, "invalidated buffer must re-transfer");
        t.release(5);
    }

    #[test]
    fn hit_rate_tracks() {
        let mut t = table(4);
        assert_eq!(t.hit_rate(), 0.0);
        t.stage_pinned(1, &buf(1.0)).unwrap();
        t.release(1);
        t.stage_pinned(1, &buf(1.0)).unwrap();
        t.release(1);
        assert!((t.hit_rate() - 0.5).abs() < 1e-12);
    }
}
