//! Reuse-graph scorer: lookahead residency predictions from the request
//! stream (ISSUE 7, paper section 3.2 + the graph-based GPU caching model
//! of PAPERS.md, arXiv 1605.02043).
//!
//! The coordinator sees every work request before the device does, so it
//! can observe each residency key's *reference gaps* — how many stream
//! positions pass between successive uses of the same buffer. The scorer
//! keeps, per key, an EWMA of those gaps and forecasts the next use as
//! `last_seq + gap_ewma`. That forecast is the reuse graph's edge weight
//! collapsed onto the node: nodes are residency keys, a forward edge's
//! weight is the observed re-reference distance, and the per-key EWMA is
//! the running aggregate the eviction policy actually needs (the full
//! adjacency is never materialized — the stream is consumed online).
//!
//! Two properties carry the multi-tenant contract:
//!
//! * **Keys are job-namespaced** (`coordinator::job_key` packs the job id
//!   into the high 16 bits), so one scorer instance per `(device, kind)`
//!   scores co-tenant streams side by side without aliasing.
//! * **Single-reference keys are unscored** ([`UNSCORED`]): a streaming
//!   scan that never revisits a buffer gets no forecast, sorts as
//!   farthest-next-use, and is evicted first — which is exactly how a
//!   co-tenant's scan is kept from flushing another job's hot set.
//!
//! Determinism: the key map is a `BTreeMap`, so candidate enumeration
//! order is a pure function of the inputs (the chaos harness replays
//! schedules bit-identically and would catch hash-order leaks).

use std::collections::BTreeMap;

use crate::runtime::memory::BufferId;

use super::key_job;

/// Prediction for a key with no known forward reference: sorts farthest,
/// evicts first.
pub const UNSCORED: u64 = u64::MAX;

/// EWMA weight of the newest observed gap.
const GAP_ALPHA: f64 = 0.5;

/// Tracked keys per scorer; beyond this the stalest key is dropped.
const MAX_KEYS: usize = 8192;

#[derive(Debug, Clone, Copy)]
struct KeyStat {
    /// Stream position of the most recent reference.
    last_seq: u64,
    /// EWMA of reference gaps (valid once `refs >= 2`).
    gap_ewma: f64,
    /// References seen.
    refs: u32,
}

/// Online reuse scorer for one `(device, kernel kind)` request stream.
#[derive(Debug, Default)]
pub struct ReuseScorer {
    seq: u64,
    keys: BTreeMap<BufferId, KeyStat>,
}

impl ReuseScorer {
    pub fn new() -> ReuseScorer {
        ReuseScorer::default()
    }

    /// Stream positions consumed so far.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Record one reference of `key` and return the forecast of its
    /// *next* reference (`UNSCORED` until the key has a gap history).
    pub fn note(&mut self, key: BufferId) -> u64 {
        self.seq += 1;
        let seq = self.seq;
        let stat = self.keys.entry(key).or_insert(KeyStat {
            last_seq: seq,
            gap_ewma: 0.0,
            refs: 0,
        });
        if stat.refs > 0 {
            let gap = (seq - stat.last_seq) as f64;
            stat.gap_ewma = if stat.refs == 1 {
                gap
            } else {
                GAP_ALPHA * gap + (1.0 - GAP_ALPHA) * stat.gap_ewma
            };
        }
        stat.last_seq = seq;
        stat.refs += 1;
        let prediction = Self::forecast(stat);
        if self.keys.len() > MAX_KEYS {
            // Drop the stalest key (farthest-back last reference); the
            // bound keeps a pathological key churn from growing the map
            // without limit.
            if let Some(stale) = self
                .keys
                .iter()
                .min_by_key(|(_, s)| s.last_seq)
                .map(|(&k, _)| k)
            {
                self.keys.remove(&stale);
            }
        }
        prediction
    }

    /// Forecast of `key`'s next reference without recording one.
    pub fn predicted_next(&self, key: BufferId) -> u64 {
        self.keys.get(&key).map(Self::forecast).unwrap_or(UNSCORED)
    }

    fn forecast(stat: &KeyStat) -> u64 {
        if stat.refs >= 2 {
            stat.last_seq.saturating_add(stat.gap_ewma.round() as u64)
        } else {
            UNSCORED
        }
    }

    /// The scored keys predicted to be referenced soonest: up to `max`
    /// `(key, predicted_next)` pairs with forecasts inside `horizon`
    /// stream positions of now, soonest first (key order breaks ties —
    /// deterministic). This is the prefetch shortlist.
    pub fn hot_candidates(
        &self,
        max: usize,
        horizon: u64,
    ) -> Vec<(BufferId, u64)> {
        let limit = self.seq.saturating_add(horizon);
        let mut hot: Vec<(BufferId, u64)> = self
            .keys
            .iter()
            .filter_map(|(&k, s)| {
                let p = Self::forecast(s);
                (p != UNSCORED && p <= limit).then_some((k, p))
            })
            .collect();
        hot.sort_by_key(|&(k, p)| (p, k));
        hot.truncate(max);
        hot
    }

    /// Drop every key belonging to `job` (job teardown / invalidation):
    /// its forecasts must not outlive its residency.
    pub fn forget_job(&mut self, job: u64) {
        self.keys.retain(|&k, _| key_job(k) != job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job_key;
    use crate::coordinator::JobId;

    #[test]
    fn periodic_stream_predicts_its_period() {
        let mut s = ReuseScorer::new();
        // key 7 every 4 positions: 1, 5, 9, ...
        let mut last = 0;
        for i in 1..=12u64 {
            let key = if i % 4 == 1 { 7 } else { 100 + i };
            last = s.note(key);
            if key != 7 {
                assert_eq!(last, UNSCORED, "single-ref keys stay unscored");
            }
        }
        // after refs at 1, 5, 9 the gap EWMA is exactly 4
        assert_eq!(s.predicted_next(7), 9 + 4);
        let _ = last;
    }

    #[test]
    fn first_reference_is_unscored() {
        let mut s = ReuseScorer::new();
        assert_eq!(s.note(1), UNSCORED);
        assert_eq!(s.predicted_next(1), UNSCORED);
        assert_ne!(s.note(1), UNSCORED, "second ref has a gap history");
    }

    #[test]
    fn hot_candidates_sorted_soonest_first_within_horizon() {
        let mut s = ReuseScorer::new();
        // key 1 gap 2, key 2 gap 6 (interleaved filler keeps gaps honest)
        for _ in 0..3 {
            s.note(1);
            s.note(2);
        }
        // seq = 6; 1 last at 5 gap 2 -> 7; 2 last at 6 gap 2 -> 8
        let hot = s.hot_candidates(8, 100);
        assert_eq!(hot.len(), 2);
        assert!(hot[0].1 <= hot[1].1, "soonest first");
        let tight = s.hot_candidates(8, 0);
        assert!(tight.len() <= hot.len());
        assert_eq!(s.hot_candidates(1, 100).len(), 1, "max caps the list");
    }

    #[test]
    fn forget_job_purges_only_that_tenant() {
        let mut s = ReuseScorer::new();
        let (a, b) = (JobId(3), JobId(4));
        for _ in 0..2 {
            s.note(job_key(a, 1));
            s.note(job_key(b, 1));
        }
        assert_ne!(s.predicted_next(job_key(a, 1)), UNSCORED);
        s.forget_job(a.0);
        assert_eq!(s.predicted_next(job_key(a, 1)), UNSCORED);
        assert_ne!(
            s.predicted_next(job_key(b, 1)),
            UNSCORED,
            "co-tenant forecasts survive"
        );
    }

    #[test]
    fn key_table_is_bounded() {
        let mut s = ReuseScorer::new();
        for k in 0..(MAX_KEYS as u64 + 500) {
            s.note(k);
        }
        assert!(s.keys.len() <= MAX_KEYS);
        // the stalest (smallest last_seq) keys are the ones dropped
        assert_eq!(s.predicted_next(0), UNSCORED);
        assert!(s.keys.contains_key(&(MAX_KEYS as u64 + 499)));
    }

    #[test]
    fn scan_keys_never_enter_the_hot_list() {
        let mut s = ReuseScorer::new();
        for k in 0..100u64 {
            s.note(k); // a pure scan: no key repeats
        }
        assert!(s.hot_candidates(100, u64::MAX - s.seq()).is_empty());
    }
}
