//! Run metrics: what the figure benches and EXPERIMENTS.md report.
//!
//! Aggregates the quantities the paper plots: GPU kernel time and CPU-GPU
//! data-transfer time (Fig 3), combined-launch counts and sizes (Fig 2),
//! and the CPU/GPU split of hybrid executions (Fig 5). Both measured wall
//! clock (CPU PJRT executor) and modeled-K20 times are kept side by side
//! (DESIGN.md section 2).
//!
//! Multi-tenant split: the runtime-wide [`PoolReport`] aggregates across
//! every job a persistent `Runtime` served (plus pool-level quantities
//! like steals and cross-job combined launches), while each job gets its
//! own [`JobReport`] whose request/item/byte counters sum back to the
//! pool totals (shared launches are attributed per request, bytes per
//! item charge). `Report` remains an alias of `PoolReport` for the
//! single-job `GCharm` shim and existing callers.

use super::chare::JobId;
use super::combiner::FlushReason;

/// Per-device breakdown of the sharded GPU pool.
#[derive(Debug, Clone, Default)]
pub struct DeviceStats {
    /// Combined launches executed on this device.
    pub launches: u64,
    /// Work requests those launches carried.
    pub requests: u64,
    /// Data items those launches carried.
    pub items: u64,
    /// Residency hits / misses in this device's chare + node tables.
    pub hits: u64,
    pub misses: u64,
    /// Batches this device stole from overloaded peers.
    pub steals_in: u64,
    /// Batches idle peers stole from this device.
    pub steals_out: u64,
    /// Measured wall seconds this device's engine spent executing.
    pub busy_wall: f64,
    /// Modeled-K20 seconds (kernel + transfer) of this device's launches.
    pub busy_modeled: f64,
}

/// Per-registered-kernel-family breakdown, keyed by the family's
/// registered name (`KernelRegistry` kind order).
#[derive(Debug, Clone, Default)]
pub struct KindStats {
    /// Registered family name.
    pub name: String,
    /// Combined launches of this family.
    pub launches: u64,
    /// Work requests of this family that executed on the GPU / on the
    /// hybrid CPU pool.
    pub gpu_requests: u64,
    pub cpu_requests: u64,
    /// Data items of this family on each side of the hybrid split.
    pub gpu_items: u64,
    pub cpu_items: u64,
    /// Residency hits / misses in this family's chare tables (summed
    /// over devices). These partition the pool's *table* counters minus
    /// the node entry cache, which belongs to no family.
    pub table_hits: u64,
    pub table_misses: u64,
    /// Prefetch staging outcomes for this family's tables (ReuseGraph
    /// residency): prefetched buffers later demanded vs. evicted or
    /// invalidated unused. Sum over kinds equals the pool totals —
    /// invariant-checked in `chaos::invariants`.
    pub prefetch_hits: u64,
    pub prefetch_wasted: u64,
    /// Launch-mode split of this family's launches: batches drained by a
    /// resident persistent loop vs. plain host launches. Partition
    /// `persistent_batches + per_batch_launches == launches` —
    /// invariant-checked in `chaos::invariants`.
    pub persistent_batches: u64,
    pub per_batch_launches: u64,
}

impl KindStats {
    /// Fraction of this family's data items the CPU side took.
    pub fn cpu_item_share(&self) -> f64 {
        let t = self.cpu_items + self.gpu_items;
        if t == 0 {
            0.0
        } else {
            self.cpu_items as f64 / t as f64
        }
    }

    /// Residency hit rate of this family's chare tables (0 if unused).
    pub fn hit_rate(&self) -> f64 {
        let t = self.table_hits + self.table_misses;
        if t == 0 {
            0.0
        } else {
            self.table_hits as f64 / t as f64
        }
    }
}

impl DeviceStats {
    pub fn hit_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.hits as f64 / t as f64
        }
    }

    /// Busy fraction of the run (modeled occupancy) given its wall time.
    pub fn occupancy(&self, total_wall: f64) -> f64 {
        if total_wall <= 0.0 {
            0.0
        } else {
            (self.busy_modeled / total_wall).min(1.0)
        }
    }
}

/// Point-in-time copy of one job's live counters
/// (`JobHandle::metrics_snapshot`): what the job has consumed so far and
/// how much of it is still in flight.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JobMetricsSnapshot {
    /// Combined launches this job's requests rode in so far.
    pub launches: u64,
    /// Of those, launches shared with at least one other job.
    pub cross_job_launches: u64,
    pub gpu_requests: u64,
    pub cpu_requests: u64,
    pub gpu_items: u64,
    pub cpu_items: u64,
    /// PCIe bytes attributed to this job's requests.
    pub transfer_bytes: u64,
    /// Requests drained off this node for remote execution (cross-node
    /// steal), including any a peer-down requeue later bounced back.
    pub remote_requests: u64,
    /// Requests submitted but not yet completed.
    pub queued_requests: i64,
    /// In-flight units (messages + work requests) of the job.
    pub outstanding: i64,
}

/// Final per-job report, sealed when the job's driver returns and its
/// last in-flight work drains.
#[derive(Debug, Clone, Default)]
pub struct JobReport {
    pub job: JobId,
    /// The name the `JobSpec` was submitted under.
    pub name: String,
    /// Combined launches this job's requests rode in. A launch shared by
    /// k jobs appears in each of their reports, so these do **not** sum
    /// to `PoolReport::launches` when cross-job combining fired; the
    /// request/item/byte counters below always do.
    pub launches: u64,
    /// Launches shared with at least one co-tenant job.
    pub cross_job_launches: u64,
    pub gpu_requests: u64,
    pub cpu_requests: u64,
    pub gpu_items: u64,
    pub cpu_items: u64,
    /// PCIe bytes attributed to this job's requests (exact per-item
    /// attribution: summing over jobs reproduces the pool total).
    pub transfer_bytes: u64,
    /// Requests of this job drained off the node for remote execution
    /// (cross-node steal). Sums over jobs to the pool's
    /// `remote_requests_out` — invariant-checked in `chaos::invariants`.
    pub remote_requests: u64,
    /// Wall seconds from submission to the sealed report.
    pub wall: f64,
    /// The per-iteration reduction series the job's driver returned
    /// (energies, residuals, ...). Empty if the driver failed or was
    /// cancelled.
    pub series: Vec<f64>,
}

impl JobReport {
    /// Fraction of this job's launches that were cross-job combined.
    pub fn cross_job_share(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.cross_job_launches as f64 / self.launches as f64
        }
    }
}

impl std::fmt::Display for JobReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} ({}): {} launches ({} cross-job); reqs gpu {} / cpu {}; \
             items gpu {} / cpu {}; {:.2} MiB transferred; {:.4}s wall",
            self.name,
            self.job,
            self.launches,
            self.cross_job_launches,
            self.gpu_requests,
            self.cpu_requests,
            self.gpu_items,
            self.cpu_items,
            self.transfer_bytes as f64 / (1 << 20) as f64,
            self.wall
        )
    }
}

/// Backwards-compatible name for the runtime-wide report: the single-run
/// `GCharm` shim and the figure benches predate the multi-tenant split.
pub type Report = PoolReport;

/// Aggregated statistics of one runtime (all jobs it served).
#[derive(Debug, Clone, Default)]
pub struct PoolReport {
    /// Combined kernel launches submitted to the device.
    pub launches: u64,
    /// Work requests that went to the GPU.
    pub gpu_requests: u64,
    /// Work requests executed on CPU workers (hybrid path).
    pub cpu_requests: u64,
    /// Measured wall seconds inside PJRT execute calls.
    pub kernel_wall: f64,
    /// Modeled-K20 kernel seconds.
    pub kernel_modeled: f64,
    /// Accounted PCIe bytes host->device.
    pub transfer_bytes: u64,
    /// Modeled-K20 transfer seconds.
    pub transfer_modeled: f64,
    /// Chare-table residency hits / misses.
    pub table_hits: u64,
    pub table_misses: u64,
    /// Bytes saved by reuse.
    pub saved_bytes: u64,
    /// Prefetch staging totals (ReuseGraph residency): buffers staged
    /// ahead of demand and later hit, staged and never demanded, and the
    /// PCIe bytes the stagings cost (a subset of `transfer_bytes`). Each
    /// equals the sum of its `kind_stats` counterpart — the node entry
    /// cache never prefetches.
    pub prefetch_hits: u64,
    pub prefetch_wasted: u64,
    pub prefetch_bytes: u64,
    /// Launch-mode split (ISSUE 8): combined batches drained by a
    /// device-resident persistent loop vs. plain per-batch host launches,
    /// by *effective* mode (backend demotions count as per-batch). The
    /// two always partition `launches`.
    pub persistent_batches: u64,
    pub per_batch_launches: u64,
    /// Flush counts by reason.
    pub flush_full: u64,
    pub flush_idle: u64,
    pub flush_static: u64,
    pub flush_forced: u64,
    pub flush_stolen: u64,
    /// Deadline-budget flushes (serve front end, ISSUE 10): a
    /// latency-class job's oldest queued request aged past its deadline
    /// budget, so the combiner drained early, below maxSize.
    pub flush_deadline: u64,
    /// Serve-front-end admission ledger (ISSUE 10), summed over QoS
    /// classes. The front end reports every admission decision through
    /// `Runtime::serve_account`, and the invariant
    /// `serve_offered == serve_admitted + serve_rejected + serve_shed`
    /// must close exactly — audited by `chaos::invariants`. All zero
    /// when no serve front end ran.
    pub serve_offered: u64,
    pub serve_admitted: u64,
    pub serve_rejected: u64,
    pub serve_shed: u64,
    /// Sum of flushed batch sizes (for the average).
    pub flushed_requests: u64,
    /// CPU-side task wall seconds (hybrid path).
    pub cpu_task_wall: f64,
    /// Data items executed on each device (hybrid accounting).
    pub cpu_items: u64,
    pub gpu_items: u64,
    /// End-to-end wall seconds of the run (driver-measured).
    pub total_wall: f64,
    /// Idle-steal migrations between devices (batches moved).
    pub steals: u64,
    /// Work requests those stolen batches carried.
    pub migrated_requests: u64,
    /// Bytes re-transferred to restage migrated buffers on their new
    /// device (the explicit migration cost in the reuse model).
    pub migrated_bytes: u64,
    /// Per-device breakdown; one entry per pool device.
    pub device_stats: Vec<DeviceStats>,
    /// Per-kernel-family breakdown; one entry per registered kind, in
    /// registry order.
    pub kind_stats: Vec<KindStats>,
    /// Combined launches whose requests came from more than one job
    /// (cross-job combining: the acceptance signal that the runtime is
    /// genuinely multiplexing tenants into shared launches).
    pub cross_job_launches: u64,
    /// Cross-node steal, home side: shipments drained off this node for
    /// remote execution and the requests they carried.
    pub remote_steals_out: u64,
    pub remote_requests_out: u64,
    /// Cross-node steal, thief side: shipments this node executed for
    /// peers (counted when the results ship home, so a thief that dies
    /// mid-shipment never counts one).
    pub remote_steals_in: u64,
    pub remote_requests_in: u64,
    /// Shipments (and their requests) bounced back to this node's
    /// combiners because the thief vanished or declined — the
    /// peer-down draining path. `steals_out` splits exactly into
    /// `steals_in + requeues + stale` across the cluster; the chaos
    /// checker audits the conservation.
    pub remote_requeues: u64,
    pub remote_requeued_requests: u64,
    /// Results that arrived for a shipment already requeued (the peer
    /// was presumed dead, then spoke): dropped here, counted so the
    /// cluster-wide conservation still balances.
    pub remote_stale_batches: u64,
    pub remote_stale_results: u64,
    /// Frame-body bytes this node put on / took off the wire (loopback
    /// charges the encoded length of its zero-copy handoffs).
    pub wire_bytes_out: u64,
    pub wire_bytes_in: u64,
    /// Modeled serialize+transfer seconds of outbound shipments — the
    /// explicit cost a remote steal pays in the report.
    pub remote_wire_secs: f64,
    /// Sealed per-job reports, in completion order. Filled by
    /// `Runtime::shutdown`; live snapshots leave it empty.
    pub jobs: Vec<JobReport>,
}

impl PoolReport {
    /// Per-job report by submitted name.
    pub fn job(&self, name: &str) -> Option<&JobReport> {
        self.jobs.iter().find(|j| j.name == name)
    }
    /// Record one flush event.
    pub fn record_flush(&mut self, reason: FlushReason, size: usize) {
        match reason {
            FlushReason::Full => self.flush_full += 1,
            FlushReason::IdleTimeout => self.flush_idle += 1,
            FlushReason::StaticPeriod => self.flush_static += 1,
            FlushReason::Forced => self.flush_forced += 1,
            FlushReason::Stolen => self.flush_stolen += 1,
            FlushReason::Deadline => self.flush_deadline += 1,
        }
        self.flushed_requests += size as u64;
    }

    /// Total flush count.
    pub fn flushes(&self) -> u64 {
        self.flush_full
            + self.flush_idle
            + self.flush_static
            + self.flush_forced
            + self.flush_stolen
            + self.flush_deadline
    }

    /// Mutable per-device entry, growing the vec on demand.
    pub fn device_mut(&mut self, device: usize) -> &mut DeviceStats {
        if self.device_stats.len() <= device {
            self.device_stats.resize(device + 1, DeviceStats::default());
        }
        &mut self.device_stats[device]
    }

    /// Mutable per-kind entry, growing the vec on demand (entries created
    /// this way carry an empty name until the coordinator labels them).
    pub fn kind_mut(&mut self, kind: usize) -> &mut KindStats {
        if self.kind_stats.len() <= kind {
            self.kind_stats.resize(kind + 1, KindStats::default());
        }
        &mut self.kind_stats[kind]
    }

    /// Per-kind entry by registered family name.
    pub fn kind(&self, name: &str) -> Option<&KindStats> {
        self.kind_stats.iter().find(|k| k.name == name)
    }

    /// Modeled makespan of the device pool: the busiest device's modeled
    /// seconds (devices run concurrently, so the busiest one bounds the
    /// pool). Falls back to the aggregate modeled total for single-device
    /// runs with no breakdown recorded.
    pub fn device_makespan(&self) -> f64 {
        if self.device_stats.is_empty() {
            return self.modeled_total();
        }
        self.device_stats
            .iter()
            .map(|d| d.busy_modeled)
            .fold(0.0, f64::max)
    }

    /// Mean combined-batch size (0 if nothing flushed).
    pub fn avg_batch(&self) -> f64 {
        if self.flushes() == 0 {
            0.0
        } else {
            self.flushed_requests as f64 / self.flushes() as f64
        }
    }

    /// Residency hit rate.
    pub fn hit_rate(&self) -> f64 {
        let t = self.table_hits + self.table_misses;
        if t == 0 {
            0.0
        } else {
            self.table_hits as f64 / t as f64
        }
    }

    /// Modeled device-side total (kernel + transfer).
    pub fn modeled_total(&self) -> f64 {
        self.kernel_modeled + self.transfer_modeled
    }
}

impl std::fmt::Display for PoolReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "launches            {}", self.launches)?;
        writeln!(
            f,
            "requests            gpu {} / cpu {}",
            self.gpu_requests, self.cpu_requests
        )?;
        writeln!(
            f,
            "flushes             full {} / idle {} / static {} / forced {} / stolen {} / deadline {} (avg batch {:.1})",
            self.flush_full,
            self.flush_idle,
            self.flush_static,
            self.flush_forced,
            self.flush_stolen,
            self.flush_deadline,
            self.avg_batch()
        )?;
        if self.serve_offered > 0 {
            writeln!(
                f,
                "serve admission     offered {} = admitted {} + rejected {} + shed {}",
                self.serve_offered,
                self.serve_admitted,
                self.serve_rejected,
                self.serve_shed
            )?;
        }
        writeln!(
            f,
            "kernel time         wall {:.4}s   modeled-K20 {:.4}s",
            self.kernel_wall, self.kernel_modeled
        )?;
        writeln!(
            f,
            "transfers           {:.2} MiB   modeled-K20 {:.4}s   saved {:.2} MiB",
            self.transfer_bytes as f64 / (1 << 20) as f64,
            self.transfer_modeled,
            self.saved_bytes as f64 / (1 << 20) as f64
        )?;
        writeln!(
            f,
            "chare table         {} hits / {} misses ({:.0}% hit rate)",
            self.table_hits,
            self.table_misses,
            self.hit_rate() * 100.0
        )?;
        if self.persistent_batches > 0 {
            writeln!(
                f,
                "persistent          {} batches via resident loops / {} per-batch launches",
                self.persistent_batches, self.per_batch_launches
            )?;
        }
        if self.prefetch_hits + self.prefetch_wasted > 0 {
            writeln!(
                f,
                "prefetch            {} hits / {} wasted ({:.2} MiB staged ahead)",
                self.prefetch_hits,
                self.prefetch_wasted,
                self.prefetch_bytes as f64 / (1 << 20) as f64
            )?;
        }
        writeln!(
            f,
            "hybrid              cpu {:.4}s task wall; items cpu {} / gpu {}",
            self.cpu_task_wall, self.cpu_items, self.gpu_items
        )?;
        if !self.kind_stats.is_empty() {
            for k in &self.kind_stats {
                writeln!(
                    f,
                    "  kind {:<12} {} launches; reqs gpu {} / cpu {}; items gpu {} / cpu {} ({:.0}% cpu); table {:.0}% hit; prefetch {} hit / {} wasted",
                    k.name,
                    k.launches,
                    k.gpu_requests,
                    k.cpu_requests,
                    k.gpu_items,
                    k.cpu_items,
                    k.cpu_item_share() * 100.0,
                    k.hit_rate() * 100.0,
                    k.prefetch_hits,
                    k.prefetch_wasted
                )?;
            }
        }
        if self.device_stats.len() > 1 {
            writeln!(
                f,
                "device pool         {} devices; {} steals ({} requests, {:.2} MiB restaged); modeled makespan {:.4}s",
                self.device_stats.len(),
                self.steals,
                self.migrated_requests,
                self.migrated_bytes as f64 / (1 << 20) as f64,
                self.device_makespan()
            )?;
            for (d, s) in self.device_stats.iter().enumerate() {
                writeln!(
                    f,
                    "  dev{d}              {} launches / {} reqs; {} hits / {} misses ({:.0}%); steals in {} out {}; busy wall {:.4}s modeled {:.4}s",
                    s.launches,
                    s.requests,
                    s.hits,
                    s.misses,
                    s.hit_rate() * 100.0,
                    s.steals_in,
                    s.steals_out,
                    s.busy_wall,
                    s.busy_modeled
                )?;
            }
        }
        if self.remote_steals_out + self.remote_steals_in > 0 {
            writeln!(
                f,
                "remote steal        out {} shipments ({} reqs, modeled wire {:.4}s) / in {} ({} reqs); requeued {} ({} reqs); stale {} ({} reqs)",
                self.remote_steals_out,
                self.remote_requests_out,
                self.remote_wire_secs,
                self.remote_steals_in,
                self.remote_requests_in,
                self.remote_requeues,
                self.remote_requeued_requests,
                self.remote_stale_batches,
                self.remote_stale_results
            )?;
        }
        if self.wire_bytes_out + self.wire_bytes_in > 0 {
            writeln!(
                f,
                "wire                {:.2} MiB out / {:.2} MiB in",
                self.wire_bytes_out as f64 / (1 << 20) as f64,
                self.wire_bytes_in as f64 / (1 << 20) as f64
            )?;
        }
        if self.cross_job_launches > 0 || !self.jobs.is_empty() {
            writeln!(
                f,
                "cross-job combines  {} launches merged tiles from \
                 several jobs",
                self.cross_job_launches
            )?;
        }
        for j in &self.jobs {
            writeln!(f, "  job {j}")?;
        }
        write!(f, "total wall          {:.4}s", self.total_wall)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flush_accounting() {
        let mut r = Report::default();
        r.record_flush(FlushReason::Full, 104);
        r.record_flush(FlushReason::IdleTimeout, 10);
        r.record_flush(FlushReason::Forced, 6);
        assert_eq!(r.flushes(), 3);
        assert_eq!(r.flush_full, 1);
        assert!((r.avg_batch() - 40.0).abs() < 1e-12);
    }

    #[test]
    fn rates_handle_zero() {
        let r = Report::default();
        assert_eq!(r.avg_batch(), 0.0);
        assert_eq!(r.hit_rate(), 0.0);
    }

    #[test]
    fn hit_rate() {
        let r = Report { table_hits: 3, table_misses: 1, ..Default::default() };
        assert!((r.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn display_renders() {
        let r = Report::default();
        let s = format!("{r}");
        assert!(s.contains("launches"));
        assert!(s.contains("total wall"));
    }

    #[test]
    fn stolen_flushes_counted() {
        let mut r = Report::default();
        r.record_flush(FlushReason::Stolen, 12);
        assert_eq!(r.flush_stolen, 1);
        assert_eq!(r.flushes(), 1);
        assert!((r.avg_batch() - 12.0).abs() < 1e-12);
    }

    #[test]
    fn deadline_flushes_counted_and_rendered() {
        let mut r = Report::default();
        r.record_flush(FlushReason::Deadline, 3);
        assert_eq!(r.flush_deadline, 1);
        assert_eq!(r.flushes(), 1);
        assert!((r.avg_batch() - 3.0).abs() < 1e-12);
        let s = format!("{r}");
        assert!(s.contains("deadline 1"), "{s}");
    }

    #[test]
    fn serve_ledger_renders_only_when_offered() {
        let quiet = Report::default();
        assert!(!format!("{quiet}").contains("serve admission"));
        let r = Report {
            serve_offered: 10,
            serve_admitted: 6,
            serve_rejected: 1,
            serve_shed: 3,
            ..Report::default()
        };
        let s = format!("{r}");
        assert!(
            s.contains("serve admission     offered 10 = admitted 6 + rejected 1 + shed 3"),
            "{s}"
        );
    }

    #[test]
    fn device_mut_grows_and_makespan_is_max() {
        let mut r = Report::default();
        r.device_mut(2).busy_modeled = 0.5;
        r.device_mut(0).busy_modeled = 0.2;
        assert_eq!(r.device_stats.len(), 3);
        assert!((r.device_makespan() - 0.5).abs() < 1e-12);
        // no breakdown: falls back to aggregate modeled total
        let agg = Report { kernel_modeled: 0.3, ..Default::default() };
        assert!((agg.device_makespan() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn device_stats_rates() {
        let d = DeviceStats {
            hits: 3,
            misses: 1,
            busy_modeled: 0.5,
            ..Default::default()
        };
        assert!((d.hit_rate() - 0.75).abs() < 1e-12);
        assert!((d.occupancy(1.0) - 0.5).abs() < 1e-12);
        assert_eq!(d.occupancy(0.0), 0.0);
    }

    #[test]
    fn kind_stats_grow_and_lookup_by_name() {
        let mut r = Report::default();
        r.kind_mut(1).name = "spmv_row".to_string();
        r.kind_mut(1).cpu_items = 30;
        r.kind_mut(1).gpu_items = 70;
        assert_eq!(r.kind_stats.len(), 2);
        let k = r.kind("spmv_row").unwrap();
        assert!((k.cpu_item_share() - 0.3).abs() < 1e-12);
        assert!(r.kind("nope").is_none());
        let s = format!("{r}");
        assert!(s.contains("spmv_row"));
    }

    #[test]
    fn kind_hit_rate_handles_zero_and_counts() {
        let k = KindStats::default();
        assert_eq!(k.hit_rate(), 0.0);
        let k = KindStats {
            table_hits: 9,
            table_misses: 3,
            ..KindStats::default()
        };
        assert!((k.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn prefetch_line_renders_only_when_counted() {
        let quiet = Report::default();
        assert!(!format!("{quiet}").contains("prefetch "));
        let mut r = Report {
            prefetch_hits: 5,
            prefetch_wasted: 2,
            prefetch_bytes: 3 << 20,
            ..Report::default()
        };
        r.kind_mut(0).name = "nbody_tile".to_string();
        r.kind_mut(0).prefetch_hits = 5;
        r.kind_mut(0).prefetch_wasted = 2;
        let s = format!("{r}");
        assert!(s.contains("prefetch            5 hits / 2 wasted"), "{s}");
        assert!(s.contains("prefetch 5 hit / 2 wasted"), "{s}");
    }

    #[test]
    fn persistent_line_renders_only_when_counted() {
        let quiet = Report { per_batch_launches: 7, ..Report::default() };
        assert!(!format!("{quiet}").contains("persistent"));
        let r = Report {
            launches: 10,
            persistent_batches: 8,
            per_batch_launches: 2,
            ..Report::default()
        };
        let s = format!("{r}");
        assert!(
            s.contains(
                "persistent          8 batches via resident loops / 2 per-batch launches"
            ),
            "{s}"
        );
    }

    #[test]
    fn remote_and_wire_lines_render_only_when_counted() {
        let quiet = Report::default();
        let s = format!("{quiet}");
        assert!(!s.contains("remote steal"), "{s}");
        assert!(!s.contains("wire "), "{s}");
        let r = Report {
            remote_steals_out: 2,
            remote_requests_out: 16,
            remote_steals_in: 1,
            remote_requests_in: 8,
            remote_requeues: 1,
            remote_requeued_requests: 8,
            wire_bytes_out: 3 << 20,
            wire_bytes_in: 1 << 20,
            remote_wire_secs: 0.001,
            ..Report::default()
        };
        let s = format!("{r}");
        assert!(s.contains("remote steal        out 2 shipments (16 reqs"), "{s}");
        assert!(s.contains("requeued 1 (8 reqs)"), "{s}");
        assert!(s.contains("wire                3.00 MiB out / 1.00 MiB in"), "{s}");
    }

    #[test]
    fn job_reports_render_and_lookup() {
        let mut r = PoolReport {
            cross_job_launches: 2,
            ..PoolReport::default()
        };
        r.jobs.push(JobReport {
            job: JobId(1),
            name: "spmv-a".to_string(),
            launches: 4,
            cross_job_launches: 2,
            gpu_requests: 100,
            transfer_bytes: 1 << 20,
            wall: 0.5,
            ..JobReport::default()
        });
        assert!((r.job("spmv-a").unwrap().cross_job_share() - 0.5).abs()
            < 1e-12);
        assert!(r.job("nope").is_none());
        let s = format!("{r}");
        assert!(s.contains("cross-job combines"), "{s}");
        assert!(s.contains("spmv-a (job1)"), "{s}");
    }

    #[test]
    fn display_renders_device_rows() {
        let mut r = Report::default();
        r.device_mut(0).launches = 1;
        r.device_mut(1).launches = 2;
        r.steals = 3;
        let s = format!("{r}");
        assert!(s.contains("device pool"));
        assert!(s.contains("dev1"));
    }
}
