//! Kernel combining: aggregate small work requests into one GPU launch.
//!
//! Paper section 3.1. Combining kernels reduces launch count and raises GPU
//! occupancy, but waiting too long idles the GPU when arrivals are
//! irregular. The *adaptive* policy combines up to `maxSize` requests
//! (occupancy-derived: blocks/SM from the occupancy calculator x SM count)
//! and flushes early when the gap since the last arrival exceeds
//! `2 x maxInterval`, where `maxInterval` is the running maximum of
//! inter-arrival gaps. The *static* baseline (the regular-application
//! strategy from the earlier G-Charm paper) flushes whatever is available
//! after every `period` arrivals.

use std::collections::{HashMap, VecDeque};

use super::chare::JobId;
use super::work_request::WorkRequest;

/// Combining policy for one workGroupList.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CombinePolicy {
    /// Occupancy + inter-arrival adaptive strategy (section 3.1).
    Adaptive,
    /// Flush available requests after every `period` arrivals (the paper's
    /// static baseline uses 100).
    StaticEvery(usize),
}

/// Why a batch was flushed (recorded for the figure benches).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FlushReason {
    /// maxSize requests were available (full-occupancy launch).
    Full,
    /// Idle gap exceeded 2 x maxInterval.
    IdleTimeout,
    /// Static policy period elapsed.
    StaticPeriod,
    /// Forced drain (end of iteration / shutdown).
    Forced,
    /// Drained for migration to an idle device (steal rebalancing).
    Stolen,
    /// A latency-class job's deadline budget approached (serving front
    /// end, ISSUE 10): flush early, even below maxSize.
    Deadline,
}

/// A pending work request plus the device slot its buffer was staged into
/// (None when the data policy is NoReuse).
#[derive(Debug, Clone)]
pub struct Pending {
    pub wr: WorkRequest,
    pub slot: Option<u32>,
    /// Bytes the staging transferred (0 on a residency hit or NoReuse).
    pub staged_bytes: u64,
}

/// One flushed batch, ready to become a combined launch.
#[derive(Debug)]
pub struct Batch {
    pub items: Vec<Pending>,
    pub reason: FlushReason,
}

/// One workGroupList with its combining policy.
#[derive(Debug)]
pub struct Combiner {
    policy: CombinePolicy,
    /// Occupancy-derived combine target (section 4.3: 104 force, 65 Ewald).
    max_size: usize,
    /// Keep pending requests sorted by device slot (binary insert at
    /// insert-request time -- the coalescing strategy of section 3.2).
    sort_by_slot: bool,
    queue: VecDeque<Pending>,
    last_arrival: Option<f64>,
    max_interval: f64,
    arrivals_since_flush: usize,
    /// Static policy: a period flush was capped at `max_size` and left
    /// requests behind; drain them on subsequent polls instead of letting
    /// them sit until the next full period (or the idle-drain rescue).
    residual: bool,
    /// Per-job combine weights for the weighted-fair take (multi-tenant
    /// runtime). Jobs without an entry weigh 1.0. The coordinator feeds
    /// these from the hybrid scheduler's measured per-(job, kind)
    /// items-per-request rates, so a heavy job's oversized requests do
    /// not crowd lighter jobs out of oversubscribed flushes.
    job_weights: HashMap<u64, f64>,
    /// Oversubscribed flushes whose take spanned more than one job.
    cross_job_takes: u64,
    flushes: Vec<(FlushReason, usize)>,
    probes: u64,
}

/// Floor for maxInterval before two arrivals have been seen; prevents the
/// adaptive policy from flushing single requests during warm-up.
const MIN_INTERVAL: f64 = 100e-6;

impl Combiner {
    pub fn new(policy: CombinePolicy, max_size: usize, sort_by_slot: bool) -> Combiner {
        assert!(max_size > 0);
        Combiner {
            policy,
            max_size,
            sort_by_slot,
            queue: VecDeque::new(),
            last_arrival: None,
            max_interval: MIN_INTERVAL,
            arrivals_since_flush: 0,
            residual: false,
            job_weights: HashMap::new(),
            cross_job_takes: 0,
            flushes: Vec::new(),
            probes: 0,
        }
    }

    /// Set one job's combine weight (relative to the default 1.0). Zero
    /// and negative weights are ignored: every job always keeps a share.
    pub fn set_job_weight(&mut self, job: JobId, weight: f64) {
        if weight > 0.0 && weight.is_finite() {
            self.job_weights.insert(job.0, weight);
        }
    }

    /// Forget a finished job's weight.
    pub fn clear_job_weight(&mut self, job: JobId) {
        self.job_weights.remove(&job.0);
    }

    /// Oversubscribed takes that interleaved requests of several jobs.
    pub fn cross_job_takes(&self) -> u64 {
        self.cross_job_takes
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn max_size(&self) -> usize {
        self.max_size
    }

    /// Running maximum inter-arrival gap observed so far.
    pub fn max_interval(&self) -> f64 {
        self.max_interval
    }

    /// Timeline time of the most recent arrival.
    pub fn last_arrival(&self) -> Option<f64> {
        self.last_arrival
    }

    /// Total binary-search probes spent keeping the queue slot-sorted.
    pub fn probes(&self) -> u64 {
        self.probes
    }

    /// Device-resident requests in the batch a `steal_flush` would take
    /// right now: queued requests within the `max_size` cap whose buffer
    /// occupies a device slot. Stealing them forfeits that residency —
    /// each must be restaged on the thief — so the reuse-aware steal
    /// policy subtracts this count from a victim's depth
    /// (`DeviceRouter::steal_candidate_with_cost`). An estimate under
    /// the weighted-fair multi-job take (which may select a different
    /// subset), exact for the common FIFO prefix.
    pub fn resident_slots(&self) -> usize {
        self.queue
            .iter()
            .take(self.max_size)
            .filter(|p| p.slot.is_some())
            .count()
    }

    /// Flush history: (reason, batch size) per flush.
    pub fn flush_log(&self) -> &[(FlushReason, usize)] {
        &self.flushes
    }

    /// `gcharm_insert_request`: add a work request at time `now`, updating
    /// the inter-arrival maximum; if slot-sorting is on, binary-insert by
    /// device slot (section 3.2's O(log N!) incremental sort).
    pub fn insert(&mut self, item: Pending, now: f64) {
        if let Some(last) = self.last_arrival {
            let gap = (now - last).max(0.0);
            if gap > self.max_interval {
                self.max_interval = gap;
            }
        }
        self.last_arrival = Some(now);
        self.arrivals_since_flush += 1;

        if self.sort_by_slot {
            let key = item.slot.unwrap_or(u32::MAX);
            // Upper-bound binary search over the VecDeque (stable).
            let mut lo = 0usize;
            let mut hi = self.queue.len();
            while lo < hi {
                let mid = (lo + hi) / 2;
                self.probes += 1;
                if self.queue[mid].slot.unwrap_or(u32::MAX) <= key {
                    lo = mid + 1;
                } else {
                    hi = mid;
                }
            }
            self.queue.insert(lo, item);
        } else {
            self.queue.push_back(item);
        }
    }

    /// The periodic *combine* routine: decide whether to flush now.
    pub fn poll(&mut self, now: f64) -> Option<Batch> {
        match self.policy {
            CombinePolicy::Adaptive => {
                if self.queue.len() >= self.max_size {
                    return Some(self.take(self.max_size, FlushReason::Full));
                }
                if !self.queue.is_empty() {
                    let last = self.last_arrival.unwrap_or(now);
                    if now - last > 2.0 * self.max_interval {
                        let n = self.queue.len();
                        return Some(self.take(n, FlushReason::IdleTimeout));
                    }
                }
                None
            }
            CombinePolicy::StaticEvery(period) => {
                if (self.arrivals_since_flush >= period || self.residual)
                    && !self.queue.is_empty()
                {
                    let n = self.queue.len().min(self.max_size);
                    return Some(self.take(n, FlushReason::StaticPeriod));
                }
                None
            }
        }
    }

    /// Forced drain of everything pending (iteration end / shutdown).
    /// Batches are capped at max_size; call until `None`.
    pub fn force_flush(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.queue.len().min(self.max_size);
        Some(self.take(n, FlushReason::Forced))
    }

    /// Drain one batch (capped at max_size) for migration to another
    /// device. Unlike `force_flush` the reason is `Stolen`, and an
    /// in-progress residual drain (static policy) survives the steal.
    pub fn steal_flush(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.queue.len().min(self.max_size);
        Some(self.take(n, FlushReason::Stolen))
    }

    /// Drain one batch (capped at max_size) because a latency-class
    /// job's deadline budget is running out. Fires below `maxSize` — the
    /// whole point is to trade occupancy for tail latency — and, like a
    /// full/idle flush, counts as this queue's own flush cycle (arrival
    /// debt resets; `take`'s residual match leaves no residual debt for
    /// `Deadline` since callers loop until `None`). Call until `None`.
    pub fn deadline_flush(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.queue.len().min(self.max_size);
        Some(self.take(n, FlushReason::Deadline))
    }

    /// Earliest arrival time among this queue's pending requests of one
    /// job, if any. The coordinator's deadline-flush trigger compares it
    /// against the job's deadline budget.
    pub fn oldest_arrival_of(&self, job: JobId) -> Option<f64> {
        self.queue
            .iter()
            .filter(|p| p.wr.job == job)
            .map(|p| p.wr.arrival)
            .fold(None, |m, a| {
                Some(match m {
                    Some(m) if m <= a => m,
                    _ => a,
                })
            })
    }

    fn take(&mut self, n: usize, reason: FlushReason) -> Batch {
        let items = self.select(n);
        // A steal is not this queue's own flush cycle: the victim's
        // arrival debt (static policy) keeps counting toward its next
        // period flush so the leftovers are not stalled a full period.
        if reason != FlushReason::Stolen {
            self.arrivals_since_flush = 0;
        }
        // A capped period or forced flush leaves residuals that must not
        // wait a whole further period: `force_flush` callers that loop
        // until `None` never see the flag, but a single forced flush
        // (chaos flush jitter, future one-shot drains) must not strand
        // its leftovers behind a fresh arrival count. A steal neither
        // creates nor clears that debt (the leftovers it skips still
        // must drain promptly); a full-occupancy or idle flush clears it.
        self.residual = !self.queue.is_empty()
            && match reason {
                FlushReason::StaticPeriod | FlushReason::Forced => true,
                FlushReason::Stolen => self.residual,
                _ => false,
            };
        self.flushes.push((reason, items.len()));
        Batch { items, reason }
    }

    /// Drain `n` requests from the queue. A full drain, or a queue
    /// holding only one job, takes the exact FIFO/slot-sorted prefix as
    /// before. An *oversubscribed* multi-job flush (requests left behind)
    /// instead gives each job a weighted-fair quota of the launch —
    /// largest-remainder on the per-job weights, shortfalls refilled in
    /// queue order — so one bursty job cannot starve its co-tenants out
    /// of consecutive launches. Selection is stable: the relative queue
    /// order (and therefore slot-sorted coalescing order) of the taken
    /// requests is preserved.
    fn select(&mut self, n: usize) -> Vec<Pending> {
        if n >= self.queue.len() {
            return self.queue.drain(..).collect();
        }
        // Distinct jobs present, first-seen order, with their counts.
        let mut jobs: Vec<(u64, usize)> = Vec::new();
        for p in &self.queue {
            let j = p.wr.job.0;
            match jobs.iter_mut().find(|(id, _)| *id == j) {
                Some((_, c)) => *c += 1,
                None => jobs.push((j, 1)),
            }
        }
        if jobs.len() <= 1 {
            return self.queue.drain(..n).collect();
        }
        self.cross_job_takes += 1;

        // Weighted quotas summing exactly to n (largest remainder).
        let weight = |j: u64| -> f64 {
            self.job_weights.get(&j).copied().unwrap_or(1.0)
        };
        let shares: Vec<f64> = jobs.iter().map(|&(j, _)| weight(j)).collect();
        let total_w: f64 = shares.iter().sum();
        let ideal: Vec<f64> =
            shares.iter().map(|w| n as f64 * w / total_w).collect();
        let mut quota: Vec<usize> =
            ideal.iter().map(|x| x.floor() as usize).collect();
        let mut left = n - quota.iter().sum::<usize>();
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by(|&a, &b| {
            let ra = ideal[a] - quota[a] as f64;
            let rb = ideal[b] - quota[b] as f64;
            rb.partial_cmp(&ra).expect("finite remainders")
        });
        for &i in &order {
            if left == 0 {
                break;
            }
            quota[i] += 1;
            left -= 1;
        }

        // Stable selection pass: honor quotas, then refill any shortfall
        // (a job with fewer pending requests than its quota) in queue
        // order.
        let mut selected = vec![false; self.queue.len()];
        let mut taken = 0usize;
        for (i, p) in self.queue.iter().enumerate() {
            if taken == n {
                break;
            }
            let ji = jobs
                .iter()
                .position(|&(j, _)| j == p.wr.job.0)
                .expect("job counted above");
            if quota[ji] > 0 {
                quota[ji] -= 1;
                selected[i] = true;
                taken += 1;
            }
        }
        if taken < n {
            for s in selected.iter_mut() {
                if taken == n {
                    break;
                }
                if !*s {
                    *s = true;
                    taken += 1;
                }
            }
        }

        let mut items = Vec::with_capacity(n);
        let mut rest = VecDeque::with_capacity(self.queue.len() - n);
        for (i, p) in std::mem::take(&mut self.queue).into_iter().enumerate()
        {
            if selected[i] {
                items.push(p);
            } else {
                rest.push_back(p);
            }
        }
        self.queue = rest;
        items
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::chare::ChareId;
    use crate::coordinator::registry::KernelKindId;
    use crate::coordinator::work_request::Tile;

    fn wr(id: u64, arrival: f64) -> WorkRequest {
        WorkRequest {
            id,
            job: JobId(0),
            chare: ChareId::new(0, id as u32),
            kind: KernelKindId(0),
            buffer: Some(id),
            data_items: 10,
            tag: 0,
            arrival,
            payload: Tile::default(),
        }
    }

    fn pending(id: u64, arrival: f64, slot: Option<u32>) -> Pending {
        Pending { wr: wr(id, arrival), slot, staged_bytes: 0 }
    }

    #[test]
    fn adaptive_flushes_at_max_size() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 4, false);
        for i in 0..3 {
            c.insert(pending(i, i as f64 * 0.001, None), i as f64 * 0.001);
            assert!(c.poll(i as f64 * 0.001).is_none());
        }
        c.insert(pending(3, 0.003, None), 0.003);
        let b = c.poll(0.003).expect("flush at max size");
        assert_eq!(b.reason, FlushReason::Full);
        assert_eq!(b.items.len(), 4);
        assert!(c.is_empty());
    }

    #[test]
    fn adaptive_takes_exactly_max_size_leaving_rest() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 4, false);
        for i in 0..6 {
            c.insert(pending(i, 0.0, None), 0.0);
        }
        let b = c.poll(0.0).unwrap();
        assert_eq!(b.items.len(), 4);
        assert_eq!(c.len(), 2);
        let ids: Vec<u64> = b.items.iter().map(|p| p.wr.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]); // FIFO order preserved
    }

    #[test]
    fn adaptive_idle_timeout_uses_twice_max_interval() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 100, false);
        // arrivals at t=0 and t=0.01: maxInterval = 0.01
        c.insert(pending(0, 0.0, None), 0.0);
        c.insert(pending(1, 0.01, None), 0.01);
        assert!((c.max_interval() - 0.01).abs() < 1e-12);
        // gap of 0.015 < 2 x 0.01: hold
        assert!(c.poll(0.025).is_none());
        // gap of 0.021 > 2 x 0.01: flush all available
        let b = c.poll(0.0311).expect("idle flush");
        assert_eq!(b.reason, FlushReason::IdleTimeout);
        assert_eq!(b.items.len(), 2);
    }

    #[test]
    fn adaptive_empty_never_flushes() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 4, false);
        assert!(c.poll(100.0).is_none());
    }

    #[test]
    fn static_flushes_on_period() {
        let mut c = Combiner::new(CombinePolicy::StaticEvery(3), 100, false);
        c.insert(pending(0, 0.0, None), 0.0);
        c.insert(pending(1, 0.0, None), 0.0);
        assert!(c.poll(0.0).is_none());
        c.insert(pending(2, 0.0, None), 0.0);
        let b = c.poll(0.0).expect("static flush");
        assert_eq!(b.reason, FlushReason::StaticPeriod);
        assert_eq!(b.items.len(), 3);
        // counter reset
        c.insert(pending(3, 0.0, None), 0.0);
        assert!(c.poll(0.0).is_none());
    }

    #[test]
    fn static_batch_capped_at_max_size() {
        let mut c = Combiner::new(CombinePolicy::StaticEvery(8), 4, false);
        for i in 0..8 {
            c.insert(pending(i, 0.0, None), 0.0);
        }
        let b = c.poll(0.0).unwrap();
        assert_eq!(b.items.len(), 4);
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn static_residual_drains_on_subsequent_polls() {
        // period flush capped at max_size must not strand the leftovers
        // until the next full period
        let mut c = Combiner::new(CombinePolicy::StaticEvery(8), 3, false);
        for i in 0..8 {
            c.insert(pending(i, 0.0, None), 0.0);
        }
        let b = c.poll(0.0).expect("period flush");
        assert_eq!(b.items.len(), 3);
        // residuals drain immediately, still capped at max_size
        let b2 = c.poll(0.0).expect("residual flush");
        assert_eq!(b2.reason, FlushReason::StaticPeriod);
        assert_eq!(b2.items.len(), 3);
        let b3 = c.poll(0.0).expect("residual flush");
        assert_eq!(b3.items.len(), 2);
        assert!(c.is_empty());
        // debt cleared: the next arrival does not trigger an early flush
        c.insert(pending(8, 0.0, None), 0.0);
        assert!(c.poll(0.0).is_none());
    }

    #[test]
    fn static_residual_drains_next_poll_despite_subperiod_arrivals() {
        // Regression for the StaticEvery residual stall: a period flush
        // capped at max_size must drain its leftovers on the very next
        // poll — not after another full period of arrivals, and new
        // sub-period arrivals must not postpone the drain.
        let mut c = Combiner::new(CombinePolicy::StaticEvery(10), 4, false);
        for i in 0..10 {
            c.insert(pending(i, 0.0, None), 0.0);
        }
        let b = c.poll(0.0).expect("period flush");
        assert_eq!(b.items.len(), 4);
        assert_eq!(c.len(), 6, "6 leftovers stranded by the cap");
        // one new arrival: far below the period of 10, yet the residual
        // debt must still drain now
        c.insert(pending(10, 0.001, None), 0.001);
        let b2 = c.poll(0.001).expect("residual drains on next poll");
        assert_eq!(b2.reason, FlushReason::StaticPeriod);
        assert_eq!(b2.items.len(), 4);
        let b3 = c.poll(0.001).expect("remaining residual drains");
        assert_eq!(b3.items.len(), 3);
        assert!(c.is_empty());
        // debt fully cleared: sub-period arrivals hold again
        c.insert(pending(11, 0.002, None), 0.002);
        assert!(c.poll(0.002).is_none());
    }

    #[test]
    fn steal_flush_caps_and_reports_stolen() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 4, false);
        for i in 0..6 {
            c.insert(pending(i, 0.0, None), 0.0);
        }
        let b = c.steal_flush().expect("steal");
        assert_eq!(b.reason, FlushReason::Stolen);
        assert_eq!(b.items.len(), 4, "stolen batches capped at max_size");
        assert_eq!(c.len(), 2, "rest stays with the victim");
        assert!(c.steal_flush().is_some());
        assert!(c.steal_flush().is_none());
    }

    #[test]
    fn steal_does_not_reset_static_arrival_debt() {
        // A steal is the thief's launch, not the victim's flush: the
        // victim's arrival count keeps building toward its period so the
        // leftovers are not stalled a full fresh period.
        let mut c = Combiner::new(CombinePolicy::StaticEvery(3), 10, false);
        c.insert(pending(0, 0.0, None), 0.0);
        c.insert(pending(1, 0.0, None), 0.0);
        assert!(c.poll(0.0).is_none(), "2 of 3 arrivals");
        assert_eq!(c.steal_flush().unwrap().items.len(), 2);
        // one more arrival completes the original period
        c.insert(pending(2, 0.0, None), 0.0);
        let b = c.poll(0.0).expect("period completes despite the steal");
        assert_eq!(b.reason, FlushReason::StaticPeriod);
        assert_eq!(b.items.len(), 1);
    }

    #[test]
    fn steal_preserves_residual_debt() {
        // period flush capped -> residual debt; a steal takes some of the
        // leftovers but must not cancel the prompt drain of the rest
        let mut c = Combiner::new(CombinePolicy::StaticEvery(8), 3, false);
        for i in 0..8 {
            c.insert(pending(i, 0.0, None), 0.0);
        }
        assert_eq!(c.poll(0.0).unwrap().items.len(), 3);
        assert_eq!(c.steal_flush().unwrap().items.len(), 3);
        assert_eq!(c.len(), 2);
        let b = c.poll(0.0).expect("residual still drains after steal");
        assert_eq!(b.reason, FlushReason::StaticPeriod);
        assert_eq!(b.items.len(), 2);
    }

    #[test]
    fn single_forced_flush_leaves_residual_debt() {
        // Regression (found by the chaos harness's flush jitter): one
        // forced flush on an oversized StaticEvery queue was clearing
        // both the arrival count and the residual flag, stranding the
        // capped-off leftovers for a full fresh period. A single Forced
        // flush with leftovers must leave the residual debt set so the
        // next poll drains them.
        let mut c = Combiner::new(CombinePolicy::StaticEvery(8), 3, false);
        for i in 0..8 {
            c.insert(pending(i, 0.0, None), 0.0);
        }
        let b = c.force_flush().expect("forced flush");
        assert_eq!(b.reason, FlushReason::Forced);
        assert_eq!(b.items.len(), 3);
        assert_eq!(c.len(), 5, "cap left 5 behind");
        // no new arrivals: the leftovers still drain on the next polls
        let b2 = c.poll(0.0).expect("residual drains after forced flush");
        assert_eq!(b2.reason, FlushReason::StaticPeriod);
        assert_eq!(b2.items.len(), 3);
        assert_eq!(c.poll(0.0).expect("rest drains").items.len(), 2);
        assert!(c.is_empty());
        // debt cleared: sub-period arrivals hold again
        c.insert(pending(8, 0.0, None), 0.0);
        assert!(c.poll(0.0).is_none());
    }

    #[test]
    fn static_uncapped_flush_leaves_no_residual_debt() {
        let mut c = Combiner::new(CombinePolicy::StaticEvery(3), 100, false);
        for i in 0..3 {
            c.insert(pending(i, 0.0, None), 0.0);
        }
        assert!(c.poll(0.0).is_some());
        assert!(c.is_empty());
        c.insert(pending(3, 0.0, None), 0.0);
        assert!(c.poll(0.0).is_none(), "no residual debt after full drain");
    }

    #[test]
    fn force_flush_drains_in_caps() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 4, false);
        for i in 0..10 {
            c.insert(pending(i, 0.0, None), 0.0);
        }
        let mut sizes = Vec::new();
        while let Some(b) = c.force_flush() {
            assert_eq!(b.reason, FlushReason::Forced);
            sizes.push(b.items.len());
        }
        assert_eq!(sizes, vec![4, 4, 2]);
        assert!(c.is_empty());
    }

    #[test]
    fn slot_sorted_insert_orders_batch_by_slot() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 8, true);
        for (i, &s) in [7u32, 2, 9, 4, 0, 5].iter().enumerate() {
            c.insert(pending(i as u64, 0.0, Some(s)), 0.0);
        }
        let mut drained = Vec::new();
        while let Some(b) = c.force_flush() {
            drained.extend(b.items.into_iter().map(|p| p.slot.unwrap()));
        }
        assert_eq!(drained, vec![0, 2, 4, 5, 7, 9]);
        assert!(c.probes() > 0);
    }

    #[test]
    fn unsorted_keeps_arrival_order() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 8, false);
        for (i, &s) in [7u32, 2, 9].iter().enumerate() {
            c.insert(pending(i as u64, 0.0, Some(s)), 0.0);
        }
        let b = c.force_flush().unwrap();
        let slots: Vec<u32> = b.items.iter().map(|p| p.slot.unwrap()).collect();
        assert_eq!(slots, vec![7, 2, 9]);
    }

    fn pending_job(id: u64, job: u64) -> Pending {
        let mut p = pending(id, 0.0, None);
        p.wr.job = JobId(job);
        p
    }

    #[test]
    fn oversubscribed_multi_job_take_is_fair() {
        // job 0 floods 12 requests, then job 1 adds 4; an 8-slot flush
        // under equal weights gives each job 4 slots instead of handing
        // the whole launch to the flood.
        let mut c = Combiner::new(CombinePolicy::Adaptive, 8, false);
        for i in 0..12 {
            c.insert(pending_job(i, 0), 0.0);
        }
        for i in 12..16 {
            c.insert(pending_job(i, 1), 0.0);
        }
        let b = c.poll(0.0).expect("full flush");
        assert_eq!(b.items.len(), 8);
        let job1 = b.items.iter().filter(|p| p.wr.job == JobId(1)).count();
        assert_eq!(job1, 4, "job 1 gets its equal share");
        assert_eq!(c.cross_job_takes(), 1);
        // stable: job-0 requests keep FIFO order, job-1 likewise
        let ids0: Vec<u64> = b
            .items
            .iter()
            .filter(|p| p.wr.job == JobId(0))
            .map(|p| p.wr.id)
            .collect();
        assert_eq!(ids0, vec![0, 1, 2, 3]);
    }

    #[test]
    fn fair_take_respects_learned_weights() {
        // job 0 measured 3x heavier per request: its weight drops to 1/3,
        // so an 8-slot flush gives it 2 slots and job 1 six.
        let mut c = Combiner::new(CombinePolicy::Adaptive, 8, false);
        c.set_job_weight(JobId(0), 1.0 / 3.0);
        for i in 0..10 {
            c.insert(pending_job(i, 0), 0.0);
        }
        for i in 10..20 {
            c.insert(pending_job(i, 1), 0.0);
        }
        let b = c.poll(0.0).expect("full flush");
        let job0 = b.items.iter().filter(|p| p.wr.job == JobId(0)).count();
        assert_eq!(job0, 2, "heavy job throttled to its weighted share");
    }

    #[test]
    fn fair_take_refills_shortfall_from_queue_order() {
        // job 1 has only 1 request; its unused quota refills FIFO.
        let mut c = Combiner::new(CombinePolicy::Adaptive, 8, false);
        for i in 0..11 {
            c.insert(pending_job(i, 0), 0.0);
        }
        c.insert(pending_job(11, 1), 0.0);
        let b = c.poll(0.0).expect("full flush");
        assert_eq!(b.items.len(), 8, "shortfall refilled to a full launch");
        assert!(b.items.iter().any(|p| p.wr.job == JobId(1)));
    }

    #[test]
    fn single_job_take_keeps_exact_fifo_prefix() {
        // the multi-tenant path must not perturb single-job behavior
        let mut c = Combiner::new(CombinePolicy::Adaptive, 4, false);
        c.set_job_weight(JobId(0), 0.25);
        for i in 0..6 {
            c.insert(pending_job(i, 0), 0.0);
        }
        let b = c.poll(0.0).unwrap();
        let ids: Vec<u64> = b.items.iter().map(|p| p.wr.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(c.cross_job_takes(), 0);
    }

    #[test]
    fn resident_slots_counts_staged_requests_within_cap() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 3, false);
        c.insert(pending(0, 0.0, Some(1)), 0.0);
        c.insert(pending(1, 0.0, None), 0.0);
        c.insert(pending(2, 0.0, Some(2)), 0.0);
        // beyond the max_size cap: not part of the stealable batch
        c.insert(pending(3, 0.0, Some(3)), 0.0);
        assert_eq!(c.resident_slots(), 2);
        assert_eq!(
            Combiner::new(CombinePolicy::Adaptive, 4, false).resident_slots(),
            0
        );
    }

    #[test]
    fn deadline_flush_fires_below_max_size() {
        // the whole point: a deadline drain must not wait for maxSize
        let mut c = Combiner::new(CombinePolicy::Adaptive, 104, false);
        c.insert(pending(0, 0.0, None), 0.0);
        c.insert(pending(1, 0.0001, None), 0.0001);
        let b = c.deadline_flush().expect("deadline flush");
        assert_eq!(b.reason, FlushReason::Deadline);
        assert_eq!(b.items.len(), 2);
        assert!(c.is_empty());
        assert!(c.deadline_flush().is_none(), "empty queue never flushes");
    }

    #[test]
    fn deadline_flush_caps_at_max_size() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 4, false);
        for i in 0..6 {
            c.insert(pending(i, 0.0, None), 0.0);
        }
        let b = c.deadline_flush().unwrap();
        assert_eq!(b.items.len(), 4);
        let b2 = c.deadline_flush().expect("loop until None drains all");
        assert_eq!(b2.items.len(), 2);
        assert!(c.deadline_flush().is_none());
    }

    #[test]
    fn deadline_flush_leaves_no_residual_debt() {
        // unlike a capped Forced flush, deadline callers loop until None,
        // so a lone capped deadline drain must not arm the static
        // residual fast-path for requests that arrive later
        let mut c = Combiner::new(CombinePolicy::StaticEvery(8), 3, false);
        for i in 0..4 {
            c.insert(pending(i, 0.0, None), 0.0);
        }
        assert_eq!(c.deadline_flush().unwrap().items.len(), 3);
        assert_eq!(c.deadline_flush().unwrap().items.len(), 1);
        c.insert(pending(4, 0.0, None), 0.0);
        assert!(c.poll(0.0).is_none(), "1 of 8 arrivals: period holds");
    }

    #[test]
    fn oldest_arrival_scans_per_job() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 100, false);
        assert!(c.oldest_arrival_of(JobId(0)).is_none());
        let mut a = pending(0, 0.5, None);
        a.wr.job = JobId(1);
        c.insert(a, 0.5);
        c.insert(pending(1, 0.7, None), 0.7);
        c.insert(pending(2, 0.6, None), 0.6);
        assert!((c.oldest_arrival_of(JobId(0)).unwrap() - 0.6).abs() < 1e-12);
        assert!((c.oldest_arrival_of(JobId(1)).unwrap() - 0.5).abs() < 1e-12);
        assert!(c.oldest_arrival_of(JobId(9)).is_none());
    }

    #[test]
    fn max_interval_is_running_max() {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 100, false);
        c.insert(pending(0, 0.0, None), 0.0);
        c.insert(pending(1, 0.005, None), 0.005);
        c.insert(pending(2, 0.006, None), 0.006); // smaller gap: no change
        assert!((c.max_interval() - 0.005).abs() < 1e-12);
    }
}
