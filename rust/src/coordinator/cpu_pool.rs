//! CPU worker pool for the hybrid split (paper section 3.3).
//!
//! The CPU half of a hybrid split used to ride on the PE threads,
//! serialized behind whatever chare messages each PE was already
//! processing. This pool gives the CPU side its own small set of worker
//! threads: a flushed batch's CPU prefix is chunked by cumulative
//! `data_items` (the paper's workload model) into at most one chunk per
//! worker, the chunks execute concurrently, and each worker reports its
//! own timing back to the coordinator. The coordinator folds the
//! per-worker timings into one `HybridScheduler::record_cpu` observation
//! per batch -- total items over the batch *makespan* -- so the adaptive
//! split sees the pool's true per-item rate (W workers make the pool ~W
//! times faster per item than one worker; recording per-chunk rates would
//! report the single-worker rate instead).
//!
//! Execution is table-driven: each request's registered family provides
//! the native `slot_fn` and constant, so any family with
//! `cpu_fallback: true` runs here without pool changes.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::util::timeline::SpanKind;

use super::chare::JobId;
use super::combiner::Pending;
use super::registry::SharedRegistry;
use super::scheduler::{CoordMsg, Shared};
use super::work_request::WrResult;
use super::ChareId;

/// Messages a pool worker consumes.
enum PoolMsg {
    /// Execute one chunk of a hybrid batch.
    Chunk { batch: u64, items: Vec<Pending> },
    Stop,
}

/// Execute a slice of pending work requests with their families' native
/// slot functions. Returns (total data items, per-request results tagged
/// with their owning jobs — a hybrid batch may mix co-tenant jobs).
///
/// The registry read guard is held only long enough to clone the batch's
/// kernel `Arc`s: the actual kernel math (potentially milliseconds) runs
/// without the lock, so a concurrent `submit_job` registering new
/// families is never serialized behind a CPU batch.
pub(crate) fn execute_pending(
    registry: &SharedRegistry,
    batch: &[Pending],
) -> (usize, Vec<(JobId, ChareId, WrResult)>) {
    let kernels: Vec<Arc<crate::runtime::TileKernel>> = {
        let reg = registry.read();
        batch.iter().map(|p| reg.kernel(p.wr.kind).clone()).collect()
    };
    let mut items = 0usize;
    let mut results = Vec::with_capacity(batch.len());
    for (p, kernel) in batch.iter().zip(&kernels) {
        items += p.wr.data_items;
        let slices: Vec<&[f32]> =
            p.wr.payload.bufs.iter().map(Vec::as_slice).collect();
        let out = (kernel.slot_fn)(&slices, &kernel.constant);
        results.push((
            p.wr.job,
            p.wr.chare,
            WrResult {
                wr_id: p.wr.id,
                tag: p.wr.tag,
                kind: p.wr.kind,
                out,
            },
        ));
    }
    (items, results)
}

/// Split a batch into at most `parts` contiguous chunks with roughly equal
/// cumulative `data_items` (order preserved; chunks are non-empty).
pub fn chunk_by_items(batch: Vec<Pending>, parts: usize) -> Vec<Vec<Pending>> {
    let parts = parts.max(1);
    if batch.is_empty() {
        return Vec::new();
    }
    let total: usize = batch.iter().map(|p| p.wr.data_items).sum();
    let mut chunks: Vec<Vec<Pending>> = Vec::with_capacity(parts);
    let mut cur: Vec<Pending> = Vec::new();
    let mut cum = 0usize;
    for p in batch {
        cum += p.wr.data_items;
        cur.push(p);
        // Cut once the cumulative sum crosses the next even share, while
        // later requests still have a chunk to land in.
        if chunks.len() + 1 < parts && cum * parts >= total * (chunks.len() + 1)
        {
            chunks.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        chunks.push(cur);
    }
    chunks
}

/// Handle to the worker threads. Owned by the coordinator; workers send
/// `CoordMsg::CpuChunk` results straight to the coordinator queue.
pub(crate) struct CpuPool {
    txs: Vec<Sender<PoolMsg>>,
    handles: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    next_batch: u64,
    rr: usize,
}

impl CpuPool {
    pub(crate) fn spawn(
        workers: usize,
        coord: Sender<CoordMsg>,
        shared: Arc<Shared>,
        registry: Arc<SharedRegistry>,
    ) -> Result<CpuPool> {
        let workers = workers.max(1);
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<PoolMsg>();
            let coord = coord.clone();
            let shared = shared.clone();
            let registry = registry.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("cpu-pool-{w}"))
                    .spawn(move || worker_loop(rx, coord, shared, registry))?,
            );
            txs.push(tx);
        }
        Ok(CpuPool { txs, handles, shared, next_batch: 0, rr: 0 })
    }

    /// Fan a batch out across the workers. Returns the batch id and the
    /// number of chunks submitted; the coordinator folds that many
    /// `CpuChunk` messages back into one hybrid observation. Each chunk
    /// holds +1 on `outstanding` until its result message is processed.
    pub(crate) fn submit(&mut self, batch: Vec<Pending>) -> (u64, usize) {
        let id = self.next_batch;
        self.next_batch += 1;
        let chunks = chunk_by_items(batch, self.txs.len());
        let n = chunks.len();
        self.shared
            .outstanding
            .fetch_add(n as i64, Ordering::SeqCst);
        for chunk in chunks {
            let w = self.rr % self.txs.len();
            self.rr += 1;
            self.txs[w]
                .send(PoolMsg::Chunk { batch: id, items: chunk })
                .expect("cpu pool worker is down");
        }
        (id, n)
    }
}

impl Drop for CpuPool {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(PoolMsg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    rx: Receiver<PoolMsg>,
    coord: Sender<CoordMsg>,
    shared: Arc<Shared>,
    registry: Arc<SharedRegistry>,
) {
    while let Ok(msg) = rx.recv() {
        match msg {
            PoolMsg::Chunk { batch, items } => {
                let t0 = Instant::now();
                let (n_items, results) = execute_pending(&registry, &items);
                let secs = t0.elapsed().as_secs_f64();
                shared.timeline.record(
                    SpanKind::CpuTask,
                    "cpu-pool-chunk",
                    shared.timeline.now() - secs,
                    secs,
                    0.0,
                    n_items as u64,
                );
                // The chunk's +1 hold rides along with this message and is
                // released by the coordinator.
                if coord
                    .send(CoordMsg::CpuChunk {
                        batch,
                        items: n_items,
                        secs,
                        results,
                    })
                    .is_err()
                {
                    break; // coordinator went away
                }
            }
            PoolMsg::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::{md_descriptor, KernelKindId};
    use crate::coordinator::work_request::{Tile, WorkRequest};
    use crate::runtime::shapes::{MD_PAD_POS, MD_W, PARTS_PER_PATCH};

    fn md_registry() -> Arc<SharedRegistry> {
        let reg = SharedRegistry::new();
        reg.register(md_descriptor([1.0, 0.04, 1.0])).unwrap();
        Arc::new(reg)
    }

    fn md_pending(id: u64, items: usize) -> Pending {
        let mut pa = vec![MD_PAD_POS; PARTS_PER_PATCH * MD_W];
        let mut pb = vec![MD_PAD_POS; PARTS_PER_PATCH * MD_W];
        pa[0] = 0.0;
        pa[1] = 0.0;
        pb[0] = 0.1;
        pb[1] = 0.0;
        Pending {
            wr: WorkRequest {
                id,
                job: JobId(0),
                chare: ChareId::new(0, id as u32),
                kind: KernelKindId(0),
                buffer: None,
                data_items: items,
                tag: id,
                arrival: 0.0,
                payload: Tile::new(vec![pa, pb]),
            },
            slot: None,
            staged_bytes: 0,
        }
    }

    #[test]
    fn chunks_balance_by_items_and_preserve_order() {
        let batch: Vec<Pending> =
            (0..10).map(|i| md_pending(i, 10)).collect();
        let chunks = chunk_by_items(batch, 2);
        assert_eq!(chunks.len(), 2);
        let a: usize =
            chunks[0].iter().map(|p| p.wr.data_items).sum();
        let b: usize =
            chunks[1].iter().map(|p| p.wr.data_items).sum();
        assert_eq!(a, 50);
        assert_eq!(b, 50);
        let ids: Vec<u64> = chunks
            .iter()
            .flatten()
            .map(|p| p.wr.id)
            .collect();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn chunks_follow_item_weights_not_counts() {
        // one heavy head + many light: the heavy request fills chunk 0
        let mut batch = vec![md_pending(0, 90)];
        batch.extend((1..10).map(|i| md_pending(i, 1)));
        let chunks = chunk_by_items(batch, 3);
        assert!(chunks.len() <= 3);
        assert_eq!(chunks[0].len(), 1, "heavy head is its own chunk");
    }

    #[test]
    fn fewer_requests_than_workers() {
        let chunks = chunk_by_items(vec![md_pending(0, 5)], 4);
        assert_eq!(chunks.len(), 1);
        assert!(chunk_by_items(Vec::new(), 4).is_empty());
    }

    #[test]
    fn execute_pending_runs_registered_slot_fn() {
        let reg = md_registry();
        let (items, results) = execute_pending(&reg, &[md_pending(5, 2)]);
        assert_eq!(items, 2);
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].0, JobId(0), "result carries its job");
        assert_eq!(results[0].2.wr_id, 5);
        assert!(results[0].2.out[0] < 0.0, "repelled in -x");
    }

    #[test]
    fn pool_executes_chunks_on_two_workers() {
        let (coord_tx, coord_rx) = channel::<CoordMsg>();
        let shared = Shared::new();
        let mut pool =
            CpuPool::spawn(2, coord_tx, shared.clone(), md_registry())
                .unwrap();

        let batch: Vec<Pending> =
            (0..8).map(|i| md_pending(i, 4)).collect();
        let (id, chunks) = pool.submit(batch);
        assert_eq!(chunks, 2, "8 equal requests split across both workers");
        assert_eq!(shared.outstanding(), 2, "one hold per chunk");

        let mut got_items = 0usize;
        let mut got_results = Vec::new();
        for _ in 0..chunks {
            match coord_rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .expect("chunk result")
            {
                CoordMsg::CpuChunk { batch, items, secs, results } => {
                    assert_eq!(batch, id);
                    assert!(secs >= 0.0);
                    got_items += items;
                    got_results.extend(results);
                }
                _ => panic!("expected CpuChunk"),
            }
        }
        assert_eq!(got_items, 32);
        assert_eq!(got_results.len(), 8);
        // every request computed the same single-pair repulsion
        for (_, _, r) in &got_results {
            assert!(r.out[0] < 0.0, "repelled in -x");
        }
    }

    #[test]
    fn pool_batches_correlate_by_id() {
        let (coord_tx, coord_rx) = channel::<CoordMsg>();
        let shared = Shared::new();
        let mut pool =
            CpuPool::spawn(3, coord_tx, shared.clone(), md_registry())
                .unwrap();
        let (id_a, n_a) =
            pool.submit((0..6).map(|i| md_pending(i, 2)).collect());
        let (id_b, n_b) =
            pool.submit((6..12).map(|i| md_pending(i, 2)).collect());
        assert_ne!(id_a, id_b);
        let mut per_batch = std::collections::HashMap::new();
        for _ in 0..n_a + n_b {
            match coord_rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .unwrap()
            {
                CoordMsg::CpuChunk { batch, results, .. } => {
                    *per_batch.entry(batch).or_insert(0usize) +=
                        results.len();
                }
                _ => panic!("expected CpuChunk"),
            }
        }
        assert_eq!(per_batch.get(&id_a), Some(&6));
        assert_eq!(per_batch.get(&id_b), Some(&6));
    }
}
