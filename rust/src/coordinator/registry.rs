//! The open kernel registry: apps register kernel families at startup.
//!
//! The runtime used to hardcode exactly three kernel families as closed
//! enums (`WorkKind::{Force, Ewald, MdInteract}`) threaded through every
//! layer. This module replaces that surface: an app calls
//! [`crate::coordinator::GCharm::register_kernel`] with a
//! [`KernelDescriptor`] — the runtime half ([`TileKernel`]: tile shapes,
//! constants, occupancy resources, native slot function) plus the
//! scheduling policy half (combine override, slot-sorted insertion,
//! hybrid CPU fallback) — and receives a [`KernelKindId`]. Work requests
//! carry a shape-checked [`Tile`] payload tagged with that id, and every
//! layer (combiners, hybrid scheduler, staging pools, manifest ladders,
//! metrics) is table-driven off the registry.
//!
//! The paper's three families are provided as ready-made descriptors
//! ([`force_descriptor`], [`ewald_descriptor`], [`md_descriptor`]); apps
//! register them like any other family.

use std::sync::{Arc, RwLock, RwLockReadGuard};

use anyhow::{bail, Result};

use crate::runtime::kernel::TileKernel;
use crate::runtime::workqueue::LaunchMode;

use super::combiner::CombinePolicy;
use super::work_request::Tile;

/// Registry handle of one registered kernel family. The wrapped index is
/// the family's position in registration order; it indexes the per-device
/// combiner tables and the per-kind statistics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct KernelKindId(pub usize);

/// A tile buffer whose length disagrees with the registered shape,
/// reported with the offending argument and both lengths.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShapeError {
    /// Registered family name.
    pub kernel: String,
    /// Offending argument (a registered tile name, or a synthetic label
    /// like `<arg count>` / `<entry ids>`).
    pub arg: String,
    /// Expected length (floats, or count for the synthetic labels).
    pub expected: usize,
    /// Actual length found in the submitted payload.
    pub actual: usize,
}

impl std::fmt::Display for ShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "kernel {}: arg {} expects {} elements, got {}",
            self.kernel, self.arg, self.expected, self.actual
        )
    }
}

impl std::error::Error for ShapeError {}

/// Everything the runtime needs to schedule and execute one registered
/// kernel family.
#[derive(Debug, Clone)]
pub struct KernelDescriptor {
    /// The runtime half: tile shapes/widths, constant arg, occupancy
    /// resources (-> combiner maxSize), reuse/gather/entry-cache wiring,
    /// and the native per-slot implementation.
    pub kernel: Arc<TileKernel>,
    /// Per-family combining-policy override (`None` = the runtime
    /// config's policy).
    pub combine: Option<CombinePolicy>,
    /// Keep this family's pending queue sorted by device slot (the
    /// coalescing strategy of paper section 3.2; requires a reuse arg and
    /// takes effect under `DataPolicy::ReuseSorted`).
    pub sort_by_slot: bool,
    /// The family's `slot_fn` also serves as a CPU kernel, making it
    /// eligible for dynamic hybrid CPU/GPU scheduling (section 3.3).
    pub cpu_fallback: bool,
    /// Per-family launch-mode pin (ISSUE 8): `Some(Persistent)` keeps a
    /// resident megakernel loop fed by a work queue, `Some(PerBatch)`
    /// forces a host launch per batch, `None` defers to
    /// `Config::launch_mode` (including the adaptive break-even learner).
    pub launch_mode: Option<LaunchMode>,
}

impl KernelDescriptor {
    /// Descriptor with default policy (runtime combine policy, no
    /// slot-sorting, GPU-only) around a runtime kernel.
    pub fn new(kernel: TileKernel) -> KernelDescriptor {
        KernelDescriptor {
            kernel: Arc::new(kernel),
            combine: None,
            sort_by_slot: false,
            cpu_fallback: false,
            launch_mode: None,
        }
    }

    /// Validate a submitted tile payload against the registered shapes.
    pub fn check(&self, tile: &Tile) -> Result<(), ShapeError> {
        let k = &self.kernel;
        if tile.bufs.len() != k.args.len() {
            return Err(ShapeError {
                kernel: k.name.to_string(),
                arg: "<arg count>".to_string(),
                expected: k.args.len(),
                actual: tile.bufs.len(),
            });
        }
        for (spec, buf) in k.args.iter().zip(&tile.bufs) {
            if buf.len() != spec.slot_len() {
                return Err(ShapeError {
                    kernel: k.name.to_string(),
                    arg: spec.name.to_string(),
                    expected: spec.slot_len(),
                    actual: buf.len(),
                });
            }
        }
        match k.entry_arg {
            Some(ea) => {
                let cap = k.args[ea].rows;
                if tile.entry_ids.len() > cap {
                    return Err(ShapeError {
                        kernel: k.name.to_string(),
                        arg: "<entry ids>".to_string(),
                        expected: cap,
                        actual: tile.entry_ids.len(),
                    });
                }
            }
            None => {
                if !tile.entry_ids.is_empty() {
                    return Err(ShapeError {
                        kernel: k.name.to_string(),
                        arg: "<entry ids>".to_string(),
                        expected: 0,
                        actual: tile.entry_ids.len(),
                    });
                }
            }
        }
        Ok(())
    }
}

/// The registered kernel families of one runtime instance. Frozen
/// (`Arc`-shared) at `GCharm::start`; every layer reads it, none matches
/// on a family.
#[derive(Debug, Clone, Default)]
pub struct KernelRegistry {
    descs: Vec<KernelDescriptor>,
}

impl KernelRegistry {
    pub fn new() -> KernelRegistry {
        KernelRegistry::default()
    }

    /// Register a family; returns its kind id. Rejects duplicate names
    /// and internally inconsistent descriptors.
    pub fn register(&mut self, desc: KernelDescriptor) -> Result<KernelKindId> {
        let k = &desc.kernel;
        if k.args.is_empty() {
            bail!("kernel {}: a family needs at least one tile arg", k.name);
        }
        if k.out_slot_len() == 0 {
            bail!("kernel {}: output slot must be non-empty", k.name);
        }
        if let Some(ra) = k.reuse_arg {
            if ra >= k.args.len() {
                bail!("kernel {}: reuse arg {ra} out of range", k.name);
            }
            if k.gather_name.is_none() {
                bail!("kernel {}: a reuse arg needs a gather family", k.name);
            }
        } else if k.gather_name.is_some() {
            bail!("kernel {}: a gather family needs a reuse arg", k.name);
        }
        if let Some(ea) = k.entry_arg {
            if ea >= k.args.len() {
                bail!("kernel {}: entry arg {ea} out of range", k.name);
            }
        }
        if desc.sort_by_slot && k.reuse_arg.is_none() {
            bail!(
                "kernel {}: slot-sorted combining needs a reuse arg",
                k.name
            );
        }
        if self.find(&k.name).is_some() {
            bail!("kernel {} already registered", k.name);
        }
        self.descs.push(desc);
        Ok(KernelKindId(self.descs.len() - 1))
    }

    /// Number of registered families.
    pub fn len(&self) -> usize {
        self.descs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.descs.is_empty()
    }

    /// The descriptor of one registered family. Panics on a foreign id (a
    /// kind id is only obtainable from this registry's `register`).
    pub fn get(&self, id: KernelKindId) -> &KernelDescriptor {
        &self.descs[id.0]
    }

    /// The runtime kernel of one registered family.
    pub fn kernel(&self, id: KernelKindId) -> &Arc<TileKernel> {
        &self.get(id).kernel
    }

    /// Look a family up by registered name.
    pub fn find(&self, name: &str) -> Option<KernelKindId> {
        self.descs
            .iter()
            .position(|d| &*d.kernel.name == name)
            .map(KernelKindId)
    }

    /// All registered descriptors, in kind order.
    pub fn descriptors(&self) -> &[KernelDescriptor] {
        &self.descs
    }

    /// The runtime kernels, in kind order (what the device pool serves).
    pub fn kernels(&self) -> Vec<Arc<TileKernel>> {
        self.descs.iter().map(|d| d.kernel.clone()).collect()
    }

    /// Kind ids, in registration order.
    pub fn ids(&self) -> impl Iterator<Item = KernelKindId> {
        (0..self.descs.len()).map(KernelKindId)
    }

    /// Validate a payload against one family's registered shapes.
    pub fn check(&self, id: KernelKindId, tile: &Tile) -> Result<(), ShapeError> {
        match self.descs.get(id.0) {
            Some(d) => d.check(tile),
            None => Err(ShapeError {
                kernel: format!("<unregistered kind {}>", id.0),
                arg: "<kind>".to_string(),
                expected: self.descs.len(),
                actual: id.0,
            }),
        }
    }
}

/// Do two descriptors describe the *same* family? Cross-job combining
/// merges tiles of identically named families into one launch, so a
/// re-registration under an existing name is only accepted when every
/// execution-relevant field matches: shapes, constants, outputs,
/// resources, reuse/gather/entry wiring, and the scheduling policy
/// half. The slot function is deliberately *not* compared: function
/// pointers have no reliable identity in Rust (the same fn item can
/// take distinct addresses across codegen units), and the family name
/// plus the full data contract is the identity the runtime keys on.
fn descriptors_compatible(a: &KernelDescriptor, b: &KernelDescriptor) -> bool {
    let (ka, kb) = (&a.kernel, &b.kernel);
    ka.name == kb.name
        && ka.args == kb.args
        && ka.constant == kb.constant
        && ka.out_rows == kb.out_rows
        && ka.out_width == kb.out_width
        && ka.resources == kb.resources
        && ka.items_per_slot == kb.items_per_slot
        && ka.reuse_arg == kb.reuse_arg
        && ka.gather_name == kb.gather_name
        && ka.entry_arg == kb.entry_arg
        && a.combine == b.combine
        && a.sort_by_slot == b.sort_by_slot
        && a.cpu_fallback == b.cpu_fallback
        && a.launch_mode == b.launch_mode
}

/// The append-only kernel registry a persistent
/// [`crate::coordinator::Runtime`] shares across every job it serves.
///
/// Jobs bring their kernel registrations in their
/// [`crate::coordinator::JobSpec`]; registering a descriptor identical to
/// an already-registered family (same name, same shapes/constants/policy)
/// resolves to the *existing* kind id — that shared id is what lets the
/// combiners merge tiles from different jobs into one launch. Registering
/// an incompatible descriptor under a taken name is an error (silently
/// sharing a kind across diverging constants would corrupt both jobs'
/// physics). Ids are never reused or removed while the runtime lives.
#[derive(Debug, Default)]
pub struct SharedRegistry {
    inner: RwLock<KernelRegistry>,
}

impl SharedRegistry {
    pub fn new() -> SharedRegistry {
        SharedRegistry::default()
    }

    /// Seed a shared registry from an existing frozen registry (the
    /// `GCharm` shim path: kernels registered before `start`).
    pub fn from_registry(reg: KernelRegistry) -> SharedRegistry {
        SharedRegistry { inner: RwLock::new(reg) }
    }

    /// Register a family, or resolve an identical re-registration to the
    /// existing id (cross-job sharing). Incompatible re-registrations and
    /// malformed descriptors are rejected with a descriptive error.
    ///
    /// The returned flag reports whether the family was *newly inserted*
    /// by this call, decided atomically under the write lock — callers
    /// that must teach downstream layers about new families (the
    /// coordinator's `KindsAdded`) rely on exactly one registrant
    /// observing `true` per family, even under concurrent `submit_job`s.
    pub fn register(
        &self,
        desc: KernelDescriptor,
    ) -> Result<(KernelKindId, bool)> {
        let mut reg = self.inner.write().expect("registry poisoned");
        if let Some(id) = reg.find(&desc.kernel.name) {
            let existing = reg.get(id);
            if descriptors_compatible(existing, &desc) {
                return Ok((id, false));
            }
            bail!(
                "kernel {}: already registered by another job with a \
                 different descriptor (shapes, constants, or policy \
                 differ); rename the family or align the registrations",
                desc.kernel.name
            );
        }
        reg.register(desc).map(|id| (id, true))
    }

    /// Read access to the underlying registry (shape checks, slot
    /// functions). Hold the guard only briefly: registration blocks on it.
    pub fn read(&self) -> RwLockReadGuard<'_, KernelRegistry> {
        self.inner.read().expect("registry poisoned")
    }

    /// Clone of the current registration set.
    pub fn snapshot(&self) -> KernelRegistry {
        self.read().clone()
    }

    /// Number of registered families so far.
    pub fn len(&self) -> usize {
        self.read().len()
    }

    pub fn is_empty(&self) -> bool {
        self.read().is_empty()
    }

    /// The runtime kernel of one registered family (cloned `Arc`).
    pub fn kernel(&self, id: KernelKindId) -> Arc<TileKernel> {
        self.read().kernel(id).clone()
    }

    /// Look a family up by registered name.
    pub fn find(&self, name: &str) -> Option<KernelKindId> {
        self.read().find(name)
    }

    /// Validate a payload against one family's registered shapes.
    pub fn check(&self, id: KernelKindId, tile: &Tile) -> Result<(), ShapeError> {
        self.read().check(id, tile)
    }
}

/// The N-Body bucket gravity family (paper section 4.1): slot-sorted
/// combining, particle-buffer reuse with a gather variant, entry-cache
/// accounting of the interaction list. GPU-only.
pub fn force_descriptor(eps2: f32) -> KernelDescriptor {
    KernelDescriptor {
        kernel: Arc::new(TileKernel::gravity(eps2)),
        combine: None,
        sort_by_slot: true,
        cpu_fallback: false,
        launch_mode: None,
    }
}

/// The N-Body Ewald periodic-correction family: contiguous transfers (no
/// gather variant), GPU-only.
pub fn ewald_descriptor(ktab: Vec<f32>) -> KernelDescriptor {
    KernelDescriptor {
        kernel: Arc::new(TileKernel::ewald(ktab)),
        combine: None,
        sort_by_slot: false,
        cpu_fallback: false,
        launch_mode: None,
    }
}

/// The MD patch-pair family (paper section 4.2): has kernels on both
/// devices, so it is eligible for dynamic hybrid scheduling (Fig 5).
pub fn md_descriptor(params: [f32; 3]) -> KernelDescriptor {
    KernelDescriptor {
        kernel: Arc::new(TileKernel::md_force(params)),
        combine: None,
        sort_by_slot: false,
        cpu_fallback: true,
        launch_mode: None,
    }
}

/// Registry holding the paper's three built-in families, in
/// (force, ewald, md) kind order. Tests and benches share this set.
pub fn builtin_registry(
    eps2: f32,
    ktab: Vec<f32>,
    md_params: [f32; 3],
) -> KernelRegistry {
    let mut reg = KernelRegistry::new();
    reg.register(force_descriptor(eps2)).expect("force registers");
    reg.register(ewald_descriptor(ktab)).expect("ewald registers");
    reg.register(md_descriptor(md_params)).expect("md registers");
    reg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::shapes::{
        INTERACTIONS, INTER_W, KTABLE, KTAB_W, PARTICLE_W, PARTS_PER_BUCKET,
    };

    fn builtins() -> KernelRegistry {
        builtin_registry(1e-2, vec![0.0; KTABLE * KTAB_W], [1.0, 0.04, 1.0])
    }

    #[test]
    fn registration_assigns_sequential_ids() {
        let reg = builtins();
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.find("gravity"), Some(KernelKindId(0)));
        assert_eq!(reg.find("ewald"), Some(KernelKindId(1)));
        assert_eq!(reg.find("md_force"), Some(KernelKindId(2)));
        assert_eq!(reg.kernel(KernelKindId(0)).max_combine(), 104);
        assert_eq!(reg.kernel(KernelKindId(1)).max_combine(), 65);
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut reg = builtins();
        assert!(reg.register(force_descriptor(0.5)).is_err());
    }

    #[test]
    fn inconsistent_descriptors_rejected() {
        let mut reg = KernelRegistry::new();
        // slot-sorting without a reuse arg
        let mut d = ewald_descriptor(vec![0.0; KTABLE * KTAB_W]);
        d.sort_by_slot = true;
        assert!(reg.register(d).is_err());
    }

    #[test]
    fn check_accepts_canonical_shapes() {
        let reg = builtins();
        let tile = Tile::with_entries(
            vec![
                vec![0.0; PARTS_PER_BUCKET * PARTICLE_W],
                vec![0.0; INTERACTIONS * INTER_W],
            ],
            vec![0; 8],
        );
        assert!(reg.check(KernelKindId(0), &tile).is_ok());
    }

    #[test]
    fn check_names_offending_dimension() {
        let reg = builtins();
        let tile = Tile::new(vec![
            vec![0.0; 3],
            vec![0.0; INTERACTIONS * INTER_W],
        ]);
        let e = reg.check(KernelKindId(0), &tile).unwrap_err();
        assert_eq!(e.arg, "parts");
        assert_eq!(e.expected, PARTS_PER_BUCKET * PARTICLE_W);
        assert_eq!(e.actual, 3);
        let msg = e.to_string();
        assert!(msg.contains("gravity") && msg.contains("parts"));
    }

    #[test]
    fn shared_registry_dedupes_identical_and_rejects_divergent() {
        let shared = SharedRegistry::new();
        let (a, new_a) =
            shared.register(md_descriptor([1.0, 0.04, 1.0])).unwrap();
        assert!(new_a, "first registration inserts");
        // a second job registering the identical family shares the id
        let (b, new_b) =
            shared.register(md_descriptor([1.0, 0.04, 1.0])).unwrap();
        assert_eq!(a, b, "identical re-registration must share the kind");
        assert!(!new_b, "dedupe must not report an insertion");
        assert_eq!(shared.len(), 1);
        // same name, different constants: combining would corrupt physics
        let err = shared
            .register(md_descriptor([2.0, 0.04, 1.0]))
            .unwrap_err();
        assert!(
            err.to_string().contains("md_force"),
            "error names the family: {err}"
        );
        // a different family still appends
        let (c, new_c) = shared.register(force_descriptor(0.01)).unwrap();
        assert_eq!(c, KernelKindId(1));
        assert!(new_c);
        assert_eq!(shared.find("gravity"), Some(c));
    }

    #[test]
    fn shared_registry_policy_divergence_rejected() {
        let shared = SharedRegistry::new();
        shared.register(md_descriptor([1.0, 0.04, 1.0])).unwrap();
        let mut d = md_descriptor([1.0, 0.04, 1.0]);
        d.cpu_fallback = false; // same kernel, different scheduling policy
        assert!(shared.register(d).is_err());
    }

    #[test]
    fn shared_registry_launch_mode_divergence_rejected() {
        // combining a per-batch and a persistent registration of the
        // same family into one launch would charge the wrong cost model
        let shared = SharedRegistry::new();
        shared.register(md_descriptor([1.0, 0.04, 1.0])).unwrap();
        let mut d = md_descriptor([1.0, 0.04, 1.0]);
        d.launch_mode = Some(LaunchMode::Persistent);
        assert!(shared.register(d).is_err());
    }

    #[test]
    fn check_rejects_wrong_arg_count_and_excess_entries() {
        let reg = builtins();
        let e = reg
            .check(KernelKindId(0), &Tile::new(vec![vec![]]))
            .unwrap_err();
        assert_eq!(e.arg, "<arg count>");
        // too many entry ids for the interaction list
        let tile = Tile::with_entries(
            vec![
                vec![0.0; PARTS_PER_BUCKET * PARTICLE_W],
                vec![0.0; INTERACTIONS * INTER_W],
            ],
            vec![0; INTERACTIONS + 1],
        );
        let e = reg.check(KernelKindId(0), &tile).unwrap_err();
        assert_eq!(e.arg, "<entry ids>");
        // entry ids on a family without an entry cache
        let tile = Tile::with_entries(
            vec![vec![0.0; PARTS_PER_BUCKET * PARTICLE_W]],
            vec![1],
        );
        assert!(reg.check(KernelKindId(1), &tile).is_err());
        // unregistered kind id
        assert!(reg.check(KernelKindId(9), &Tile::default()).is_err());
    }
}
