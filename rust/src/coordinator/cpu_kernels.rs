//! Native CPU kernels for hybrid execution and CPU-only baselines.
//!
//! G-Charm schedules a task on CPU or GPU only when "kernel functions exist
//! for both CPU and GPU" (paper section 3.3). The implementations live in
//! `runtime::native` so the sim GPU backend interprets the *same* f32
//! arithmetic and masking rules -- hybrid execution is bit-compatible with
//! pure-GPU execution to f32 tolerance (bitwise on the sim backend).

pub use crate::runtime::native::{cpu_ewald, cpu_gravity, cpu_md_interact};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gravity_single_pair_analytic() {
        // unit mass at distance r on x: a_x = m r / (r^2+eps2)^{3/2}
        let parts = vec![0.0, 0.0, 0.0, 1.0];
        let inters = vec![2.0, 0.0, 0.0, 3.0];
        let eps2 = 0.01f32;
        let out = cpu_gravity(&parts, &inters, eps2);
        let want = 3.0 * 2.0 / (4.0f32 + eps2).powf(1.5);
        assert!((out[0] - want).abs() < 1e-6);
        assert_eq!(out[1], 0.0);
        assert!(out[3] < 0.0);
    }

    #[test]
    fn gravity_zero_mass_inert() {
        let parts = vec![0.5, 0.5, 0.5, 1.0];
        let inters = vec![1.0, 2.0, 3.0, 0.0];
        let out = cpu_gravity(&parts, &inters, 0.01);
        assert_eq!(&out[..3], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn ewald_single_k_analytic() {
        // particle mass 2 at x = pi/2 with k = (1,0,0), coef = 0.5:
        // fx = 2 * 0.5 * sin(pi/2) = 1, pot = 2 * 0.5 * cos(pi/2) = 0
        let parts = vec![std::f32::consts::FRAC_PI_2, 0.0, 0.0, 2.0];
        let ktab = vec![1.0, 0.0, 0.0, 0.5];
        let out = cpu_ewald(&parts, &ktab);
        assert!((out[0] - 1.0).abs() < 1e-6);
        assert!(out[3].abs() < 1e-6);
    }

    #[test]
    fn ewald_zero_mass_inert() {
        let parts = vec![1.0, 2.0, 3.0, 0.0];
        let ktab = vec![1.0, 1.0, 1.0, 1.0];
        let out = cpu_ewald(&parts, &ktab);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn md_short_range_repulsion_and_symmetry() {
        let params = [1.0, 0.04, 1.0];
        let pa = vec![0.0, 0.0];
        let pb = vec![0.1, 0.0];
        let fa = cpu_md_interact(&pa, &pb, params);
        let fb = cpu_md_interact(&pb, &pa, params);
        assert!(fa[0] < 0.0, "repelled in -x");
        // Newton's third law between the two single-particle patches
        assert!((fa[0] + fb[0]).abs() < 1e-3 * fa[0].abs());
        assert!((fa[1] + fb[1]).abs() < 1e-6);
    }

    #[test]
    fn md_beyond_cutoff_zero() {
        let params = [1.0, 0.04, 1.0];
        let pa = vec![0.0, 0.0];
        let pb = vec![5.0, 0.0];
        let f = cpu_md_interact(&pa, &pb, params);
        assert_eq!(f, vec![0.0, 0.0]);
    }

    #[test]
    fn md_self_pair_masked() {
        let params = [1.0, 0.04, 1.0];
        let pa = vec![1.0, 1.0, 1.3, 1.0];
        let f = cpu_md_interact(&pa, &pa, params);
        assert!(f.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn md_many_body_finite_and_nontrivial() {
        let mut rng = Rng::new(3);
        let n = 64;
        let mut pa = Vec::with_capacity(n * 2);
        for _ in 0..n * 2 {
            pa.push(rng.range(0.0, 2.0) as f32);
        }
        let f = cpu_md_interact(&pa, &pa, [1.0, 0.04, 1.0]);
        assert_eq!(f.len(), n * 2);
        assert!(f.iter().all(|x| x.is_finite()));
        assert!(f.iter().any(|&x| x != 0.0));
    }
}
