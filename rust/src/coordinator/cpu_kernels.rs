//! Native CPU kernels for hybrid execution and CPU-only baselines.
//!
//! G-Charm schedules a task on CPU or GPU only when "kernel functions exist
//! for both CPU and GPU" (paper section 3.3). These are the CPU-side
//! implementations, numerically matching the Pallas kernels (same f32
//! arithmetic and masking rules) so hybrid execution is bit-compatible
//! with pure-GPU execution to f32 tolerance.

use crate::runtime::shapes::{
    INTER_W, MD_W, OUT_W, PARTICLE_W,
};

/// CPU bucket gravity: `parts` (P x 4), `inters` (I x 4) -> (P x 4)
/// [ax, ay, az, pot]. Mirrors `kernels/gravity.py`.
pub fn cpu_gravity(parts: &[f32], inters: &[f32], eps2: f32) -> Vec<f32> {
    let p = parts.len() / PARTICLE_W;
    let n = inters.len() / INTER_W;
    let mut out = vec![0.0f32; p * OUT_W];
    for i in 0..p {
        let px = parts[i * PARTICLE_W];
        let py = parts[i * PARTICLE_W + 1];
        let pz = parts[i * PARTICLE_W + 2];
        let (mut ax, mut ay, mut az, mut pot) = (0.0f32, 0.0, 0.0, 0.0);
        for j in 0..n {
            let dx = inters[j * INTER_W] - px;
            let dy = inters[j * INTER_W + 1] - py;
            let dz = inters[j * INTER_W + 2] - pz;
            let m = inters[j * INTER_W + 3];
            let r2 = dx * dx + dy * dy + dz * dz + eps2;
            let inv = 1.0 / r2.sqrt();
            let inv3 = inv * inv * inv;
            let w = m * inv3;
            ax += w * dx;
            ay += w * dy;
            az += w * dz;
            pot -= m * inv;
        }
        out[i * OUT_W] = ax;
        out[i * OUT_W + 1] = ay;
        out[i * OUT_W + 2] = az;
        out[i * OUT_W + 3] = pot;
    }
    out
}

/// CPU Ewald k-space correction: `parts` (P x 4), `ktab` (K x 4) ->
/// (P x 4) [fx, fy, fz, pot]. Mirrors `kernels/ewald.py`.
pub fn cpu_ewald(parts: &[f32], ktab: &[f32]) -> Vec<f32> {
    let p = parts.len() / PARTICLE_W;
    let k = ktab.len() / 4;
    let mut out = vec![0.0f32; p * OUT_W];
    for i in 0..p {
        let px = parts[i * PARTICLE_W];
        let py = parts[i * PARTICLE_W + 1];
        let pz = parts[i * PARTICLE_W + 2];
        let mass = parts[i * PARTICLE_W + 3];
        let (mut fx, mut fy, mut fz, mut pot) = (0.0f32, 0.0, 0.0, 0.0);
        for j in 0..k {
            let kx = ktab[j * 4];
            let ky = ktab[j * 4 + 1];
            let kz = ktab[j * 4 + 2];
            let coef = ktab[j * 4 + 3];
            let phase = px * kx + py * ky + pz * kz;
            let s = coef * phase.sin();
            let c = coef * phase.cos();
            fx += s * kx;
            fy += s * ky;
            fz += s * kz;
            pot += c;
        }
        out[i * OUT_W] = mass * fx;
        out[i * OUT_W + 1] = mass * fy;
        out[i * OUT_W + 2] = mass * fz;
        out[i * OUT_W + 3] = mass * pot;
    }
    out
}

/// CPU MD patch-pair LJ force: `pa`, `pb` (N x 2) -> forces on `pa` (N x 2).
/// Mirrors `kernels/md_force.py` including the self-pair mask.
pub fn cpu_md_interact(pa: &[f32], pb: &[f32], params: [f32; 3]) -> Vec<f32> {
    let [rc2, sig2, eps] = params;
    let n = pa.len() / MD_W;
    let m = pb.len() / MD_W;
    let mut out = vec![0.0f32; n * MD_W];
    for i in 0..n {
        let xi = pa[i * MD_W];
        let yi = pa[i * MD_W + 1];
        let (mut fx, mut fy) = (0.0f32, 0.0f32);
        for j in 0..m {
            let dx = xi - pb[j * MD_W];
            let dy = yi - pb[j * MD_W + 1];
            let r2 = dx * dx + dy * dy;
            if r2 < rc2 && r2 > 1e-9 {
                let s2 = sig2 / r2;
                let s6 = s2 * s2 * s2;
                let f = 24.0 * eps * (2.0 * s6 * s6 - s6) / r2;
                fx += f * dx;
                fy += f * dy;
            }
        }
        out[i * MD_W] = fx;
        out[i * MD_W + 1] = fy;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gravity_single_pair_analytic() {
        // unit mass at distance r on x: a_x = m r / (r^2+eps2)^{3/2}
        let parts = vec![0.0, 0.0, 0.0, 1.0];
        let inters = vec![2.0, 0.0, 0.0, 3.0];
        let eps2 = 0.01f32;
        let out = cpu_gravity(&parts, &inters, eps2);
        let want = 3.0 * 2.0 / (4.0f32 + eps2).powf(1.5);
        assert!((out[0] - want).abs() < 1e-6);
        assert_eq!(out[1], 0.0);
        assert!(out[3] < 0.0);
    }

    #[test]
    fn gravity_zero_mass_inert() {
        let parts = vec![0.5, 0.5, 0.5, 1.0];
        let inters = vec![1.0, 2.0, 3.0, 0.0];
        let out = cpu_gravity(&parts, &inters, 0.01);
        assert_eq!(&out[..3], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn ewald_single_k_analytic() {
        // particle mass 2 at x = pi/2 with k = (1,0,0), coef = 0.5:
        // fx = 2 * 0.5 * sin(pi/2) = 1, pot = 2 * 0.5 * cos(pi/2) = 0
        let parts = vec![std::f32::consts::FRAC_PI_2, 0.0, 0.0, 2.0];
        let ktab = vec![1.0, 0.0, 0.0, 0.5];
        let out = cpu_ewald(&parts, &ktab);
        assert!((out[0] - 1.0).abs() < 1e-6);
        assert!(out[3].abs() < 1e-6);
    }

    #[test]
    fn ewald_zero_mass_inert() {
        let parts = vec![1.0, 2.0, 3.0, 0.0];
        let ktab = vec![1.0, 1.0, 1.0, 1.0];
        let out = cpu_ewald(&parts, &ktab);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn md_short_range_repulsion_and_symmetry() {
        let params = [1.0, 0.04, 1.0];
        let pa = vec![0.0, 0.0];
        let pb = vec![0.1, 0.0];
        let fa = cpu_md_interact(&pa, &pb, params);
        let fb = cpu_md_interact(&pb, &pa, params);
        assert!(fa[0] < 0.0, "repelled in -x");
        // Newton's third law between the two single-particle patches
        assert!((fa[0] + fb[0]).abs() < 1e-3 * fa[0].abs());
        assert!((fa[1] + fb[1]).abs() < 1e-6);
    }

    #[test]
    fn md_beyond_cutoff_zero() {
        let params = [1.0, 0.04, 1.0];
        let pa = vec![0.0, 0.0];
        let pb = vec![5.0, 0.0];
        let f = cpu_md_interact(&pa, &pb, params);
        assert_eq!(f, vec![0.0, 0.0]);
    }

    #[test]
    fn md_self_pair_masked() {
        let params = [1.0, 0.04, 1.0];
        let pa = vec![1.0, 1.0, 1.3, 1.0];
        let f = cpu_md_interact(&pa, &pa, params);
        assert!(f.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn md_many_body_finite_and_nontrivial() {
        let mut rng = Rng::new(3);
        let n = 64;
        let mut pa = Vec::with_capacity(n * 2);
        for _ in 0..n * 2 {
            pa.push(rng.range(0.0, 2.0) as f32);
        }
        let f = cpu_md_interact(&pa, &pa, [1.0, 0.04, 1.0]);
        assert_eq!(f.len(), n * 2);
        assert!(f.iter().all(|x| x.is_finite()));
        assert!(f.iter().any(|&x| x != 0.0));
    }
}
