//! Sorted-index maintenance for coalesced gather access (paper section 3.2).
//!
//! Data reuse leaves a combined kernel's inputs scattered across device
//! slots (Fig 1c). The paper's fix: keep the slot indices *sorted* so
//! consecutive thread blocks touch nearby memory (Fig 1d). Sorting after
//! combining would cost O(N log N) per flush; instead each index is
//! binary-search-inserted at `gcharm_insert_request()` time, for a total of
//! O(log 1) + O(log 2) + ... + O(log N) = O(log N!).
//!
//! `SortedPending` keeps (slot, wr-position) pairs ordered by slot and
//! reports a *locality score* -- the fraction of consecutive launch slots
//! that land on adjacent device rows -- which the Fig 3 bench prints
//! alongside the timing deltas.

/// Pending combined-launch membership ordered by device slot.
#[derive(Debug, Default, Clone)]
pub struct SortedPending {
    /// (device slot, submitter token) sorted ascending by slot; ties keep
    /// insertion order (stable for equal slots).
    entries: Vec<(u32, u64)>,
    /// Total binary-search probe count, to validate the O(log N!) claim.
    probes: u64,
}

impl SortedPending {
    pub fn new() -> SortedPending {
        SortedPending::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn probes(&self) -> u64 {
        self.probes
    }

    /// Binary-search-insert, keeping entries sorted by slot.
    pub fn insert(&mut self, slot: u32, token: u64) {
        // Find the end of the run of equal slots (stable insert).
        let mut lo = 0usize;
        let mut hi = self.entries.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            self.probes += 1;
            if self.entries[mid].0 <= slot {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        self.entries.insert(lo, (slot, token));
    }

    /// Drain up to `n` entries in slot order.
    pub fn drain(&mut self, n: usize) -> Vec<(u32, u64)> {
        let n = n.min(self.entries.len());
        self.entries.drain(..n).collect()
    }

    /// Drain everything in slot order.
    pub fn drain_all(&mut self) -> Vec<(u32, u64)> {
        std::mem::take(&mut self.entries)
    }

    /// Current slots, in order.
    pub fn slots(&self) -> Vec<u32> {
        self.entries.iter().map(|&(s, _)| s).collect()
    }
}

/// Fraction of consecutive positions whose slots are adjacent
/// (slot[i+1] == slot[i] + 1) -- local coalesced runs (Fig 1d). 1.0 for a
/// fully contiguous layout, ~0 for random placement in a large pool.
pub fn locality_score(slots: &[u32]) -> f64 {
    if slots.len() < 2 {
        return 1.0;
    }
    let adjacent = slots
        .windows(2)
        .filter(|w| w[1] == w[0].wrapping_add(1))
        .count();
    adjacent as f64 / (slots.len() - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn inserts_keep_sorted_order() {
        let mut sp = SortedPending::new();
        for &s in &[5u32, 1, 9, 3, 7, 2, 8, 0, 6, 4] {
            sp.insert(s, s as u64);
        }
        assert_eq!(sp.slots(), (0..10).collect::<Vec<u32>>());
    }

    #[test]
    fn random_inserts_stay_sorted() {
        let mut rng = Rng::new(23);
        let mut sp = SortedPending::new();
        for i in 0..500 {
            sp.insert(rng.below(10_000) as u32, i);
        }
        let slots = sp.slots();
        assert!(slots.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(sp.len(), 500);
    }

    #[test]
    fn equal_slots_keep_insertion_order() {
        let mut sp = SortedPending::new();
        sp.insert(3, 100);
        sp.insert(3, 101);
        sp.insert(3, 102);
        let drained = sp.drain_all();
        assert_eq!(
            drained.iter().map(|&(_, t)| t).collect::<Vec<_>>(),
            vec![100, 101, 102]
        );
    }

    #[test]
    fn drain_takes_prefix_in_slot_order() {
        let mut sp = SortedPending::new();
        for &s in &[9u32, 1, 5, 3] {
            sp.insert(s, s as u64);
        }
        let first = sp.drain(2);
        assert_eq!(first.iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(sp.slots(), vec![5, 9]);
    }

    #[test]
    fn probe_count_is_log_factorial_not_quadratic() {
        // O(log N!) = O(N log N) probes; check we are well under N^2/4
        // and within a small constant of N log2 N.
        let mut rng = Rng::new(31);
        let n = 4_096usize;
        let mut sp = SortedPending::new();
        for i in 0..n {
            sp.insert(rng.next_u64() as u32, i as u64);
        }
        let probes = sp.probes() as f64;
        let nlogn = (n as f64) * (n as f64).log2();
        assert!(probes < 2.0 * nlogn, "probes = {probes}, n log n = {nlogn}");
        assert!(probes > 0.5 * nlogn, "suspiciously few probes: {probes}");
    }

    #[test]
    fn locality_scores() {
        assert_eq!(locality_score(&[]), 1.0);
        assert_eq!(locality_score(&[7]), 1.0);
        assert_eq!(locality_score(&[0, 1, 2, 3]), 1.0);
        assert_eq!(locality_score(&[3, 2, 1, 0]), 0.0);
        // half the steps adjacent
        assert!((locality_score(&[0, 1, 5, 6]) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn sorted_beats_arrival_order_on_locality() {
        let mut rng = Rng::new(37);
        // arrival order: random slots from a small pool with clusters
        let mut arrival: Vec<u32> = (0..64u32).collect();
        rng.shuffle(&mut arrival);
        let mut sp = SortedPending::new();
        for (i, &s) in arrival.iter().enumerate() {
            sp.insert(s, i as u64);
        }
        let sorted = sp.slots();
        assert!(locality_score(&sorted) > locality_score(&arrival));
        assert_eq!(locality_score(&sorted), 1.0); // dense slot set
    }
}
