//! Jobs, not runs: the persistent multi-tenant runtime API.
//!
//! A [`Runtime`] owns the device pool, the append-only kernel registry,
//! the hybrid scheduler, and the PE worker threads for its whole
//! lifetime. Applications submit [`JobSpec`]s — a chare set, the kernel
//! families the job needs, and a *driver* closure that paces the job
//! (sends, per-job reductions, per-job quiescence) and decides when it is
//! complete by returning — and get back a [`JobHandle`] with blocking
//! `wait`, non-blocking `poll`, `cancel`, and a live `metrics_snapshot`.
//!
//! Concurrent jobs genuinely share the machinery: identical kernel
//! registrations resolve to one shared kind id, so the combiners may
//! merge tiles from *different* jobs into one launch (cross-job
//! combining, `PoolReport::cross_job_launches`), with accounting split
//! back out per job on completion and a weighted-fair share keeping a
//! heavy job from starving its co-tenants. Per-job state — reductions,
//! quiescence counters, residency keys, routing affinity, rate models —
//! is namespaced by [`JobId`] and torn down when the job's report seals.
//!
//! The pre-redesign one-shot API survives as [`GCharm`]: one
//! interactively driven job on a private runtime.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::runtime::executor::Completion;
use crate::runtime::kernel::TileKernel;
use crate::runtime::Manifest;

use super::chare::{Chare, ChareId, JobId, Msg};
use super::metrics::{JobMetricsSnapshot, JobReport, PoolReport};
use super::registry::{
    KernelDescriptor, KernelKindId, KernelRegistry, SharedRegistry,
};
use super::scheduler::{
    pe_loop, CoordMsg, JobState, JobStatus, NetAccountDelta, NetShipment,
    PeMsg, Router, Shared,
};
use super::work_request::{WorkRequest, WrResult};
use super::{Config, Coord};

/// The driver of one job: paces the job through its [`JobCtx`] and
/// returns the job's reduction series (energies, residuals, ...) when the
/// completion condition is met. Returning is what completes the job.
pub type JobDriver =
    Box<dyn FnOnce(&mut JobCtx) -> Result<Vec<f64>> + Send + 'static>;

/// Everything one job brings to a [`Runtime`]: a name, the kernel
/// families it needs (resolved against the shared append-only registry —
/// identical registrations from concurrent jobs share one kind id), its
/// chare set, and the driver closure that paces it to completion.
pub struct JobSpec {
    name: String,
    kernels: Vec<KernelDescriptor>,
    chares: Vec<(ChareId, usize, Box<dyn Chare>)>,
    driver: Option<JobDriver>,
}

impl JobSpec {
    pub fn new(name: impl Into<String>) -> JobSpec {
        JobSpec {
            name: name.into(),
            kernels: Vec::new(),
            chares: Vec::new(),
            driver: None,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Add a kernel-family registration. Resolved ids arrive in the
    /// driver as [`JobCtx::kinds`], in this call order.
    pub fn kernel(mut self, desc: KernelDescriptor) -> JobSpec {
        self.kernels.push(desc);
        self
    }

    /// Place a chare on PE `pe % pes`.
    pub fn chare(
        mut self,
        id: ChareId,
        pe: usize,
        chare: Box<dyn Chare>,
    ) -> JobSpec {
        self.chares.push((id, pe, chare));
        self
    }

    /// Set the driver: the job's completion condition is the driver
    /// returning (its `Vec<f64>` becomes `JobReport::series`).
    pub fn driver<F>(mut self, f: F) -> JobSpec
    where
        F: FnOnce(&mut JobCtx) -> Result<Vec<f64>> + Send + 'static,
    {
        self.driver = Some(Box::new(f));
        self
    }

    /// The kernel-family registrations added so far, in call order. The
    /// cluster session fingerprints these (family names) into its
    /// `Hello` frame: the SPMD contract is that every node registers
    /// the same families in the same order, so kind ids agree across
    /// the mesh without a name service.
    pub fn kernel_descs(&self) -> &[KernelDescriptor] {
        &self.kernels
    }
}

/// Shared innards of a [`Runtime`]; job drivers keep it alive through
/// their [`JobCtx`] until their reports seal.
struct RuntimeCore {
    cfg: Config,
    router: Router,
    next_job: AtomicU64,
    /// Ids of sealed jobs, reusable by later submissions. Residency
    /// keys namespace jobs in 16 bits ([`super::job_key`]), so a
    /// persistent runtime recycles ids instead of growing without
    /// bound: the limit is 65536 *concurrent* jobs, not total. A sealed
    /// job's id only re-enters this pool after its `JobEnded` teardown
    /// was queued to the coordinator, so a successor reusing the id can
    /// never race the predecessor's cleanup.
    free_ids: Mutex<Vec<u64>>,
    /// Jobs submitted (or begun) whose reports have not sealed yet.
    active_jobs: AtomicI64,
    /// Sealed job reports, completion order; drained into
    /// `PoolReport::jobs` at shutdown.
    finished: Mutex<Vec<JobReport>>,
}

/// A persistent, multi-tenant G-Charm runtime.
///
/// Owns the sharded GPU pool, the PE worker threads, the coordinator,
/// and the shared kernel registry for its whole lifetime; serves any
/// number of concurrent [`JobSpec`]s submitted through
/// [`Runtime::submit_job`]. See the module docs for the tenancy model.
pub struct Runtime {
    core: Arc<RuntimeCore>,
    pe_handles: Vec<JoinHandle<()>>,
    coord_handle: JoinHandle<PoolReport>,
    forwarder: JoinHandle<()>,
}

impl Runtime {
    /// Spawn the runtime over a validated configuration (see
    /// [`Config::validate`] for what is rejected): PE threads, the
    /// coordinator, and the device pool all start here and live until
    /// [`Runtime::shutdown`].
    pub fn new(cfg: Config) -> Result<Runtime> {
        cfg.validate()?;
        let cfg = Config { pes: cfg.pes.max(1), ..cfg };
        let shared = Shared::new();
        let registry = Arc::new(SharedRegistry::new());
        let (coord_tx, coord_rx) = channel::<CoordMsg>();
        let mut pe_txs = Vec::new();
        let mut pe_rxs = Vec::new();
        for _ in 0..cfg.pes {
            let (tx, rx) = channel::<PeMsg>();
            pe_txs.push(tx);
            pe_rxs.push(rx);
        }
        let router = Router {
            pes: pe_txs,
            coord: coord_tx.clone(),
            placement: Arc::new(RwLock::new(HashMap::new())),
            shared: shared.clone(),
            registry,
        };

        // GPU completion forwarder: DevicePool -> coordinator queue.
        let (done_tx, done_rx) = channel::<Result<Completion>>();
        let fwd_coord = coord_tx.clone();
        let forwarder = std::thread::Builder::new()
            .name("gpu-forwarder".into())
            .spawn(move || {
                while let Ok(c) = done_rx.recv() {
                    if fwd_coord.send(CoordMsg::GpuDone(c)).is_err() {
                        break;
                    }
                }
            })?;

        let coord = Coord::new(cfg.clone(), router.clone(), done_tx)
            .context("starting coordinator")?;
        let coord_handle = std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || coord.run(coord_rx))?;

        let mut pe_handles = Vec::new();
        for (pe, rx) in pe_rxs.into_iter().enumerate() {
            let r = router.clone();
            pe_handles.push(
                std::thread::Builder::new()
                    .name(format!("pe-{pe}"))
                    .spawn(move || pe_loop(pe, rx, r))?,
            );
        }

        Ok(Runtime {
            core: Arc::new(RuntimeCore {
                cfg,
                router,
                next_job: AtomicU64::new(0),
                free_ids: Mutex::new(Vec::new()),
                active_jobs: AtomicI64::new(0),
                finished: Mutex::new(Vec::new()),
            }),
            pe_handles,
            coord_handle,
            forwarder,
        })
    }

    pub fn config(&self) -> &Config {
        &self.core.cfg
    }

    /// Timeline seconds since the runtime spawned.
    pub fn now(&self) -> f64 {
        self.core.router.shared.timeline.now()
    }

    /// The runtime's shared state (timeline, live-job table).
    pub fn shared(&self) -> Arc<Shared> {
        self.core.router.shared.clone()
    }

    /// Submit a job: registers its kernels against the shared registry
    /// (identical registrations resolve to existing kinds — the hook for
    /// cross-job combining), places its chares on the live PE set, and
    /// spawns its driver on a dedicated thread. Returns immediately with
    /// the job's handle.
    pub fn submit_job(&self, spec: JobSpec) -> Result<JobHandle> {
        let JobSpec { name, kernels, chares, driver } = spec;
        let driver = driver.ok_or_else(|| {
            anyhow::anyhow!(
                "job {name}: a JobSpec needs a driver (its completion \
                 condition); see JobSpec::driver"
            )
        })?;
        let ctx = self.begin_job_inner(name.clone(), kernels, chares)?;
        let job = ctx.job();
        let state = ctx.state.clone();
        let handle = std::thread::Builder::new()
            .name(format!("job-{}-{name}", job.0))
            .spawn(move || {
                let mut ctx = ctx;
                match driver(&mut ctx) {
                    Ok(series) => Ok(ctx.seal(series, JobStatus::Done)),
                    Err(_) if ctx.cancelled() => {
                        Ok(ctx.seal(Vec::new(), JobStatus::Cancelled))
                    }
                    Err(e) => {
                        ctx.seal(Vec::new(), JobStatus::Failed);
                        Err(e)
                    }
                }
            })?;
        Ok(JobHandle { job, name, state, handle: Some(handle) })
    }

    /// Begin an *interactively driven* job: same registration and
    /// placement as [`Runtime::submit_job`], but the caller holds the
    /// [`JobCtx`] and paces the job from its own thread (the [`GCharm`]
    /// compatibility shim). Finish with [`Runtime::end_job`].
    pub fn begin_job(
        &self,
        name: impl Into<String>,
        kernels: Vec<KernelDescriptor>,
        chares: Vec<(ChareId, usize, Box<dyn Chare>)>,
    ) -> Result<JobCtx> {
        self.begin_job_inner(name.into(), kernels, chares)
    }

    /// Seal an interactively driven job begun with
    /// [`Runtime::begin_job`]: drains the job, seals its report with
    /// `series`, and tears its state down.
    pub fn end_job(&self, ctx: JobCtx, series: Vec<f64>) -> JobReport {
        ctx.seal(series, JobStatus::Done)
    }

    fn begin_job_inner(
        &self,
        name: String,
        kernels: Vec<KernelDescriptor>,
        chares: Vec<(ChareId, usize, Box<dyn Chare>)>,
    ) -> Result<JobCtx> {
        let core = &self.core;
        // Recycle a sealed job's id, or mint a fresh one. Ids must fit
        // the 16-bit residency-key namespace (`super::job_key`); with
        // recycling that caps *concurrent* jobs, which a real config can
        // never approach, but fail loudly rather than alias tenants.
        let job = {
            let mut free = core.free_ids.lock().unwrap();
            match free.pop() {
                Some(id) => JobId(id),
                None => JobId(core.next_job.fetch_add(1, Ordering::SeqCst)),
            }
        };
        anyhow::ensure!(
            job.0 < 1 << 16,
            "job {name}: {} jobs already live on this runtime (the \
             residency-key namespace holds 65536 concurrent jobs)",
            job.0
        );
        // An over-range id is deliberately NOT restored above: it can
        // never be used, and parking it in the free pool would hand it
        // back to (and fail) every later submission. In-range ids, in
        // contrast, must flow back on *every* rejection below — a
        // rejected spec used to leak its id from the 65536-wide
        // namespace permanently (found by the chaos harness's
        // live-registration schedules).
        let r = self.begin_job_with_id(job, name, kernels, chares);
        if r.is_err() {
            core.free_ids.lock().unwrap().push(job.0);
        }
        r
    }

    /// The fallible part of [`Runtime::begin_job_inner`], after the job
    /// id is reserved; the caller owns returning the id to the pool on
    /// error.
    fn begin_job_with_id(
        &self,
        job: JobId,
        name: String,
        kernels: Vec<KernelDescriptor>,
        chares: Vec<(ChareId, usize, Box<dyn Chare>)>,
    ) -> Result<JobCtx> {
        let core = &self.core;
        // Resolve kernels against the shared append-only registry;
        // genuinely new families are validated against the artifact set
        // and taught to the live coordinator + device pool, ordered
        // ahead of any submission of theirs. Validation runs *before*
        // the registry mutates, so a rejected spec leaves the runtime
        // exactly as it was.
        let maybe_new: Vec<Arc<TileKernel>> = kernels
            .iter()
            .filter(|d| core.router.registry.find(&d.kernel.name).is_none())
            .map(|d| d.kernel.clone())
            .collect();
        if !maybe_new.is_empty() {
            Manifest::for_kernels(&core.cfg.artifacts, &maybe_new)
                .with_context(|| {
                    format!("job {name}: validating kernel artifacts")
                })?;
        }
        let mut kinds = Vec::with_capacity(kernels.len());
        let mut added: Vec<KernelDescriptor> = Vec::new();
        let mut reg_err = None;
        for desc in kernels {
            // `newly` is decided atomically inside the registry's write
            // lock: under concurrent submit_jobs of the same family,
            // exactly one registrant teaches the coordinator about it.
            match core.router.registry.register(desc.clone()) {
                Ok((id, newly)) => {
                    if newly {
                        added.push(desc);
                    }
                    kinds.push(id);
                }
                Err(e) => {
                    reg_err = Some(e);
                    break;
                }
            }
        }
        // Families appended before a failure stay registered (the
        // registry is append-only), so the coordinator must learn them
        // either way to stay in sync with the registry.
        if !added.is_empty() {
            core.router
                .coord
                .send(CoordMsg::KindsAdded(added))
                .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
        }
        if let Some(e) = reg_err {
            return Err(e.context(format!("job {name}: registering kernels")));
        }

        // Place the chare set on the live PEs. Duplicates are rejected
        // before anything touches shared state.
        let pes = core.cfg.pes;
        let mut per_pe: Vec<Vec<(ChareId, Box<dyn Chare>)>> =
            (0..pes).map(|_| Vec::new()).collect();
        let mut seen = HashSet::new();
        for (id, pe, chare) in chares {
            anyhow::ensure!(
                seen.insert(id),
                "job {name}: chare {id:?} registered twice"
            );
            per_pe[pe % pes].push((id, chare));
        }
        {
            let mut placement =
                core.router.placement.write().expect("placement poisoned");
            for (pe, batch) in per_pe.iter().enumerate() {
                for (id, _) in batch {
                    placement.insert((job, *id), pe);
                }
            }
        }
        for (pe, batch) in per_pe.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            core.router.pes[pe]
                .send(PeMsg::AddChares { job, chares: batch })
                .map_err(|_| anyhow::anyhow!("pe {pe} is down"))?;
        }

        let state = core.router.shared.add_job(job);
        core.active_jobs.fetch_add(1, Ordering::SeqCst);
        Ok(JobCtx {
            core: core.clone(),
            job,
            name,
            state,
            kinds,
            started: Instant::now(),
            sealed: false,
        })
    }

    /// The cluster session's side door into this runtime: job-scoped
    /// message posting plus the coordinator's cross-node drain /
    /// finish / requeue / accounting hooks. Every method funnels into
    /// the same FIFO queues as local traffic, so remote work is
    /// ordered exactly like a co-tenant's.
    pub(crate) fn net_endpoint(&self) -> NetEndpoint {
        NetEndpoint { router: self.core.router.clone() }
    }

    /// Live snapshot of the pool-wide report (counters up to now; the
    /// per-job `jobs` list stays empty until shutdown).
    pub fn pool_snapshot(&self) -> Result<PoolReport> {
        let (tx, rx) = channel();
        self.core
            .router
            .coord
            .send(CoordMsg::Snapshot(tx))
            .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
        rx.recv_timeout(Duration::from_secs(30))
            .context("coordinator snapshot timed out")
    }

    /// Detached live-snapshot handle: a clonable, thread-safe way for
    /// the serve metrics endpoint (or any observer thread) to take
    /// [`Runtime::pool_snapshot`]s without borrowing the runtime.
    pub fn snapshot_handle(&self) -> PoolSnapshotHandle {
        PoolSnapshotHandle { coord: self.core.router.coord.clone() }
    }

    /// Classify a job for the serving front end (ISSUE 10): its QoS
    /// class, and — for latency-sensitive jobs — a deadline budget in
    /// timeline seconds that arms the coordinator's deadline-aware
    /// flush trigger. Queued FIFO behind the job's own submission, so
    /// the class is in force before any of its work flushes.
    pub fn set_job_qos(
        &self,
        job: JobId,
        class: crate::serve::QosClass,
        deadline: Option<f64>,
    ) -> Result<()> {
        self.core
            .router
            .coord
            .send(CoordMsg::SetJobQos { job, class, deadline })
            .map_err(|_| anyhow::anyhow!("coordinator is down"))
    }

    /// Fold serve-front-end admission-ledger deltas (offered, admitted,
    /// rejected, shed) into the pool report. The ledger must close
    /// exactly: `offered == admitted + rejected + shed` over all calls,
    /// audited by `chaos::invariants`.
    pub fn serve_account(
        &self,
        offered: u64,
        admitted: u64,
        rejected: u64,
        shed: u64,
    ) -> Result<()> {
        self.core
            .router
            .coord
            .send(CoordMsg::ServeAccount { offered, admitted, rejected, shed })
            .map_err(|_| anyhow::anyhow!("coordinator is down"))
    }

    /// Stop the runtime and return the pool-wide report with every
    /// sealed [`JobReport`] attached. Blocks until running jobs finish
    /// (use `JobHandle::cancel` first for an early stop).
    pub fn shutdown(self) -> PoolReport {
        while self.core.active_jobs.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_micros(100));
        }
        self.core.router.coord.send(CoordMsg::Stop).ok();
        let mut report =
            self.coord_handle.join().expect("coordinator panicked");
        for tx in &self.core.router.pes {
            tx.send(PeMsg::Stop).ok();
        }
        for h in self.pe_handles {
            h.join().expect("pe panicked");
        }
        report.jobs =
            std::mem::take(&mut *self.core.finished.lock().unwrap());
        // The forwarder ends once the pool (owned by the coordinator)
        // drops its completion senders.
        self.forwarder.join().ok();
        report
    }
}

/// Chaos-harness injections on a live runtime. Compiled only under
/// `#[cfg(any(test, feature = "chaos"))]` — the release hot path carries
/// none of this. The methods queue [`super::scheduler::ChaosCmd`]s onto
/// the coordinator's one FIFO queue, so every injection is ordered
/// against the real traffic exactly like a hostile schedule would be.
#[cfg(any(test, feature = "chaos"))]
impl Runtime {
    /// Overwrite the live router's steal watermarks. `low` far above any
    /// realistic depth plus a tiny `high` turns every poll into a steal
    /// candidate (a steal storm); restoring the configured values ends
    /// the storm.
    pub fn chaos_set_watermarks(&self, low: usize, high: usize) -> Result<()> {
        use super::scheduler::ChaosCmd;
        self.core
            .router
            .coord
            .send(CoordMsg::Chaos(ChaosCmd::SetWatermarks { low, high }))
            .map_err(|_| anyhow::anyhow!("coordinator is down"))
    }

    /// Force one single-shot flush of every combiner (flush-timing
    /// jitter). Deliberately not drained to empty: capped leftovers must
    /// drain through the regular poll path.
    pub fn chaos_flush_jitter(&self) -> Result<()> {
        use super::scheduler::ChaosCmd;
        self.core
            .router
            .coord
            .send(CoordMsg::Chaos(ChaosCmd::FlushJitter))
            .map_err(|_| anyhow::anyhow!("coordinator is down"))
    }

    /// Jitter every persistent work ring to `queue_cap` slots and flip
    /// the forced launch mode (alternating Persistent / PerBatch across
    /// injections) — the launch-flip chaos theme's entry point.
    pub fn chaos_launch_mode_flip(&self, queue_cap: usize) -> Result<()> {
        use super::scheduler::ChaosCmd;
        self.core
            .router
            .coord
            .send(CoordMsg::Chaos(ChaosCmd::LaunchModeFlip { queue_cap }))
            .map_err(|_| anyhow::anyhow!("coordinator is down"))
    }

    /// Job ids (key high halves) with any buffer still resident on any
    /// device. Queued behind every teardown already sent, so auditing
    /// after a job sealed cannot race its `JobEnded` cleanup.
    pub fn chaos_resident_jobs(&self) -> Result<Vec<u64>> {
        use super::scheduler::ChaosCmd;
        let (tx, rx) = channel();
        self.core
            .router
            .coord
            .send(CoordMsg::Chaos(ChaosCmd::AuditResidency(tx)))
            .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
        rx.recv_timeout(Duration::from_secs(30))
            .context("coordinator residency audit timed out")
    }
}

/// The cluster session's handle into a [`Runtime`]
/// ([`Runtime::net_endpoint`]). Wraps the router so the net layer can
/// deliver remote chare messages and drive the coordinator's
/// cross-node hooks without owning (or outliving) the runtime — every
/// method degrades to a no-op/`None` once the runtime is down.
pub(crate) struct NetEndpoint {
    router: Router,
}

impl NetEndpoint {
    /// Deliver a remote chare message. Returns `false` when the target
    /// `(job, chare)` is not placed (the job already sealed, or never
    /// existed here) — a cross-node race, not an error.
    pub(crate) fn post(&self, job: JobId, to: ChareId, msg: Msg) -> bool {
        self.router.try_send_msg(job, to, msg)
    }

    /// Ask the coordinator for one outbound shipment on behalf of a
    /// thief reporting `peer_depth`. `None`: nothing worth shipping.
    pub(crate) fn drain(
        &self,
        peer_depth: usize,
        est_item_secs: f64,
    ) -> Option<NetShipment> {
        let (tx, rx) = channel();
        self.router
            .coord
            .send(CoordMsg::NetDrain { peer_depth, est_item_secs, reply: tx })
            .ok()?;
        rx.recv_timeout(Duration::from_secs(30)).ok().flatten()
    }

    /// Scatter a returned shipment's results to their owning chares and
    /// release the holds that kept quiescence up while it was remote.
    pub(crate) fn finish(&self, results: Vec<(JobId, ChareId, WrResult)>) {
        self.router.coord.send(CoordMsg::NetFinish { results }).ok();
    }

    /// Requeue a shipment that could not complete remotely.
    pub(crate) fn requeue(&self, kind: KernelKindId, reqs: Vec<WorkRequest>) {
        self.router.coord.send(CoordMsg::NetRequeue { kind, reqs }).ok();
    }

    /// This node's total pending depth (the number heartbeats
    /// advertise). `0` once the coordinator is gone.
    pub(crate) fn depth(&self) -> u64 {
        let (tx, rx) = channel();
        if self.router.coord.send(CoordMsg::NetDepth(tx)).is_err() {
            return 0;
        }
        rx.recv_timeout(Duration::from_secs(30)).unwrap_or(0)
    }

    /// Fold a cluster-session accounting delta into the pool report.
    pub(crate) fn account(&self, delta: NetAccountDelta) {
        self.router.coord.send(CoordMsg::NetAccount(delta)).ok();
    }
}

/// A clonable handle that takes live [`PoolReport`] snapshots of a
/// running [`Runtime`] without borrowing it
/// ([`Runtime::snapshot_handle`]). Snapshots keep working until the
/// runtime shuts down, after which they error.
#[derive(Clone)]
pub struct PoolSnapshotHandle {
    coord: Sender<CoordMsg>,
}

impl PoolSnapshotHandle {
    /// Live snapshot of the pool-wide report (same contract as
    /// [`Runtime::pool_snapshot`]).
    pub fn pool_snapshot(&self) -> Result<PoolReport> {
        let (tx, rx) = channel();
        self.coord
            .send(CoordMsg::Snapshot(tx))
            .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
        rx.recv_timeout(Duration::from_secs(30))
            .context("coordinator snapshot timed out")
    }
}

/// A submitted job's handle: blocking [`JobHandle::wait`], non-blocking
/// [`JobHandle::poll`], [`JobHandle::cancel`], and a live
/// [`JobHandle::metrics_snapshot`] that works while the job runs and
/// after it finishes.
pub struct JobHandle {
    job: JobId,
    name: String,
    state: Arc<JobState>,
    handle: Option<JoinHandle<Result<JobReport>>>,
}

impl JobHandle {
    pub fn job(&self) -> JobId {
        self.job
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Block until the job completes and return its sealed report.
    /// A cancelled job returns `Ok` with an empty series; a failed
    /// driver propagates its error.
    pub fn wait(mut self) -> Result<JobReport> {
        let handle = self.handle.take().expect("wait called once");
        handle
            .join()
            .map_err(|_| anyhow::anyhow!("job {} panicked", self.job))?
    }

    /// Non-blocking status probe.
    pub fn poll(&self) -> JobStatus {
        self.state.status()
    }

    /// Request cancellation: wakes a driver blocked in
    /// `JobCtx::await_reduction`; in-flight work drains before the job
    /// seals (no work is abandoned mid-launch).
    pub fn cancel(&self) {
        self.state.cancel();
    }

    /// Point-in-time copy of the job's live counters.
    pub fn metrics_snapshot(&self) -> JobMetricsSnapshot {
        self.state.metrics_snapshot()
    }

    /// The job's shared state, for observers (the serve front end)
    /// that outlive or never hold the handle itself.
    pub(crate) fn state_arc(&self) -> Arc<JobState> {
        self.state.clone()
    }
}

/// The driver-side face of one job: job-scoped sends, reductions,
/// quiescence, buffer invalidation, and the resolved kernel kinds.
pub struct JobCtx {
    core: Arc<RuntimeCore>,
    job: JobId,
    name: String,
    state: Arc<JobState>,
    kinds: Vec<KernelKindId>,
    started: Instant,
    /// Set by `seal`. A `JobCtx` dropped unsealed (a panicking driver,
    /// or a failed driver-thread spawn) tears the job down as `Failed`
    /// from `Drop`, so `Runtime::shutdown` never waits on a job that
    /// can no longer finish.
    sealed: bool,
}

impl JobCtx {
    pub fn job(&self) -> JobId {
        self.job
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Resolved kind ids of the spec's kernel registrations, in
    /// registration order.
    pub fn kinds(&self) -> &[KernelKindId] {
        &self.kinds
    }

    /// Kind id of a registered family by name (any family on the shared
    /// registry, not just this job's).
    pub fn kind(&self, name: &str) -> Option<KernelKindId> {
        self.core.router.registry.find(name)
    }

    /// Driver-side message send to one of this job's chares.
    pub fn send(&self, to: ChareId, msg: Msg) {
        self.core.router.send_msg(self.job, to, msg);
    }

    /// Timeline seconds since the runtime spawned.
    pub fn now(&self) -> f64 {
        self.core.router.shared.timeline.now()
    }

    /// Has `JobHandle::cancel` been requested?
    pub fn cancelled(&self) -> bool {
        self.state.cancelled()
    }

    /// Live counters of this job.
    pub fn metrics_snapshot(&self) -> JobMetricsSnapshot {
        self.state.metrics_snapshot()
    }

    /// Block until `n` contributions from this job's chares have
    /// arrived; returns their sum and resets the reduction. Errors when
    /// the job is cancelled while waiting.
    pub fn await_reduction(&self, n: u64) -> Result<f64> {
        let state = &self.state;
        let mut guard = state.reduction.lock().unwrap();
        loop {
            anyhow::ensure!(
                !state.cancelled(),
                "job {} ({}) cancelled",
                self.job,
                self.name
            );
            if guard.count >= n {
                break;
            }
            guard = state.reduction_cv.wait(guard).unwrap();
        }
        let sum = guard.sum;
        guard.count = 0;
        guard.sum = 0.0;
        Ok(sum)
    }

    /// Block until this job is quiescent: none of *its* messages queued,
    /// none of *its* work requests pending or in flight. Co-tenant
    /// activity is irrelevant.
    pub fn await_quiescence(&self) {
        while self.state.outstanding() != 0 {
            std::thread::sleep(Duration::from_micros(50));
        }
    }

    /// Invalidate this job's device-resident buffers. Call only at the
    /// job's quiescence (iteration boundary): pinned slots back in-flight
    /// launches. Co-tenant residency is untouched.
    pub fn invalidate_buffers(&self) {
        self.core
            .router
            .coord
            .send(CoordMsg::InvalidateJob(self.job))
            .expect("coordinator is down");
    }

    /// Drain the job, seal its report, and tear its state down.
    fn seal(mut self, series: Vec<f64>, status: JobStatus) -> JobReport {
        let report = self.drain_and_teardown(series, status);
        self.sealed = true;
        report
    }

    /// The shared seal/abort path: wait for the job's in-flight work,
    /// build the report from the live counters, and tear the job's
    /// state out of the runtime. Used by `seal` and the unsealed-drop
    /// guard.
    fn drain_and_teardown(
        &self,
        series: Vec<f64>,
        status: JobStatus,
    ) -> JobReport {
        self.await_quiescence();
        let snap = self.state.metrics_snapshot();
        let report = JobReport {
            job: self.job,
            name: self.name.clone(),
            launches: snap.launches,
            cross_job_launches: snap.cross_job_launches,
            gpu_requests: snap.gpu_requests,
            cpu_requests: snap.cpu_requests,
            gpu_items: snap.gpu_items,
            cpu_items: snap.cpu_items,
            transfer_bytes: snap.transfer_bytes,
            wall: self.started.elapsed().as_secs_f64(),
            series,
        };
        // Teardown: chares off the PEs, placement entries, coordinator
        // residency/rate models, the live-job entry.
        for tx in &self.core.router.pes {
            tx.send(PeMsg::RemoveJob(self.job)).ok();
        }
        self.core
            .router
            .placement
            .write()
            .expect("placement poisoned")
            .retain(|(j, _), _| *j != self.job);
        self.core.router.coord.send(CoordMsg::JobEnded(self.job)).ok();
        self.core.router.shared.remove_job(self.job);
        self.state.set_status(status);
        self.core.finished.lock().unwrap().push(report.clone());
        self.core.active_jobs.fetch_sub(1, Ordering::SeqCst);
        // Only now — after JobEnded is queued — may a successor reuse
        // the id (see RuntimeCore::free_ids).
        self.core.free_ids.lock().unwrap().push(self.job.0);
        report
    }
}

impl Drop for JobCtx {
    fn drop(&mut self) {
        if self.sealed {
            return;
        }
        // The driver panicked (or its thread never spawned): drain the
        // job's in-flight work and seal it as Failed so the runtime's
        // shutdown does not wait forever on a job that cannot finish.
        self.drain_and_teardown(Vec::new(), JobStatus::Failed);
    }
}

/// The pre-redesign one-shot API, preserved as a compatibility shim: a
/// `GCharm` is one interactively driven job on a private [`Runtime`].
/// `register_kernel`/`register` buffer the job's spec before `start`
/// spawns the runtime and begins the job; `shutdown` seals the job and
/// returns the pool report (whose aggregate fields match the old
/// single-run `Report` exactly).
pub struct GCharm {
    cfg: Config,
    kernels: KernelRegistry,
    chares: Vec<(ChareId, usize, Box<dyn Chare>)>,
    registered: HashSet<ChareId>,
    running: Option<(Runtime, JobCtx)>,
}

impl GCharm {
    /// Build a runtime over a validated configuration (see
    /// [`Config::validate`] for what is rejected).
    pub fn new(cfg: Config) -> Result<GCharm> {
        cfg.validate()?;
        let pes = cfg.pes.max(1);
        Ok(GCharm {
            cfg: Config { pes, ..cfg },
            kernels: KernelRegistry::new(),
            chares: Vec::new(),
            registered: HashSet::new(),
            running: None,
        })
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Register a kernel family (must happen before `start`). Returns the
    /// kind id work drafts are tagged with. The paper's built-in families
    /// are available as [`super::force_descriptor`],
    /// [`super::ewald_descriptor`], and [`super::md_descriptor`]; new
    /// workloads register their own descriptors through this same call —
    /// see PERF.md, "Adding a workload".
    pub fn register_kernel(
        &mut self,
        desc: KernelDescriptor,
    ) -> Result<KernelKindId> {
        anyhow::ensure!(
            self.running.is_none(),
            "register kernels before start"
        );
        // Ids match the shared registry `start` will seed in the same
        // order (the private runtime starts empty).
        self.kernels.register(desc)
    }

    /// The registered kernel families so far.
    pub fn kernel_registry(&self) -> &KernelRegistry {
        &self.kernels
    }

    /// Register a chare on a PE (must happen before `start`).
    pub fn register(&mut self, id: ChareId, pe: usize, chare: Box<dyn Chare>) {
        assert!(self.running.is_none(), "register before start");
        assert!(
            self.registered.insert(id),
            "chare {id:?} registered twice"
        );
        self.chares.push((id, pe % self.cfg.pes, chare));
    }

    /// Spawn the private runtime and begin the single job.
    pub fn start(&mut self) -> Result<()> {
        anyhow::ensure!(self.running.is_none(), "already started");
        let rt = Runtime::new(self.cfg.clone())?;
        let descs: Vec<KernelDescriptor> =
            self.kernels.descriptors().to_vec();
        let chares = std::mem::take(&mut self.chares);
        let ctx = rt.begin_job("gcharm", descs, chares)?;
        self.running = Some((rt, ctx));
        Ok(())
    }

    fn running(&self) -> &(Runtime, JobCtx) {
        self.running.as_ref().expect("runtime not started")
    }

    /// Driver-side message send.
    pub fn send(&self, to: ChareId, msg: Msg) {
        self.running().1.send(to, msg);
    }

    /// Timeline seconds since start.
    pub fn now(&self) -> f64 {
        self.running().0.now()
    }

    pub fn shared(&self) -> Arc<Shared> {
        self.running().0.shared()
    }

    /// Block until the job is quiescent: no queued messages, no pending
    /// or in-flight work requests.
    pub fn await_quiescence(&self) {
        self.running().1.await_quiescence();
    }

    /// Block until `n` contributions have arrived; returns their sum and
    /// resets the reduction.
    pub fn await_reduction(&self, n: u64) -> f64 {
        self.running()
            .1
            .await_reduction(n)
            .expect("gcharm job cancelled")
    }

    /// Invalidate all device-resident buffers. Call only at quiescence
    /// (iteration boundary): pinned slots back in-flight launches.
    pub fn invalidate_device_buffers(&self) {
        self.running().1.invalidate_buffers();
    }

    /// Stop all threads and return the run report.
    pub fn shutdown(mut self) -> PoolReport {
        let (rt, ctx) = self.running.take().expect("runtime not started");
        rt.end_job(ctx, Vec::new());
        rt.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_builder_collects() {
        let spec = JobSpec::new("t")
            .kernel(super::super::registry::md_descriptor([1.0, 0.04, 1.0]))
            .driver(|_ctx| Ok(vec![1.0]));
        assert_eq!(spec.name(), "t");
        assert_eq!(spec.kernels.len(), 1);
        assert!(spec.driver.is_some());
    }

    #[test]
    fn submit_without_driver_is_a_named_error() {
        let rt = Runtime::new(Config {
            pes: 1,
            ..Config::default()
        })
        .unwrap();
        let err = rt.submit_job(JobSpec::new("nodriver")).unwrap_err();
        assert!(err.to_string().contains("nodriver"), "{err}");
        assert!(err.to_string().contains("driver"), "{err}");
        rt.shutdown();
    }

    #[test]
    fn config_validate_errors_name_fields() {
        let bad = Config { devices: 0, ..Config::default() };
        let e = bad.validate().unwrap_err().to_string();
        assert!(e.contains("devices"), "{e}");
        let bad = Config { steal_low: 9, steal_high: 3, ..Config::default() };
        let e = bad.validate().unwrap_err().to_string();
        assert!(e.contains("steal_low") && e.contains("steal_high"), "{e}");
        let bad = Config { cpu_workers: 0, ..Config::default() };
        let e = bad.validate().unwrap_err().to_string();
        assert!(e.contains("cpu_workers"), "{e}");
    }

    #[test]
    fn runtime_spawns_and_shuts_down_with_no_jobs() {
        let rt = Runtime::new(Config { pes: 2, ..Config::default() })
            .unwrap();
        let snap = rt.pool_snapshot().unwrap();
        assert_eq!(snap.launches, 0);
        let report = rt.shutdown();
        assert_eq!(report.launches, 0);
        assert!(report.jobs.is_empty());
    }
}
