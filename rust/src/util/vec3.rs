//! Small 3-vector used by the N-Body substrate (positions, accelerations).

use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// f64 3-vector. Physics state is kept in f64 on the host; the GPU kernels
/// operate in f32 (matching the paper's CUDA kernels).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Vec3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };

    pub fn new(x: f64, y: f64, z: f64) -> Vec3 {
        Vec3 { x, y, z }
    }

    pub fn dot(self, o: Vec3) -> f64 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    pub fn norm2(self) -> f64 {
        self.dot(self)
    }

    pub fn norm(self) -> f64 {
        self.norm2().sqrt()
    }

    /// Component-wise minimum.
    pub fn min(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.min(o.x), self.y.min(o.y), self.z.min(o.z))
    }

    /// Component-wise maximum.
    pub fn max(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.max(o.x), self.y.max(o.y), self.z.max(o.z))
    }

    /// Largest component.
    pub fn max_component(self) -> f64 {
        self.x.max(self.y).max(self.z)
    }
}

impl Add for Vec3 {
    type Output = Vec3;
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl AddAssign for Vec3 {
    fn add_assign(&mut self, o: Vec3) {
        *self = *self + o;
    }
}

impl Sub for Vec3 {
    type Output = Vec3;
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl Mul<f64> for Vec3 {
    type Output = Vec3;
    fn mul(self, s: f64) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }
}

impl Div<f64> for Vec3 {
    type Output = Vec3;
    fn div(self, s: f64) -> Vec3 {
        Vec3::new(self.x / s, self.y / s, self.z / s)
    }
}

impl Neg for Vec3 {
    type Output = Vec3;
    fn neg(self) -> Vec3 {
        Vec3::new(-self.x, -self.y, -self.z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(4.0, 5.0, 6.0);
        assert_eq!(a + b, Vec3::new(5.0, 7.0, 9.0));
        assert_eq!(b - a, Vec3::new(3.0, 3.0, 3.0));
        assert_eq!(a * 2.0, Vec3::new(2.0, 4.0, 6.0));
        assert_eq!(b / 2.0, Vec3::new(2.0, 2.5, 3.0));
        assert_eq!(-a, Vec3::new(-1.0, -2.0, -3.0));
    }

    #[test]
    fn dot_and_norm() {
        let a = Vec3::new(3.0, 4.0, 0.0);
        assert_eq!(a.dot(a), 25.0);
        assert_eq!(a.norm(), 5.0);
    }

    #[test]
    fn min_max() {
        let a = Vec3::new(1.0, 5.0, 3.0);
        let b = Vec3::new(2.0, 4.0, 3.0);
        assert_eq!(a.min(b), Vec3::new(1.0, 4.0, 3.0));
        assert_eq!(a.max(b), Vec3::new(2.0, 5.0, 3.0));
        assert_eq!(a.max_component(), 5.0);
    }
}
