//! Minimal recursive-descent JSON parser.
//!
//! Parses the machine-generated `artifacts/manifest.json` emitted by
//! `python/compile/aot.py` and the app config files. Supports the full JSON
//! value grammar (objects, arrays, strings with escapes, numbers, booleans,
//! null); no serde in the vendored crate set.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document; trailing whitespace is allowed,
    /// trailing garbage is an error.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array view; `None` for non-arrays.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(b) => {
                    // Re-assemble multi-byte UTF-8 sequences.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self.peek().is_some_and(|b| b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse("\"π≈3\"").unwrap();
        assert_eq!(v.as_str(), Some("π≈3"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn as_usize_rules() {
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(Json::parse("-7").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7.5").unwrap().as_usize(), None);
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "format": "hlo-text",
            "return_tuple": true,
            "entries": [
                {"name": "gravity_B8", "file": "gravity_B8.hlo.txt",
                 "args": [{"shape": [8, 16, 4], "dtype": "float32"}],
                 "meta": {"kernel": "gravity", "batch": 8}}
            ]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        let shape = e.get("args").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(8));
    }
}
