//! Event timeline recorder for runtime introspection.
//!
//! The coordinator and the GPU service record begin/end spans (kernel
//! launches, transfers, combines, scheduling decisions). Timelines feed the
//! metrics printed by `gcharm figures` and the EXPERIMENTS.md numbers.

use std::sync::Mutex;
use std::time::Instant;

/// Category of a recorded span.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// GPU kernel execution (PJRT execute call).
    Kernel,
    /// Host to device transfer (modeled PCIe cost + real staging).
    Transfer,
    /// Combiner flush: workRequests -> CombinedWorkRequest.
    Combine,
    /// CPU-side task execution (hybrid scheduling path).
    CpuTask,
    /// Scheduler decision point.
    Schedule,
    /// Everything else (app phases etc.).
    Other,
}

/// One closed span.
#[derive(Debug, Clone)]
pub struct Span {
    pub kind: SpanKind,
    pub label: &'static str,
    /// Seconds since the timeline epoch.
    pub start: f64,
    /// Span duration in seconds (wall clock).
    pub wall: f64,
    /// Modeled device time in seconds (0 if not applicable). See
    /// `runtime::device_sim` for the cost model.
    pub modeled: f64,
    /// Work items covered by this span (buckets, pairs, bytes...).
    pub items: u64,
}

/// Thread-safe append-only timeline.
#[derive(Debug)]
pub struct Timeline {
    epoch: Instant,
    spans: Mutex<Vec<Span>>,
}

impl Default for Timeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Timeline {
    pub fn new() -> Self {
        Timeline { epoch: Instant::now(), spans: Mutex::new(Vec::new()) }
    }

    /// Seconds since timeline creation.
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Record a closed span.
    pub fn record(
        &self,
        kind: SpanKind,
        label: &'static str,
        start: f64,
        wall: f64,
        modeled: f64,
        items: u64,
    ) {
        self.spans.lock().unwrap().push(Span {
            kind,
            label,
            start,
            wall,
            modeled,
            items,
        });
    }

    /// Snapshot of all spans recorded so far.
    pub fn spans(&self) -> Vec<Span> {
        self.spans.lock().unwrap().clone()
    }

    /// Total wall time of spans of one kind.
    pub fn total_wall(&self, kind: SpanKind) -> f64 {
        self.spans
            .lock()
            .unwrap()
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.wall)
            .sum()
    }

    /// Total modeled device time of spans of one kind.
    pub fn total_modeled(&self, kind: SpanKind) -> f64 {
        self.spans
            .lock()
            .unwrap()
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.modeled)
            .sum()
    }

    /// Count of spans of one kind.
    pub fn count(&self, kind: SpanKind) -> usize {
        self.spans
            .lock()
            .unwrap()
            .iter()
            .filter(|s| s.kind == kind)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let tl = Timeline::new();
        tl.record(SpanKind::Kernel, "force", 0.0, 0.5, 0.1, 104);
        tl.record(SpanKind::Kernel, "ewald", 0.6, 0.25, 0.05, 65);
        tl.record(SpanKind::Transfer, "h2d", 0.0, 0.1, 0.2, 4096);
        assert_eq!(tl.count(SpanKind::Kernel), 2);
        assert!((tl.total_wall(SpanKind::Kernel) - 0.75).abs() < 1e-12);
        assert!((tl.total_modeled(SpanKind::Kernel) - 0.15).abs() < 1e-12);
        assert!((tl.total_wall(SpanKind::Transfer) - 0.1).abs() < 1e-12);
        assert_eq!(tl.count(SpanKind::Combine), 0);
    }

    #[test]
    fn now_is_monotonic() {
        let tl = Timeline::new();
        let a = tl.now();
        let b = tl.now();
        assert!(b >= a);
    }
}
