//! Deterministic pseudo-random number generation (splitmix64 + xoshiro256**).
//!
//! All workload generation in the repository is seeded through this RNG so
//! experiments are bit-reproducible across runs and machines. The vendored
//! crate set has no `rand`, so this is self-contained.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (splitmix64 state expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // modulo bias is negligible for our n << 2^64 workloads.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponentially distributed value with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * self.f64().max(1e-12).ln()
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean = {mean}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.05, "var = {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean = {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
