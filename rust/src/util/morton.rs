//! 3D Morton (Z-order) codes for Barnes-Hut domain decomposition.
//!
//! ChaNGa decomposes particle space with a space-filling curve and assigns
//! contiguous key ranges to TreePiece chares (paper section 4.1). We use
//! 21-bits-per-axis Morton keys (63-bit codes), which is what the tree
//! construction in `apps/nbody/tree.rs` sorts by.

/// Spread the low 21 bits of `v` so there are two zero bits between each.
fn spread(v: u64) -> u64 {
    let mut x = v & 0x1F_FFFF; // 21 bits
    x = (x | (x << 32)) & 0x1F00000000FFFF;
    x = (x | (x << 16)) & 0x1F0000FF0000FF;
    x = (x | (x << 8)) & 0x100F00F00F00F00F;
    x = (x | (x << 4)) & 0x10C30C30C30C30C3;
    x = (x | (x << 2)) & 0x1249249249249249;
    x
}

/// Inverse of `spread`.
fn compact(v: u64) -> u64 {
    let mut x = v & 0x1249249249249249;
    x = (x ^ (x >> 2)) & 0x10C30C30C30C30C3;
    x = (x ^ (x >> 4)) & 0x100F00F00F00F00F;
    x = (x ^ (x >> 8)) & 0x1F0000FF0000FF;
    x = (x ^ (x >> 16)) & 0x1F00000000FFFF;
    x = (x ^ (x >> 32)) & 0x1F_FFFF;
    x
}

/// Interleave three 21-bit coordinates into a 63-bit Morton code.
pub fn encode(ix: u64, iy: u64, iz: u64) -> u64 {
    spread(ix) | (spread(iy) << 1) | (spread(iz) << 2)
}

/// Recover the three 21-bit coordinates.
pub fn decode(code: u64) -> (u64, u64, u64) {
    (compact(code), compact(code >> 1), compact(code >> 2))
}

/// Quantize a position in `[lo, hi)^3` to a Morton code.
pub fn from_position(p: [f64; 3], lo: f64, hi: f64) -> u64 {
    let scale = (1u64 << 21) as f64;
    let q = |v: f64| -> u64 {
        let t = ((v - lo) / (hi - lo)).clamp(0.0, 1.0 - 1e-12);
        (t * scale) as u64
    };
    encode(q(p[0]), q(p[1]), q(p[2]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_corners() {
        for &(x, y, z) in &[
            (0, 0, 0),
            (1, 0, 0),
            (0, 1, 0),
            (0, 0, 1),
            (0x1F_FFFF, 0x1F_FFFF, 0x1F_FFFF),
        ] {
            assert_eq!(decode(encode(x, y, z)), (x, y, z));
        }
    }

    #[test]
    fn roundtrip_random() {
        let mut rng = Rng::new(17);
        for _ in 0..1_000 {
            let x = rng.next_u64() & 0x1F_FFFF;
            let y = rng.next_u64() & 0x1F_FFFF;
            let z = rng.next_u64() & 0x1F_FFFF;
            assert_eq!(decode(encode(x, y, z)), (x, y, z));
        }
    }

    #[test]
    fn encoding_is_injective_on_sample() {
        let mut rng = Rng::new(19);
        let mut codes = std::collections::HashSet::new();
        for _ in 0..1_000 {
            let x = rng.next_u64() & 0xFFFF;
            let y = rng.next_u64() & 0xFFFF;
            let z = rng.next_u64() & 0xFFFF;
            codes.insert(encode(x, y, z));
        }
        // collisions would indicate a broken spread
        assert!(codes.len() > 990);
    }

    #[test]
    fn locality_of_neighbors() {
        // adjacent cells differ in few high bits: codes of close points are
        // closer than codes of far points (weak but useful sanity check)
        let near = encode(100, 100, 100) ^ encode(101, 100, 100);
        let far = encode(100, 100, 100) ^ encode(100_000, 100, 100);
        assert!(near < far);
    }

    #[test]
    fn from_position_clamps_and_orders() {
        let a = from_position([-10.0, 0.0, 0.0], 0.0, 1.0); // clamped to lo
        let b = from_position([0.5, 0.0, 0.0], 0.0, 1.0);
        let c = from_position([10.0, 0.0, 0.0], 0.0, 1.0); // clamped to hi
        assert!(a < b && b < c);
    }
}
