//! Shared utilities: minimal JSON, deterministic RNG, statistics, Morton
//! codes, 3-vectors, and a wall-clock timeline recorder.
//!
//! The build environment is fully offline with a small vendored crate set
//! (no serde / rand / criterion / proptest), so this module provides the
//! minimal self-contained equivalents the rest of the crate needs.

pub mod json;
pub mod morton;
pub mod rng;
pub mod stats;
pub mod timeline;
pub mod vec3;

pub use rng::Rng;
pub use stats::RunningAverage;
pub use vec3::Vec3;
