//! Running statistics used by the adaptive strategies.
//!
//! The paper's dynamic scheduler (section 3.3) maintains *running averages* of
//! per-data-item execution times, and the adaptive combiner (section 3.1)
//! maintains a *running maximum* of work-request inter-arrival intervals.

/// Incremental arithmetic mean (Welford-style, no stored samples).
#[derive(Debug, Clone, Default)]
pub struct RunningAverage {
    count: u64,
    mean: f64,
}

impl RunningAverage {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observation into the mean.
    pub fn update(&mut self, x: f64) {
        self.count += 1;
        self.mean += (x - self.mean) / self.count as f64;
    }

    /// Current mean; `None` before the first observation.
    pub fn mean(&self) -> Option<f64> {
        (self.count > 0).then_some(self.mean)
    }

    pub fn count(&self) -> u64 {
        self.count
    }
}

/// Exponentially-weighted moving average, for signals that drift (the MD
/// workload changes as particles cluster).
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` in (0, 1]: weight of the newest observation.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ewma { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        });
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

/// Summary statistics over a sample set (used by the bench harness).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub max: f64,
}

impl Summary {
    /// Compute from a sample slice. Panics on empty input.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            median,
            max: sorted[n - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_average_matches_batch_mean() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut ra = RunningAverage::new();
        for &x in &xs {
            ra.update(x);
        }
        assert!((ra.mean().unwrap() - 4.0).abs() < 1e-12);
        assert_eq!(ra.count(), 5);
    }

    #[test]
    fn running_average_empty_is_none() {
        assert_eq!(RunningAverage::new().mean(), None);
    }

    #[test]
    fn ewma_first_value_passthrough() {
        let mut e = Ewma::new(0.25);
        assert_eq!(e.value(), None);
        e.update(8.0);
        assert_eq!(e.value(), Some(8.0));
    }

    #[test]
    fn ewma_converges_to_constant_signal() {
        let mut e = Ewma::new(0.5);
        for _ in 0..64 {
            e.update(3.0);
        }
        assert!((e.value().unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_tracks_shift_faster_than_mean() {
        let mut e = Ewma::new(0.5);
        let mut ra = RunningAverage::new();
        for _ in 0..100 {
            e.update(1.0);
            ra.update(1.0);
        }
        for _ in 0..10 {
            e.update(10.0);
            ra.update(10.0);
        }
        assert!(e.value().unwrap() > ra.mean().unwrap());
    }

    #[test]
    fn summary_odd_and_even_median() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        let s = Summary::of(&[4.0, 1.0, 2.0, 3.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn summary_std_of_constant_is_zero() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.mean, 5.0);
    }
}
