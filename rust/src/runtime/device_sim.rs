//! Analytic GPU device model: Kepler occupancy calculator + cost model.
//!
//! The paper's combiner (section 3.1) asks the *CUDA occupancy calculator* for
//! the maximum number of thread blocks per SM, and multiplies by the SM
//! count to get `maxSize` -- the number of work requests worth combining
//! into one kernel. No CUDA here, so this module reimplements the occupancy
//! arithmetic for the paper's NVIDIA Kepler K20 (section 4.3: force kernel
//! 50% occupancy -> 8 blocks/SM -> maxSize 104 = 8 x 13 SMs; Ewald 31% ->
//! maxSize 65).
//!
//! The same module provides the *cost model* used to report modeled-K20
//! kernel and transfer times next to measured wall clock in the figure
//! benches (DESIGN.md section 2 substitution table).

/// Static resources of one GPU (Kepler K20 defaults).
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub name: &'static str,
    pub sms: u32,
    pub max_threads_per_sm: u32,
    pub max_blocks_per_sm: u32,
    pub regs_per_sm: u32,
    pub smem_per_sm: u32,
    pub warp_size: u32,
    /// Register allocation granularity (regs rounded up per warp).
    pub reg_granularity: u32,
    /// Shared-memory allocation granularity in bytes.
    pub smem_granularity: u32,
    /// Sustained PCIe bandwidth, bytes/second (Gen2 x16 practical).
    pub pcie_bytes_per_sec: f64,
    /// Per-transfer latency, seconds.
    pub pcie_latency: f64,
    /// Kernel launch overhead, seconds.
    pub launch_overhead: f64,
    /// Persistent mode (ISSUE 8): cost for the resident loop to dequeue
    /// one batch descriptor and check the doorbell, seconds. Replaces
    /// `launch_overhead` per batch once the loop is resident.
    pub queue_poll_cost: f64,
    /// Persistent mode: modeled device time burned spin-polling an empty
    /// ring before the loop parks on the doorbell — charged once per
    /// *time-sparse* batch (one that arrived after the loop went idle).
    /// Deliberately larger than `launch_overhead - queue_poll_cost`, so
    /// sparse traffic honestly loses in persistent mode.
    pub poll_idle_cost: f64,
    /// Per-SM throughput for the interaction inner loop,
    /// particle-interactions per second at full occupancy.
    pub interactions_per_sm_per_sec: f64,
}

impl GpuSpec {
    /// NVIDIA Kepler K20c (the paper's testbed GPU).
    pub fn kepler_k20() -> GpuSpec {
        GpuSpec {
            name: "Kepler K20",
            sms: 13,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 16,
            regs_per_sm: 65_536,
            smem_per_sm: 49_152,
            warp_size: 32,
            reg_granularity: 256,
            smem_granularity: 256,
            pcie_bytes_per_sec: 6.0e9,
            pcie_latency: 10.0e-6,
            launch_overhead: 5.0e-6,
            queue_poll_cost: 0.4e-6,
            poll_idle_cost: 12.0e-6,
            // ~3.5 TFLOPs peak / ~26 flops per interaction / 13 SMs,
            // derated to a realistic 40% of peak for this kernel class.
            interactions_per_sm_per_sec: 4.1e9,
        }
    }
}

/// Per-kernel resource usage, as the CUDA compiler would report.
#[derive(Debug, Clone, Copy)]
pub struct KernelResources {
    pub threads_per_block: u32,
    pub regs_per_thread: u32,
    pub smem_per_block: u32,
}

impl KernelResources {
    /// ChaNGa force-computation kernel: 16x8 = 128-thread blocks. Register
    /// pressure (64/thread) limits residency to 8 blocks/SM on Kepler ->
    /// 50% occupancy, matching the paper's section 4.3.
    pub fn force_kernel() -> KernelResources {
        KernelResources {
            threads_per_block: 128,
            regs_per_thread: 64,
            smem_per_block: 4_096,
        }
    }

    /// Ewald summation kernel: heavier register use (96/thread) limits
    /// residency to 5 blocks/SM -> 31% occupancy, maxSize 65 (section 4.3).
    pub fn ewald_kernel() -> KernelResources {
        KernelResources {
            threads_per_block: 128,
            regs_per_thread: 96,
            smem_per_block: 2_048,
        }
    }

    /// MD pairwise interaction kernel (one block per patch pair).
    pub fn md_kernel() -> KernelResources {
        KernelResources {
            threads_per_block: 64,
            regs_per_thread: 48,
            smem_per_block: 2_048,
        }
    }
}

/// Output of the occupancy calculation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Occupancy {
    /// Resident blocks per SM, after all limiters.
    pub blocks_per_sm: u32,
    /// Fraction of max resident threads (0..=1).
    pub occupancy: f64,
    /// blocks_per_sm x SM count: the combiner's maxSize.
    pub max_size: u32,
    /// Which resource limited residency.
    pub limiter: Limiter,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Limiter {
    Blocks,
    Threads,
    Registers,
    SharedMemory,
}

fn round_up(v: u32, granularity: u32) -> u32 {
    v.div_ceil(granularity) * granularity
}

/// The occupancy calculator: blocks/SM under the four Kepler limits.
pub fn occupancy(spec: &GpuSpec, k: &KernelResources) -> Occupancy {
    let by_blocks = spec.max_blocks_per_sm;
    let by_threads = spec.max_threads_per_sm / k.threads_per_block;

    // Registers are allocated per warp with granularity.
    let warps = k.threads_per_block.div_ceil(spec.warp_size);
    let regs_per_block =
        round_up(k.regs_per_thread * spec.warp_size, spec.reg_granularity)
            * warps;
    let by_regs = if regs_per_block == 0 {
        u32::MAX
    } else {
        spec.regs_per_sm / regs_per_block
    };

    let smem = round_up(k.smem_per_block.max(1), spec.smem_granularity);
    let by_smem = spec.smem_per_sm / smem;

    let (blocks, limiter) = [
        (by_blocks, Limiter::Blocks),
        (by_threads, Limiter::Threads),
        (by_regs, Limiter::Registers),
        (by_smem, Limiter::SharedMemory),
    ]
    .into_iter()
    .min_by_key(|&(b, _)| b)
    .unwrap();

    let occ = (blocks * k.threads_per_block) as f64
        / spec.max_threads_per_sm as f64;
    Occupancy {
        blocks_per_sm: blocks,
        occupancy: occ,
        max_size: blocks * spec.sms,
        limiter,
    }
}

/// Memory-access pattern class of a combined kernel (paper Fig 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoalescingClass {
    /// Freshly packed contiguous buffers: fully coalesced (Fig 1b).
    Contiguous,
    /// Data reuse with sorted index array: local coalesced runs (Fig 1d).
    SortedGather,
    /// Data reuse with unsorted indices: uncoalesced (Fig 1c).
    RandomGather,
}

impl CoalescingClass {
    /// Multiplier on kernel memory time. Calibrated so the modeled Fig 3
    /// deltas land near the paper's: random gather costs ~1.49x kernel time
    /// vs contiguous (paper: +49%), sorted gather recovers ~10% of that.
    pub fn kernel_time_factor(self) -> f64 {
        match self {
            CoalescingClass::Contiguous => 1.0,
            CoalescingClass::SortedGather => 1.34,
            CoalescingClass::RandomGather => 1.49,
        }
    }

    /// Gather variants read the index buffer from global memory too
    /// (the paper notes reuse "doubles the number of accesses").
    pub fn extra_index_reads(self) -> bool {
        !matches!(self, CoalescingClass::Contiguous)
    }
}

/// Modeled timings for one combined kernel launch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ModeledCost {
    /// Host->device transfer seconds (PCIe model).
    pub transfer: f64,
    /// Kernel execution seconds on the modeled device.
    pub kernel: f64,
}

/// Device cost model: combines the occupancy, PCIe, and coalescing models.
#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub spec: GpuSpec,
}

impl DeviceModel {
    pub fn new(spec: GpuSpec) -> DeviceModel {
        DeviceModel { spec }
    }

    pub fn kepler_k20() -> DeviceModel {
        DeviceModel::new(GpuSpec::kepler_k20())
    }

    /// Modeled host->device transfer time for `bytes` payload bytes.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.spec.pcie_latency + bytes as f64 / self.spec.pcie_bytes_per_sec
    }

    /// Modeled kernel time: `blocks` work requests of `interactions`
    /// particle-interactions each, with the given residency and access
    /// pattern.
    pub fn kernel_time(
        &self,
        k: &KernelResources,
        blocks: u64,
        interactions_per_block: u64,
        pattern: CoalescingClass,
    ) -> f64 {
        let occ = occupancy(&self.spec, k);
        // Waves of resident blocks across the whole chip.
        let wave_size = occ.max_size.max(1) as u64;
        let waves = blocks.div_ceil(wave_size).max(1);
        let per_wave = interactions_per_block as f64
            / (self.spec.interactions_per_sm_per_sec
                * occ.occupancy.max(1e-3));
        let mut t = self.spec.launch_overhead + waves as f64 * per_wave;
        t *= pattern.kernel_time_factor();
        if pattern.extra_index_reads() {
            t *= 1.08; // index-buffer reads from global memory
        }
        t
    }

    /// Modeled kernel time for a batch drained by a resident persistent
    /// loop (ISSUE 8): same wave model as [`kernel_time`], but the batch
    /// pays `queue_poll_cost` (dequeue + doorbell check) instead of
    /// `launch_overhead`. The one-time [`residency_cost`] and any
    /// [`poll_idle_cost`] for sparse arrivals are charged by the caller.
    ///
    /// [`kernel_time`]: DeviceModel::kernel_time
    /// [`residency_cost`]: DeviceModel::residency_cost
    /// [`poll_idle_cost`]: DeviceModel::poll_idle_cost
    pub fn kernel_time_persistent(
        &self,
        k: &KernelResources,
        blocks: u64,
        interactions_per_block: u64,
        pattern: CoalescingClass,
    ) -> f64 {
        let occ = occupancy(&self.spec, k);
        let wave_size = occ.max_size.max(1) as u64;
        let waves = blocks.div_ceil(wave_size).max(1);
        let per_wave = interactions_per_block as f64
            / (self.spec.interactions_per_sm_per_sec
                * occ.occupancy.max(1e-3));
        let mut t = self.spec.queue_poll_cost + waves as f64 * per_wave;
        t *= pattern.kernel_time_factor();
        if pattern.extra_index_reads() {
            t *= 1.08; // index-buffer reads from global memory
        }
        t
    }

    /// One-time cost to make a family's megakernel loop resident on the
    /// device: a single host launch.
    pub fn residency_cost(&self) -> f64 {
        self.spec.launch_overhead
    }

    /// Idle-poll burn charged per time-sparse persistent batch.
    pub fn poll_idle_cost(&self) -> f64 {
        self.spec.poll_idle_cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn force_kernel_matches_paper_numbers() {
        // Paper section 4.3: 50% occupancy, 8 blocks/SM, maxSize = 104.
        let occ = occupancy(&GpuSpec::kepler_k20(), &KernelResources::force_kernel());
        assert_eq!(occ.blocks_per_sm, 8);
        assert!((occ.occupancy - 0.50).abs() < 1e-9);
        assert_eq!(occ.max_size, 104);
        assert_eq!(occ.limiter, Limiter::Registers);
    }

    #[test]
    fn ewald_kernel_matches_paper_numbers() {
        // Paper section 4.3: 31% occupancy, maxSize = 65.
        let occ = occupancy(&GpuSpec::kepler_k20(), &KernelResources::ewald_kernel());
        assert_eq!(occ.blocks_per_sm, 5);
        assert!((occ.occupancy - 0.3125).abs() < 1e-9);
        assert_eq!(occ.max_size, 65);
    }

    #[test]
    fn occupancy_limited_by_block_cap_for_tiny_kernels() {
        let spec = GpuSpec::kepler_k20();
        let k = KernelResources {
            threads_per_block: 32,
            regs_per_thread: 8,
            smem_per_block: 64,
        };
        let occ = occupancy(&spec, &k);
        assert_eq!(occ.blocks_per_sm, 16);
        assert_eq!(occ.limiter, Limiter::Blocks);
    }

    #[test]
    fn occupancy_limited_by_threads_for_huge_blocks() {
        let spec = GpuSpec::kepler_k20();
        let k = KernelResources {
            threads_per_block: 1024,
            regs_per_thread: 8,
            smem_per_block: 64,
        };
        let occ = occupancy(&spec, &k);
        assert_eq!(occ.blocks_per_sm, 2);
        assert_eq!(occ.limiter, Limiter::Threads);
    }

    #[test]
    fn occupancy_limited_by_smem() {
        let spec = GpuSpec::kepler_k20();
        let k = KernelResources {
            threads_per_block: 64,
            regs_per_thread: 8,
            smem_per_block: 16_384,
        };
        let occ = occupancy(&spec, &k);
        assert_eq!(occ.blocks_per_sm, 3);
        assert_eq!(occ.limiter, Limiter::SharedMemory);
    }

    #[test]
    fn transfer_time_monotonic_in_bytes() {
        let m = DeviceModel::kepler_k20();
        assert_eq!(m.transfer_time(0), 0.0);
        let small = m.transfer_time(1024);
        let big = m.transfer_time(1 << 24);
        assert!(small > 0.0 && big > small);
        // 16 MiB at 6 GB/s is about 2.8 ms
        assert!((big - 2.8e-3).abs() < 0.5e-3, "big = {big}");
    }

    #[test]
    fn kernel_time_orders_by_coalescing_class() {
        let m = DeviceModel::kepler_k20();
        let k = KernelResources::force_kernel();
        let c = m.kernel_time(&k, 104, 16 * 128, CoalescingClass::Contiguous);
        let s = m.kernel_time(&k, 104, 16 * 128, CoalescingClass::SortedGather);
        let r = m.kernel_time(&k, 104, 16 * 128, CoalescingClass::RandomGather);
        assert!(c < s && s < r);
        // paper Fig 3: random gather ~ +49% kernel time (x the index reads)
        let ratio = r / c;
        assert!((1.45..1.75).contains(&ratio), "ratio = {ratio}");
    }

    #[test]
    fn kernel_time_scales_with_waves() {
        let m = DeviceModel::kepler_k20();
        let k = KernelResources::force_kernel();
        let one = m.kernel_time(&k, 104, 2048, CoalescingClass::Contiguous);
        let two = m.kernel_time(&k, 208, 2048, CoalescingClass::Contiguous);
        assert!(two > one);
        let overhead = m.spec.launch_overhead;
        let ratio = (two - overhead) / (one - overhead);
        assert!((ratio - 2.0).abs() < 1e-6, "ratio = {ratio}");
    }

    #[test]
    fn persistent_batch_saves_exactly_the_overhead_delta() {
        // Contiguous pattern: factor 1.0, no index reads, so the two
        // variants differ by precisely launch_overhead - queue_poll_cost.
        let m = DeviceModel::kepler_k20();
        let k = KernelResources::force_kernel();
        let per_batch = m.kernel_time(&k, 8, 2048, CoalescingClass::Contiguous);
        let persistent =
            m.kernel_time_persistent(&k, 8, 2048, CoalescingClass::Contiguous);
        let delta = m.spec.launch_overhead - m.spec.queue_poll_cost;
        assert!(delta > 0.0);
        assert!(
            (per_batch - persistent - delta).abs() < 1e-12,
            "per_batch={per_batch} persistent={persistent}"
        );
    }

    #[test]
    fn persistent_break_even_needs_dense_traffic() {
        // Residency is a fixed cost and every sparse batch pays the idle
        // burn: 1 batch loses, a dense run of 16 wins.
        let m = DeviceModel::kepler_k20();
        let k = KernelResources::force_kernel();
        let pb = m.kernel_time(&k, 8, 2048, CoalescingClass::Contiguous);
        let ps = m.kernel_time_persistent(&k, 8, 2048, CoalescingClass::Contiguous);

        let sparse_persistent = m.residency_cost() + ps + m.poll_idle_cost();
        assert!(sparse_persistent > pb, "one sparse batch must lose");

        let n = 16.0;
        let dense_persistent = m.residency_cost() + n * ps;
        assert!(dense_persistent < n * pb, "dense traffic must win");
        // and the idle burn alone outweighs the per-batch saving
        assert!(m.poll_idle_cost() > m.spec.launch_overhead - m.spec.queue_poll_cost);
    }
}
