//! Zero-allocation staging arena for padded launch arguments.
//!
//! The launch hot path used to re-allocate and zero-fill every padded
//! argument buffer per chunk, clone the constant args per launch, and redo
//! variant selection (`manifest.select` plus a `String` clone of the
//! variant name) for every chunk of a split launch. This module removes
//! all three costs:
//!
//! - **Buffer pool**: padded argument buffers are pooled per
//!   `(variant, arg-slot)` and checked out per chunk. A checked-out buffer
//!   is overwritten only on its live slots; the pad tail is already inert
//!   from allocation time, so only the *dirty* tail a smaller batch leaves
//!   behind is re-padded (`live` slot watermark per buffer).
//! - **Constant args**: owned by each registered [`TileKernel`] (built
//!   once at registration) and shared (`Arc`) into every launch instead of
//!   cloned.
//! - **Variant memo**: `(kernel, n, pool)` -> selected variant name/batch,
//!   so repeated chunk sizes of split launches skip `manifest.select` and
//!   the name clone entirely.
//!
//! Staging is fully table-driven off the payload's `TileKernel`: tile
//! shapes and pad values come from the registered arg specs, so an
//! app-registered family stages through the same code as the built-ins.
//! Both the synchronous `Executor` and the pipelined `GpuService` stage
//! through this arena, which is what makes their outputs bitwise
//! identical: the padded bytes handed to the engine are produced by the
//! same code in both paths.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::executor::Payload;
use super::manifest::Manifest;
use super::pjrt::HostArg;

/// Copy `n_slots` slots of width `slot_len` from `src[start_slot..]` to the
/// head of `dst`.
pub(crate) fn copy_slots<T: Copy>(
    dst: &mut [T],
    src: &[T],
    start_slot: usize,
    n_slots: usize,
    slot_len: usize,
) {
    let src_off = start_slot * slot_len;
    dst[..n_slots * slot_len]
        .copy_from_slice(&src[src_off..src_off + n_slots * slot_len]);
}

/// Write one slot of width `slot_len` into `dst` at `slot`: the shared
/// mirror-write primitive of demand staging and prefetch staging
/// (`coordinator::chare_table`), and the read side of the victim cache.
pub(crate) fn write_slot<T: Copy>(
    dst: &mut [T],
    slot: usize,
    slot_len: usize,
    src: &[T],
) {
    let off = slot * slot_len;
    dst[off..off + slot_len].copy_from_slice(&src[..slot_len]);
}

/// Pool key: variant name + argument slot index.
type BufKey = (Arc<str>, usize);

#[derive(Debug)]
enum BufData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types the arena can pool, with their `BufData` plumbing (keeps
/// `checkout` a single implementation for f32 and i32 buffers).
trait PadElem: Copy {
    fn wrap(v: Vec<Self>) -> BufData
    where
        Self: Sized;
    fn slice_mut(data: &mut BufData) -> &mut [Self]
    where
        Self: Sized;
}

impl PadElem for f32 {
    fn wrap(v: Vec<f32>) -> BufData {
        BufData::F32(v)
    }

    fn slice_mut(data: &mut BufData) -> &mut [f32] {
        match data {
            BufData::F32(v) => v,
            BufData::I32(_) => unreachable!("f32 buffer expected"),
        }
    }
}

impl PadElem for i32 {
    fn wrap(v: Vec<i32>) -> BufData {
        BufData::I32(v)
    }

    fn slice_mut(data: &mut BufData) -> &mut [i32] {
        match data {
            BufData::I32(v) => v,
            BufData::F32(_) => unreachable!("i32 buffer expected"),
        }
    }
}

/// One pooled padded buffer, plus the slot watermark that is dirty with
/// live data from its last use (everything past it is pristine pad).
#[derive(Debug)]
pub struct ArenaBuf {
    key: BufKey,
    data: BufData,
    /// Slots `[0, live)` hold (or will hold) live data.
    live: usize,
}

impl ArenaBuf {
    fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            BufData::F32(v) => v,
            BufData::I32(_) => unreachable!("f32 buffer expected"),
        }
    }

    fn as_i32_mut(&mut self) -> &mut [i32] {
        match &mut self.data {
            BufData::I32(v) => v,
            BufData::F32(_) => unreachable!("i32 buffer expected"),
        }
    }
}

/// One staged launch argument: a pooled padded buffer, or a shared
/// (constant / zero-copy) buffer.
#[derive(Debug)]
pub enum ArenaArg {
    Owned(ArenaBuf),
    Shared(Arc<Vec<f32>>),
}

impl ArenaArg {
    pub fn as_host_arg(&self) -> HostArg<'_> {
        match self {
            ArenaArg::Owned(b) => match &b.data {
                BufData::F32(v) => HostArg::F32(v),
                BufData::I32(v) => HostArg::I32(v),
            },
            ArenaArg::Shared(v) => HostArg::F32(v),
        }
    }
}

/// One padded chunk, ready to execute: variant name + argument buffers.
#[derive(Debug)]
pub struct StagedChunk {
    pub name: Arc<str>,
    /// Live (unpadded) slots in this chunk.
    pub n: usize,
    pub args: Vec<ArenaArg>,
}

/// Memoized variant selection for one `(kernel, n, pool)` query.
#[derive(Debug, Clone)]
struct CachedVariant {
    name: Arc<str>,
    batch: usize,
    pool: usize,
}

/// Arena counters (the hotpath bench and the memoization tests read them).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Buffers checked out of the arena.
    pub checkouts: u64,
    /// Checkouts that allocated a fresh buffer (arena misses).
    pub buffer_allocs: u64,
    /// Checkouts served from the pool.
    pub buffer_reuses: u64,
    /// Elements re-padded on reuse (dirty tails of smaller batches).
    pub repadded_elems: u64,
    /// `manifest.select` calls actually performed.
    pub variant_lookups: u64,
    /// Variant queries answered from the memo.
    pub variant_hits: u64,
}

/// Reusable staging state: buffer pools and the variant memo.
#[derive(Debug, Default)]
pub struct StagingArena {
    pools: HashMap<BufKey, Vec<ArenaBuf>>,
    variants: HashMap<(Arc<str>, usize, usize), CachedVariant>,
    stats: ArenaStats,
}

impl StagingArena {
    pub fn new() -> StagingArena {
        StagingArena::default()
    }

    pub fn stats(&self) -> ArenaStats {
        self.stats
    }

    /// Memoized `manifest.select` for `(kernel, n, pool)`.
    fn variant(
        &mut self,
        manifest: &Manifest,
        kernel: &Arc<str>,
        n: usize,
        pool: usize,
    ) -> Result<CachedVariant> {
        if let Some(v) = self.variants.get(&(kernel.clone(), n, pool)) {
            self.stats.variant_hits += 1;
            return Ok(v.clone());
        }
        self.stats.variant_lookups += 1;
        let v = manifest
            .select(kernel, n, pool)
            .with_context(|| format!("no variant for kernel {kernel}"))?;
        let cached = CachedVariant {
            name: Arc::from(v.name.as_str()),
            batch: v.batch,
            pool: v.pool,
        };
        self.variants
            .insert((kernel.clone(), n, pool), cached.clone());
        Ok(cached)
    }

    /// Check a padded buffer out of the pool: slots `[0, n)` are about
    /// to be overwritten by the caller; the rest is guaranteed `pad`.
    /// A reused buffer has only its dirty tail (`[n, live)` slots of the
    /// previous use) re-padded.
    fn checkout<T: PadElem>(
        &mut self,
        name: &Arc<str>,
        arg: usize,
        batch: usize,
        slot_len: usize,
        n: usize,
        pad: T,
    ) -> ArenaBuf {
        self.stats.checkouts += 1;
        let key = (name.clone(), arg);
        if let Some(mut buf) = self.pools.get_mut(&key).and_then(|p| p.pop()) {
            self.stats.buffer_reuses += 1;
            if buf.live > n {
                let (a, b) = (n * slot_len, buf.live * slot_len);
                T::slice_mut(&mut buf.data)[a..b].fill(pad);
                self.stats.repadded_elems += (b - a) as u64;
            }
            buf.live = n;
            return buf;
        }
        self.stats.buffer_allocs += 1;
        ArenaBuf {
            key,
            data: T::wrap(vec![pad; batch * slot_len]),
            live: n,
        }
    }

    /// Return a chunk's pooled buffers for reuse by later chunks.
    pub fn recycle(&mut self, chunk: StagedChunk) {
        for arg in chunk.args {
            if let ArenaArg::Owned(buf) = arg {
                self.pools.entry(buf.key.clone()).or_default().push(buf);
            }
        }
    }

    /// Stage payload slots `[start, start + n)` into padded buffers for
    /// the selected variant, table-driven off the payload's `TileKernel`.
    ///
    /// `pool_cache` is a per-launch memo of the padded gather pool: the
    /// chare-table mirror is pool-wide and identical across the chunks of
    /// one launch, so it is padded at most once per launch instead of once
    /// per chunk. Callers must pass a fresh `None` per launch (the mirror
    /// is copy-on-write and may be rewritten between launches).
    pub fn stage_chunk(
        &mut self,
        manifest: &Manifest,
        payload: &Payload,
        start: usize,
        n: usize,
        pool_cache: &mut Option<(usize, Arc<Vec<f32>>)>,
    ) -> Result<StagedChunk> {
        match payload {
            Payload::Tile { kernel, bufs, .. } => {
                let v = self.variant(manifest, &kernel.name, n, 0)?;
                let mut args = Vec::with_capacity(kernel.args.len() + 1);
                for (i, (spec, src)) in
                    kernel.args.iter().zip(bufs).enumerate()
                {
                    let slot = spec.slot_len();
                    let mut b =
                        self.checkout(&v.name, i, v.batch, slot, n, spec.pad);
                    copy_slots(b.as_f32_mut(), src, start, n, slot);
                    args.push(ArenaArg::Owned(b));
                }
                if !kernel.constant.is_empty() {
                    args.push(ArenaArg::Shared(kernel.constant.clone()));
                }
                Ok(StagedChunk { name: v.name, n, args })
            }
            Payload::TileGather { kernel, pool, idx, bufs, .. } => {
                let gather = kernel
                    .gather_name
                    .as_ref()
                    .context("gather payload for a family without one")?;
                let ra = kernel
                    .reuse_arg
                    .context("gather payload without a reuse arg")?;
                let spec = kernel.args[ra];
                let rows = pool.len() / spec.width;
                let v = self.variant(manifest, gather, n, rows)?;
                anyhow::ensure!(
                    v.pool >= rows,
                    "pool of {rows} rows exceeds largest gather variant ({})",
                    v.pool
                );
                // zero-copy when the mirror exactly matches the variant;
                // otherwise pad once per launch and share across chunks
                let pool_arg = if rows == v.pool {
                    ArenaArg::Shared(pool.clone())
                } else {
                    match pool_cache {
                        Some((vp, padded)) if *vp == v.pool => {
                            ArenaArg::Shared(padded.clone())
                        }
                        _ => {
                            let mut pl = vec![0.0f32; v.pool * spec.width];
                            pl[..pool.len()].copy_from_slice(pool);
                            let padded = Arc::new(pl);
                            *pool_cache = Some((v.pool, padded.clone()));
                            ArenaArg::Shared(padded)
                        }
                    }
                };
                let mut args = Vec::with_capacity(kernel.args.len() + 2);
                args.push(pool_arg);
                let mut ix =
                    self.checkout(&v.name, 1, v.batch, spec.rows, n, 0i32);
                copy_slots(ix.as_i32_mut(), idx, start, n, spec.rows);
                args.push(ArenaArg::Owned(ix));
                // remaining tiles keep their registration order; `bufs`
                // holds them in that order (reuse arg omitted)
                let mut slot_arg = 2usize;
                let mut src_it = bufs.iter();
                for (i, a) in kernel.args.iter().enumerate() {
                    if i == ra {
                        continue;
                    }
                    let src =
                        src_it.next().context("gather payload missing a tile")?;
                    let slot = a.slot_len();
                    let mut b = self
                        .checkout(&v.name, slot_arg, v.batch, slot, n, a.pad);
                    copy_slots(b.as_f32_mut(), src, start, n, slot);
                    args.push(ArenaArg::Owned(b));
                    slot_arg += 1;
                }
                if !kernel.constant.is_empty() {
                    args.push(ArenaArg::Shared(kernel.constant.clone()));
                }
                Ok(StagedChunk { name: v.name, n, args })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kernel::TileKernel;
    use crate::runtime::shapes::{
        INTERACTIONS, INTER_W, MD_PAD_POS, MD_W, PARTICLE_W, PARTS_PER_BUCKET,
        PARTS_PER_PATCH,
    };
    use std::path::Path;

    fn arena() -> (StagingArena, Manifest) {
        (StagingArena::new(), Manifest::synthetic(Path::new("/tmp/x")))
    }

    fn gravity_payload(batch: usize, fill: f32) -> Payload {
        Payload::Tile {
            kernel: Arc::new(TileKernel::gravity(0.01)),
            bufs: vec![
                vec![fill; batch * PARTS_PER_BUCKET * PARTICLE_W],
                vec![fill; batch * INTERACTIONS * INTER_W],
            ],
            batch,
        }
    }

    #[test]
    fn copy_slots_copies_window() {
        let src: Vec<i32> = (0..12).collect();
        let mut dst = vec![0i32; 8];
        copy_slots(&mut dst, &src, 1, 2, 3); // slots 1..3 of width 3
        assert_eq!(&dst[..6], &[3, 4, 5, 6, 7, 8]);
        assert_eq!(&dst[6..], &[0, 0]);
    }

    #[test]
    fn write_slot_targets_one_slot() {
        let mut dst = vec![0i32; 9];
        write_slot(&mut dst, 1, 3, &[7, 8, 9]);
        assert_eq!(dst, vec![0, 0, 0, 7, 8, 9, 0, 0, 0]);
    }

    #[test]
    fn checkout_reuses_and_repads_dirty_tail() {
        let (mut a, m) = arena();
        // n=4 and n=3 both select the B4 variant: same pool key
        let p = gravity_payload(4, 7.0);
        let c = a.stage_chunk(&m, &p, 0, 4, &mut None).unwrap();
        assert_eq!(a.stats().buffer_allocs, 2);
        a.recycle(c);

        let q = gravity_payload(3, 2.0);
        let c2 = a.stage_chunk(&m, &q, 0, 3, &mut None).unwrap();
        let s = a.stats();
        assert_eq!(s.buffer_allocs, 2, "no new allocations");
        assert_eq!(s.buffer_reuses, 2);
        assert!(s.repadded_elems > 0, "dirty slot [3, 4) must be re-padded");
        match c2.args[0].as_host_arg() {
            HostArg::F32(buf) => {
                let slot = PARTS_PER_BUCKET * PARTICLE_W;
                assert!(buf[..3 * slot].iter().all(|&x| x == 2.0));
                assert!(
                    buf[3 * slot..].iter().all(|&x| x == 0.0),
                    "stale slot must be re-padded"
                );
            }
            _ => panic!("f32 arg expected"),
        }
        a.recycle(c2);
    }

    #[test]
    fn growing_batch_needs_no_repad() {
        let (mut a, m) = arena();
        let c = a
            .stage_chunk(&m, &gravity_payload(3, 1.0), 0, 3, &mut None)
            .unwrap();
        a.recycle(c);
        // n=4 reuses the B4 buffers; the grown live region is overwritten
        let c2 = a
            .stage_chunk(&m, &gravity_payload(4, 3.0), 0, 4, &mut None)
            .unwrap();
        let s = a.stats();
        assert_eq!(s.buffer_reuses, 2);
        assert_eq!(s.repadded_elems, 0);
        match c2.args[0].as_host_arg() {
            HostArg::F32(buf) => {
                let slot = PARTS_PER_BUCKET * PARTICLE_W;
                assert!(buf[..4 * slot].iter().all(|&x| x == 3.0));
            }
            _ => panic!("f32 arg expected"),
        }
    }

    #[test]
    fn variant_selection_is_memoized() {
        let (mut a, m) = arena();
        for _ in 0..5 {
            let c = a
                .stage_chunk(&m, &gravity_payload(3, 0.5), 0, 3, &mut None)
                .unwrap();
            a.recycle(c);
        }
        let s = a.stats();
        assert_eq!(s.variant_lookups, 1, "one real select per (kernel, n)");
        assert_eq!(s.variant_hits, 4);
    }

    #[test]
    fn pad_uses_registered_pad_value() {
        let (mut a, m) = arena();
        // batch 3 selects the B4 variant: slot 3 is a pad slot
        let p = Payload::Tile {
            kernel: Arc::new(TileKernel::md_force([1.0, 0.04, 1.0])),
            bufs: vec![
                vec![0.25; 3 * PARTS_PER_PATCH * MD_W],
                vec![0.75; 3 * PARTS_PER_PATCH * MD_W],
            ],
            batch: 3,
        };
        let c = a.stage_chunk(&m, &p, 0, 3, &mut None).unwrap();
        match c.args[0].as_host_arg() {
            HostArg::F32(buf) => {
                let slot = PARTS_PER_PATCH * MD_W;
                assert_eq!(buf.len(), 4 * slot);
                assert!(buf[..3 * slot].iter().all(|&x| x == 0.25));
                assert!(
                    buf[3 * slot..].iter().all(|&x| x == MD_PAD_POS),
                    "MD pad slots must park at MD_PAD_POS, not zero"
                );
            }
            _ => panic!("f32 arg expected"),
        }
        // the constant arg rides along shared
        match c.args[2].as_host_arg() {
            HostArg::F32(buf) => assert_eq!(buf, &[1.0, 0.04, 1.0]),
            _ => panic!("f32 constant expected"),
        }
    }

    #[test]
    fn gather_pool_padded_once_per_launch() {
        let (mut a, m) = arena();
        let rows = 512; // smaller than every ladder pool: forces padding
        let pool = Arc::new(vec![1.5f32; rows * PARTICLE_W]);
        let batch = 4;
        let p = Payload::TileGather {
            kernel: Arc::new(TileKernel::gravity(0.01)),
            pool: pool.clone(),
            idx: vec![0; batch * PARTS_PER_BUCKET],
            bufs: vec![vec![0.0; batch * INTERACTIONS * INTER_W]],
            batch,
        };
        let mut cache = None;
        let c1 = a.stage_chunk(&m, &p, 0, 2, &mut cache).unwrap();
        let c2 = a.stage_chunk(&m, &p, 2, 2, &mut cache).unwrap();
        let (p1, p2) = match (&c1.args[0], &c2.args[0]) {
            (ArenaArg::Shared(x), ArenaArg::Shared(y)) => (x, y),
            _ => panic!("shared pool args expected"),
        };
        assert!(Arc::ptr_eq(p1, p2), "pool padded once, shared by chunks");
        assert!(!Arc::ptr_eq(p1, &pool), "padded copy, not the mirror");
    }
}
