//! Zero-allocation staging arena for padded launch arguments.
//!
//! The launch hot path used to re-allocate and zero-fill every padded
//! argument buffer per chunk, clone the constant args (`eps2`, `ktab`,
//! `md_params`) per launch, and redo variant selection (`manifest.select`
//! plus a `String` clone of the variant name) for every chunk of a split
//! launch. This module removes all three costs:
//!
//! - **Buffer pool**: padded argument buffers are pooled per
//!   `(variant, arg-slot)` and checked out per chunk. A checked-out buffer
//!   is overwritten only on its live slots; the pad tail is already inert
//!   from allocation time, so only the *dirty* tail a smaller batch leaves
//!   behind is re-padded (`live` slot watermark per buffer).
//! - **Constant args**: built once from `ExecutorConfig` and shared
//!   (`Arc`) into every launch instead of cloned.
//! - **Variant memo**: `(kernel, n, pool)` -> selected variant name/batch,
//!   so repeated chunk sizes of split launches skip `manifest.select` and
//!   the name clone entirely.
//!
//! Both the synchronous `Executor` and the pipelined `GpuService` stage
//! through this arena, which is what makes their outputs bitwise
//! identical: the padded bytes handed to the engine are produced by the
//! same code in both paths.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::executor::{ExecutorConfig, Payload};
use super::manifest::Manifest;
use super::pjrt::HostArg;
use super::shapes::{
    INTERACTIONS, INTER_W, MD_PAD_POS, MD_W, PARTICLE_W, PARTS_PER_BUCKET,
    PARTS_PER_PATCH,
};

/// Copy `n_slots` slots of width `slot_len` from `src[start_slot..]` to the
/// head of `dst`.
pub(crate) fn copy_slots<T: Copy>(
    dst: &mut [T],
    src: &[T],
    start_slot: usize,
    n_slots: usize,
    slot_len: usize,
) {
    let src_off = start_slot * slot_len;
    dst[..n_slots * slot_len]
        .copy_from_slice(&src[src_off..src_off + n_slots * slot_len]);
}

/// Pool key: variant name + argument slot index.
type BufKey = (Arc<str>, usize);

#[derive(Debug)]
enum BufData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types the arena can pool, with their `BufData` plumbing (keeps
/// `checkout` a single implementation for f32 and i32 buffers).
trait PadElem: Copy {
    fn wrap(v: Vec<Self>) -> BufData
    where
        Self: Sized;
    fn slice_mut(data: &mut BufData) -> &mut [Self]
    where
        Self: Sized;
}

impl PadElem for f32 {
    fn wrap(v: Vec<f32>) -> BufData {
        BufData::F32(v)
    }

    fn slice_mut(data: &mut BufData) -> &mut [f32] {
        match data {
            BufData::F32(v) => v,
            BufData::I32(_) => unreachable!("f32 buffer expected"),
        }
    }
}

impl PadElem for i32 {
    fn wrap(v: Vec<i32>) -> BufData {
        BufData::I32(v)
    }

    fn slice_mut(data: &mut BufData) -> &mut [i32] {
        match data {
            BufData::I32(v) => v,
            BufData::F32(_) => unreachable!("i32 buffer expected"),
        }
    }
}

/// One pooled padded buffer, plus the slot watermark that is dirty with
/// live data from its last use (everything past it is pristine pad).
#[derive(Debug)]
pub struct ArenaBuf {
    key: BufKey,
    data: BufData,
    /// Slots `[0, live)` hold (or will hold) live data.
    live: usize,
}

impl ArenaBuf {
    fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            BufData::F32(v) => v,
            BufData::I32(_) => unreachable!("f32 buffer expected"),
        }
    }

    fn as_i32_mut(&mut self) -> &mut [i32] {
        match &mut self.data {
            BufData::I32(v) => v,
            BufData::F32(_) => unreachable!("i32 buffer expected"),
        }
    }
}

/// One staged launch argument: a pooled padded buffer, or a shared
/// (constant / zero-copy) buffer.
#[derive(Debug)]
pub enum ArenaArg {
    Owned(ArenaBuf),
    Shared(Arc<Vec<f32>>),
}

impl ArenaArg {
    pub fn as_host_arg(&self) -> HostArg<'_> {
        match self {
            ArenaArg::Owned(b) => match &b.data {
                BufData::F32(v) => HostArg::F32(v),
                BufData::I32(v) => HostArg::I32(v),
            },
            ArenaArg::Shared(v) => HostArg::F32(v),
        }
    }
}

/// One padded chunk, ready to execute: variant name + argument buffers.
#[derive(Debug)]
pub struct StagedChunk {
    pub name: Arc<str>,
    /// Live (unpadded) slots in this chunk.
    pub n: usize,
    pub args: Vec<ArenaArg>,
}

/// Memoized variant selection for one `(kernel, n, pool)` query.
#[derive(Debug, Clone)]
struct CachedVariant {
    name: Arc<str>,
    batch: usize,
    pool: usize,
}

/// Arena counters (the hotpath bench and the memoization tests read them).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Buffers checked out of the arena.
    pub checkouts: u64,
    /// Checkouts that allocated a fresh buffer (arena misses).
    pub buffer_allocs: u64,
    /// Checkouts served from the pool.
    pub buffer_reuses: u64,
    /// Elements re-padded on reuse (dirty tails of smaller batches).
    pub repadded_elems: u64,
    /// `manifest.select` calls actually performed.
    pub variant_lookups: u64,
    /// Variant queries answered from the memo.
    pub variant_hits: u64,
}

/// Reusable staging state: buffer pools, constant args, variant memo.
#[derive(Debug)]
pub struct StagingArena {
    pools: HashMap<BufKey, Vec<ArenaBuf>>,
    variants: HashMap<(&'static str, usize, usize), CachedVariant>,
    /// Constant launch args, built once per run (not per launch).
    eps2: Arc<Vec<f32>>,
    ktab: Arc<Vec<f32>>,
    md_params: Arc<Vec<f32>>,
    stats: ArenaStats,
}

impl StagingArena {
    pub fn new(config: &ExecutorConfig) -> StagingArena {
        StagingArena {
            pools: HashMap::new(),
            variants: HashMap::new(),
            eps2: Arc::new(vec![config.eps2]),
            ktab: Arc::new(config.ktab.clone()),
            md_params: Arc::new(config.md_params.to_vec()),
            stats: ArenaStats::default(),
        }
    }

    pub fn stats(&self) -> ArenaStats {
        self.stats
    }

    /// Memoized `manifest.select` for `(kernel, n, pool)`.
    fn variant(
        &mut self,
        manifest: &Manifest,
        kernel: &'static str,
        n: usize,
        pool: usize,
    ) -> Result<CachedVariant> {
        if let Some(v) = self.variants.get(&(kernel, n, pool)) {
            self.stats.variant_hits += 1;
            return Ok(v.clone());
        }
        self.stats.variant_lookups += 1;
        let v = manifest
            .select(kernel, n, pool)
            .with_context(|| format!("no variant for kernel {kernel}"))?;
        let cached = CachedVariant {
            name: Arc::from(v.name.as_str()),
            batch: v.batch,
            pool: v.pool,
        };
        self.variants
            .insert((kernel, n, pool), cached.clone());
        Ok(cached)
    }

    /// Check a padded buffer out of the pool: slots `[0, n)` are about
    /// to be overwritten by the caller; the rest is guaranteed `pad`.
    /// A reused buffer has only its dirty tail (`[n, live)` slots of the
    /// previous use) re-padded.
    fn checkout<T: PadElem>(
        &mut self,
        name: &Arc<str>,
        arg: usize,
        batch: usize,
        slot_len: usize,
        n: usize,
        pad: T,
    ) -> ArenaBuf {
        self.stats.checkouts += 1;
        let key = (name.clone(), arg);
        if let Some(mut buf) = self.pools.get_mut(&key).and_then(|p| p.pop()) {
            self.stats.buffer_reuses += 1;
            if buf.live > n {
                let (a, b) = (n * slot_len, buf.live * slot_len);
                T::slice_mut(&mut buf.data)[a..b].fill(pad);
                self.stats.repadded_elems += (b - a) as u64;
            }
            buf.live = n;
            return buf;
        }
        self.stats.buffer_allocs += 1;
        ArenaBuf {
            key,
            data: T::wrap(vec![pad; batch * slot_len]),
            live: n,
        }
    }

    /// Return a chunk's pooled buffers for reuse by later chunks.
    pub fn recycle(&mut self, chunk: StagedChunk) {
        for arg in chunk.args {
            if let ArenaArg::Owned(buf) = arg {
                self.pools.entry(buf.key.clone()).or_default().push(buf);
            }
        }
    }

    /// Stage payload slots `[start, start + n)` into padded buffers for
    /// the selected variant.
    ///
    /// `pool_cache` is a per-launch memo of the padded gather pool: the
    /// chare-table mirror is pool-wide and identical across the chunks of
    /// one launch, so it is padded at most once per launch instead of once
    /// per chunk. Callers must pass a fresh `None` per launch (the mirror
    /// is copy-on-write and may be rewritten between launches).
    pub fn stage_chunk(
        &mut self,
        manifest: &Manifest,
        payload: &Payload,
        start: usize,
        n: usize,
        pool_cache: &mut Option<(usize, Arc<Vec<f32>>)>,
    ) -> Result<StagedChunk> {
        match payload {
            Payload::Gravity { parts, inters, .. } => {
                let v = self.variant(manifest, "gravity", n, 0)?;
                let ps = PARTS_PER_BUCKET * PARTICLE_W;
                let is = INTERACTIONS * INTER_W;
                let mut p =
                    self.checkout(&v.name, 0, v.batch, ps, n, 0.0f32);
                copy_slots(p.as_f32_mut(), parts, start, n, ps);
                let mut i =
                    self.checkout(&v.name, 1, v.batch, is, n, 0.0f32);
                copy_slots(i.as_f32_mut(), inters, start, n, is);
                Ok(StagedChunk {
                    name: v.name,
                    n,
                    args: vec![
                        ArenaArg::Owned(p),
                        ArenaArg::Owned(i),
                        ArenaArg::Shared(self.eps2.clone()),
                    ],
                })
            }
            Payload::GravityGather { pool, idx, inters, .. } => {
                let rows = pool.len() / PARTICLE_W;
                let v =
                    self.variant(manifest, "gravity_gather", n, rows)?;
                anyhow::ensure!(
                    v.pool >= rows,
                    "pool of {rows} rows exceeds largest gather variant ({})",
                    v.pool
                );
                // zero-copy when the mirror exactly matches the variant;
                // otherwise pad once per launch and share across chunks
                let pool_arg = if rows == v.pool {
                    ArenaArg::Shared(pool.clone())
                } else {
                    match pool_cache {
                        Some((vp, padded)) if *vp == v.pool => {
                            ArenaArg::Shared(padded.clone())
                        }
                        _ => {
                            let mut pl = vec![0.0f32; v.pool * PARTICLE_W];
                            pl[..pool.len()].copy_from_slice(pool);
                            let padded = Arc::new(pl);
                            *pool_cache = Some((v.pool, padded.clone()));
                            ArenaArg::Shared(padded)
                        }
                    }
                };
                let mut ix = self.checkout(
                    &v.name,
                    1,
                    v.batch,
                    PARTS_PER_BUCKET,
                    n,
                    0i32,
                );
                copy_slots(ix.as_i32_mut(), idx, start, n, PARTS_PER_BUCKET);
                let is = INTERACTIONS * INTER_W;
                let mut it =
                    self.checkout(&v.name, 2, v.batch, is, n, 0.0f32);
                copy_slots(it.as_f32_mut(), inters, start, n, is);
                Ok(StagedChunk {
                    name: v.name,
                    n,
                    args: vec![
                        pool_arg,
                        ArenaArg::Owned(ix),
                        ArenaArg::Owned(it),
                        ArenaArg::Shared(self.eps2.clone()),
                    ],
                })
            }
            Payload::Ewald { parts, .. } => {
                let v = self.variant(manifest, "ewald", n, 0)?;
                let ps = PARTS_PER_BUCKET * PARTICLE_W;
                let mut p =
                    self.checkout(&v.name, 0, v.batch, ps, n, 0.0f32);
                copy_slots(p.as_f32_mut(), parts, start, n, ps);
                Ok(StagedChunk {
                    name: v.name,
                    n,
                    args: vec![
                        ArenaArg::Owned(p),
                        ArenaArg::Shared(self.ktab.clone()),
                    ],
                })
            }
            Payload::MdForce { pa, pb, .. } => {
                let v = self.variant(manifest, "md_force", n, 0)?;
                let slot = PARTS_PER_PATCH * MD_W;
                let mut a = self
                    .checkout(&v.name, 0, v.batch, slot, n, MD_PAD_POS);
                copy_slots(a.as_f32_mut(), pa, start, n, slot);
                let mut b = self
                    .checkout(&v.name, 1, v.batch, slot, n, MD_PAD_POS);
                copy_slots(b.as_f32_mut(), pb, start, n, slot);
                Ok(StagedChunk {
                    name: v.name,
                    n,
                    args: vec![
                        ArenaArg::Owned(a),
                        ArenaArg::Owned(b),
                        ArenaArg::Shared(self.md_params.clone()),
                    ],
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn arena() -> (StagingArena, Manifest) {
        let cfg = ExecutorConfig::default();
        (StagingArena::new(&cfg), Manifest::synthetic(Path::new("/tmp/x")))
    }

    fn gravity_payload(batch: usize, fill: f32) -> Payload {
        Payload::Gravity {
            parts: vec![fill; batch * PARTS_PER_BUCKET * PARTICLE_W],
            inters: vec![fill; batch * INTERACTIONS * INTER_W],
            batch,
        }
    }

    #[test]
    fn copy_slots_copies_window() {
        let src: Vec<i32> = (0..12).collect();
        let mut dst = vec![0i32; 8];
        copy_slots(&mut dst, &src, 1, 2, 3); // slots 1..3 of width 3
        assert_eq!(&dst[..6], &[3, 4, 5, 6, 7, 8]);
        assert_eq!(&dst[6..], &[0, 0]);
    }

    #[test]
    fn checkout_reuses_and_repads_dirty_tail() {
        let (mut a, m) = arena();
        // n=4 and n=3 both select the B4 variant: same pool key
        let p = gravity_payload(4, 7.0);
        let c = a.stage_chunk(&m, &p, 0, 4, &mut None).unwrap();
        assert_eq!(a.stats().buffer_allocs, 2);
        a.recycle(c);

        let q = gravity_payload(3, 2.0);
        let c2 = a.stage_chunk(&m, &q, 0, 3, &mut None).unwrap();
        let s = a.stats();
        assert_eq!(s.buffer_allocs, 2, "no new allocations");
        assert_eq!(s.buffer_reuses, 2);
        assert!(s.repadded_elems > 0, "dirty slot [3, 4) must be re-padded");
        match c2.args[0].as_host_arg() {
            HostArg::F32(buf) => {
                let slot = PARTS_PER_BUCKET * PARTICLE_W;
                assert!(buf[..3 * slot].iter().all(|&x| x == 2.0));
                assert!(
                    buf[3 * slot..].iter().all(|&x| x == 0.0),
                    "stale slot must be re-padded"
                );
            }
            _ => panic!("f32 arg expected"),
        }
        a.recycle(c2);
    }

    #[test]
    fn growing_batch_needs_no_repad() {
        let (mut a, m) = arena();
        let c = a
            .stage_chunk(&m, &gravity_payload(3, 1.0), 0, 3, &mut None)
            .unwrap();
        a.recycle(c);
        // n=4 reuses the B4 buffers; the grown live region is overwritten
        let c2 = a
            .stage_chunk(&m, &gravity_payload(4, 3.0), 0, 4, &mut None)
            .unwrap();
        let s = a.stats();
        assert_eq!(s.buffer_reuses, 2);
        assert_eq!(s.repadded_elems, 0);
        match c2.args[0].as_host_arg() {
            HostArg::F32(buf) => {
                let slot = PARTS_PER_BUCKET * PARTICLE_W;
                assert!(buf[..4 * slot].iter().all(|&x| x == 3.0));
            }
            _ => panic!("f32 arg expected"),
        }
    }

    #[test]
    fn variant_selection_is_memoized() {
        let (mut a, m) = arena();
        for _ in 0..5 {
            let c = a
                .stage_chunk(&m, &gravity_payload(3, 0.5), 0, 3, &mut None)
                .unwrap();
            a.recycle(c);
        }
        let s = a.stats();
        assert_eq!(s.variant_lookups, 1, "one real select per (kernel, n)");
        assert_eq!(s.variant_hits, 4);
    }

    #[test]
    fn md_pad_uses_parked_position() {
        let (mut a, m) = arena();
        // batch 3 selects the B4 variant: slot 3 is a pad slot
        let p = Payload::MdForce {
            pa: vec![0.25; 3 * PARTS_PER_PATCH * MD_W],
            pb: vec![0.75; 3 * PARTS_PER_PATCH * MD_W],
            batch: 3,
        };
        let c = a.stage_chunk(&m, &p, 0, 3, &mut None).unwrap();
        match c.args[0].as_host_arg() {
            HostArg::F32(buf) => {
                let slot = PARTS_PER_PATCH * MD_W;
                assert_eq!(buf.len(), 4 * slot);
                assert!(buf[..3 * slot].iter().all(|&x| x == 0.25));
                assert!(
                    buf[3 * slot..].iter().all(|&x| x == MD_PAD_POS),
                    "MD pad slots must park at MD_PAD_POS, not zero"
                );
            }
            _ => panic!("f32 arg expected"),
        }
    }

    #[test]
    fn gather_pool_padded_once_per_launch() {
        let (mut a, m) = arena();
        let rows = 512; // smaller than every ladder pool: forces padding
        let pool = Arc::new(vec![1.5f32; rows * PARTICLE_W]);
        let batch = 4;
        let p = Payload::GravityGather {
            pool: pool.clone(),
            idx: vec![0; batch * PARTS_PER_BUCKET],
            inters: vec![0.0; batch * INTERACTIONS * INTER_W],
            batch,
        };
        let mut cache = None;
        let c1 = a.stage_chunk(&m, &p, 0, 2, &mut cache).unwrap();
        let c2 = a.stage_chunk(&m, &p, 2, 2, &mut cache).unwrap();
        let (p1, p2) = match (&c1.args[0], &c2.args[0]) {
            (ArenaArg::Shared(x), ArenaArg::Shared(y)) => (x, y),
            _ => panic!("shared pool args expected"),
        };
        assert!(Arc::ptr_eq(p1, p2), "pool padded once, shared by chunks");
        assert!(!Arc::ptr_eq(p1, &pool), "padded copy, not the mirror");
    }
}
