//! Runtime kernel-family descriptors: the open half of the kernel registry.
//!
//! A [`TileKernel`] describes everything the *runtime* needs to execute a
//! kernel family the coordinator never heard of at compile time: the
//! per-request tile shapes (staging + shape validation), the trailing
//! constant argument (shared into every launch), the occupancy resources
//! (combiner maxSize and the modeled cost), and a per-slot native function
//! that both the sim backend and the hybrid CPU path interpret — one f32
//! implementation, so CPU fallback, sim-GPU, and the pipelined service are
//! bit-compatible by construction.
//!
//! Apps register kernels through `coordinator::registry` (which wraps a
//! `TileKernel` with scheduling policy); the runtime layers (staging,
//! manifest ladders, the engine, the cost model) are all table-driven off
//! this type and contain no per-family `match`.

use std::sync::Arc;

use super::device_sim::{occupancy, GpuSpec, KernelResources};
use super::native::{cpu_ewald, cpu_gravity, cpu_md_interact};
use super::shapes::{
    INTERACTIONS, INTER_W, MD_PAD_POS, MD_W, OUT_W, PARTICLE_W,
    PARTS_PER_BUCKET, PARTS_PER_PATCH,
};

/// Native per-slot kernel function: `args` holds one slot-sized slice per
/// registered tile argument (in registration order), `constant` the
/// kernel's constant argument; returns the slot's output rows
/// (`out_rows * out_width` floats). The same function serves the sim GPU
/// backend and the hybrid CPU fallback.
pub type SlotFn = fn(args: &[&[f32]], constant: &[f32]) -> Vec<f32>;

/// Shape of one per-request input tile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TileArgSpec {
    /// Argument name, used in shape-error messages.
    pub name: &'static str,
    /// Rows per request slot.
    pub rows: usize,
    /// Floats per row.
    pub width: usize,
    /// Pad value for unused slots/rows (e.g. `MD_PAD_POS` parks padding
    /// particles outside every cutoff).
    pub pad: f32,
}

impl TileArgSpec {
    /// Floats in one request slot of this argument.
    pub fn slot_len(&self) -> usize {
        self.rows * self.width
    }
}

/// Runtime descriptor of one kernel family.
///
/// Built once at registration (`coordinator::registry`) and shared
/// (`Arc`) into payloads, the staging arena, the engine, and the manifest
/// ladder. See the module docs for the role of each field.
#[derive(Debug)]
pub struct TileKernel {
    /// Family name: the AOT manifest key and the per-kind report label.
    pub name: Arc<str>,
    /// Per-request input tiles, in launch-argument order.
    pub args: Vec<TileArgSpec>,
    /// Trailing constant launch argument (empty = none). Shared into every
    /// launch instead of cloned per chunk.
    pub constant: Arc<Vec<f32>>,
    /// Output rows per request slot.
    pub out_rows: usize,
    /// Floats per output row.
    pub out_width: usize,
    /// Kernel resource usage, as the CUDA compiler would report it; the
    /// occupancy calculator derives the combiner's maxSize from this
    /// (paper section 3.1 / 4.3).
    pub resources: KernelResources,
    /// Modeled particle-interactions per combined slot (cost model).
    pub items_per_slot: u64,
    /// Which tile argument is the reusable chare buffer (section 3.2
    /// residency), if any. Requests carrying a `buffer` id get this arg
    /// staged into the device pool and launched through the gather
    /// variant when fully resident.
    pub reuse_arg: Option<usize>,
    /// Manifest family name of the gather variant (required iff
    /// `reuse_arg` is set).
    pub gather_name: Option<Arc<str>>,
    /// Which tile argument the payload's `entry_ids` describe: residency
    /// keys of interaction entries (tree moments / cached particles)
    /// accounted against the device's entry cache.
    pub entry_arg: Option<usize>,
    /// The native per-slot implementation (sim backend + CPU fallback).
    pub slot_fn: SlotFn,
}

impl TileKernel {
    /// Output floats per request slot.
    pub fn out_slot_len(&self) -> usize {
        self.out_rows * self.out_width
    }

    /// Occupancy-derived combine target on the modeled device (paper
    /// section 4.3: force 104, Ewald 65).
    pub fn max_combine(&self) -> usize {
        occupancy(&GpuSpec::kepler_k20(), &self.resources).max_size as usize
    }

    /// Synthetic variant-ladder batch sizes: powers of two up to the
    /// first one that covers `max_combine`.
    pub fn ladder(&self) -> Vec<usize> {
        let max = self.max_combine().max(1);
        let mut out = Vec::new();
        let mut b = 1usize;
        while b < max {
            out.push(b);
            b *= 2;
        }
        out.push(b);
        out
    }

    /// The bucket gravity force kernel (N-Body): `parts` (P x 4) +
    /// interaction list (I x 4), eps2 constant, reusable particle buffer
    /// with a gather variant and entry-cache accounting of the list.
    pub fn gravity(eps2: f32) -> TileKernel {
        TileKernel {
            name: Arc::from("gravity"),
            args: vec![
                TileArgSpec {
                    name: "parts",
                    rows: PARTS_PER_BUCKET,
                    width: PARTICLE_W,
                    pad: 0.0,
                },
                TileArgSpec {
                    name: "inters",
                    rows: INTERACTIONS,
                    width: INTER_W,
                    pad: 0.0,
                },
            ],
            constant: Arc::new(vec![eps2]),
            out_rows: PARTS_PER_BUCKET,
            out_width: OUT_W,
            resources: KernelResources::force_kernel(),
            items_per_slot: (PARTS_PER_BUCKET * INTERACTIONS) as u64,
            reuse_arg: Some(0),
            gather_name: Some(Arc::from("gravity_gather")),
            entry_arg: Some(1),
            slot_fn: gravity_slot,
        }
    }

    /// The Ewald periodic-correction kernel (N-Body): `parts` (P x 4)
    /// against the k-vector table constant.
    pub fn ewald(ktab: Vec<f32>) -> TileKernel {
        TileKernel {
            name: Arc::from("ewald"),
            args: vec![TileArgSpec {
                name: "parts",
                rows: PARTS_PER_BUCKET,
                width: PARTICLE_W,
                pad: 0.0,
            }],
            constant: Arc::new(ktab),
            out_rows: PARTS_PER_BUCKET,
            out_width: OUT_W,
            resources: KernelResources::ewald_kernel(),
            items_per_slot: (PARTS_PER_BUCKET * super::shapes::KTABLE) as u64,
            reuse_arg: None,
            gather_name: None,
            entry_arg: None,
            slot_fn: ewald_slot,
        }
    }

    /// The MD patch-pair LJ kernel: two patch particle sets (N x 2),
    /// `[cutoff^2, sigma^2, epsilon]` constant, padding parked at
    /// `MD_PAD_POS`.
    pub fn md_force(params: [f32; 3]) -> TileKernel {
        TileKernel {
            name: Arc::from("md_force"),
            args: vec![
                TileArgSpec {
                    name: "pa",
                    rows: PARTS_PER_PATCH,
                    width: MD_W,
                    pad: MD_PAD_POS,
                },
                TileArgSpec {
                    name: "pb",
                    rows: PARTS_PER_PATCH,
                    width: MD_W,
                    pad: MD_PAD_POS,
                },
            ],
            constant: Arc::new(params.to_vec()),
            out_rows: PARTS_PER_PATCH,
            out_width: MD_W,
            resources: KernelResources::md_kernel(),
            items_per_slot: (PARTS_PER_PATCH * PARTS_PER_PATCH) as u64,
            reuse_arg: None,
            gather_name: None,
            entry_arg: None,
            slot_fn: md_slot,
        }
    }
}

fn gravity_slot(args: &[&[f32]], constant: &[f32]) -> Vec<f32> {
    cpu_gravity(args[0], args[1], constant[0])
}

fn ewald_slot(args: &[&[f32]], constant: &[f32]) -> Vec<f32> {
    cpu_ewald(args[0], constant)
}

fn md_slot(args: &[&[f32]], constant: &[f32]) -> Vec<f32> {
    cpu_md_interact(args[0], args[1], [constant[0], constant[1], constant[2]])
}

/// The three built-in kernel families the paper's apps use, over their
/// physics constants. Tests and the figure benches share this set.
pub fn builtin_kernels(
    eps2: f32,
    ktab: Vec<f32>,
    md_params: [f32; 3],
) -> Vec<Arc<TileKernel>> {
    vec![
        Arc::new(TileKernel::gravity(eps2)),
        Arc::new(TileKernel::ewald(ktab)),
        Arc::new(TileKernel::md_force(md_params)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_max_combine_matches_paper() {
        assert_eq!(TileKernel::gravity(0.01).max_combine(), 104);
        assert_eq!(TileKernel::ewald(vec![0.0; 4]).max_combine(), 65);
    }

    #[test]
    fn ladder_covers_max_combine() {
        let g = TileKernel::gravity(0.01);
        let l = g.ladder();
        assert_eq!(l, vec![1, 2, 4, 8, 16, 32, 64, 128]);
        assert!(*l.last().unwrap() >= g.max_combine());
    }

    #[test]
    fn slot_lens() {
        let g = TileKernel::gravity(0.01);
        assert_eq!(g.args[0].slot_len(), PARTS_PER_BUCKET * PARTICLE_W);
        assert_eq!(g.out_slot_len(), PARTS_PER_BUCKET * OUT_W);
        let m = TileKernel::md_force([1.0, 0.04, 1.0]);
        assert_eq!(m.out_slot_len(), PARTS_PER_PATCH * MD_W);
    }

    #[test]
    fn builtin_slot_fns_match_native_kernels() {
        let g = TileKernel::gravity(0.01);
        let parts = vec![0.0f32; PARTS_PER_BUCKET * PARTICLE_W];
        let inters = vec![0.5f32; INTERACTIONS * INTER_W];
        let got = (g.slot_fn)(&[&parts, &inters], &g.constant);
        assert_eq!(got, cpu_gravity(&parts, &inters, 0.01));
    }
}
