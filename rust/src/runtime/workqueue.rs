//! Persistent-kernel work queues: bounded descriptor rings feeding a
//! device-resident "megakernel" loop (ISSUE 8; Atos, arXiv 2112.00132).
//!
//! In persistent mode a device keeps one resident kernel alive per
//! family and drains combined batches from a mapped ring instead of
//! paying a host launch round-trip per batch. The sim backend models the
//! cost side ([`crate::runtime::device_sim::DeviceModel`]: a one-time
//! residency launch, then `queue_poll_cost` per batch instead of
//! `launch_overhead`, plus an idle-poll burn when traffic goes sparse);
//! this module is the host-side half: a bounded MPSC descriptor ring per
//! `(device, kernel family)` with occupancy/backpressure accounting, a
//! doorbell condvar for wakeups, and a clean quiesce/close story so job
//! seal and `Runtime::shutdown` terminate even with batches still queued
//! (the chaos watchdog pins that).
//!
//! Backpressure is a *mode decision*, not an error: when the ring is
//! full the coordinator launches that batch per-batch instead (counted
//! in [`QueueStats::rejected`]), so a jittered-down queue capacity
//! degrades throughput, never correctness.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// How a combined batch reaches the device (ISSUE 8).
///
/// `PerBatch` is the seed path: every combined batch pays a host kernel
/// launch (`launch_overhead` in the device model). `Persistent` keeps a
/// resident loop alive per `(device, family)` and enqueues batch
/// descriptors into a [`WorkQueue`] instead: a one-time residency launch,
/// then `queue_poll_cost` per batch. Resolution is table-driven —
/// [`crate::coordinator::KernelDescriptor`] may pin a family's mode, and
/// `Config::launch_mode` sets the policy (including the adaptive
/// break-even learner) for the rest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LaunchMode {
    /// One host kernel launch per combined batch (the seed path).
    PerBatch,
    /// Resident device loop fed by a mapped work queue.
    Persistent,
}

impl LaunchMode {
    /// The other mode (chaos mode-flip injections toggle with this).
    pub fn flipped(self) -> LaunchMode {
        match self {
            LaunchMode::PerBatch => LaunchMode::Persistent,
            LaunchMode::Persistent => LaunchMode::PerBatch,
        }
    }
}

/// Default descriptor-ring capacity per `(device, family)` queue.
pub const DEFAULT_QUEUE_DEPTH: usize = 64;

/// Monotonic counters of one queue's lifetime (backpressure visibility).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// Descriptors accepted into the ring.
    pub enqueued: u64,
    /// Descriptors drained by completions.
    pub completed: u64,
    /// Push attempts refused because the ring was full (the batch fell
    /// back to a per-batch launch).
    pub rejected: u64,
    /// Deepest occupancy ever observed.
    pub high_watermark: usize,
}

/// Ring state behind the mutex.
#[derive(Debug)]
struct Ring {
    /// Queued batch descriptors (launch ids), FIFO.
    slots: VecDeque<u64>,
    capacity: usize,
    stats: QueueStats,
    /// Closed queues accept no new descriptors; quiesce waiters wake.
    closed: bool,
}

/// A bounded MPSC descriptor ring for one `(device, kernel family)`
/// persistent loop. Producers [`push`](WorkQueue::push) batch ids as
/// flushes dispatch; completions [`complete`](WorkQueue::complete) them
/// out in FIFO order; the doorbell wakes anything blocked in
/// [`quiesce`](WorkQueue::quiesce).
#[derive(Debug)]
pub struct WorkQueue {
    ring: Mutex<Ring>,
    /// Doorbell: signalled on every push, complete, close, and resize.
    doorbell: Condvar,
}

impl WorkQueue {
    /// An open ring holding at most `capacity` descriptors (floor 1).
    pub fn new(capacity: usize) -> WorkQueue {
        WorkQueue {
            ring: Mutex::new(Ring {
                slots: VecDeque::new(),
                capacity: capacity.max(1),
                stats: QueueStats::default(),
                closed: false,
            }),
            doorbell: Condvar::new(),
        }
    }

    /// Enqueue one batch descriptor. `Ok(occupancy)` on success;
    /// `Err(())` when the ring is full or closed — the caller must fall
    /// back to a per-batch launch (counted in [`QueueStats::rejected`]).
    pub fn push(&self, id: u64) -> Result<usize, ()> {
        let mut r = self.ring.lock().expect("workqueue poisoned");
        if r.closed || r.slots.len() >= r.capacity {
            r.stats.rejected += 1;
            return Err(());
        }
        r.slots.push_back(id);
        r.stats.enqueued += 1;
        let depth = r.slots.len();
        if depth > r.stats.high_watermark {
            r.stats.high_watermark = depth;
        }
        self.doorbell.notify_all();
        Ok(depth)
    }

    /// Drain one completed descriptor. The resident loop consumes FIFO,
    /// but completions may be observed out of order on the host side, so
    /// any queued id is accepted; unknown ids are ignored (the batch was
    /// a backpressure fallback).
    pub fn complete(&self, id: u64) {
        let mut r = self.ring.lock().expect("workqueue poisoned");
        if let Some(pos) = r.slots.iter().position(|&x| x == id) {
            r.slots.remove(pos);
            r.stats.completed += 1;
            self.doorbell.notify_all();
        }
    }

    /// Queued descriptors right now.
    pub fn occupancy(&self) -> usize {
        self.ring.lock().expect("workqueue poisoned").slots.len()
    }

    /// Current ring capacity.
    pub fn capacity(&self) -> usize {
        self.ring.lock().expect("workqueue poisoned").capacity
    }

    /// Lifetime counters.
    pub fn stats(&self) -> QueueStats {
        self.ring.lock().expect("workqueue poisoned").stats
    }

    /// Resize the ring (chaos queue-depth jitter; floor 1). Shrinking
    /// below the current occupancy strands nothing: queued descriptors
    /// stay and drain normally, only new pushes see the smaller cap.
    pub fn set_capacity(&self, capacity: usize) {
        let mut r = self.ring.lock().expect("workqueue poisoned");
        r.capacity = capacity.max(1);
        self.doorbell.notify_all();
    }

    /// Close the queue: every further [`push`](WorkQueue::push) is
    /// refused (per-batch fallback) and quiesce waiters are woken.
    /// Queued descriptors still drain through
    /// [`complete`](WorkQueue::complete).
    pub fn close(&self) {
        let mut r = self.ring.lock().expect("workqueue poisoned");
        r.closed = true;
        self.doorbell.notify_all();
    }

    /// Whether [`close`](WorkQueue::close) has been called.
    pub fn is_closed(&self) -> bool {
        self.ring.lock().expect("workqueue poisoned").closed
    }

    /// Block on the doorbell until the ring is empty (clean teardown on
    /// job seal / shutdown) or `timeout` elapses; `true` iff empty. A
    /// closed queue can still quiesce — close stops *new* work, the
    /// in-flight tail drains through completions.
    pub fn quiesce(&self, timeout: Duration) -> bool {
        let r = self.ring.lock().expect("workqueue poisoned");
        let (r, res) = self
            .doorbell
            .wait_timeout_while(r, timeout, |r| !r.slots.is_empty())
            .expect("workqueue poisoned");
        !res.timed_out() && r.slots.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_complete_roundtrip_tracks_occupancy() {
        let q = WorkQueue::new(4);
        assert_eq!(q.push(1), Ok(1));
        assert_eq!(q.push(2), Ok(2));
        assert_eq!(q.occupancy(), 2);
        q.complete(1);
        assert_eq!(q.occupancy(), 1);
        q.complete(2);
        assert_eq!(q.occupancy(), 0);
        let s = q.stats();
        assert_eq!((s.enqueued, s.completed, s.rejected), (2, 2, 0));
        assert_eq!(s.high_watermark, 2);
    }

    #[test]
    fn full_ring_rejects_as_backpressure() {
        let q = WorkQueue::new(2);
        assert!(q.push(1).is_ok());
        assert!(q.push(2).is_ok());
        assert!(q.push(3).is_err(), "third push must backpressure");
        assert_eq!(q.stats().rejected, 1);
        q.complete(1);
        assert!(q.push(3).is_ok(), "drain frees a slot");
    }

    #[test]
    fn unknown_completion_is_ignored() {
        // a backpressure-fallback batch completes without ever having
        // been queued; its id must not perturb the ring
        let q = WorkQueue::new(2);
        q.push(7).unwrap();
        q.complete(99);
        assert_eq!(q.occupancy(), 1);
        assert_eq!(q.stats().completed, 0);
    }

    #[test]
    fn capacity_jitter_floors_at_one_and_strands_nothing() {
        let q = WorkQueue::new(8);
        for id in 0..5 {
            q.push(id).unwrap();
        }
        q.set_capacity(0); // chaos jitter: floored to 1
        assert_eq!(q.capacity(), 1);
        assert!(q.push(9).is_err(), "over the jittered cap");
        // the queued tail still drains
        for id in 0..5 {
            q.complete(id);
        }
        assert_eq!(q.occupancy(), 0);
        assert!(q.push(9).is_ok());
    }

    #[test]
    fn closed_queue_refuses_pushes_but_drains() {
        let q = WorkQueue::new(4);
        q.push(1).unwrap();
        q.close();
        assert!(q.is_closed());
        assert!(q.push(2).is_err(), "closed ring takes no new work");
        q.complete(1);
        assert_eq!(q.occupancy(), 0);
    }

    #[test]
    fn quiesce_wakes_on_doorbell_drain() {
        let q = Arc::new(WorkQueue::new(4));
        for id in 0..3 {
            q.push(id).unwrap();
        }
        let qc = q.clone();
        let h = std::thread::spawn(move || {
            qc.quiesce(Duration::from_secs(30))
        });
        // drain from this thread; the waiter must wake via the doorbell
        for id in 0..3 {
            std::thread::sleep(Duration::from_millis(2));
            q.complete(id);
        }
        assert!(h.join().unwrap(), "quiesce saw the empty ring");
    }

    #[test]
    fn quiesce_times_out_on_a_stuck_ring() {
        let q = WorkQueue::new(4);
        q.push(1).unwrap();
        assert!(
            !q.quiesce(Duration::from_millis(20)),
            "a non-empty ring must report a failed quiesce, not hang"
        );
    }

    #[test]
    fn flipped_toggles_modes() {
        assert_eq!(LaunchMode::PerBatch.flipped(), LaunchMode::Persistent);
        assert_eq!(LaunchMode::Persistent.flipped(), LaunchMode::PerBatch);
    }
}
