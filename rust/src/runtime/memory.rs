//! Simulated GPU device-memory slot allocator.
//!
//! G-Charm tracks which chare buffers are resident in GPU memory so kernel
//! launches can skip redundant PCIe transfers (paper section 3.2). Device
//! memory is modeled as a pool of fixed-size *slots* (one chare buffer --
//! e.g. one bucket of particles -- per slot). The allocator hands out slot
//! indices, reclaims via LRU when full, and reports hit/miss statistics.
//!
//! The *positions* handed out here are what makes reuse uncoalesced: a
//! combined kernel's buffers end up scattered across slot indices, and the
//! coalescing module (coordinator/coalescing.rs) measures how sorted-index
//! access restores locality.
//!
//! Eviction is policy-driven ([`ResidencyPolicy`]): the seed behavior is
//! plain LRU; the reuse-graph policy (ISSUE 7) lets the coordinator pass a
//! *predicted next use* with each acquire and evicts the buffer whose next
//! use is farthest away (Belady-style, LRU as the tiebreak), and adds
//! free-slot-only [`DeviceMemory::prefetch`] so hot buffers can be staged
//! ahead of the flush that needs them.

use std::collections::HashMap;

/// Identifies one chare data buffer in the application domain.
pub type BufferId = u64;

/// How a [`DeviceMemory`] picks its eviction victim (`Config::residency`).
///
/// * `Lru` — the seed behavior: evict the least-recently-used unpinned
///   slot. Ignores reuse predictions entirely; selecting it reproduces
///   pre-ISSUE-7 behavior bitwise (pinned in
///   `tests/pipeline_equivalence.rs`).
/// * `ReuseGraph` — lookahead eviction: the coordinator's reuse scorer
///   (`coordinator::residency`) predicts each buffer's next reference
///   from the pending request stream, and the victim is the unpinned
///   slot with the *farthest* predicted next use (ties broken LRU).
///   Buffers with no forward prediction — streaming scans that never
///   re-reference — predict `u64::MAX` and are evicted first, which is
///   what keeps one tenant's scan from flushing a co-tenant's hot
///   working set. Also enables ahead-of-flush prefetch staging.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ResidencyPolicy {
    Lru,
    #[default]
    ReuseGraph,
}

/// Result of requesting residency for a buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// Buffer already resident in this slot: no transfer needed.
    Hit(usize),
    /// Buffer placed into this slot: transfer required.
    Miss(usize),
}

impl Residency {
    pub fn slot(&self) -> usize {
        match *self {
            Residency::Hit(s) | Residency::Miss(s) => s,
        }
    }

    pub fn is_hit(&self) -> bool {
        matches!(self, Residency::Hit(_))
    }
}

/// Policy-driven slot allocator over a fixed-capacity device pool.
#[derive(Debug)]
pub struct DeviceMemory {
    capacity: usize,
    policy: ResidencyPolicy,
    /// slot -> resident buffer (None = free).
    slots: Vec<Option<BufferId>>,
    /// buffer -> slot for residents.
    resident: HashMap<BufferId, usize>,
    /// slot -> last-touch tick, for LRU eviction.
    last_touch: Vec<u64>,
    /// slot -> predicted next-use sequence (ReuseGraph only; `u64::MAX`
    /// means "no forward reference known", which sorts first for
    /// eviction).
    predicted: Vec<u64>,
    /// slot -> staged by `prefetch` and not yet demanded. Cleared (and
    /// counted as a prefetch hit) by the first demand acquire; counted
    /// as wasted if the slot is evicted or invalidated first.
    prefetched: Vec<bool>,
    free: Vec<usize>,
    /// Pin counts per slot; pinned slots are never evicted (they back
    /// pending combined launches).
    pins: Vec<u32>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    prefetch_hits: u64,
    prefetch_wasted: u64,
}

impl DeviceMemory {
    /// `capacity`: number of buffer slots the device pool holds. Plain
    /// LRU eviction; use [`DeviceMemory::with_policy`] for lookahead.
    pub fn new(capacity: usize) -> DeviceMemory {
        DeviceMemory::with_policy(capacity, ResidencyPolicy::Lru)
    }

    /// A pool with an explicit eviction policy (`Config::residency`).
    pub fn with_policy(
        capacity: usize,
        policy: ResidencyPolicy,
    ) -> DeviceMemory {
        assert!(capacity > 0, "DeviceMemory capacity must be > 0");
        DeviceMemory {
            capacity,
            policy,
            slots: vec![None; capacity],
            resident: HashMap::new(),
            last_touch: vec![0; capacity],
            predicted: vec![u64::MAX; capacity],
            prefetched: vec![false; capacity],
            free: (0..capacity).rev().collect(),
            pins: vec![0; capacity],
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            prefetch_hits: 0,
            prefetch_wasted: 0,
        }
    }

    pub fn policy(&self) -> ResidencyPolicy {
        self.policy
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    /// Is this buffer currently resident (without touching LRU state)?
    pub fn peek(&self, id: BufferId) -> Option<usize> {
        self.resident.get(&id).copied()
    }

    /// Ids of every resident buffer, for the chaos harness's residency
    /// audit (no sealed job's keys may stay resident). Test/chaos only.
    #[cfg(any(test, feature = "chaos"))]
    pub fn resident_keys(&self) -> Vec<BufferId> {
        self.resident.keys().copied().collect()
    }

    /// Ensure `id` is resident; returns Hit(slot) or Miss(slot). On miss
    /// a victim is evicted per the policy if the pool is full; `None` if
    /// every slot is pinned (caller must flush pending launches first).
    pub fn acquire(&mut self, id: BufferId) -> Option<Residency> {
        self.acquire_predicted(id, u64::MAX).map(|(r, _)| r)
    }

    /// [`DeviceMemory::acquire`] with a reuse prediction attached:
    /// `predicted_next` is the scorer's forecast of this buffer's *next*
    /// reference (a stream sequence number; `u64::MAX` = no forward
    /// reference known). Under `ReuseGraph` it sets the slot's eviction
    /// priority; under `Lru` it is ignored. Also surfaces the evicted
    /// buffer id on a capacity miss so the caller can retain a host-side
    /// victim copy for later prefetch.
    pub fn acquire_predicted(
        &mut self,
        id: BufferId,
        predicted_next: u64,
    ) -> Option<(Residency, Option<BufferId>)> {
        self.tick += 1;
        if let Some(&slot) = self.resident.get(&id) {
            self.last_touch[slot] = self.tick;
            self.predicted[slot] = predicted_next;
            self.hits += 1;
            if self.prefetched[slot] {
                self.prefetched[slot] = false;
                self.prefetch_hits += 1;
            }
            return Some((Residency::Hit(slot), None));
        }
        let (slot, evicted) = match self.free.pop() {
            Some(s) => (s, None),
            None => {
                let victim = match self.policy {
                    ResidencyPolicy::Lru => self.lru_slot()?,
                    ResidencyPolicy::ReuseGraph => self.farthest_slot()?,
                };
                debug_assert_eq!(
                    self.pins[victim], 0,
                    "evicting pinned slot {victim}"
                );
                let old = self.slots[victim].take().expect("occupied");
                self.resident.remove(&old);
                if self.prefetched[victim] {
                    self.prefetched[victim] = false;
                    self.prefetch_wasted += 1;
                }
                self.evictions += 1;
                (victim, Some(old))
            }
        };
        self.misses += 1;
        self.slots[slot] = Some(id);
        self.resident.insert(id, slot);
        self.last_touch[slot] = self.tick;
        self.predicted[slot] = predicted_next;
        Some((Residency::Miss(slot), evicted))
    }

    /// Stage `id` into a *free* slot ahead of demand (ReuseGraph
    /// prefetch). Never evicts and never touches the hit/miss counters:
    /// returns the slot only when one is free and `id` is not already
    /// resident, else `None`. The later demand `acquire` of a prefetched
    /// buffer counts both a table hit and a prefetch hit; eviction or
    /// invalidation before that demand counts the prefetch as wasted.
    pub fn prefetch(
        &mut self,
        id: BufferId,
        predicted_next: u64,
    ) -> Option<usize> {
        if self.resident.contains_key(&id) {
            return None;
        }
        let slot = self.free.pop()?;
        self.tick += 1;
        self.slots[slot] = Some(id);
        self.resident.insert(id, slot);
        self.last_touch[slot] = self.tick;
        self.predicted[slot] = predicted_next;
        self.prefetched[slot] = true;
        Some(slot)
    }

    /// Pin a resident buffer's slot (no-op if absent). Pins nest.
    pub fn pin(&mut self, id: BufferId) {
        if let Some(&slot) = self.resident.get(&id) {
            self.pins[slot] += 1;
        }
    }

    /// Release one pin on a buffer's slot.
    ///
    /// Unpinning a slot that holds no pins is a caller bug (a double
    /// release would let a later pin be cancelled by the earlier
    /// launch's cleanup, un-protecting a slot a pending launch still
    /// reads). Debug builds assert, mirroring the `invalidate`
    /// contract; release builds saturate so the pool cannot underflow.
    pub fn unpin(&mut self, id: BufferId) {
        if let Some(&slot) = self.resident.get(&id) {
            debug_assert!(
                self.pins[slot] > 0,
                "unpinning slot {slot} (buffer {id}) with zero pins: \
                 double-unpin masks pin-accounting bugs"
            );
            self.pins[slot] = self.pins[slot].saturating_sub(1);
        }
    }

    /// Number of currently pinned slots.
    pub fn pinned_count(&self) -> usize {
        self.pins.iter().filter(|&&p| p > 0).count()
    }

    /// Drop a buffer from the pool (e.g. chare data invalidated by an
    /// iteration update).
    ///
    /// A pinned slot backs a pending combined launch: invalidating it is a
    /// caller bug (the launch would read a slot the allocator may hand
    /// out again). Debug builds assert; release builds drop the pin so
    /// the pool does not leak slots permanently.
    pub fn invalidate(&mut self, id: BufferId) {
        if let Some(slot) = self.resident.remove(&id) {
            debug_assert_eq!(
                self.pins[slot], 0,
                "invalidating pinned slot {slot} (buffer {id}): \
                 it backs a pending launch"
            );
            self.slots[slot] = None;
            self.pins[slot] = 0;
            self.predicted[slot] = u64::MAX;
            if self.prefetched[slot] {
                self.prefetched[slot] = false;
                self.prefetch_wasted += 1;
            }
            self.free.push(slot);
        }
    }

    /// Drop every resident buffer whose id satisfies `pred` — e.g. all
    /// buffers of one job advancing its iteration on a multi-tenant pool,
    /// leaving co-tenant residency intact. The per-buffer pinned-slot
    /// contract of `invalidate` applies: call at the *job's* quiescence.
    pub fn invalidate_where(&mut self, pred: impl Fn(BufferId) -> bool) {
        let ids: Vec<BufferId> =
            self.resident.keys().copied().filter(|&id| pred(id)).collect();
        for id in ids {
            self.invalidate(id);
        }
    }

    /// Drop everything (new iteration with fully rewritten data). Must be
    /// called at quiescence: see `invalidate` for the pinned-slot contract.
    pub fn invalidate_all(&mut self) {
        debug_assert_eq!(
            self.pinned_count(),
            0,
            "invalidate_all with {} pinned slot(s): they back pending \
             launches",
            self.pinned_count()
        );
        self.resident.clear();
        self.slots.iter_mut().for_each(|s| *s = None);
        self.pins.iter_mut().for_each(|p| *p = 0);
        self.predicted.iter_mut().for_each(|p| *p = u64::MAX);
        self.prefetch_wasted +=
            self.prefetched.iter().filter(|&&p| p).count() as u64;
        self.prefetched.iter_mut().for_each(|p| *p = false);
        self.free = (0..self.capacity).rev().collect();
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Prefetched slots later claimed by a demand acquire.
    pub fn prefetch_hits(&self) -> u64 {
        self.prefetch_hits
    }

    /// Prefetched slots evicted or invalidated before any demand.
    pub fn prefetch_wasted(&self) -> u64 {
        self.prefetch_wasted
    }

    fn lru_slot(&self) -> Option<usize> {
        (0..self.capacity)
            .filter(|&s| self.slots[s].is_some() && self.pins[s] == 0)
            .min_by_key(|&s| self.last_touch[s])
    }

    /// ReuseGraph victim: the unpinned occupied slot whose predicted
    /// next use is farthest away (`u64::MAX` — no known forward
    /// reference — sorts farthest of all), ties broken LRU.
    fn farthest_slot(&self) -> Option<usize> {
        (0..self.capacity)
            .filter(|&s| self.slots[s].is_some() && self.pins[s] == 0)
            .max_by_key(|&s| {
                (self.predicted[s], std::cmp::Reverse(self.last_touch[s]))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invalidate_where_scopes_to_predicate() {
        let mut m = DeviceMemory::new(8);
        // two "jobs" in the upper bits of the key
        let key = |job: u64, id: u64| (job << 48) | id;
        for id in 0..3 {
            m.acquire(key(1, id)).unwrap();
            m.acquire(key(2, id)).unwrap();
        }
        m.invalidate_where(|k| k >> 48 == 1);
        for id in 0..3 {
            assert!(m.peek(key(1, id)).is_none(), "job 1 dropped");
            assert!(m.peek(key(2, id)).is_some(), "job 2 untouched");
        }
    }

    #[test]
    fn first_acquire_is_miss_second_is_hit() {
        let mut m = DeviceMemory::new(4);
        let r1 = m.acquire(7).unwrap();
        assert!(!r1.is_hit());
        let r2 = m.acquire(7).unwrap();
        assert!(r2.is_hit());
        assert_eq!(r1.slot(), r2.slot());
        assert_eq!(m.hits(), 1);
        assert_eq!(m.misses(), 1);
    }

    #[test]
    fn distinct_buffers_get_distinct_slots() {
        let mut m = DeviceMemory::new(4);
        let s: Vec<usize> =
            (0..4).map(|i| m.acquire(i).unwrap().slot()).collect();
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
    }

    #[test]
    fn lru_eviction_picks_least_recently_used() {
        let mut m = DeviceMemory::new(2);
        let s0 = m.acquire(0).unwrap().slot();
        let _s1 = m.acquire(1).unwrap().slot();
        m.acquire(1); // touch 1; 0 is now LRU
        let s2 = m.acquire(2).unwrap(); // evicts 0
        assert_eq!(s2.slot(), s0);
        assert!(m.peek(0).is_none());
        assert!(m.peek(1).is_some());
        assert_eq!(m.evictions(), 1);
    }

    #[test]
    fn invalidate_frees_slot() {
        let mut m = DeviceMemory::new(2);
        m.acquire(0);
        m.acquire(1);
        m.invalidate(0);
        assert_eq!(m.resident_count(), 1);
        let r = m.acquire(2).unwrap(); // must not evict 1
        assert!(!r.is_hit());
        assert!(m.peek(1).is_some());
        assert_eq!(m.evictions(), 0);
    }

    #[test]
    fn invalidate_all_resets() {
        let mut m = DeviceMemory::new(3);
        for i in 0..3 {
            m.acquire(i);
        }
        m.invalidate_all();
        assert_eq!(m.resident_count(), 0);
        for i in 10..13 {
            assert!(!m.acquire(i).unwrap().is_hit());
        }
    }

    #[test]
    fn capacity_respected_under_thrash() {
        let mut m = DeviceMemory::new(8);
        for i in 0..1_000u64 {
            m.acquire(i % 17).unwrap();
            assert!(m.resident_count() <= 8);
        }
    }

    #[test]
    fn pinned_slots_survive_eviction_pressure() {
        let mut m = DeviceMemory::new(2);
        m.acquire(0).unwrap();
        m.pin(0);
        m.acquire(1).unwrap();
        // 0 is LRU but pinned: 1 must be evicted instead
        let r = m.acquire(2).unwrap();
        assert!(m.peek(0).is_some());
        assert!(m.peek(1).is_none());
        assert!(!r.is_hit());
    }

    #[test]
    fn all_pinned_returns_none() {
        let mut m = DeviceMemory::new(2);
        m.acquire(0).unwrap();
        m.acquire(1).unwrap();
        m.pin(0);
        m.pin(1);
        assert!(m.acquire(2).is_none());
        m.unpin(0);
        assert!(m.acquire(2).is_some());
        assert!(m.peek(0).is_none()); // 0 was the only evictable slot
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "backs a pending launch")]
    fn invalidate_pinned_slot_asserts() {
        let mut m = DeviceMemory::new(2);
        m.acquire(0).unwrap();
        m.pin(0);
        m.invalidate(0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "pending")]
    fn invalidate_all_with_pins_asserts() {
        let mut m = DeviceMemory::new(2);
        m.acquire(0).unwrap();
        m.pin(0);
        m.invalidate_all();
    }

    #[test]
    fn invalidate_unpinned_after_release_is_fine() {
        let mut m = DeviceMemory::new(2);
        m.acquire(0).unwrap();
        m.pin(0);
        m.unpin(0);
        m.invalidate(0);
        assert!(m.peek(0).is_none());
        assert_eq!(m.pinned_count(), 0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "double-unpin")]
    fn double_unpin_asserts() {
        let mut m = DeviceMemory::new(2);
        m.acquire(0).unwrap();
        m.pin(0);
        m.unpin(0);
        m.unpin(0);
    }

    #[test]
    fn reuse_graph_evicts_farthest_predicted_use() {
        let mut m = DeviceMemory::with_policy(3, ResidencyPolicy::ReuseGraph);
        m.acquire_predicted(0, 10).unwrap();
        m.acquire_predicted(1, 500).unwrap(); // farthest known next use
        m.acquire_predicted(2, 20).unwrap();
        let (r, evicted) = m.acquire_predicted(3, 15).unwrap();
        assert!(!r.is_hit());
        assert_eq!(evicted, Some(1), "victim is the farthest next use");
        assert!(m.peek(0).is_some() && m.peek(2).is_some());
    }

    #[test]
    fn unscored_buffers_evict_before_scored_ones() {
        // A streaming scan (no forward reference -> u64::MAX) must lose
        // to any buffer with a known next use, however distant.
        let mut m = DeviceMemory::with_policy(2, ResidencyPolicy::ReuseGraph);
        m.acquire_predicted(7, 1_000_000).unwrap(); // hot co-tenant
        m.acquire_predicted(8, u64::MAX).unwrap(); // scan
        m.acquire_predicted(9, u64::MAX).unwrap(); // scan evicts scan
        assert!(m.peek(7).is_some(), "scored buffer survived the scan");
        assert!(m.peek(8).is_none());
    }

    #[test]
    fn reuse_graph_ties_break_lru() {
        let mut m = DeviceMemory::with_policy(2, ResidencyPolicy::ReuseGraph);
        m.acquire_predicted(0, 50).unwrap();
        m.acquire_predicted(1, 50).unwrap();
        m.acquire_predicted(1, 50).unwrap(); // touch 1; 0 is LRU
        let (_, evicted) = m.acquire_predicted(2, 50).unwrap();
        assert_eq!(evicted, Some(0));
    }

    #[test]
    fn prefetch_uses_free_slots_only() {
        let mut m = DeviceMemory::with_policy(2, ResidencyPolicy::ReuseGraph);
        assert!(m.prefetch(0, 5).is_some());
        assert!(m.prefetch(0, 5).is_none(), "already resident");
        assert!(m.prefetch(1, 6).is_some());
        // pool full: prefetch must refuse rather than evict
        assert!(m.prefetch(2, 1).is_none());
        assert!(m.peek(0).is_some() && m.peek(1).is_some());
        assert_eq!(m.misses(), 0, "prefetch is not a demand miss");
    }

    #[test]
    fn demanded_prefetch_counts_hit_and_prefetch_hit() {
        let mut m = DeviceMemory::with_policy(2, ResidencyPolicy::ReuseGraph);
        m.prefetch(0, 5).unwrap();
        let (r, _) = m.acquire_predicted(0, 9).unwrap();
        assert!(r.is_hit());
        assert_eq!(m.hits(), 1);
        assert_eq!(m.prefetch_hits(), 1);
        // second demand is a plain hit, not another prefetch hit
        m.acquire_predicted(0, 9).unwrap();
        assert_eq!(m.prefetch_hits(), 1);
        assert_eq!(m.prefetch_wasted(), 0);
    }

    #[test]
    fn undemanded_prefetch_counts_wasted_on_eviction_and_invalidate() {
        let mut m = DeviceMemory::with_policy(2, ResidencyPolicy::ReuseGraph);
        m.prefetch(0, u64::MAX).unwrap();
        m.prefetch(1, u64::MAX).unwrap();
        m.invalidate(0);
        assert_eq!(m.prefetch_wasted(), 1);
        m.acquire_predicted(2, 1).unwrap(); // fills the freed slot
        m.acquire_predicted(3, 2).unwrap(); // evicts the unscored prefetch
        assert_eq!(m.prefetch_wasted(), 2);
        assert_eq!(m.prefetch_hits(), 0);
    }

    #[test]
    fn lru_policy_ignores_predictions() {
        // Same stream as lru_eviction_picks_least_recently_used but with
        // adversarial predictions attached: Lru must not care.
        let mut m = DeviceMemory::with_policy(2, ResidencyPolicy::Lru);
        let s0 = m.acquire_predicted(0, u64::MAX).unwrap().0.slot();
        m.acquire_predicted(1, 1).unwrap();
        m.acquire_predicted(1, 1).unwrap(); // touch 1; 0 is LRU
        let (r, evicted) = m.acquire_predicted(2, 3).unwrap();
        assert_eq!(r.slot(), s0);
        assert_eq!(evicted, Some(0));
    }

    #[test]
    fn pins_nest() {
        let mut m = DeviceMemory::new(1);
        m.acquire(0).unwrap();
        m.pin(0);
        m.pin(0);
        m.unpin(0);
        assert!(m.acquire(1).is_none()); // still pinned once
        m.unpin(0);
        assert!(m.acquire(1).is_some());
        assert_eq!(m.pinned_count(), 0);
    }
}
