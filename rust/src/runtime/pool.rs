//! Sharded GPU pool: N `GpuService` instances behind one submit API.
//!
//! The runtime used to assume exactly one GPU — one service, one device
//! memory, one staging arena. `DevicePool` owns N services (each keeping
//! its own stager+engine thread pair and staging arena), exposes a single
//! `submit(device, spec)` entry point, and funnels every device's
//! completions onto one channel with `Completion::device` tagging the
//! origin. Per-device *memory* (chare tables, node residency) lives with
//! the coordinator, which decides routing; the pool is purely the
//! execution fabric.
//!
//! Every service serves the same registered kernel families, so any
//! device can execute any registered kind (the steal rebalancer relies on
//! this). `devices = 1` is exactly the old single-service path: one
//! service, the same threads, the same completion stream.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

use anyhow::Result;

use super::executor::{Completion, GpuService, LaunchSpec};
use super::kernel::TileKernel;

/// A pool of N simulated GPU devices, each a full `GpuService`.
pub struct DevicePool {
    services: Vec<GpuService>,
    /// Launches submitted to each device whose completions have not been
    /// acknowledged yet (the [`InFlightGuard`] returned by `submit` is
    /// still alive). The reuse-graph prefetch path gates on this:
    /// ahead-of-flush staging only runs *while a combined batch is
    /// executing* on the device, so the prefetch overlaps compute instead
    /// of delaying the next launch.
    in_flight: Vec<Arc<AtomicUsize>>,
}

/// RAII acknowledgement of one submitted launch: the device's in-flight
/// gauge is decremented when the guard drops, so error, cancel, and
/// early-return paths can never leak a count and permanently wedge the
/// `in_flight == 0` prefetch gate (ISSUE 8 satellite; previously a manual
/// `note_completion` call the completion path had to remember).
#[derive(Debug)]
pub struct InFlightGuard(Arc<AtomicUsize>);

impl Drop for InFlightGuard {
    fn drop(&mut self) {
        let prev = self.0.fetch_sub(1, Ordering::SeqCst);
        debug_assert!(prev > 0, "completion without a submission");
    }
}

impl DevicePool {
    /// Spawn `devices` (clamped to >= 1) services over the same artifact
    /// set, each serving the registered `kernels`. Completions from every
    /// device arrive on `done`, tagged with their device index; per-device
    /// ordering follows submission order, cross-device ordering is
    /// whatever the engines produce.
    pub fn spawn(
        artifacts: &Path,
        kernels: Vec<Arc<TileKernel>>,
        devices: usize,
        done: Sender<Result<Completion>>,
    ) -> Result<DevicePool> {
        let devices = devices.max(1);
        let services = (0..devices)
            .map(|d| {
                GpuService::spawn_on(artifacts, kernels.clone(), d, done.clone())
            })
            .collect::<Result<Vec<_>>>()?;
        let in_flight =
            (0..devices).map(|_| Arc::new(AtomicUsize::new(0))).collect();
        Ok(DevicePool { services, in_flight })
    }

    pub fn devices(&self) -> usize {
        self.services.len()
    }

    /// Teach every device new kernel families (append-only registry
    /// growth: a job submitted to a live runtime may bring families the
    /// pool was not spawned with). Ordered ahead of any launch of those
    /// families on each service's queue.
    pub fn add_kernels(&self, kernels: &[Arc<TileKernel>]) -> Result<()> {
        for svc in &self.services {
            svc.add_kernels(kernels.to_vec())?;
        }
        Ok(())
    }

    /// Submit a launch to one device; its completion arrives on the pool's
    /// `done` channel tagged with `device`. The returned guard keeps the
    /// device's in-flight gauge raised until dropped — hold it with the
    /// launch's bookkeeping and the gauge self-corrects on every exit
    /// path.
    pub fn submit(
        &self,
        device: usize,
        spec: LaunchSpec,
    ) -> Result<InFlightGuard> {
        let svc = self.services.get(device).ok_or_else(|| {
            anyhow::anyhow!(
                "device {device} out of range (pool has {})",
                self.services.len()
            )
        })?;
        svc.submit(spec)?;
        let gauge = self.in_flight[device].clone();
        gauge.fetch_add(1, Ordering::SeqCst);
        Ok(InFlightGuard(gauge))
    }

    /// Launches submitted to `device` and not yet acknowledged complete.
    pub fn in_flight(&self, device: usize) -> usize {
        self.in_flight
            .get(device)
            .map(|g| g.load(Ordering::SeqCst))
            .unwrap_or(0)
    }

    /// Launches in flight across the whole pool. The cross-node drain
    /// handler uses this as a busy gate: a node only gives work away
    /// while its own devices are actually executing (an empty pipeline
    /// means the backlog is about to dispatch locally).
    pub fn in_flight_total(&self) -> usize {
        self.in_flight.iter().map(|g| g.load(Ordering::SeqCst)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::device_sim::CoalescingClass;
    use crate::runtime::executor::Payload;
    use crate::runtime::workqueue::LaunchMode;
    use crate::runtime::shapes::{
        INTERACTIONS, INTER_W, PARTICLE_W, PARTS_PER_BUCKET,
    };
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn gravity() -> Vec<Arc<TileKernel>> {
        vec![Arc::new(TileKernel::gravity(0.01))]
    }

    fn gravity_spec(id: u64, batch: usize, fill: f32) -> LaunchSpec {
        LaunchSpec {
            id,
            payload: Payload::Tile {
                kernel: Arc::new(TileKernel::gravity(0.01)),
                bufs: vec![
                    vec![fill; batch * PARTS_PER_BUCKET * PARTICLE_W],
                    vec![fill; batch * INTERACTIONS * INTER_W],
                ],
                batch,
            },
            transfer_bytes: 0,
            pattern: CoalescingClass::Contiguous,
            mode: LaunchMode::PerBatch,
        }
    }

    #[test]
    fn completions_carry_device_tags() {
        let (tx, rx) = channel();
        let pool = DevicePool::spawn(
            Path::new("/tmp/gcharm-missing-artifacts"),
            gravity(),
            3,
            tx,
        )
        .unwrap();
        assert_eq!(pool.devices(), 3);
        for d in 0..3 {
            pool.submit(d, gravity_spec(d as u64, 2, 0.5)).unwrap();
        }
        let mut seen = [false; 3];
        for _ in 0..3 {
            let c = rx
                .recv_timeout(Duration::from_secs(60))
                .expect("completion")
                .expect("launch ok");
            assert_eq!(c.id as usize, c.device, "routed to the device asked");
            seen[c.device] = true;
        }
        assert!(seen.iter().all(|&s| s), "every device executed");
    }

    #[test]
    fn devices_produce_identical_outputs_for_identical_launches() {
        let (tx, rx) = channel();
        let pool = DevicePool::spawn(
            Path::new("/tmp/gcharm-missing-artifacts"),
            gravity(),
            2,
            tx,
        )
        .unwrap();
        pool.submit(0, gravity_spec(0, 3, 0.25)).unwrap();
        pool.submit(1, gravity_spec(1, 3, 0.25)).unwrap();
        let mut outs: Vec<(usize, Vec<u32>)> = (0..2)
            .map(|_| {
                let c = rx
                    .recv_timeout(Duration::from_secs(60))
                    .unwrap()
                    .unwrap();
                (c.device, c.out.iter().map(|x| x.to_bits()).collect())
            })
            .collect();
        outs.sort_by_key(|(d, _)| *d);
        assert_eq!(outs[0].1, outs[1].1, "devices run the same engine code");
    }

    #[test]
    fn in_flight_tracks_submissions_and_acks() {
        let (tx, rx) = channel();
        let pool = DevicePool::spawn(
            Path::new("/tmp/gcharm-missing-artifacts"),
            gravity(),
            2,
            tx,
        )
        .unwrap();
        assert_eq!(pool.in_flight(0), 0);
        let g0 = pool.submit(0, gravity_spec(0, 1, 0.5)).unwrap();
        let g1 = pool.submit(0, gravity_spec(1, 1, 0.5)).unwrap();
        assert_eq!(pool.in_flight(0), 2);
        assert_eq!(pool.in_flight(1), 0);
        for _ in 0..2 {
            rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        }
        // the gauge drops with the guards, not with any manual ack call —
        // an error path that just unwinds cannot leak a count
        drop(g0);
        assert_eq!(pool.in_flight(0), 1);
        drop(g1);
        assert_eq!(pool.in_flight(0), 0);
        assert_eq!(pool.in_flight(9), 0, "out of range reads as idle");
    }

    #[test]
    fn out_of_range_device_is_rejected() {
        let (tx, _rx) = channel();
        let pool = DevicePool::spawn(
            Path::new("/tmp/gcharm-missing-artifacts"),
            gravity(),
            2,
            tx,
        )
        .unwrap();
        assert!(pool.submit(2, gravity_spec(0, 1, 0.0)).is_err());
    }

    #[test]
    fn kernels_added_after_spawn_are_servable() {
        // a persistent runtime spawns its pool before any job arrives;
        // families registered later must execute on every device
        let (tx, rx) = channel();
        let pool = DevicePool::spawn(
            Path::new("/tmp/gcharm-missing-artifacts"),
            Vec::new(),
            2,
            tx,
        )
        .unwrap();
        pool.add_kernels(&gravity()).unwrap();
        for d in 0..2 {
            pool.submit(d, gravity_spec(d as u64, 2, 0.5)).unwrap();
        }
        for _ in 0..2 {
            let c = rx
                .recv_timeout(Duration::from_secs(60))
                .expect("completion")
                .expect("late-registered family executes");
            assert_eq!(c.batch, 2);
        }
    }

    #[test]
    fn zero_devices_clamps_to_one() {
        let (tx, _rx) = channel();
        let pool = DevicePool::spawn(
            Path::new("/tmp/gcharm-missing-artifacts"),
            gravity(),
            0,
            tx,
        )
        .unwrap();
        assert_eq!(pool.devices(), 1);
    }
}
