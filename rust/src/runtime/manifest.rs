//! Loader for `artifacts/manifest.json` and AOT variant selection.
//!
//! The Python AOT pipeline (python/compile/aot.py) lowers every Layer-2
//! entry point at a ladder of static batch sizes. At runtime the executor
//! must pick, for a combined work request of `n` items, the smallest
//! compiled variant with batch >= n, then zero-pad to its shape. This
//! module parses the manifest and answers those queries.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::kernel::TileKernel;

/// Gather-variant pool-row ladder shared by the built-in synthetic set and
/// `ensure_family` for registered reuse kernels.
const SYNTH_POOLS: [usize; 7] =
    [1024, 2048, 4096, 8192, 16_384, 32_768, 65_536];

/// Gather-variant batch ladder (mirrors `python/compile/aot.py`).
const SYNTH_GATHER_BATCHES: [usize; 3] = [16, 64, 128];

/// Element type of one AOT argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One argument slot of a compiled variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled artifact (an HLO text file plus its calling convention).
#[derive(Debug, Clone)]
pub struct Variant {
    pub name: String,
    pub path: PathBuf,
    pub args: Vec<ArgSpec>,
    /// Which Layer-1 kernel this lowers ("gravity", "gravity_gather",
    /// "ewald", "md_force").
    pub kernel: String,
    /// Number of combined work-request slots (buckets / patch pairs).
    pub batch: usize,
    /// Pool rows for gather variants (0 otherwise).
    pub pool: usize,
}

/// Parsed manifest with variant lookup.
#[derive(Debug, Clone)]
pub struct Manifest {
    variants: Vec<Variant>,
    /// kernel name -> indices into `variants`, sorted by (batch, pool).
    by_kernel: BTreeMap<String, Vec<usize>>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Load real artifacts if `<dir>/manifest.json` exists, otherwise fall
    /// back to the built-in synthetic ladder (served by the sim backend).
    /// The bool reports whether real artifacts back the manifest.
    pub fn load_or_synthetic(dir: &Path) -> Result<(Manifest, bool)> {
        if dir.join("manifest.json").exists() {
            Ok((Self::load(dir)?, true))
        } else {
            Ok((Self::synthetic(dir), false))
        }
    }

    /// One-stop manifest preparation for a set of registered kernel
    /// families: load (or synthesize), extend with synthetic ladders for
    /// families the artifact set does not serve, and validate every
    /// family's tile shapes against the selected variants. The bool
    /// reports whether real artifacts back the manifest.
    pub fn for_kernels(
        dir: &Path,
        kernels: &[Arc<TileKernel>],
    ) -> Result<(Manifest, bool)> {
        let (mut manifest, real) = Self::load_or_synthetic(dir)?;
        for k in kernels {
            manifest.ensure_family(k);
        }
        manifest.validate_kernels(kernels)?;
        Ok((manifest, real))
    }

    /// Validate registered families against this manifest's variants
    /// (fail fast if AOT artifacts drifted from the registered shapes).
    pub fn validate_kernels(&self, kernels: &[Arc<TileKernel>]) -> Result<()> {
        for k in kernels {
            let v = self.select(&k.name, 1, 0).with_context(|| {
                format!("no variants for kernel {}", k.name)
            })?;
            let want = k.args.len() + usize::from(!k.constant.is_empty());
            anyhow::ensure!(
                v.args.len() == want,
                "{}: variant {} has {} args, family registered {want}",
                k.name,
                v.name,
                v.args.len()
            );
            for (i, a) in k.args.iter().enumerate() {
                anyhow::ensure!(
                    v.args[i].elements() == v.batch * a.slot_len(),
                    "{} arg {} ({}): variant shape {:?} disagrees with the \
                     registered {}x{} tile",
                    k.name,
                    i,
                    a.name,
                    v.args[i].shape,
                    a.rows,
                    a.width
                );
            }
            if !k.constant.is_empty() {
                anyhow::ensure!(
                    v.args[want - 1].elements() == k.constant.len(),
                    "{}: variant constant arg holds {} elements, registered \
                     constant has {}",
                    k.name,
                    v.args[want - 1].elements(),
                    k.constant.len()
                );
            }
        }
        Ok(())
    }

    /// Built-in variant ladder mirroring what `python/compile/aot.py`
    /// emits, for environments without the AOT artifacts. The referenced
    /// HLO files do not exist; only the sim backend may execute these.
    pub fn synthetic(dir: &Path) -> Manifest {
        use crate::runtime::shapes::{
            INTERACTIONS, INTER_W, KTABLE, KTAB_W, MD_W, PARTICLE_W,
            PARTS_PER_BUCKET, PARTS_PER_PATCH,
        };
        const BATCHES: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];
        const GATHER_BATCHES: [usize; 3] = SYNTH_GATHER_BATCHES;
        const POOLS: [usize; 7] = SYNTH_POOLS;

        let f32s = |shape: Vec<usize>| ArgSpec { shape, dtype: DType::F32 };
        let i32s = |shape: Vec<usize>| ArgSpec { shape, dtype: DType::I32 };
        let mut variants = Vec::new();
        let mut push = |name: String, kernel: &str, batch, pool, args| {
            variants.push(Variant {
                path: dir.join(format!("{name}.hlo.txt")),
                name,
                args,
                kernel: kernel.to_string(),
                batch,
                pool,
            });
        };

        for b in BATCHES {
            push(
                format!("gravity_B{b}"),
                "gravity",
                b,
                0,
                vec![
                    f32s(vec![b, PARTS_PER_BUCKET, PARTICLE_W]),
                    f32s(vec![b, INTERACTIONS, INTER_W]),
                    f32s(vec![1]),
                ],
            );
            push(
                format!("ewald_B{b}"),
                "ewald",
                b,
                0,
                vec![
                    f32s(vec![b, PARTS_PER_BUCKET, PARTICLE_W]),
                    f32s(vec![KTABLE, KTAB_W]),
                ],
            );
            push(
                format!("md_force_B{b}"),
                "md_force",
                b,
                0,
                vec![
                    f32s(vec![b, PARTS_PER_PATCH, MD_W]),
                    f32s(vec![b, PARTS_PER_PATCH, MD_W]),
                    f32s(vec![3]),
                ],
            );
        }
        for b in GATHER_BATCHES {
            for s in POOLS {
                push(
                    format!("gravity_gather_B{b}_S{s}"),
                    "gravity_gather",
                    b,
                    s,
                    vec![
                        f32s(vec![s, PARTICLE_W]),
                        i32s(vec![b, PARTS_PER_BUCKET]),
                        f32s(vec![b, INTERACTIONS, INTER_W]),
                        f32s(vec![1]),
                    ],
                );
            }
        }
        Self::index(variants)
    }

    /// Parse manifest text; artifact paths resolve relative to `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let doc = Json::parse(text).context("parsing manifest.json")?;
        match doc.get("format").and_then(Json::as_str) {
            Some("hlo-text") => {}
            other => bail!("unsupported manifest format: {other:?}"),
        }
        let entries = doc
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest has no entries array")?;

        let mut variants = Vec::with_capacity(entries.len());
        for e in entries {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .context("entry missing name")?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .context("entry missing file")?;
            let meta = e.get("meta").context("entry missing meta")?;
            let kernel = meta
                .get("kernel")
                .and_then(Json::as_str)
                .context("meta missing kernel")?
                .to_string();
            let batch = meta
                .get("batch")
                .and_then(Json::as_usize)
                .context("meta missing batch")?;
            let pool = meta.get("pool").and_then(Json::as_usize).unwrap_or(0);

            let mut args = Vec::new();
            for a in e
                .get("args")
                .and_then(Json::as_arr)
                .context("entry missing args")?
            {
                let shape = a
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("arg missing shape")?
                    .iter()
                    .map(|d| d.as_usize().context("bad dim"))
                    .collect::<Result<Vec<_>>>()?;
                let dtype = match a.get("dtype").and_then(Json::as_str) {
                    Some("float32") => DType::F32,
                    Some("int32") => DType::I32,
                    other => bail!("unsupported dtype {other:?}"),
                };
                args.push(ArgSpec { shape, dtype });
            }
            variants.push(Variant {
                name,
                path: dir.join(file),
                args,
                kernel,
                batch,
                pool,
            });
        }

        Ok(Self::index(variants))
    }

    /// Build the per-kernel (batch, pool)-sorted lookup index.
    fn index(variants: Vec<Variant>) -> Manifest {
        let mut by_kernel: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, v) in variants.iter().enumerate() {
            by_kernel.entry(v.kernel.clone()).or_default().push(i);
        }
        for idx in by_kernel.values_mut() {
            idx.sort_by_key(|&i| (variants[i].batch, variants[i].pool));
        }
        Manifest { variants, by_kernel }
    }

    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }

    /// Make sure a registered kernel family is servable: if no variants
    /// exist for `kernel.name` (AOT artifacts or the built-in synthetic
    /// set), synthesize a power-of-two batch ladder covering the family's
    /// occupancy-derived combine target, plus a gather ladder when the
    /// family declares a reuse argument. Synthetic variants reference no
    /// HLO file and are served by the sim backend.
    pub fn ensure_family(&mut self, kernel: &TileKernel) {
        let mut added = false;
        if !self.by_kernel.contains_key(&*kernel.name) {
            for b in kernel.ladder() {
                let mut args: Vec<ArgSpec> = kernel
                    .args
                    .iter()
                    .map(|a| ArgSpec {
                        shape: vec![b, a.rows, a.width],
                        dtype: DType::F32,
                    })
                    .collect();
                if !kernel.constant.is_empty() {
                    args.push(ArgSpec {
                        shape: vec![kernel.constant.len()],
                        dtype: DType::F32,
                    });
                }
                let name = format!("{}_B{b}", kernel.name);
                self.variants.push(Variant {
                    path: PathBuf::from(format!("{name}.hlo.txt")),
                    name,
                    args,
                    kernel: kernel.name.to_string(),
                    batch: b,
                    pool: 0,
                });
            }
            added = true;
        }
        if let (Some(gather), Some(ra)) =
            (&kernel.gather_name, kernel.reuse_arg)
        {
            if !self.by_kernel.contains_key(&**gather) {
                let spec = kernel.args[ra];
                for b in SYNTH_GATHER_BATCHES {
                    for s in SYNTH_POOLS {
                        let mut args = vec![
                            ArgSpec {
                                shape: vec![s, spec.width],
                                dtype: DType::F32,
                            },
                            ArgSpec {
                                shape: vec![b, spec.rows],
                                dtype: DType::I32,
                            },
                        ];
                        for (i, a) in kernel.args.iter().enumerate() {
                            if i == ra {
                                continue;
                            }
                            args.push(ArgSpec {
                                shape: vec![b, a.rows, a.width],
                                dtype: DType::F32,
                            });
                        }
                        if !kernel.constant.is_empty() {
                            args.push(ArgSpec {
                                shape: vec![kernel.constant.len()],
                                dtype: DType::F32,
                            });
                        }
                        let name = format!("{gather}_B{b}_S{s}");
                        self.variants.push(Variant {
                            path: PathBuf::from(format!("{name}.hlo.txt")),
                            name,
                            args,
                            kernel: gather.to_string(),
                            batch: b,
                            pool: s,
                        });
                    }
                }
                added = true;
            }
        }
        if added {
            *self = Self::index(std::mem::take(&mut self.variants));
        }
    }

    /// Smallest variant of `kernel` with batch >= `n` (and pool >= `pool`
    /// for gather kernels). Falls back to the largest available batch if
    /// `n` exceeds every ladder rung (caller then splits the launch).
    pub fn select(&self, kernel: &str, n: usize, pool: usize) -> Option<&Variant> {
        let idx = self.by_kernel.get(kernel)?;
        idx.iter()
            .map(|&i| &self.variants[i])
            .filter(|v| v.pool >= pool || v.pool == 0)
            .find(|v| v.batch >= n)
            .or_else(|| {
                idx.iter()
                    .map(|&i| &self.variants[i])
                    .filter(|v| v.pool >= pool || v.pool == 0)
                    .last()
            })
    }

    /// Largest batch size available for a kernel (launch-splitting bound).
    pub fn max_batch(&self, kernel: &str) -> Option<usize> {
        self.by_kernel.get(kernel).map(|idx| {
            idx.iter().map(|&i| self.variants[i].batch).max().unwrap()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "return_tuple": true,
      "entries": [
        {"name": "gravity_B8", "file": "gravity_B8.hlo.txt",
         "args": [{"shape": [8, 16, 4], "dtype": "float32"},
                  {"shape": [8, 128, 4], "dtype": "float32"},
                  {"shape": [1], "dtype": "float32"}],
         "meta": {"kernel": "gravity", "batch": 8},
         "sha256": "x"},
        {"name": "gravity_B32", "file": "gravity_B32.hlo.txt",
         "args": [{"shape": [32, 16, 4], "dtype": "float32"},
                  {"shape": [32, 128, 4], "dtype": "float32"},
                  {"shape": [1], "dtype": "float32"}],
         "meta": {"kernel": "gravity", "batch": 32},
         "sha256": "x"},
        {"name": "gravity_gather_B16_S2048", "file": "gg.hlo.txt",
         "args": [{"shape": [2048, 4], "dtype": "float32"},
                  {"shape": [16, 16], "dtype": "int32"},
                  {"shape": [16, 128, 4], "dtype": "float32"},
                  {"shape": [1], "dtype": "float32"}],
         "meta": {"kernel": "gravity_gather", "batch": 16, "pool": 2048},
         "sha256": "x"}
      ]
    }"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.variants().len(), 3);
        let v = &m.variants()[0];
        assert_eq!(v.kernel, "gravity");
        assert_eq!(v.batch, 8);
        assert_eq!(v.args[0].shape, vec![8, 16, 4]);
        assert_eq!(v.args[1].dtype, DType::F32);
        assert_eq!(m.variants()[2].args[1].dtype, DType::I32);
        assert_eq!(m.variants()[2].pool, 2048);
        assert!(v.path.ends_with("gravity_B8.hlo.txt"));
    }

    #[test]
    fn select_picks_smallest_fitting_batch() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.select("gravity", 5, 0).unwrap().batch, 8);
        assert_eq!(m.select("gravity", 8, 0).unwrap().batch, 8);
        assert_eq!(m.select("gravity", 9, 0).unwrap().batch, 32);
    }

    #[test]
    fn select_overflow_falls_back_to_largest() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.select("gravity", 1000, 0).unwrap().batch, 32);
        assert_eq!(m.max_batch("gravity"), Some(32));
    }

    #[test]
    fn select_unknown_kernel_is_none() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.select("nope", 1, 0).is_none());
    }

    #[test]
    fn rejects_bad_format() {
        let bad = r#"{"format": "protobuf", "entries": []}"#;
        assert!(Manifest::parse(bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn synthetic_ladder_serves_all_kernels() {
        let m = Manifest::synthetic(Path::new("/tmp/none"));
        assert_eq!(m.select("gravity", 104, 0).unwrap().batch, 128);
        assert_eq!(m.max_batch("gravity"), Some(128));
        assert!(m.select("ewald", 65, 0).is_some());
        assert!(m.select("md_force", 10, 0).is_some());
        let g = m.select("gravity_gather", 64, 16_384).unwrap();
        assert!(g.pool >= 16_384);
        assert_eq!(g.args[1].dtype, DType::I32);
    }

    #[test]
    fn load_or_synthetic_falls_back_when_missing() {
        let dir = Path::new("/tmp/gcharm-definitely-missing-artifacts");
        let (m, real) = Manifest::load_or_synthetic(dir).unwrap();
        assert!(!real);
        assert!(!m.variants().is_empty());
    }

    #[test]
    fn ensure_family_synthesizes_ladder_once() {
        use crate::runtime::device_sim::KernelResources;
        use crate::runtime::kernel::{TileArgSpec, TileKernel};
        use std::sync::Arc;

        fn noop(_: &[&[f32]], _: &[f32]) -> Vec<f32> {
            vec![0.0]
        }
        let k = TileKernel {
            name: Arc::from("custom_family"),
            args: vec![TileArgSpec { name: "t", rows: 3, width: 2, pad: 0.0 }],
            constant: Arc::new(vec![1.0, 2.0]),
            out_rows: 1,
            out_width: 1,
            resources: KernelResources {
                threads_per_block: 128,
                regs_per_thread: 64,
                smem_per_block: 4096,
            },
            items_per_slot: 6,
            reuse_arg: None,
            gather_name: None,
            entry_arg: None,
            slot_fn: noop,
        };
        let mut m = Manifest::synthetic(Path::new("/tmp/none"));
        let before = m.variants().len();
        m.ensure_family(&k);
        let after = m.variants().len();
        assert_eq!(after - before, k.ladder().len());
        let v = m.select("custom_family", 3, 0).unwrap();
        assert_eq!(v.batch, 4);
        assert_eq!(v.args.len(), 2, "tile arg + constant");
        assert_eq!(v.args[0].elements(), 4 * 3 * 2);
        assert_eq!(v.args[1].elements(), 2);
        // idempotent: a second call adds nothing
        m.ensure_family(&k);
        assert_eq!(m.variants().len(), after);
        // built-in families are already servable: no additions
        m.ensure_family(&TileKernel::gravity(0.01));
        assert_eq!(m.variants().len(), after);
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.select("gravity", 104, 0).is_some());
            assert!(m.select("ewald", 65, 0).is_some());
            assert!(m.select("md_force", 10, 0).is_some());
            assert!(m.select("gravity_gather", 64, 1024).is_some());
        }
    }
}
