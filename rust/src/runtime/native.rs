//! Native f32 kernels shared by the sim backend and the hybrid CPU path.
//!
//! One implementation serves two callers: `coordinator::cpu_kernels`
//! (hybrid execution, paper section 3.3) and `runtime::pjrt`'s sim backend
//! (the default engine when the PJRT toolchain or AOT artifacts are
//! absent). Sharing the exact f32 arithmetic and masking rules means the
//! CPU half of a hybrid split is bit-compatible with the simulated GPU
//! half, and the pipelined `GpuService` is bitwise-identical to the
//! synchronous `Executor` (both interpret through these functions).

use super::shapes::{INTER_W, MD_W, OUT_W, PARTICLE_W};

/// CPU bucket gravity: `parts` (P x 4), `inters` (I x 4) -> (P x 4)
/// [ax, ay, az, pot]. Mirrors `kernels/gravity.py`.
pub fn cpu_gravity(parts: &[f32], inters: &[f32], eps2: f32) -> Vec<f32> {
    let p = parts.len() / PARTICLE_W;
    let n = inters.len() / INTER_W;
    let mut out = vec![0.0f32; p * OUT_W];
    for i in 0..p {
        let px = parts[i * PARTICLE_W];
        let py = parts[i * PARTICLE_W + 1];
        let pz = parts[i * PARTICLE_W + 2];
        let (mut ax, mut ay, mut az, mut pot) = (0.0f32, 0.0, 0.0, 0.0);
        for j in 0..n {
            let dx = inters[j * INTER_W] - px;
            let dy = inters[j * INTER_W + 1] - py;
            let dz = inters[j * INTER_W + 2] - pz;
            let m = inters[j * INTER_W + 3];
            let r2 = dx * dx + dy * dy + dz * dz + eps2;
            let inv = 1.0 / r2.sqrt();
            let inv3 = inv * inv * inv;
            let w = m * inv3;
            ax += w * dx;
            ay += w * dy;
            az += w * dz;
            pot -= m * inv;
        }
        out[i * OUT_W] = ax;
        out[i * OUT_W + 1] = ay;
        out[i * OUT_W + 2] = az;
        out[i * OUT_W + 3] = pot;
    }
    out
}

/// CPU Ewald k-space correction: `parts` (P x 4), `ktab` (K x 4) ->
/// (P x 4) [fx, fy, fz, pot]. Mirrors `kernels/ewald.py`.
pub fn cpu_ewald(parts: &[f32], ktab: &[f32]) -> Vec<f32> {
    let p = parts.len() / PARTICLE_W;
    let k = ktab.len() / 4;
    let mut out = vec![0.0f32; p * OUT_W];
    for i in 0..p {
        let px = parts[i * PARTICLE_W];
        let py = parts[i * PARTICLE_W + 1];
        let pz = parts[i * PARTICLE_W + 2];
        let mass = parts[i * PARTICLE_W + 3];
        let (mut fx, mut fy, mut fz, mut pot) = (0.0f32, 0.0, 0.0, 0.0);
        for j in 0..k {
            let kx = ktab[j * 4];
            let ky = ktab[j * 4 + 1];
            let kz = ktab[j * 4 + 2];
            let coef = ktab[j * 4 + 3];
            let phase = px * kx + py * ky + pz * kz;
            let s = coef * phase.sin();
            let c = coef * phase.cos();
            fx += s * kx;
            fy += s * ky;
            fz += s * kz;
            pot += c;
        }
        out[i * OUT_W] = mass * fx;
        out[i * OUT_W + 1] = mass * fy;
        out[i * OUT_W + 2] = mass * fz;
        out[i * OUT_W + 3] = mass * pot;
    }
    out
}

/// CPU MD patch-pair LJ force: `pa`, `pb` (N x 2) -> forces on `pa` (N x 2).
/// Mirrors `kernels/md_force.py` including the self-pair mask.
pub fn cpu_md_interact(pa: &[f32], pb: &[f32], params: [f32; 3]) -> Vec<f32> {
    let [rc2, sig2, eps] = params;
    let n = pa.len() / MD_W;
    let m = pb.len() / MD_W;
    let mut out = vec![0.0f32; n * MD_W];
    for i in 0..n {
        let xi = pa[i * MD_W];
        let yi = pa[i * MD_W + 1];
        let (mut fx, mut fy) = (0.0f32, 0.0f32);
        for j in 0..m {
            let dx = xi - pb[j * MD_W];
            let dy = yi - pb[j * MD_W + 1];
            let r2 = dx * dx + dy * dy;
            if r2 < rc2 && r2 > 1e-9 {
                let s2 = sig2 / r2;
                let s6 = s2 * s2 * s2;
                let f = 24.0 * eps * (2.0 * s6 * s6 - s6) / r2;
                fx += f * dx;
                fy += f * dy;
            }
        }
        out[i * MD_W] = fx;
        out[i * MD_W + 1] = fy;
    }
    out
}
