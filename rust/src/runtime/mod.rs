//! Runtime layer: PJRT engine, GPU service, device model, device memory.
//!
//! This is the boundary between the rust coordinator (Layer 3) and the
//! AOT-compiled XLA computations (Layers 1-2). The "GPU" of the paper is
//! realized as the CPU PJRT client executing Pallas-lowered HLO, plus an
//! analytic Kepler K20 model for occupancy and modeled timings
//! (DESIGN.md section 2, substitution table).

pub mod device_sim;
pub mod executor;
pub mod kernel;
pub mod manifest;
pub mod memory;
pub mod native;
pub mod pjrt;
pub mod pool;
pub mod shapes;
pub mod staging;
pub mod workqueue;

pub use device_sim::{
    occupancy, CoalescingClass, DeviceModel, GpuSpec, KernelResources,
    ModeledCost, Occupancy,
};
pub use executor::{Completion, Executor, GpuService, LaunchSpec, Payload};
pub use kernel::{builtin_kernels, SlotFn, TileArgSpec, TileKernel};
pub use manifest::Manifest;
pub use memory::{BufferId, DeviceMemory, Residency, ResidencyPolicy};
pub use pjrt::{Engine, HostArg};
pub use pool::{DevicePool, InFlightGuard};
pub use staging::{ArenaArg, ArenaStats, StagedChunk, StagingArena};
pub use workqueue::{LaunchMode, QueueStats, WorkQueue, DEFAULT_QUEUE_DEPTH};

use std::path::PathBuf;

/// Default artifacts directory: `$GCHARM_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("GCHARM_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
