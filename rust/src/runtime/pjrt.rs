//! Execution engine: native sim backend + optional PJRT backend.
//!
//! The engine executes combined-kernel variants against one of two
//! backends:
//!
//! - **Sim** (default): a table-driven native interpreter over the
//!   registered [`TileKernel`] families: each variant is executed slot by
//!   slot through the family's `slot_fn` (gather variants first gather the
//!   reusable tile out of the pool argument). The same f32 arithmetic
//!   serves the hybrid CPU fallback, so hybrid execution is bit-compatible
//!   with sim-GPU execution, and an app-registered family executes without
//!   any engine change.
//! - **Pjrt** (`--features pjrt`): loads AOT HLO-text artifacts and
//!   executes them on the CPU PJRT client (the simulated "GPU device" --
//!   DESIGN.md section 2). Pattern follows /opt/xla-example/load_hlo:
//!   `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//!   `client.compile` -> `execute`. Variants compile lazily on first
//!   launch and are cached; synthetic variants (no HLO file on disk, e.g.
//!   an app-registered family without AOT artifacts) fall back to the sim
//!   interpreter per launch.
//!
//! Backend selection: PJRT is used when the feature is compiled in, real
//! artifacts are on disk, and `GCHARM_ENGINE` is not set to `sim`;
//! otherwise the sim backend serves every launch.

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::kernel::TileKernel;
use super::manifest::{DType, Manifest, Variant};

/// One host-side argument for a launch; must match the variant's ArgSpec.
#[derive(Debug, Clone, Copy)]
pub enum HostArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl HostArg<'_> {
    pub fn len(&self) -> usize {
        match self {
            HostArg::F32(s) => s.len(),
            HostArg::I32(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype(&self) -> DType {
        match self {
            HostArg::F32(_) => DType::F32,
            HostArg::I32(_) => DType::I32,
        }
    }

    fn as_f32(&self) -> &[f32] {
        match self {
            HostArg::F32(s) => s,
            HostArg::I32(_) => &[],
        }
    }

    fn as_i32(&self) -> &[i32] {
        match self {
            HostArg::I32(s) => s,
            HostArg::F32(_) => &[],
        }
    }
}

enum Backend {
    /// Table-driven native interpreter over the registered families.
    Sim,
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt_backend::PjrtBackend),
}

/// Variant-executing engine over a manifest and a set of registered
/// kernel families (sim or PJRT backend).
pub struct Engine {
    manifest: Manifest,
    /// Family name (and gather-family name) -> runtime kernel descriptor.
    kernels: HashMap<String, Arc<TileKernel>>,
    backend: Backend,
    /// Variant names prepared so far (PJRT: compiled executables).
    compiled: HashSet<String>,
}

impl Engine {
    /// Create an engine over the artifacts in `dir` serving `kernels`
    /// (ladders synthesized and shapes validated via
    /// `Manifest::for_kernels`); falls back to the synthetic manifest +
    /// sim backend when no artifacts are present.
    pub fn load(dir: &Path, kernels: &[Arc<TileKernel>]) -> Result<Engine> {
        let (manifest, real) = Manifest::for_kernels(dir, kernels)?;
        Engine::with_manifest(manifest, real, kernels)
    }

    /// Build an engine from an already-loaded manifest. `artifacts_on_disk`
    /// gates the PJRT backend (the sim backend never reads HLO files).
    pub fn with_manifest(
        manifest: Manifest,
        artifacts_on_disk: bool,
        kernels: &[Arc<TileKernel>],
    ) -> Result<Engine> {
        let mut map = HashMap::new();
        for k in kernels {
            map.insert(k.name.to_string(), k.clone());
            if let Some(g) = &k.gather_name {
                map.insert(g.to_string(), k.clone());
            }
        }
        let force_sim = std::env::var("GCHARM_ENGINE")
            .map(|v| v == "sim")
            .unwrap_or(false);
        #[cfg(feature = "pjrt")]
        if artifacts_on_disk && !force_sim {
            match pjrt_backend::PjrtBackend::new() {
                Ok(b) => {
                    return Ok(Engine {
                        manifest,
                        kernels: map,
                        backend: Backend::Pjrt(b),
                        compiled: HashSet::new(),
                    })
                }
                Err(e) => {
                    eprintln!(
                        "gcharm: PJRT client unavailable ({e}); \
                         falling back to the sim backend"
                    );
                }
            }
        }
        let _ = (artifacts_on_disk, force_sim);
        Ok(Engine {
            manifest,
            kernels: map,
            backend: Backend::Sim,
            compiled: HashSet::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Register additional kernel families on a live engine. The shared
    /// registry of a persistent runtime is append-only: a later job may
    /// bring families the engine never saw at construction. Synthesizes
    /// manifest ladders for the new families (no-op for ones already
    /// servable) and wires their slot functions into the sim dispatch
    /// table.
    pub fn add_kernels(&mut self, kernels: &[Arc<TileKernel>]) {
        for k in kernels {
            self.manifest.ensure_family(k);
            self.kernels.insert(k.name.to_string(), k.clone());
            if let Some(g) = &k.gather_name {
                self.kernels.insert(g.to_string(), k.clone());
            }
        }
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Sim => "sim-native".to_string(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.platform(),
        }
    }

    /// Whether this backend can keep a resident megakernel loop alive
    /// (ISSUE 8). The sim backend models one; PJRT executables launch per
    /// invocation with no device-resident scheduler, so persistent
    /// launches on that backend gracefully fall back to per-batch (the
    /// `Completion` reports the effective mode).
    pub fn persistent_capable(&self) -> bool {
        match &self.backend {
            Backend::Sim => true,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => false,
        }
    }

    /// Prepare (PJRT: compile and cache) the named variant.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains(name) {
            return Ok(());
        }
        let variant = self
            .manifest
            .variants()
            .iter()
            .find(|v| v.name == name)
            .with_context(|| format!("unknown variant {name}"))?;
        match &mut self.backend {
            Backend::Sim => {}
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => {
                // Synthetic variants (no HLO file) are served by the sim
                // interpreter instead of compiled.
                if variant.path.exists() {
                    b.compile(variant)?;
                }
            }
        }
        let _ = variant;
        self.compiled.insert(name.to_string());
        Ok(())
    }

    /// Number of variants prepared so far.
    pub fn compiled_count(&self) -> usize {
        self.compiled.len()
    }

    /// Execute a variant with validated host arguments; returns the first
    /// (and only) output buffer as f32 (return_tuple=True convention).
    pub fn execute(&mut self, name: &str, args: &[HostArg]) -> Result<Vec<f32>> {
        self.ensure_compiled(name)?;
        // Direct field borrow (not a &self helper) so the variant stays
        // borrowed from `self.manifest` while `self.backend` is mutably
        // borrowed below -- avoids deep-cloning the Variant per chunk.
        let variant = self
            .manifest
            .variants()
            .iter()
            .find(|v| v.name == name)
            .with_context(|| format!("unknown variant {name}"))?;
        validate(variant, args)?;
        match &mut self.backend {
            Backend::Sim => sim_execute(&self.kernels, variant, args),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => {
                if variant.path.exists() {
                    b.execute(variant, args)
                } else {
                    sim_execute(&self.kernels, variant, args)
                }
            }
        }
    }
}

fn validate(variant: &Variant, args: &[HostArg]) -> Result<()> {
    if args.len() != variant.args.len() {
        bail!(
            "{}: expected {} args, got {}",
            variant.name,
            variant.args.len(),
            args.len()
        );
    }
    for (i, (arg, spec)) in args.iter().zip(&variant.args).enumerate() {
        if arg.len() != spec.elements() {
            bail!(
                "{} arg {i}: expected {} elements for shape {:?}, got {}",
                variant.name,
                spec.elements(),
                spec.shape,
                arg.len()
            );
        }
        if arg.dtype() != spec.dtype {
            bail!("{} arg {i}: dtype mismatch", variant.name);
        }
    }
    Ok(())
}

/// Interpret one combined launch natively, dispatching through the
/// registered kernel table (the sim backend).
fn sim_execute(
    kernels: &HashMap<String, Arc<TileKernel>>,
    variant: &Variant,
    args: &[HostArg],
) -> Result<Vec<f32>> {
    let Some(tk) = kernels.get(variant.kernel.as_str()) else {
        bail!("sim backend: unregistered kernel family {}", variant.kernel);
    };
    let is_gather = tk
        .gather_name
        .as_deref()
        .is_some_and(|g| g == variant.kernel.as_str());
    if is_gather {
        sim_gather(tk, variant, args)
    } else {
        sim_tile(tk, variant, args)
    }
}

/// Direct tile variant: one `slot_fn` call per combined slot.
fn sim_tile(
    tk: &TileKernel,
    variant: &Variant,
    args: &[HostArg],
) -> Result<Vec<f32>> {
    let b = variant.batch;
    let has_const = !tk.constant.is_empty();
    anyhow::ensure!(
        args.len() == tk.args.len() + has_const as usize,
        "{}: {} args for a {}-tile family",
        variant.name,
        args.len(),
        tk.args.len()
    );
    let cbuf: &[f32] =
        if has_const { args[tk.args.len()].as_f32() } else { &[] };
    let mut out = Vec::with_capacity(b * tk.out_slot_len());
    for s in 0..b {
        let slices: Vec<&[f32]> = tk
            .args
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let slot = spec.slot_len();
                &args[i].as_f32()[s * slot..(s + 1) * slot]
            })
            .collect();
        out.extend((tk.slot_fn)(&slices, cbuf));
    }
    Ok(out)
}

/// Gather variant: args are `[pool, idx, <non-reuse tiles...>, constant]`;
/// the reusable tile is gathered out of the pool per slot, then `slot_fn`
/// runs with the tiles reassembled in registration order.
fn sim_gather(
    tk: &TileKernel,
    variant: &Variant,
    args: &[HostArg],
) -> Result<Vec<f32>> {
    let b = variant.batch;
    let ra = tk
        .reuse_arg
        .context("gather variant for a family without a reuse arg")?;
    let spec = tk.args[ra];
    let pool = args[0].as_f32();
    let idx = args[1].as_i32();
    let pool_rows = pool.len() / spec.width;
    let has_const = !tk.constant.is_empty();
    let cbuf: &[f32] =
        if has_const { args[args.len() - 1].as_f32() } else { &[] };
    let mut gathered = vec![0.0f32; spec.slot_len()];
    let mut out = Vec::with_capacity(b * tk.out_slot_len());
    for s in 0..b {
        for (j, &row) in
            idx[s * spec.rows..(s + 1) * spec.rows].iter().enumerate()
        {
            let row = row as usize;
            anyhow::ensure!(
                row < pool_rows,
                "{}: gather index {row} out of pool ({pool_rows} rows)",
                variant.name
            );
            gathered[j * spec.width..(j + 1) * spec.width]
                .copy_from_slice(&pool[row * spec.width..(row + 1) * spec.width]);
        }
        let mut slices: Vec<&[f32]> = Vec::with_capacity(tk.args.len());
        let mut passed = 2usize; // next non-reuse tile among `args`
        for (i, a) in tk.args.iter().enumerate() {
            if i == ra {
                slices.push(&gathered);
            } else {
                let slot = a.slot_len();
                slices.push(&args[passed].as_f32()[s * slot..(s + 1) * slot]);
                passed += 1;
            }
        }
        out.extend((tk.slot_fn)(&slices, cbuf));
    }
    Ok(out)
}

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    //! The real PJRT CPU client over AOT HLO-text artifacts.

    use std::collections::HashMap;

    use anyhow::Result;

    use super::super::manifest::Variant;
    use super::HostArg;

    pub struct PjrtBackend {
        client: xla::PjRtClient,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl PjrtBackend {
        pub fn new() -> Result<PjrtBackend> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e}"))?;
            Ok(PjrtBackend { client, executables: HashMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn compile(&mut self, variant: &Variant) -> Result<()> {
            if self.executables.contains_key(&variant.name) {
                return Ok(());
            }
            let proto = xla::HloModuleProto::from_text_file(&variant.path)
                .map_err(|e| {
                    anyhow::anyhow!("loading {}: {e}", variant.path.display())
                })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| {
                anyhow::anyhow!("compiling {}: {e}", variant.name)
            })?;
            self.executables.insert(variant.name.clone(), exe);
            Ok(())
        }

        pub fn execute(
            &mut self,
            variant: &Variant,
            args: &[HostArg],
        ) -> Result<Vec<f32>> {
            self.compile(variant)?;
            let name = &variant.name;
            // Single-copy literal creation (perf: `vec1(..).reshape(..)`
            // copies the payload twice; this path once -- see
            // EXPERIMENTS.md section Perf).
            let literals = args
                .iter()
                .zip(&variant.args)
                .map(|(arg, spec)| {
                    let (ty, bytes): (xla::ElementType, &[u8]) = match arg {
                        HostArg::F32(data) => {
                            (xla::ElementType::F32, bytes_of(data))
                        }
                        HostArg::I32(data) => {
                            (xla::ElementType::S32, bytes_of(data))
                        }
                    };
                    xla::Literal::create_from_shape_and_untyped_data(
                        ty,
                        &spec.shape,
                        bytes,
                    )
                    .map_err(|e| anyhow::anyhow!("literal {name}: {e}"))
                })
                .collect::<Result<Vec<_>>>()?;

            let exe = self.executables.get(name.as_str()).unwrap();
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("to_literal {name}: {e}"))?
                .to_tuple1()
                .map_err(|e| anyhow::anyhow!("to_tuple1 {name}: {e}"))?;
            out.to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("to_vec {name}: {e}"))
        }
    }

    /// Reinterpret a typed slice as raw bytes (for literal creation).
    fn bytes_of<T: Copy>(data: &[T]) -> &[u8] {
        // SAFETY: T is a plain Copy scalar (f32/i32); size and alignment
        // of the byte view are trivially valid.
        unsafe {
            std::slice::from_raw_parts(
                data.as_ptr() as *const u8,
                std::mem::size_of_val(data),
            )
        }
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.platform())
            .field("variants", &self.manifest.variants().len())
            .field("families", &self.kernels.len())
            .field("compiled", &self.compiled.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kernel::builtin_kernels;
    use crate::runtime::native::cpu_gravity;
    use crate::runtime::shapes::{
        INTERACTIONS, INTER_W, KTABLE, KTAB_W, OUT_W, PARTICLE_W,
        PARTS_PER_BUCKET,
    };

    fn sim_engine() -> Engine {
        let kernels =
            builtin_kernels(0.01, vec![0.0; KTABLE * KTAB_W], [1.0, 0.04, 1.0]);
        let m = Manifest::synthetic(Path::new("/tmp/none"));
        Engine::with_manifest(m, false, &kernels).unwrap()
    }

    #[test]
    fn sim_gravity_matches_native_kernel() {
        let mut e = sim_engine();
        let b = 2;
        let mut parts = vec![0.0f32; b * PARTS_PER_BUCKET * PARTICLE_W];
        let mut inters = vec![0.0f32; b * INTERACTIONS * INTER_W];
        parts[3] = 1.0; // slot 0 particle 0: mass 1 at origin
        inters[0] = 2.0; // slot 0 interaction 0: mass 3 at (2,0,0)
        inters[3] = 3.0;
        let out = e
            .execute(
                "gravity_B2",
                &[
                    HostArg::F32(&parts),
                    HostArg::F32(&inters),
                    HostArg::F32(&[0.01]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), b * PARTS_PER_BUCKET * OUT_W);
        let native = cpu_gravity(
            &parts[..PARTS_PER_BUCKET * PARTICLE_W],
            &inters[..INTERACTIONS * INTER_W],
            0.01,
        );
        assert_eq!(&out[..PARTS_PER_BUCKET * OUT_W], &native[..]);
        // slot 1 is all padding: zero output
        assert!(out[PARTS_PER_BUCKET * OUT_W..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn sim_rejects_shape_mismatch() {
        let mut e = sim_engine();
        let r = e.execute("gravity_B1", &[HostArg::F32(&[0.0])]);
        assert!(r.is_err());
    }

    #[test]
    fn sim_gather_rejects_out_of_pool_index() {
        let mut e = sim_engine();
        let pool = vec![0.0f32; 1024 * PARTICLE_W];
        let idx = vec![5000i32; 16 * PARTS_PER_BUCKET];
        let inters = vec![0.0f32; 16 * INTERACTIONS * INTER_W];
        let r = e.execute(
            "gravity_gather_B16_S1024",
            &[
                HostArg::F32(&pool),
                HostArg::I32(&idx),
                HostArg::F32(&inters),
                HostArg::F32(&[0.01]),
            ],
        );
        assert!(r.is_err());
    }

    #[test]
    fn sim_executes_registered_custom_family() {
        use crate::runtime::device_sim::KernelResources;
        use crate::runtime::kernel::{TileArgSpec, TileKernel};

        fn double_sum(args: &[&[f32]], c: &[f32]) -> Vec<f32> {
            vec![args[0].iter().sum::<f32>() * c[0]]
        }
        let k = Arc::new(TileKernel {
            name: Arc::from("double_sum"),
            args: vec![TileArgSpec { name: "t", rows: 2, width: 2, pad: 0.0 }],
            constant: Arc::new(vec![2.0]),
            out_rows: 1,
            out_width: 1,
            resources: KernelResources {
                threads_per_block: 64,
                regs_per_thread: 32,
                smem_per_block: 1024,
            },
            items_per_slot: 4,
            reuse_arg: None,
            gather_name: None,
            entry_arg: None,
            slot_fn: double_sum,
        });
        let mut e =
            Engine::load(Path::new("/tmp/gcharm-missing-artifacts"), &[k])
                .unwrap();
        // batch-2 variant: slots [1,1,1,1] and [0.5, 0.5, 0, 0]
        let buf = [1.0f32, 1.0, 1.0, 1.0, 0.5, 0.5, 0.0, 0.0];
        let out = e
            .execute("double_sum_B2", &[HostArg::F32(&buf), HostArg::F32(&[2.0])])
            .unwrap();
        assert_eq!(out, vec![8.0, 2.0]);
    }

    #[test]
    fn compiled_count_tracks_prepared_variants() {
        let mut e = sim_engine();
        assert_eq!(e.compiled_count(), 0);
        e.ensure_compiled("ewald_B1").unwrap();
        e.ensure_compiled("ewald_B1").unwrap();
        assert_eq!(e.compiled_count(), 1);
        assert!(e.ensure_compiled("nope").is_err());
    }
}
