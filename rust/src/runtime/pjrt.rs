//! PJRT engine: loads AOT HLO-text artifacts and executes them on the CPU
//! PJRT client (the simulated "GPU device" -- DESIGN.md section 2).
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`. Variants
//! are compiled lazily on first launch and cached for the lifetime of the
//! engine (compilation is the expensive step; execution is the hot path).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{DType, Manifest, Variant};

/// One host-side argument for a launch; must match the variant's ArgSpec.
#[derive(Debug, Clone, Copy)]
pub enum HostArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl HostArg<'_> {
    pub fn len(&self) -> usize {
        match self {
            HostArg::F32(s) => s.len(),
            HostArg::I32(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype(&self) -> DType {
        match self {
            HostArg::F32(_) => DType::F32,
            HostArg::I32(_) => DType::I32,
        }
    }
}

/// PJRT client + compiled-executable cache for the artifact set.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU-PJRT engine over the artifacts in `dir`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e}"))?;
        Ok(Engine { client, manifest, executables: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) the named variant.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let variant = self
            .manifest
            .variants()
            .iter()
            .find(|v| v.name == name)
            .with_context(|| format!("unknown variant {name}"))?;
        let proto = xla::HloModuleProto::from_text_file(&variant.path)
            .map_err(|e| {
                anyhow::anyhow!("loading {}: {e}", variant.path.display())
            })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Number of variants compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.executables.len()
    }

    /// Execute a variant with validated host arguments; returns the first
    /// (and only) output buffer as f32 (return_tuple=True convention).
    pub fn execute(&mut self, name: &str, args: &[HostArg]) -> Result<Vec<f32>> {
        self.ensure_compiled(name)?;
        let variant = self
            .manifest
            .variants()
            .iter()
            .find(|v| v.name == name)
            .unwrap()
            .clone();
        self.validate(&variant, args)?;

        // Single-copy literal creation (perf: `vec1(..).reshape(..)` copies
        // the payload twice; `create_from_shape_and_untyped_data` once --
        // see EXPERIMENTS.md section Perf).
        let literals = args
            .iter()
            .zip(&variant.args)
            .map(|(arg, spec)| {
                let (ty, bytes): (xla::ElementType, &[u8]) = match arg {
                    HostArg::F32(data) => {
                        (xla::ElementType::F32, bytes_of(data))
                    }
                    HostArg::I32(data) => {
                        (xla::ElementType::S32, bytes_of(data))
                    }
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    ty,
                    &spec.shape,
                    bytes,
                )
                .map_err(|e| anyhow::anyhow!("literal {name}: {e}"))
            })
            .collect::<Result<Vec<_>>>()?;

        let exe = self.executables.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal {name}: {e}"))?
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("to_tuple1 {name}: {e}"))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec {name}: {e}"))
    }

    fn validate(&self, variant: &Variant, args: &[HostArg]) -> Result<()> {
        if args.len() != variant.args.len() {
            bail!(
                "{}: expected {} args, got {}",
                variant.name,
                variant.args.len(),
                args.len()
            );
        }
        for (i, (arg, spec)) in args.iter().zip(&variant.args).enumerate() {
            if arg.len() != spec.elements() {
                bail!(
                    "{} arg {i}: expected {} elements for shape {:?}, got {}",
                    variant.name,
                    spec.elements(),
                    spec.shape,
                    arg.len()
                );
            }
            if arg.dtype() != spec.dtype {
                bail!("{} arg {i}: dtype mismatch", variant.name);
            }
        }
        Ok(())
    }
}

/// Reinterpret a typed slice as raw bytes (for literal creation).
fn bytes_of<T: Copy>(data: &[T]) -> &[u8] {
    // SAFETY: T is a plain Copy scalar (f32/i32); size and alignment of the
    // byte view are trivially valid.
    unsafe {
        std::slice::from_raw_parts(
            data.as_ptr() as *const u8,
            std::mem::size_of_val(data),
        )
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.client.platform_name())
            .field("variants", &self.manifest.variants().len())
            .field("compiled", &self.executables.len())
            .finish()
    }
}
