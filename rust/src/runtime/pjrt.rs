//! Execution engine: native sim backend + optional PJRT backend.
//!
//! The engine executes combined-kernel variants against one of two
//! backends:
//!
//! - **Sim** (default): a native interpreter of the four kernel families
//!   (`runtime::native`), using the same f32 arithmetic and masking rules
//!   as the Pallas kernels. It serves the synthetic manifest when the AOT
//!   artifacts are absent, so the full stack runs hermetically.
//! - **Pjrt** (`--features pjrt`): loads AOT HLO-text artifacts and
//!   executes them on the CPU PJRT client (the simulated "GPU device" --
//!   DESIGN.md section 2). Pattern follows /opt/xla-example/load_hlo:
//!   `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//!   `client.compile` -> `execute`. Variants compile lazily on first
//!   launch and are cached (compilation is the expensive step; execution
//!   is the hot path).
//!
//! Backend selection: PJRT is used when the feature is compiled in, real
//! artifacts are on disk, and `GCHARM_ENGINE` is not set to `sim`;
//! otherwise the sim backend serves every launch.

use std::collections::HashSet;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{DType, Manifest, Variant};
use super::native::{cpu_ewald, cpu_gravity, cpu_md_interact};
use super::shapes::{MD_W, OUT_W, PARTICLE_W};

/// One host-side argument for a launch; must match the variant's ArgSpec.
#[derive(Debug, Clone, Copy)]
pub enum HostArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl HostArg<'_> {
    pub fn len(&self) -> usize {
        match self {
            HostArg::F32(s) => s.len(),
            HostArg::I32(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype(&self) -> DType {
        match self {
            HostArg::F32(_) => DType::F32,
            HostArg::I32(_) => DType::I32,
        }
    }

    fn as_f32(&self) -> &[f32] {
        match self {
            HostArg::F32(s) => s,
            HostArg::I32(_) => &[],
        }
    }

    fn as_i32(&self) -> &[i32] {
        match self {
            HostArg::I32(s) => s,
            HostArg::F32(_) => &[],
        }
    }
}

enum Backend {
    /// Native interpreter of the four kernel families.
    Sim,
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt_backend::PjrtBackend),
}

/// Variant-executing engine over a manifest (sim or PJRT backend).
pub struct Engine {
    manifest: Manifest,
    backend: Backend,
    /// Variant names prepared so far (PJRT: compiled executables).
    compiled: HashSet<String>,
}

impl Engine {
    /// Create an engine over the artifacts in `dir`; falls back to the
    /// synthetic manifest + sim backend when no artifacts are present.
    pub fn load(dir: &Path) -> Result<Engine> {
        let (manifest, real) = Manifest::load_or_synthetic(dir)?;
        Engine::with_manifest(manifest, real)
    }

    /// Build an engine from an already-loaded manifest. `artifacts_on_disk`
    /// gates the PJRT backend (the sim backend never reads HLO files).
    pub fn with_manifest(
        manifest: Manifest,
        artifacts_on_disk: bool,
    ) -> Result<Engine> {
        let force_sim = std::env::var("GCHARM_ENGINE")
            .map(|v| v == "sim")
            .unwrap_or(false);
        #[cfg(feature = "pjrt")]
        if artifacts_on_disk && !force_sim {
            match pjrt_backend::PjrtBackend::new() {
                Ok(b) => {
                    return Ok(Engine {
                        manifest,
                        backend: Backend::Pjrt(b),
                        compiled: HashSet::new(),
                    })
                }
                Err(e) => {
                    eprintln!(
                        "gcharm: PJRT client unavailable ({e}); \
                         falling back to the sim backend"
                    );
                }
            }
        }
        let _ = (artifacts_on_disk, force_sim);
        Ok(Engine {
            manifest,
            backend: Backend::Sim,
            compiled: HashSet::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Sim => "sim-native".to_string(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.platform(),
        }
    }

    /// Prepare (PJRT: compile and cache) the named variant.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains(name) {
            return Ok(());
        }
        match &mut self.backend {
            Backend::Sim => {
                self.manifest
                    .variants()
                    .iter()
                    .find(|v| v.name == name)
                    .with_context(|| format!("unknown variant {name}"))?;
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => {
                let variant = self
                    .manifest
                    .variants()
                    .iter()
                    .find(|v| v.name == name)
                    .with_context(|| format!("unknown variant {name}"))?;
                b.compile(variant)?;
            }
        }
        self.compiled.insert(name.to_string());
        Ok(())
    }

    /// Number of variants prepared so far.
    pub fn compiled_count(&self) -> usize {
        self.compiled.len()
    }

    /// Execute a variant with validated host arguments; returns the first
    /// (and only) output buffer as f32 (return_tuple=True convention).
    pub fn execute(&mut self, name: &str, args: &[HostArg]) -> Result<Vec<f32>> {
        self.ensure_compiled(name)?;
        // Direct field borrow (not a &self helper) so the variant stays
        // borrowed from `self.manifest` while `self.backend` is mutably
        // borrowed below -- avoids deep-cloning the Variant per chunk.
        let variant = self
            .manifest
            .variants()
            .iter()
            .find(|v| v.name == name)
            .with_context(|| format!("unknown variant {name}"))?;
        validate(variant, args)?;
        match &mut self.backend {
            Backend::Sim => sim_execute(variant, args),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.execute(variant, args),
        }
    }
}

fn validate(variant: &Variant, args: &[HostArg]) -> Result<()> {
    if args.len() != variant.args.len() {
        bail!(
            "{}: expected {} args, got {}",
            variant.name,
            variant.args.len(),
            args.len()
        );
    }
    for (i, (arg, spec)) in args.iter().zip(&variant.args).enumerate() {
        if arg.len() != spec.elements() {
            bail!(
                "{} arg {i}: expected {} elements for shape {:?}, got {}",
                variant.name,
                spec.elements(),
                spec.shape,
                arg.len()
            );
        }
        if arg.dtype() != spec.dtype {
            bail!("{} arg {i}: dtype mismatch", variant.name);
        }
    }
    Ok(())
}

/// Interpret one combined launch natively (the sim backend).
fn sim_execute(variant: &Variant, args: &[HostArg]) -> Result<Vec<f32>> {
    let b = variant.batch;
    match variant.kernel.as_str() {
        "gravity" => {
            let parts = args[0].as_f32();
            let inters = args[1].as_f32();
            let eps2 = args[2].as_f32()[0];
            let p_slot = parts.len() / b;
            let i_slot = inters.len() / b;
            let mut out = Vec::with_capacity(b * (p_slot / PARTICLE_W) * OUT_W);
            for s in 0..b {
                out.extend(cpu_gravity(
                    &parts[s * p_slot..(s + 1) * p_slot],
                    &inters[s * i_slot..(s + 1) * i_slot],
                    eps2,
                ));
            }
            Ok(out)
        }
        "gravity_gather" => {
            let pool = args[0].as_f32();
            let idx = args[1].as_i32();
            let inters = args[2].as_f32();
            let eps2 = args[3].as_f32()[0];
            let rows = pool.len() / PARTICLE_W;
            let p_slot = idx.len() / b; // particles per slot
            let i_slot = inters.len() / b;
            let mut parts = vec![0.0f32; p_slot * PARTICLE_W];
            let mut out =
                Vec::with_capacity(b * p_slot * OUT_W);
            for s in 0..b {
                for (j, &row) in idx[s * p_slot..(s + 1) * p_slot]
                    .iter()
                    .enumerate()
                {
                    let row = row as usize;
                    anyhow::ensure!(
                        row < rows,
                        "{}: gather index {row} out of pool ({rows} rows)",
                        variant.name
                    );
                    parts[j * PARTICLE_W..(j + 1) * PARTICLE_W]
                        .copy_from_slice(
                            &pool[row * PARTICLE_W..(row + 1) * PARTICLE_W],
                        );
                }
                out.extend(cpu_gravity(
                    &parts,
                    &inters[s * i_slot..(s + 1) * i_slot],
                    eps2,
                ));
            }
            Ok(out)
        }
        "ewald" => {
            let parts = args[0].as_f32();
            let ktab = args[1].as_f32();
            let p_slot = parts.len() / b;
            let mut out = Vec::with_capacity(b * (p_slot / PARTICLE_W) * OUT_W);
            for s in 0..b {
                out.extend(cpu_ewald(
                    &parts[s * p_slot..(s + 1) * p_slot],
                    ktab,
                ));
            }
            Ok(out)
        }
        "md_force" => {
            let pa = args[0].as_f32();
            let pb = args[1].as_f32();
            let pr = args[2].as_f32();
            let params = [pr[0], pr[1], pr[2]];
            let slot = pa.len() / b;
            let mut out = Vec::with_capacity(b * (slot / MD_W) * MD_W);
            for s in 0..b {
                out.extend(cpu_md_interact(
                    &pa[s * slot..(s + 1) * slot],
                    &pb[s * slot..(s + 1) * slot],
                    params,
                ));
            }
            Ok(out)
        }
        other => bail!("sim backend: unknown kernel family {other}"),
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    //! The real PJRT CPU client over AOT HLO-text artifacts.

    use std::collections::HashMap;

    use anyhow::Result;

    use super::super::manifest::Variant;
    use super::HostArg;

    pub struct PjrtBackend {
        client: xla::PjRtClient,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl PjrtBackend {
        pub fn new() -> Result<PjrtBackend> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e}"))?;
            Ok(PjrtBackend { client, executables: HashMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn compile(&mut self, variant: &Variant) -> Result<()> {
            if self.executables.contains_key(&variant.name) {
                return Ok(());
            }
            let proto = xla::HloModuleProto::from_text_file(&variant.path)
                .map_err(|e| {
                    anyhow::anyhow!("loading {}: {e}", variant.path.display())
                })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| {
                anyhow::anyhow!("compiling {}: {e}", variant.name)
            })?;
            self.executables.insert(variant.name.clone(), exe);
            Ok(())
        }

        pub fn execute(
            &mut self,
            variant: &Variant,
            args: &[HostArg],
        ) -> Result<Vec<f32>> {
            self.compile(variant)?;
            let name = &variant.name;
            // Single-copy literal creation (perf: `vec1(..).reshape(..)`
            // copies the payload twice; this path once -- see
            // EXPERIMENTS.md section Perf).
            let literals = args
                .iter()
                .zip(&variant.args)
                .map(|(arg, spec)| {
                    let (ty, bytes): (xla::ElementType, &[u8]) = match arg {
                        HostArg::F32(data) => {
                            (xla::ElementType::F32, bytes_of(data))
                        }
                        HostArg::I32(data) => {
                            (xla::ElementType::S32, bytes_of(data))
                        }
                    };
                    xla::Literal::create_from_shape_and_untyped_data(
                        ty,
                        &spec.shape,
                        bytes,
                    )
                    .map_err(|e| anyhow::anyhow!("literal {name}: {e}"))
                })
                .collect::<Result<Vec<_>>>()?;

            let exe = self.executables.get(name.as_str()).unwrap();
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("to_literal {name}: {e}"))?
                .to_tuple1()
                .map_err(|e| anyhow::anyhow!("to_tuple1 {name}: {e}"))?;
            out.to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("to_vec {name}: {e}"))
        }
    }

    /// Reinterpret a typed slice as raw bytes (for literal creation).
    fn bytes_of<T: Copy>(data: &[T]) -> &[u8] {
        // SAFETY: T is a plain Copy scalar (f32/i32); size and alignment
        // of the byte view are trivially valid.
        unsafe {
            std::slice::from_raw_parts(
                data.as_ptr() as *const u8,
                std::mem::size_of_val(data),
            )
        }
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.platform())
            .field("variants", &self.manifest.variants().len())
            .field("compiled", &self.compiled.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::shapes::{INTERACTIONS, INTER_W, PARTS_PER_BUCKET};

    fn sim_engine() -> Engine {
        let m = Manifest::synthetic(Path::new("/tmp/none"));
        Engine::with_manifest(m, false).unwrap()
    }

    #[test]
    fn sim_gravity_matches_native_kernel() {
        let mut e = sim_engine();
        let b = 2;
        let mut parts = vec![0.0f32; b * PARTS_PER_BUCKET * PARTICLE_W];
        let mut inters = vec![0.0f32; b * INTERACTIONS * INTER_W];
        parts[3] = 1.0; // slot 0 particle 0: mass 1 at origin
        inters[0] = 2.0; // slot 0 interaction 0: mass 3 at (2,0,0)
        inters[3] = 3.0;
        let out = e
            .execute(
                "gravity_B2",
                &[
                    HostArg::F32(&parts),
                    HostArg::F32(&inters),
                    HostArg::F32(&[0.01]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), b * PARTS_PER_BUCKET * OUT_W);
        let native = cpu_gravity(
            &parts[..PARTS_PER_BUCKET * PARTICLE_W],
            &inters[..INTERACTIONS * INTER_W],
            0.01,
        );
        assert_eq!(&out[..PARTS_PER_BUCKET * OUT_W], &native[..]);
        // slot 1 is all padding: zero output
        assert!(out[PARTS_PER_BUCKET * OUT_W..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn sim_rejects_shape_mismatch() {
        let mut e = sim_engine();
        let r = e.execute("gravity_B1", &[HostArg::F32(&[0.0])]);
        assert!(r.is_err());
    }

    #[test]
    fn sim_gather_rejects_out_of_pool_index() {
        let mut e = sim_engine();
        let pool = vec![0.0f32; 1024 * PARTICLE_W];
        let idx = vec![5000i32; 16 * PARTS_PER_BUCKET];
        let inters = vec![0.0f32; 16 * INTERACTIONS * INTER_W];
        let r = e.execute(
            "gravity_gather_B16_S1024",
            &[
                HostArg::F32(&pool),
                HostArg::I32(&idx),
                HostArg::F32(&inters),
                HostArg::F32(&[0.01]),
            ],
        );
        assert!(r.is_err());
    }

    #[test]
    fn compiled_count_tracks_prepared_variants() {
        let mut e = sim_engine();
        assert_eq!(e.compiled_count(), 0);
        e.ensure_compiled("ewald_B1").unwrap();
        e.ensure_compiled("ewald_B1").unwrap();
        assert_eq!(e.compiled_count(), 1);
        assert!(e.ensure_compiled("nope").is_err());
    }
}
