//! The GPU service: owns the engine and executes combined kernels.
//!
//! In G-Charm the runtime transfers data to the GPU, invokes kernels,
//! monitors completion, and invokes callbacks (paper section 2.2). Here a
//! *GPU service* owns the engine; processing elements submit `LaunchSpec`s
//! over a channel and receive `Completion`s back. A synchronous `Executor`
//! is also exposed for examples, tests, and the figure benches.
//!
//! The payload surface is open: a launch carries a [`Payload::Tile`] (or
//! [`Payload::TileGather`] on the reuse path) referencing the registered
//! [`TileKernel`] that describes its shapes, constants, resources, and
//! native implementation. No layer below this point matches on a kernel
//! family; everything is table-driven off the kernel descriptor.
//!
//! Launch hot path (see `runtime::staging` and PERF.md):
//!
//! - padded argument buffers come from a reusable `StagingArena` instead of
//!   per-chunk allocation + zero-fill; constant args are owned by the
//!   kernel descriptor and shared; variant selection is memoized per
//!   `(kernel, n, pool)`;
//! - split launches run a two-stage pipeline: chunk *k+1* is padded by a
//!   stager thread while chunk *k* executes;
//! - `GpuService` splits staging and execution onto two threads, so the
//!   next queued `LaunchSpec` is staged while the engine is busy with the
//!   current one.

use std::collections::HashSet;
use std::path::Path;
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::device_sim::{
    CoalescingClass, DeviceModel, KernelResources, ModeledCost,
};
use super::kernel::TileKernel;
use super::manifest::Manifest;
use super::pjrt::{Engine, HostArg};
use super::staging::{ArenaArg, ArenaStats, StagedChunk, StagingArena};
use super::workqueue::LaunchMode;

/// Staged-chunk queue depth: double buffering, bounded so the stager can
/// run at most this far ahead of the engine.
const PIPELINE_DEPTH: usize = 2;

/// Host payload of one combined kernel launch.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Contiguous combined launch: one batch-major buffer per registered
    /// tile argument, in registration order.
    Tile {
        /// The registered kernel family this launch belongs to.
        kernel: Arc<TileKernel>,
        /// `bufs[i]` holds `batch` slots of `kernel.args[i]`.
        bufs: Vec<Vec<f32>>,
        batch: usize,
    },
    /// Reuse-path launch through the family's gather variant: the
    /// reusable tile stays resident in `pool` (shared with the chare
    /// table's host mirror, so a launch does not copy the whole device
    /// pool) and is addressed per slot by `idx`.
    TileGather {
        kernel: Arc<TileKernel>,
        /// Device-pool mirror, `rows x width` of the reuse arg.
        pool: Arc<Vec<f32>>,
        /// Gather rows: `batch * kernel.args[reuse_arg].rows` indices.
        idx: Vec<i32>,
        /// The remaining tile args (registration order, reuse arg
        /// omitted), batch-major.
        bufs: Vec<Vec<f32>>,
        batch: usize,
    },
}

impl Payload {
    pub fn batch(&self) -> usize {
        match self {
            Payload::Tile { batch, .. } | Payload::TileGather { batch, .. } => {
                *batch
            }
        }
    }

    /// The registered kernel family.
    pub fn kernel(&self) -> &Arc<TileKernel> {
        match self {
            Payload::Tile { kernel, .. }
            | Payload::TileGather { kernel, .. } => kernel,
        }
    }

    /// Manifest family name this launch selects variants from (the gather
    /// family on the reuse path).
    pub fn kernel_name(&self) -> &str {
        match self {
            Payload::Tile { kernel, .. } => &kernel.name,
            Payload::TileGather { kernel, .. } => kernel
                .gather_name
                .as_deref()
                .expect("gather payload for a family without one"),
        }
    }

    /// Kernel resource descriptor for the occupancy/cost model.
    pub fn resources(&self) -> KernelResources {
        self.kernel().resources
    }

    /// Modeled work per combined slot, for the cost model.
    pub fn interactions_per_block(&self) -> u64 {
        self.kernel().items_per_slot
    }

    /// Output floats per combined slot.
    pub fn out_slot_len(&self) -> usize {
        self.kernel().out_slot_len()
    }
}

/// One combined launch submitted to the GPU service.
#[derive(Debug, Clone)]
pub struct LaunchSpec {
    /// Correlation id chosen by the submitter.
    pub id: u64,
    pub payload: Payload,
    /// Bytes that must cross the (modeled) PCIe bus for this launch --
    /// the coordinator has already subtracted reused-resident bytes.
    pub transfer_bytes: u64,
    /// Access-pattern class for the coalescing cost model.
    pub pattern: CoalescingClass,
    /// Requested launch mode (ISSUE 8). `Persistent` batches are drained
    /// by the family's resident loop in the modeled cost (one-time
    /// residency launch, then queue-poll instead of launch overhead); a
    /// backend that cannot keep a resident kernel falls back to
    /// `PerBatch` and the `Completion` reports the effective mode.
    pub mode: LaunchMode,
}

/// Result of a combined launch.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    /// Index of the (simulated) device that executed the launch; 0 for the
    /// synchronous `Executor` and single-service setups. `DevicePool`
    /// routes completions from all devices onto one channel, so consumers
    /// correlate by this tag.
    pub device: usize,
    /// Output rows for the *unpadded* batch, row-major
    /// (batch x rows_per_slot x out_w).
    pub out: Vec<f32>,
    pub batch: usize,
    /// Measured wall-clock seconds of the engine execute call(s).
    pub wall: f64,
    /// Modeled-K20 cost (DESIGN.md section 2).
    pub modeled: ModeledCost,
    /// *Effective* launch mode: the requested `LaunchSpec::mode`, demoted
    /// to `PerBatch` when the backend cannot keep a resident kernel.
    pub mode: LaunchMode,
}

/// Synchronous executor: stage through the arena, select variant, run,
/// slice. Split launches pipeline staging against execution.
pub struct Executor {
    engine: Engine,
    /// Own copy of the manifest so staging can borrow it while the engine
    /// is mutably borrowed by an execute call on another pipeline stage.
    manifest: Manifest,
    model: DeviceModel,
    arena: StagingArena,
    launches: u64,
    /// Families whose persistent loop is already resident (modeled): the
    /// one-time residency launch is charged on first persistent use.
    resident: HashSet<Arc<str>>,
}

impl Executor {
    /// Build a synchronous executor over `artifacts` serving the given
    /// registered kernel families.
    pub fn new(
        artifacts: &Path,
        kernels: Vec<Arc<TileKernel>>,
    ) -> Result<Executor> {
        let (manifest, real) = Manifest::for_kernels(artifacts, &kernels)?;
        let engine = Engine::with_manifest(manifest.clone(), real, &kernels)?;
        Ok(Executor {
            engine,
            manifest,
            model: DeviceModel::kepler_k20(),
            arena: StagingArena::new(),
            launches: 0,
            resident: HashSet::new(),
        })
    }

    pub fn model(&self) -> &DeviceModel {
        &self.model
    }

    pub fn launches(&self) -> u64 {
        self.launches
    }

    /// Staging-arena counters (reuse, padding, variant-memo hits).
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena.stats()
    }

    pub fn platform(&self) -> String {
        self.engine.platform()
    }

    /// Execute one combined launch synchronously.
    pub fn run(&mut self, spec: LaunchSpec) -> Result<Completion> {
        let batch = spec.payload.batch();
        anyhow::ensure!(batch > 0, "empty launch");
        let kernel = spec.payload.kernel_name();
        let max_batch = self
            .manifest
            .max_batch(kernel)
            .with_context(|| format!("no variants for kernel {kernel}"))?;
        let out_slot = spec.payload.out_slot_len();
        let mode = if spec.mode == LaunchMode::Persistent
            && self.engine.persistent_capable()
        {
            LaunchMode::Persistent
        } else {
            LaunchMode::PerBatch
        };

        let (out, wall, mut modeled_kernel) = if batch <= max_batch {
            self.run_single(&spec, batch, out_slot, mode)?
        } else {
            self.run_pipelined(&spec, batch, max_batch, out_slot, mode)?
        };
        if mode == LaunchMode::Persistent
            && self.resident.insert(spec.payload.kernel().name.clone())
        {
            // first persistent batch of this family: the loop launches
            modeled_kernel += self.model.residency_cost();
        }

        let modeled = ModeledCost {
            transfer: self.model.transfer_time(spec.transfer_bytes),
            kernel: modeled_kernel,
        };
        Ok(Completion {
            id: spec.id,
            device: 0,
            out,
            batch,
            wall,
            modeled,
            mode,
        })
    }

    /// Unsplit launch: stage and execute inline (no pipeline threads).
    fn run_single(
        &mut self,
        spec: &LaunchSpec,
        batch: usize,
        out_slot: usize,
        mode: LaunchMode,
    ) -> Result<(Vec<f32>, f64, f64)> {
        let staged = self.arena.stage_chunk(
            &self.manifest,
            &spec.payload,
            0,
            batch,
            &mut None,
        )?;
        let args: Vec<HostArg> =
            staged.args.iter().map(ArenaArg::as_host_arg).collect();
        let t0 = Instant::now();
        let mut out = self.engine.execute(&staged.name, &args)?;
        let wall = t0.elapsed().as_secs_f64();
        drop(args);
        self.launches += 1;
        self.arena.recycle(staged);

        // Keep the engine's own buffer; just drop the padded tail.
        out.truncate(batch * out_slot);
        let modeled_kernel = match mode {
            LaunchMode::PerBatch => self.model.kernel_time(
                &spec.payload.resources(),
                batch as u64,
                spec.payload.interactions_per_block(),
                spec.pattern,
            ),
            LaunchMode::Persistent => self.model.kernel_time_persistent(
                &spec.payload.resources(),
                batch as u64,
                spec.payload.interactions_per_block(),
                spec.pattern,
            ),
        };
        Ok((out, wall, modeled_kernel))
    }

    /// Split launch: a scoped stager thread pads chunk k+1 (and recycles
    /// executed buffers) while the engine executes chunk k.
    ///
    /// The stager thread is spawned per split launch. That lifecycle cost
    /// (~tens of us) is paid only when a launch exceeds `max_batch` and
    /// is dwarfed by the multi-chunk execute time it overlaps; sustained
    /// launch streams should go through `GpuService`, whose stager thread
    /// is persistent.
    fn run_pipelined(
        &mut self,
        spec: &LaunchSpec,
        batch: usize,
        max_batch: usize,
        out_slot: usize,
        mode: LaunchMode,
    ) -> Result<(Vec<f32>, f64, f64)> {
        let Executor { engine, manifest, model, arena, launches, .. } = self;
        let manifest: &Manifest = manifest;
        let payload = &spec.payload;
        let resources = payload.resources();
        let ipb = payload.interactions_per_block();
        let pattern = spec.pattern;

        let mut out = Vec::with_capacity(batch * out_slot);
        let mut wall = 0.0f64;
        let mut modeled_kernel = 0.0f64;

        std::thread::scope(|s| -> Result<()> {
            // The receiving/sending ends this body owns are dropped on
            // every exit path (including `?` on a failed execute), which
            // unblocks the stager before the scope joins it.
            let (staged_tx, staged_rx) =
                sync_channel::<Result<StagedChunk>>(PIPELINE_DEPTH);
            let (ret_tx, ret_rx) = channel::<StagedChunk>();
            s.spawn(move || {
                let mut pool_cache = None;
                let mut start = 0usize;
                while start < batch {
                    let n = (batch - start).min(max_batch);
                    while let Ok(used) = ret_rx.try_recv() {
                        arena.recycle(used);
                    }
                    let staged = arena.stage_chunk(
                        manifest,
                        payload,
                        start,
                        n,
                        &mut pool_cache,
                    );
                    let failed = staged.is_err();
                    if staged_tx.send(staged).is_err() || failed {
                        break;
                    }
                    start += n;
                }
                // Keep recycling executed chunks so their buffers are
                // pooled for the next launch.
                while let Ok(used) = ret_rx.recv() {
                    arena.recycle(used);
                }
            });

            let mut start = 0usize;
            while start < batch {
                let n = (batch - start).min(max_batch);
                let staged = staged_rx.recv().map_err(|_| {
                    anyhow::anyhow!("staging pipeline closed early")
                })??;
                debug_assert_eq!(staged.n, n);
                let args: Vec<HostArg> =
                    staged.args.iter().map(ArenaArg::as_host_arg).collect();
                let t0 = Instant::now();
                let full = engine.execute(&staged.name, &args)?;
                wall += t0.elapsed().as_secs_f64();
                drop(args);
                *launches += 1;
                out.extend_from_slice(&full[..n * out_slot]);
                modeled_kernel += match mode {
                    LaunchMode::PerBatch => {
                        model.kernel_time(&resources, n as u64, ipb, pattern)
                    }
                    LaunchMode::Persistent => model.kernel_time_persistent(
                        &resources, n as u64, ipb, pattern,
                    ),
                };
                let _ = ret_tx.send(staged);
                start += n;
            }
            drop(ret_tx); // ends the stager's recycle drain
            Ok(())
        })?;
        Ok((out, wall, modeled_kernel))
    }
}

/// Per-launch constants a staged chunk carries to the engine thread.
#[derive(Debug, Clone)]
struct LaunchMeta {
    id: u64,
    batch: usize,
    transfer_bytes: u64,
    pattern: CoalescingClass,
    resources: KernelResources,
    interactions_per_block: u64,
    out_slot: usize,
    /// Registered family name (residency is per family, not per variant).
    family: Arc<str>,
    /// Requested launch mode; the engine thread demotes it if the
    /// backend cannot keep a resident kernel.
    mode: LaunchMode,
}

impl LaunchMeta {
    fn of(spec: &LaunchSpec) -> LaunchMeta {
        LaunchMeta {
            id: spec.id,
            batch: spec.payload.batch(),
            transfer_bytes: spec.transfer_bytes,
            pattern: spec.pattern,
            resources: spec.payload.resources(),
            interactions_per_block: spec.payload.interactions_per_block(),
            out_slot: spec.payload.out_slot_len(),
            family: spec.payload.kernel().name.clone(),
            mode: spec.mode,
        }
    }
}

/// Stager -> engine-thread messages.
enum ChunkMsg {
    Chunk { meta: LaunchMeta, staged: StagedChunk, last: bool },
    Abort { id: u64, error: anyhow::Error },
    /// New registered families for the engine's dispatch table (the
    /// stager has already extended its manifest).
    AddKernels(Vec<Arc<TileKernel>>),
}

/// Submitter -> stager messages.
enum ServiceMsg {
    Launch(LaunchSpec),
    /// The shared registry grew: make the new families servable before
    /// any launch of theirs arrives (FIFO on this channel guarantees the
    /// ordering).
    AddKernels(Vec<Arc<TileKernel>>),
}

/// Handle to the pipelined GPU service: a stager thread padding launches
/// through the arena, feeding an engine thread over a bounded queue.
pub struct GpuService {
    tx: Sender<ServiceMsg>,
    stager: Option<JoinHandle<()>>,
    engine: Option<JoinHandle<Result<()>>>,
}

impl GpuService {
    /// Spawn the service threads for device 0. Completions (and errors)
    /// are delivered to `done` in submission order.
    pub fn spawn(
        artifacts: &Path,
        kernels: Vec<Arc<TileKernel>>,
        done: Sender<Result<Completion>>,
    ) -> Result<GpuService> {
        GpuService::spawn_on(artifacts, kernels, 0, done)
    }

    /// Spawn the service threads for simulated device `device`; every
    /// `Completion` this service emits carries that tag. Each service owns
    /// its own stager+engine thread pair and staging arena, so a pool of
    /// services shares nothing but the completion channel.
    pub fn spawn_on(
        artifacts: &Path,
        kernels: Vec<Arc<TileKernel>>,
        device: usize,
        done: Sender<Result<Completion>>,
    ) -> Result<GpuService> {
        let (manifest, real) = Manifest::for_kernels(artifacts, &kernels)?;

        let (tx, rx) = channel::<ServiceMsg>();
        let (chunk_tx, chunk_rx) = sync_channel::<ChunkMsg>(PIPELINE_DEPTH);
        let (ret_tx, ret_rx) = channel::<StagedChunk>();

        let stage_manifest = manifest.clone();
        let stager = std::thread::Builder::new()
            .name(format!("gpu-stager-{device}"))
            .spawn(move || {
                stager_loop(stage_manifest, rx, chunk_tx, ret_rx)
            })?;
        let engine = std::thread::Builder::new()
            .name(format!("gpu-service-{device}"))
            .spawn(move || {
                engine_loop(manifest, real, kernels, device, chunk_rx, ret_tx, done)
            })?;
        Ok(GpuService { tx, stager: Some(stager), engine: Some(engine) })
    }

    /// Submit a launch; completion arrives on the `done` channel.
    pub fn submit(&self, spec: LaunchSpec) -> Result<()> {
        self.tx
            .send(ServiceMsg::Launch(spec))
            .map_err(|_| anyhow::anyhow!("gpu service is down"))
    }

    /// Teach the live service new kernel families (append-only registry
    /// growth). Queued ahead of any launch of those families, so by the
    /// time such a launch reaches the stager/engine both can serve it.
    pub fn add_kernels(&self, kernels: Vec<Arc<TileKernel>>) -> Result<()> {
        self.tx
            .send(ServiceMsg::AddKernels(kernels))
            .map_err(|_| anyhow::anyhow!("gpu service is down"))
    }
}

impl Drop for GpuService {
    fn drop(&mut self) {
        // Closing the sender ends the stager, which closes the chunk
        // queue, which ends the engine thread.
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(h) = self.stager.take() {
            let _ = h.join();
        }
        if let Some(h) = self.engine.take() {
            let _ = h.join();
        }
    }
}

/// Stager thread: pads queued launches chunk by chunk while the engine
/// thread executes earlier ones; recycles executed buffers.
fn stager_loop(
    mut manifest: Manifest,
    rx: Receiver<ServiceMsg>,
    chunk_tx: SyncSender<ChunkMsg>,
    ret_rx: Receiver<StagedChunk>,
) {
    let mut arena = StagingArena::new();
    'specs: while let Ok(msg) = rx.recv() {
        let spec = match msg {
            ServiceMsg::Launch(spec) => spec,
            ServiceMsg::AddKernels(kernels) => {
                for k in &kernels {
                    manifest.ensure_family(k);
                }
                if chunk_tx.send(ChunkMsg::AddKernels(kernels)).is_err() {
                    break 'specs;
                }
                continue 'specs;
            }
        };
        let meta = LaunchMeta::of(&spec);
        let abort = |e: anyhow::Error| ChunkMsg::Abort { id: meta.id, error: e };
        if meta.batch == 0 {
            if chunk_tx.send(abort(anyhow::anyhow!("empty launch"))).is_err() {
                break 'specs;
            }
            continue 'specs;
        }
        let kernel = spec.payload.kernel_name();
        let Some(max_batch) = manifest.max_batch(kernel) else {
            let e = anyhow::anyhow!("no variants for kernel {kernel}");
            if chunk_tx.send(abort(e)).is_err() {
                break 'specs;
            }
            continue 'specs;
        };
        let mut pool_cache = None;
        let mut start = 0usize;
        while start < meta.batch {
            let n = (meta.batch - start).min(max_batch);
            while let Ok(used) = ret_rx.try_recv() {
                arena.recycle(used);
            }
            match arena.stage_chunk(
                &manifest,
                &spec.payload,
                start,
                n,
                &mut pool_cache,
            ) {
                Ok(staged) => {
                    let last = start + n >= meta.batch;
                    let msg =
                        ChunkMsg::Chunk { meta: meta.clone(), staged, last };
                    if chunk_tx.send(msg).is_err() {
                        break 'specs;
                    }
                }
                Err(e) => {
                    if chunk_tx.send(abort(e)).is_err() {
                        break 'specs;
                    }
                    continue 'specs;
                }
            }
            start += n;
        }
    }
}

/// Engine thread: executes staged chunks, assembles per-launch outputs and
/// wall/modeled accounting, and emits completions.
fn engine_loop(
    manifest: Manifest,
    artifacts_on_disk: bool,
    kernels: Vec<Arc<TileKernel>>,
    device: usize,
    chunk_rx: Receiver<ChunkMsg>,
    ret_tx: Sender<StagedChunk>,
    done: Sender<Result<Completion>>,
) -> Result<()> {
    struct InFlight {
        meta: LaunchMeta,
        out: Vec<f32>,
        wall: f64,
        modeled_kernel: f64,
        /// Effective mode (requested, demoted if the backend can't).
        mode: LaunchMode,
    }

    let mut engine =
        Engine::with_manifest(manifest, artifacts_on_disk, &kernels)?;
    let model = DeviceModel::kepler_k20();
    // Families whose persistent loop is already resident on this device.
    let mut resident: HashSet<Arc<str>> = HashSet::new();
    let mut cur: Option<InFlight> = None;
    // Launch whose remaining chunks are dropped after a failed execute.
    let mut skip: Option<u64> = None;

    while let Ok(msg) = chunk_rx.recv() {
        match msg {
            ChunkMsg::Chunk { meta, staged, last } => {
                if skip == Some(meta.id) {
                    let _ = ret_tx.send(staged);
                    if last {
                        skip = None;
                    }
                    continue;
                }
                // A chunk of a new launch: any stale skip (its launch was
                // abandoned by the stager) is over.
                skip = None;
                if cur.is_none() {
                    let mode = if meta.mode == LaunchMode::Persistent
                        && engine.persistent_capable()
                    {
                        LaunchMode::Persistent
                    } else {
                        LaunchMode::PerBatch
                    };
                    let mut modeled_kernel = 0.0;
                    if mode == LaunchMode::Persistent
                        && resident.insert(meta.family.clone())
                    {
                        // first persistent batch of this family here:
                        // charge the one-time residency launch
                        modeled_kernel += model.residency_cost();
                    }
                    cur = Some(InFlight {
                        out: Vec::with_capacity(meta.batch * meta.out_slot),
                        meta: meta.clone(),
                        wall: 0.0,
                        modeled_kernel,
                        mode,
                    });
                }
                let args: Vec<HostArg> =
                    staged.args.iter().map(ArenaArg::as_host_arg).collect();
                let t0 = Instant::now();
                let res = engine.execute(&staged.name, &args);
                let dt = t0.elapsed().as_secs_f64();
                drop(args);
                let n = staged.n;
                let _ = ret_tx.send(staged);
                match res {
                    Ok(full) => {
                        let st = cur.as_mut().expect("in-flight launch");
                        debug_assert_eq!(st.meta.id, meta.id);
                        st.wall += dt;
                        st.out.extend_from_slice(&full[..n * meta.out_slot]);
                        st.modeled_kernel += match st.mode {
                            LaunchMode::PerBatch => model.kernel_time(
                                &meta.resources,
                                n as u64,
                                meta.interactions_per_block,
                                meta.pattern,
                            ),
                            LaunchMode::Persistent => model
                                .kernel_time_persistent(
                                    &meta.resources,
                                    n as u64,
                                    meta.interactions_per_block,
                                    meta.pattern,
                                ),
                        };
                        if last {
                            let st = cur.take().expect("in-flight launch");
                            let completion = Completion {
                                id: st.meta.id,
                                device,
                                out: st.out,
                                batch: st.meta.batch,
                                wall: st.wall,
                                modeled: ModeledCost {
                                    transfer: model
                                        .transfer_time(st.meta.transfer_bytes),
                                    kernel: st.modeled_kernel,
                                },
                                mode: st.mode,
                            };
                            if done.send(Ok(completion)).is_err() {
                                break; // coordinator went away
                            }
                        }
                    }
                    Err(e) => {
                        cur = None;
                        if !last {
                            skip = Some(meta.id);
                        }
                        if done.send(Err(e)).is_err() {
                            break;
                        }
                    }
                }
            }
            ChunkMsg::AddKernels(kernels) => {
                engine.add_kernels(&kernels);
            }
            ChunkMsg::Abort { id, error } => {
                if skip == Some(id) {
                    // This launch already reported an execute error; the
                    // stager abandoning it is not a second failure.
                    skip = None;
                    continue;
                }
                if cur.as_ref().map(|c| c.meta.id) == Some(id) {
                    cur = None;
                }
                if done.send(Err(error)).is_err() {
                    break;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::shapes::{
        INTERACTIONS, INTER_W, MD_W, PARTICLE_W, PARTS_PER_BUCKET,
        PARTS_PER_PATCH,
    };

    fn gravity() -> Arc<TileKernel> {
        Arc::new(TileKernel::gravity(0.01))
    }

    #[test]
    fn payload_accessors() {
        let p = Payload::Tile {
            kernel: gravity(),
            bufs: vec![vec![], vec![]],
            batch: 7,
        };
        assert_eq!(p.batch(), 7);
        assert_eq!(p.kernel_name(), "gravity");
        assert_eq!(p.interactions_per_block(), (16 * 128) as u64);
        assert_eq!(p.out_slot_len(), PARTS_PER_BUCKET * 4);
        let g = Payload::TileGather {
            kernel: gravity(),
            pool: Arc::new(vec![]),
            idx: vec![],
            bufs: vec![vec![]],
            batch: 3,
        };
        assert_eq!(g.kernel_name(), "gravity_gather");
        let m = Payload::Tile {
            kernel: Arc::new(TileKernel::md_force([1.0, 0.04, 1.0])),
            bufs: vec![vec![], vec![]],
            batch: 3,
        };
        assert_eq!(m.kernel_name(), "md_force");
        assert_eq!(m.out_slot_len(), PARTS_PER_PATCH * MD_W);
    }

    #[test]
    fn validate_kernels_rejects_drifted_constant() {
        let m = Manifest::synthetic(Path::new("/tmp/none"));
        // the synthetic ewald constant is KTABLE x KTAB_W = 256 floats
        let bad = Arc::new(TileKernel::ewald(vec![0.0; 3]));
        assert!(m.validate_kernels(&[bad]).is_err());
        let good = Arc::new(TileKernel::ewald(vec![0.0; 256]));
        assert!(m.validate_kernels(&[good, gravity()]).is_ok());
    }

    #[test]
    fn split_launch_reuses_arena_buffers() {
        let mut ex = Executor::new(
            Path::new("/tmp/gcharm-missing-artifacts"),
            vec![gravity()],
        )
        .unwrap();
        let batch = 300; // > max gravity batch (128): 128 + 128 + 44
        let spec = |id| LaunchSpec {
            id,
            payload: Payload::Tile {
                kernel: gravity(),
                bufs: vec![
                    vec![0.0; batch * PARTS_PER_BUCKET * PARTICLE_W],
                    vec![0.0; batch * INTERACTIONS * INTER_W],
                ],
                batch,
            },
            transfer_bytes: 0,
            pattern: CoalescingClass::Contiguous,
            mode: LaunchMode::PerBatch,
        };
        let c = ex.run(spec(1)).unwrap();
        assert_eq!(c.batch, batch);
        assert_eq!(ex.launches(), 3);

        // The pool grows to the pipeline's high-water mark (at most a few
        // buffer sets per variant, regardless of launch count), then
        // every further launch is allocation-free. Warm for a few
        // launches, then assert the plateau.
        for id in 2..6 {
            let ci = ex.run(spec(id)).unwrap();
            assert_eq!(ci.out.len(), c.out.len());
        }
        let warm = ex.arena_stats();
        for id in 6..10 {
            ex.run(spec(id)).unwrap();
        }
        let steady = ex.arena_stats();
        assert_eq!(
            steady.buffer_allocs, warm.buffer_allocs,
            "steady-state launches must not allocate"
        );
        assert!(steady.buffer_reuses > warm.buffer_reuses);
        // variant selection memoized across chunks and launches:
        // only (gravity, 128) and (gravity, 44) ever hit the manifest
        assert_eq!(steady.variant_lookups, 2);
        assert!(steady.variant_hits >= 16);
    }

    #[test]
    fn persistent_mode_same_bits_cheaper_model() {
        let mut ex = Executor::new(
            Path::new("/tmp/gcharm-missing-artifacts"),
            vec![gravity()],
        )
        .unwrap();
        let batch = 8;
        let spec = |id, mode| LaunchSpec {
            id,
            payload: Payload::Tile {
                kernel: gravity(),
                bufs: vec![
                    vec![0.5; batch * PARTS_PER_BUCKET * PARTICLE_W],
                    vec![0.5; batch * INTERACTIONS * INTER_W],
                ],
                batch,
            },
            transfer_bytes: 1024,
            pattern: CoalescingClass::Contiguous,
            mode,
        };
        let pb = ex.run(spec(1, LaunchMode::PerBatch)).unwrap();
        assert_eq!(pb.mode, LaunchMode::PerBatch);
        // first persistent launch pays residency on top of the cheaper
        // per-batch poll; the outputs are bit-identical either way
        let p1 = ex.run(spec(2, LaunchMode::Persistent)).unwrap();
        assert_eq!(p1.mode, LaunchMode::Persistent);
        assert_eq!(p1.out, pb.out, "mode must never change bits");
        let p2 = ex.run(spec(3, LaunchMode::Persistent)).unwrap();
        let m = ex.model();
        let saved = m.spec.launch_overhead - m.spec.queue_poll_cost;
        assert!(
            (pb.modeled.kernel - p2.modeled.kernel - saved).abs() < 1e-12,
            "steady persistent batch saves the overhead delta"
        );
        assert!(
            (p1.modeled.kernel - p2.modeled.kernel - m.residency_cost())
                .abs()
                < 1e-12,
            "residency charged exactly once"
        );
    }
}
