//! The GPU service: owns the PJRT engine and executes combined kernels.
//!
//! In G-Charm the runtime transfers data to the GPU, invokes kernels,
//! monitors completion, and invokes callbacks (paper section 2.2). Here a
//! dedicated *GPU service thread* owns the `Engine`; processing elements
//! submit `LaunchSpec`s over a channel and receive `Completion`s back.
//! A synchronous `Executor` is also exposed for examples, tests, and the
//! figure benches.
//!
//! Responsibilities:
//!   - select the smallest AOT variant that fits a combined launch and
//!     zero/inert-pad the payload to its static shape,
//!   - split launches that exceed the largest compiled batch,
//!   - measure wall-clock execution and compute the modeled-K20 cost
//!     (transfer + kernel) for the figure benches.

use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::device_sim::{
    CoalescingClass, DeviceModel, KernelResources, ModeledCost,
};
use super::pjrt::{Engine, HostArg};
use super::shapes::{
    INTERACTIONS, INTER_W, KTABLE, KTAB_W, MD_PAD_POS, MD_W, OUT_W,
    PARTICLE_W, PARTS_PER_BUCKET, PARTS_PER_PATCH,
};

/// Physics constants baked per run (not per launch).
#[derive(Debug, Clone)]
pub struct ExecutorConfig {
    /// Plummer softening squared for gravity kernels.
    pub eps2: f32,
    /// Ewald k-table, KTABLE x 4 row-major [kx, ky, kz, coef].
    pub ktab: Vec<f32>,
    /// MD LJ parameters [cutoff^2, sigma^2, epsilon].
    pub md_params: [f32; 3],
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig {
            eps2: 1e-2,
            ktab: vec![0.0; KTABLE * KTAB_W],
            md_params: [1.0, 0.04, 1.0],
        }
    }
}

/// Host payload of one combined kernel launch.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Contiguous bucket gravity: parts (n,P,4), inters (n,I,4).
    Gravity { parts: Vec<f32>, inters: Vec<f32>, batch: usize },
    /// Reuse-path gravity: pool (rows,4), idx (n,P), inters (n,I,4).
    /// The pool is shared (Arc) with the chare table's host mirror so a
    /// launch does not copy the whole device pool (EXPERIMENTS.md Perf).
    GravityGather {
        pool: std::sync::Arc<Vec<f32>>,
        idx: Vec<i32>,
        inters: Vec<f32>,
        batch: usize,
    },
    /// Ewald correction: parts (n,P,4).
    Ewald { parts: Vec<f32>, batch: usize },
    /// MD patch pairs: pa (n,N,2), pb (n,N,2).
    MdForce { pa: Vec<f32>, pb: Vec<f32>, batch: usize },
}

impl Payload {
    pub fn batch(&self) -> usize {
        match self {
            Payload::Gravity { batch, .. }
            | Payload::GravityGather { batch, .. }
            | Payload::Ewald { batch, .. }
            | Payload::MdForce { batch, .. } => *batch,
        }
    }

    pub fn kernel_name(&self) -> &'static str {
        match self {
            Payload::Gravity { .. } => "gravity",
            Payload::GravityGather { .. } => "gravity_gather",
            Payload::Ewald { .. } => "ewald",
            Payload::MdForce { .. } => "md_force",
        }
    }

    /// Kernel resource descriptor for the occupancy/cost model.
    pub fn resources(&self) -> KernelResources {
        match self {
            Payload::Gravity { .. } | Payload::GravityGather { .. } => {
                KernelResources::force_kernel()
            }
            Payload::Ewald { .. } => KernelResources::ewald_kernel(),
            Payload::MdForce { .. } => KernelResources::md_kernel(),
        }
    }

    /// Particle-interactions per combined slot, for the cost model.
    pub fn interactions_per_block(&self) -> u64 {
        match self {
            Payload::Gravity { .. } | Payload::GravityGather { .. } => {
                (PARTS_PER_BUCKET * INTERACTIONS) as u64
            }
            Payload::Ewald { .. } => (PARTS_PER_BUCKET * KTABLE) as u64,
            Payload::MdForce { .. } => {
                (PARTS_PER_PATCH * PARTS_PER_PATCH) as u64
            }
        }
    }

    fn out_row_w(&self) -> usize {
        match self {
            Payload::MdForce { .. } => MD_W,
            _ => OUT_W,
        }
    }

    fn out_rows_per_slot(&self) -> usize {
        match self {
            Payload::MdForce { .. } => PARTS_PER_PATCH,
            _ => PARTS_PER_BUCKET,
        }
    }
}

/// One combined launch submitted to the GPU service.
#[derive(Debug, Clone)]
pub struct LaunchSpec {
    /// Correlation id chosen by the submitter.
    pub id: u64,
    pub payload: Payload,
    /// Bytes that must cross the (modeled) PCIe bus for this launch --
    /// the coordinator has already subtracted reused-resident bytes.
    pub transfer_bytes: u64,
    /// Access-pattern class for the coalescing cost model.
    pub pattern: CoalescingClass,
}

/// Result of a combined launch.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    /// Output rows for the *unpadded* batch, row-major
    /// (batch x rows_per_slot x out_w).
    pub out: Vec<f32>,
    pub batch: usize,
    /// Measured wall-clock seconds of the PJRT execute call(s).
    pub wall: f64,
    /// Modeled-K20 cost (DESIGN.md section 2).
    pub modeled: ModeledCost,
}

/// Synchronous executor: pad, select variant, run, slice.
pub struct Executor {
    engine: Engine,
    model: DeviceModel,
    config: ExecutorConfig,
    launches: u64,
}

impl Executor {
    pub fn new(artifacts: &Path, config: ExecutorConfig) -> Result<Executor> {
        let engine = Engine::load(artifacts)?;
        // Fail fast if the Python-side tile constants drifted.
        let v = engine
            .manifest()
            .select("gravity", 1, 0)
            .context("no gravity variants in manifest")?;
        anyhow::ensure!(
            v.args[0].shape[1] == PARTS_PER_BUCKET
                && v.args[1].shape[1] == INTERACTIONS,
            "artifact shapes {:?} disagree with runtime::shapes",
            v.args[0].shape
        );
        anyhow::ensure!(
            config.ktab.len() == KTABLE * KTAB_W,
            "ktab must be {} floats",
            KTABLE * KTAB_W
        );
        Ok(Executor {
            engine,
            model: DeviceModel::kepler_k20(),
            config,
            launches: 0,
        })
    }

    pub fn model(&self) -> &DeviceModel {
        &self.model
    }

    pub fn config(&self) -> &ExecutorConfig {
        &self.config
    }

    pub fn launches(&self) -> u64 {
        self.launches
    }

    /// Execute one combined launch synchronously.
    pub fn run(&mut self, spec: LaunchSpec) -> Result<Completion> {
        let batch = spec.payload.batch();
        anyhow::ensure!(batch > 0, "empty launch");
        let kernel = spec.payload.kernel_name();
        let max_batch = self
            .engine
            .manifest()
            .max_batch(kernel)
            .with_context(|| format!("no variants for kernel {kernel}"))?;

        let out_slot = spec.payload.out_rows_per_slot() * spec.payload.out_row_w();
        let mut out = Vec::with_capacity(batch * out_slot);
        let mut wall = 0.0;
        let mut modeled_kernel = 0.0;

        let mut start = 0;
        while start < batch {
            let n = (batch - start).min(max_batch);
            let (name, args_owned) = self.pad_chunk(&spec.payload, start, n)?;
            let args: Vec<HostArg> = args_owned.iter().map(OwnedArg::borrow).collect();
            let t0 = Instant::now();
            let full = self.engine.execute(&name, &args)?;
            wall += t0.elapsed().as_secs_f64();
            self.launches += 1;
            out.extend_from_slice(&full[..n * out_slot]);

            modeled_kernel += self.model.kernel_time(
                &spec.payload.resources(),
                n as u64,
                spec.payload.interactions_per_block(),
                spec.pattern,
            );
            start += n;
        }

        let modeled = ModeledCost {
            transfer: self.model.transfer_time(spec.transfer_bytes),
            kernel: modeled_kernel,
        };
        Ok(Completion { id: spec.id, out, batch, wall, modeled })
    }

    /// Build padded argument buffers for slots [start, start+n).
    fn pad_chunk(
        &self,
        payload: &Payload,
        start: usize,
        n: usize,
    ) -> Result<(String, Vec<OwnedArg>)> {
        let manifest = self.engine.manifest();
        match payload {
            Payload::Gravity { parts, inters, .. } => {
                let v = manifest.select("gravity", n, 0).unwrap();
                let b = v.batch;
                let mut p = vec![0.0f32; b * PARTS_PER_BUCKET * PARTICLE_W];
                let mut i = vec![0.0f32; b * INTERACTIONS * INTER_W];
                copy_slots(&mut p, parts, start, n, PARTS_PER_BUCKET * PARTICLE_W);
                copy_slots(&mut i, inters, start, n, INTERACTIONS * INTER_W);
                Ok((
                    v.name.clone(),
                    vec![
                        OwnedArg::F32(p),
                        OwnedArg::F32(i),
                        OwnedArg::F32(vec![self.config.eps2]),
                    ],
                ))
            }
            Payload::GravityGather { pool, idx, inters, .. } => {
                let rows = pool.len() / PARTICLE_W;
                let v = manifest
                    .select("gravity_gather", n, rows)
                    .context("no gather variant fits pool")?;
                anyhow::ensure!(
                    v.pool >= rows,
                    "pool of {rows} rows exceeds largest gather variant ({})",
                    v.pool
                );
                let b = v.batch;
                // zero-copy when the mirror exactly matches the variant
                let pool_arg = if rows == v.pool {
                    OwnedArg::SharedF32(pool.clone())
                } else {
                    let mut pl = vec![0.0f32; v.pool * PARTICLE_W];
                    pl[..pool.len()].copy_from_slice(pool);
                    OwnedArg::F32(pl)
                };
                let mut ix = vec![0i32; b * PARTS_PER_BUCKET];
                copy_slots(&mut ix, idx, start, n, PARTS_PER_BUCKET);
                let mut it = vec![0.0f32; b * INTERACTIONS * INTER_W];
                copy_slots(&mut it, inters, start, n, INTERACTIONS * INTER_W);
                Ok((
                    v.name.clone(),
                    vec![
                        pool_arg,
                        OwnedArg::I32(ix),
                        OwnedArg::F32(it),
                        OwnedArg::F32(vec![self.config.eps2]),
                    ],
                ))
            }
            Payload::Ewald { parts, .. } => {
                let v = manifest.select("ewald", n, 0).unwrap();
                let b = v.batch;
                let mut p = vec![0.0f32; b * PARTS_PER_BUCKET * PARTICLE_W];
                copy_slots(&mut p, parts, start, n, PARTS_PER_BUCKET * PARTICLE_W);
                Ok((
                    v.name.clone(),
                    vec![OwnedArg::F32(p), OwnedArg::F32(self.config.ktab.clone())],
                ))
            }
            Payload::MdForce { pa, pb, .. } => {
                let v = manifest.select("md_force", n, 0).unwrap();
                let b = v.batch;
                let slot = PARTS_PER_PATCH * MD_W;
                let mut a = vec![MD_PAD_POS; b * slot];
                let mut bb = vec![MD_PAD_POS; b * slot];
                copy_slots(&mut a, pa, start, n, slot);
                copy_slots(&mut bb, pb, start, n, slot);
                Ok((
                    v.name.clone(),
                    vec![
                        OwnedArg::F32(a),
                        OwnedArg::F32(bb),
                        OwnedArg::F32(self.config.md_params.to_vec()),
                    ],
                ))
            }
        }
    }
}

/// Owned argument buffer (borrowed as HostArg at execute time).
enum OwnedArg {
    F32(Vec<f32>),
    SharedF32(std::sync::Arc<Vec<f32>>),
    I32(Vec<i32>),
}

impl OwnedArg {
    fn borrow(&self) -> HostArg<'_> {
        match self {
            OwnedArg::F32(v) => HostArg::F32(v),
            OwnedArg::SharedF32(v) => HostArg::F32(v),
            OwnedArg::I32(v) => HostArg::I32(v),
        }
    }
}

fn copy_slots<T: Copy>(
    dst: &mut [T],
    src: &[T],
    start_slot: usize,
    n_slots: usize,
    slot_len: usize,
) {
    let src_off = start_slot * slot_len;
    dst[..n_slots * slot_len]
        .copy_from_slice(&src[src_off..src_off + n_slots * slot_len]);
}

/// Handle to the GPU service thread.
pub struct GpuService {
    tx: Sender<LaunchSpec>,
    handle: Option<JoinHandle<Result<()>>>,
}

impl GpuService {
    /// Spawn the service thread. Completions (and errors) are delivered to
    /// `done`.
    pub fn spawn(
        artifacts: &Path,
        config: ExecutorConfig,
        done: Sender<Result<Completion>>,
    ) -> Result<GpuService> {
        let (tx, rx): (Sender<LaunchSpec>, Receiver<LaunchSpec>) = channel();
        let artifacts = artifacts.to_path_buf();
        let handle = std::thread::Builder::new()
            .name("gpu-service".into())
            .spawn(move || -> Result<()> {
                let mut exec = Executor::new(&artifacts, config)?;
                while let Ok(spec) = rx.recv() {
                    let res = exec.run(spec);
                    if done.send(res).is_err() {
                        break; // coordinator went away
                    }
                }
                Ok(())
            })?;
        Ok(GpuService { tx, handle: Some(handle) })
    }

    /// Submit a launch; completion arrives on the `done` channel.
    pub fn submit(&self, spec: LaunchSpec) -> Result<()> {
        self.tx
            .send(spec)
            .map_err(|_| anyhow::anyhow!("gpu service is down"))
    }
}

impl Drop for GpuService {
    fn drop(&mut self) {
        // Closing the sender ends the service loop.
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_slots_copies_window() {
        let src: Vec<i32> = (0..12).collect();
        let mut dst = vec![0i32; 8];
        copy_slots(&mut dst, &src, 1, 2, 3); // slots 1..3 of width 3
        assert_eq!(&dst[..6], &[3, 4, 5, 6, 7, 8]);
        assert_eq!(&dst[6..], &[0, 0]);
    }

    #[test]
    fn payload_accessors() {
        let p = Payload::Gravity { parts: vec![], inters: vec![], batch: 7 };
        assert_eq!(p.batch(), 7);
        assert_eq!(p.kernel_name(), "gravity");
        assert_eq!(p.interactions_per_block(), (16 * 128) as u64);
        let m = Payload::MdForce { pa: vec![], pb: vec![], batch: 3 };
        assert_eq!(m.kernel_name(), "md_force");
        assert_eq!(m.out_row_w(), MD_W);
        assert_eq!(m.out_rows_per_slot(), PARTS_PER_PATCH);
    }
}
