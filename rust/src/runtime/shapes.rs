//! Canonical kernel tile shapes shared between the Python AOT pipeline and
//! the rust runtime.
//!
//! These constants mirror `python/compile/kernels/*.py`
//! (PARTS_PER_BUCKET / INTERACTIONS / KTABLE / PARTS_PER_PATCH). The
//! built-in kernel descriptors (`runtime::kernel`) are shaped from them,
//! and every registered family is validated against
//! `artifacts/manifest.json` at engine startup, so a drifting Python
//! constant fails fast instead of producing shape errors mid-run.

/// Particles per bucket (P). Matches the paper's 16-row CUDA block.
pub const PARTS_PER_BUCKET: usize = 16;

/// Interaction-list slots per bucket (I); padding entries carry mass 0.
pub const INTERACTIONS: usize = 128;

/// Ewald k-vector table rows (K); padding entries carry coef 0.
pub const KTABLE: usize = 64;

/// Particle slots per MD patch (N); padding parked at `MD_PAD_POS`.
pub const PARTS_PER_PATCH: usize = 64;

/// Where padding particles are parked (outside any cutoff).
pub const MD_PAD_POS: f32 = 1.0e8;

/// Row widths.
pub const PARTICLE_W: usize = 4; // [x, y, z, mass]
pub const INTER_W: usize = 4; // [x, y, z, mass]
pub const KTAB_W: usize = 4; // [kx, ky, kz, coef]
pub const MD_W: usize = 2; // [x, y]
pub const OUT_W: usize = 4; // [ax, ay, az, pot]

/// Bytes of one bucket particle buffer (a chare-table slot's payload).
pub const BUCKET_BYTES: u64 = (PARTS_PER_BUCKET * PARTICLE_W * 4) as u64;

/// Bytes of one bucket interaction list.
pub const INTER_BYTES: u64 = (INTERACTIONS * INTER_W * 4) as u64;

/// Bytes of one MD patch buffer.
pub const PATCH_BYTES: u64 = (PARTS_PER_PATCH * MD_W * 4) as u64;
