//! Seeded chaos schedules: a pure function from a `u64` seed to a full
//! fault plan for one multi-tenant run.
//!
//! Everything the harness does — runtime shape, job mix, which driver is
//! cancelled or panics and when, which injections fire and at which
//! per-job round — is decided HERE, from the seed alone, before the
//! runtime exists. The harness merely executes the plan, so any failure
//! replays bit-identically from its seed (`gcharm chaos --seed N`).
//!
//! Two properties the generator maintains by construction:
//!
//! - **Corpus coverage**: `seed % 8` picks the emphasized fault theme
//!   (cancel / driver panic / steal storm / live registration / cache
//!   pressure / launch-flip / node-fault / overload), so any contiguous
//!   block of 16 seeds exercises every class twice.
//! - **Reachable anchors**: every injection and cancel is anchored to a
//!   `(job, round)` pair with `round <= effective_rounds(job)` — the
//!   round counter is guaranteed to get there no matter what else the
//!   schedule does, so a schedule can never deadlock its own harness.

use crate::util::Rng;

/// How a cancelled driver is arranged to be holding the runtime when the
/// cancel lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelKind {
    /// Driver idles at quiescence (all rounds drained) when cancelled.
    AtQuiescence,
    /// Driver has a full burst in flight, un-awaited, when cancelled.
    MidFlight,
    /// Driver is blocked inside `await_reduction` with nothing coming:
    /// only the cancel can wake it. The invariant under test is that no
    /// blocked `await_reduction` survives a cancel.
    Blocked,
}

/// The fault a job's driver is scripted to suffer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Runs all its rounds and seals `Done`.
    None,
    /// Cancelled by the harness once `round` rounds completed.
    Cancel { round: u64, kind: CancelKind },
    /// Driver panics after `round` rounds (seals `Failed` via the drop
    /// guard; the runtime must survive).
    Panic { round: u64 },
}

/// One kernel family shared by one or more jobs. Jobs sharing a family
/// must register byte-identical descriptors, so the spec lives outside
/// the per-job plan.
#[derive(Debug, Clone, PartialEq)]
pub struct FamilySpec {
    pub name: String,
    /// Tile rows (width is always 1; the slot kernel sums the tile).
    pub rows: usize,
    /// Register a reuse arg + gather variant: requests carry buffer ids
    /// and stage through the chare tables (exercises residency).
    pub reuse: bool,
    /// `Some(n)`: static combining every `n` requests (the residual-debt
    /// path); `None`: the runtime's adaptive policy.
    pub static_period: Option<usize>,
    /// Give the family a CPU fallback so the hybrid split applies.
    pub cpu_fallback: bool,
    /// Pin the family's descriptor to persistent-kernel launches (the
    /// launch-flip theme starts from a persistent baseline so ring
    /// jitter and forced mode flips have a resident loop to perturb).
    pub persistent: bool,
}

/// One tenant job of the schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct JobPlan {
    pub name: String,
    /// Index into [`Schedule::families`].
    pub family: usize,
    /// Requests per chare per round.
    pub count: usize,
    /// Rounds a fault-free driver runs.
    pub rounds: u64,
    /// Chares (each on `chare_index % pes`).
    pub chares: usize,
    /// Distinct reuse-buffer ids each chare cycles through (reuse
    /// families only).
    pub nbuf: usize,
    /// Per-job tile fill value. Distinct fills make the physics
    /// per-tenant: a launch that mixed another job's tiles into this
    /// job's reduction shifts the exact integer sum and is caught.
    pub fill: f32,
    pub fault: Fault,
}

impl JobPlan {
    /// Rounds the driver completes before its scripted fault (equals
    /// `rounds` for a fault-free job). The per-job round counter always
    /// reaches this value, which is what makes anchors reachable.
    pub fn effective_rounds(&self) -> u64 {
        match self.fault {
            Fault::None => self.rounds,
            Fault::Cancel { round, .. } | Fault::Panic { round } => round,
        }
    }

    /// Exact value of one round's reduction for this job. All arithmetic
    /// is small-integer-valued in f32/f64, so equality is exact; any
    /// cross-tenant tile mixing breaks it.
    pub fn round_value(&self, fam: &FamilySpec) -> f64 {
        let per_chare: f64 = (0..self.count)
            .map(|i| {
                let v = if fam.reuse {
                    self.fill + (i % self.nbuf) as f32
                } else {
                    self.fill
                };
                fam.rows as f64 * v as f64
            })
            .sum();
        self.chares as f64 * per_chare
    }
}

/// A scripted perturbation of the live runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Injection {
    /// Collapse the steal watermarks so every coordinator poll sees a
    /// steal candidate (forced `steal_flush` + migration storm). Stays
    /// on for the rest of the run; quiescence must still be reached.
    StealStorm,
    /// `shots` single-shot forced flushes of every combiner (flush-timing
    /// jitter; capped leftovers must drain through the regular path).
    FlushJitter { shots: usize },
    /// Submit an extra job with a brand-new kernel family to the live
    /// runtime (late registration racing active traffic).
    LateRegistration,
    /// Submit a job whose spec re-registers an existing family with an
    /// incompatible shape: must be rejected, and must leave the runtime
    /// (including the job-id pool) exactly as it was.
    RejectedSubmit,
    /// Jitter every persistent work ring to `queue_cap` slots and flip
    /// the forced launch mode (Persistent on the first flip, PerBatch on
    /// the next, alternating): backpressure fallback, quiesce of
    /// still-nonempty rings, and mode-partition accounting under mid-job
    /// flips.
    LaunchModeFlip { queue_cap: usize },
}

/// An injection anchored to a per-job round counter: it fires when job
/// `job`'s driver has completed `round` rounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Anchored {
    pub job: usize,
    pub round: u64,
    pub inj: Injection,
}

/// The node-fault theme's cluster plan: the schedule's single job runs
/// SPMD on a 2-node loopback cluster whose links misbehave (mirroring
/// [`crate::net::loopback::LinkFault`]), and the peer may leave early.
/// The chaos is in the links, not the tenancy — the local job plan
/// stays fault-free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClusterPlan {
    /// Cluster size (loopback fabric endpoints).
    pub nodes: usize,
    /// Hold every frame behind this many later sends per link.
    pub delay: usize,
    /// Swap adjacent frames per link.
    pub reorder: bool,
    /// Drop every n-th heartbeat (0 = off); dropped bytes are returned
    /// by the fabric and balanced in the byte-conservation clause.
    pub drop_nth_heartbeat: usize,
    /// `Some(r)`: node 1's driver stops contributing after `r` of the
    /// job's rounds and leaves gracefully — later rounds total
    /// root-only, deterministically (contributions are FIFO before the
    /// goodbye).
    pub peer_down_round: Option<u64>,
}

/// The overload theme's serving plan: the harness stands a
/// `serve::ServeFront` (policy `Shed`, a deliberately tiny pool) in
/// front of the runtime and slams it with a saturating burst of
/// best-effort offers while the schedule's single healthy
/// latency-class tenant runs. The invariants under test are the
/// admission ledger (`offered == admitted + rejected + shed`, front-end
/// and pool-level copies both) and the latency co-tenant's exact
/// reduction physics under the burst.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OverloadPlan {
    /// Best-effort jobs offered in one saturating burst.
    pub burst: usize,
    /// Active-job cap for the best-effort class (1 keeps the door
    /// tight: at most one burst job runs at a time, the rest shed).
    pub best_effort_depth: usize,
    /// Pool-wide active cap (2: the latency tenant plus one burst job).
    pub pool_depth: usize,
    /// Rounds each admitted burst job runs.
    pub burst_rounds: u64,
}

/// Everything one chaos run does, derived purely from the seed.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    pub seed: u64,
    pub devices: usize,
    pub pes: usize,
    pub families: Vec<FamilySpec>,
    pub jobs: Vec<JobPlan>,
    /// `Some(n)`: shrink every device's chare table to `n` slots (the
    /// cache-pressure theme); `None`: the runtime default.
    pub table_slots: Option<usize>,
    /// Fired in order; every anchor is reachable by construction.
    pub injections: Vec<Anchored>,
    /// `Some`: the node-fault theme's distributed run; `None` keeps the
    /// run single-process.
    pub cluster: Option<ClusterPlan>,
    /// `Some`: the overload theme's admission-control plan.
    pub overload: Option<OverloadPlan>,
}

/// Fault themes, cycled by `seed % THEMES`.
pub const THEMES: usize = 8;

/// Human name of a seed's theme (trace + docs).
pub fn theme_name(seed: u64) -> &'static str {
    match seed % THEMES as u64 {
        0 => "cancel",
        1 => "driver-panic",
        2 => "steal-storm",
        3 => "live-registration",
        4 => "cache-pressure",
        5 => "launch-flip",
        6 => "node-fault",
        _ => "overload",
    }
}

impl Schedule {
    /// The pure generator. Same seed, same schedule, always.
    pub fn from_seed(seed: u64) -> Schedule {
        let mut rng = Rng::new(seed ^ 0xC4A0_5EED);
        let theme = (seed % THEMES as u64) as usize;
        // The steal-storm theme needs a sharded pool to have anything to
        // steal between; cache pressure wants one device so the scan and
        // the hot set fight over the same tiny table; node-fault keeps
        // each node at one device — the rebalancing under test is
        // cross-node, not cross-device; overload pins one device so the
        // burst genuinely saturates the pool.
        let devices = match theme {
            2 => 2,
            4 | 6 | 7 => 1,
            _ => 1 + rng.below(2),
        };
        let pes = 1 + rng.below(3);
        // Node-fault runs ONE SPMD job across the cluster: the fault
        // surface is the links and the departing peer, so co-tenant
        // faults would only blur attribution. Overload likewise plans
        // one healthy latency tenant — the burst jobs come from the
        // OverloadPlan, through the admission door, not from here.
        let njobs =
            if matches!(theme, 6 | 7) { 1 } else { 2 + rng.below(2) };
        // Cache-pressure theme: a chare table far smaller than the scan
        // job's footprint, so residency decisions actually evict.
        let table_slots = (theme == 4).then(|| 6 + rng.below(6));

        // Family mix: either one family shared by every job (cross-job
        // combining under fault) or one private family per job. Cache
        // pressure forces a single shared reuse family: both tenants must
        // contend for the SAME table for the namespacing claim to mean
        // anything.
        let shared = theme == 4 || rng.below(2) == 0;
        let nfam = if shared { 1 } else { njobs };
        let families: Vec<FamilySpec> = (0..nfam)
            .map(|f| FamilySpec {
                name: format!("chaos_{seed}_{f}"),
                rows: 2 + rng.below(7),
                reuse: theme == 4 || rng.below(2) == 0,
                static_period: if rng.below(3) == 0 {
                    Some(2 + rng.below(6))
                } else {
                    None
                },
                cpu_fallback: rng.below(2) == 0,
                persistent: theme == 5,
            })
            .collect();

        let mut jobs: Vec<JobPlan> = (0..njobs)
            .map(|j| JobPlan {
                name: format!("job{j}"),
                family: if shared { 0 } else { j },
                count: 40 + rng.below(120),
                rounds: 2 + rng.below(4) as u64,
                chares: 1 + rng.below(3),
                nbuf: 4 + rng.below(5),
                fill: (1 + rng.below(4)) as f32,
                fault: Fault::None,
            })
            .collect();

        // Cache-pressure theme: job 0 keeps a hot set that fits the tiny
        // table; every other tenant becomes an adversarial streaming scan
        // (each buffer referenced once per round, footprint >> table) that
        // under blind LRU would flush the hot set on every pass.
        if theme == 4 {
            jobs[0].nbuf = 3;
            for j in 1..njobs {
                jobs[j].nbuf = jobs[j].count;
            }
        }

        // Job 0 always stays healthy: a co-tenant whose exact physics
        // must survive whatever happens to its neighbours.
        for j in 1..njobs {
            let rounds = jobs[j].rounds;
            jobs[j].fault = match theme {
                0 => Fault::Cancel {
                    round: 1 + rng.below(rounds as usize - 1) as u64,
                    kind: match rng.below(3) {
                        0 => CancelKind::AtQuiescence,
                        1 => CancelKind::MidFlight,
                        _ => CancelKind::Blocked,
                    },
                },
                1 => Fault::Panic {
                    round: 1 + rng.below(rounds as usize - 1) as u64,
                },
                _ => Fault::None,
            };
        }

        let mut injections = Vec::new();
        let anchor = |rng: &mut Rng, jobs: &[JobPlan], inj: Injection| {
            let job = rng.below(jobs.len());
            let round =
                1 + rng.below(jobs[job].effective_rounds() as usize) as u64;
            Anchored { job, round, inj }
        };
        match theme {
            2 => injections
                .push(Anchored { job: 0, round: 1, inj: Injection::StealStorm }),
            3 => {
                injections.push(Anchored {
                    job: 0,
                    round: 1,
                    inj: Injection::LateRegistration,
                });
                injections.push(anchor(
                    &mut rng,
                    &jobs,
                    Injection::RejectedSubmit,
                ));
            }
            5 => {
                // Two flips so the forced mode alternates Persistent ->
                // PerBatch while rings may still hold descriptors; a tiny
                // ring makes backpressure fallback actually fire.
                for _ in 0..2 {
                    let queue_cap = 1 + rng.below(4);
                    injections.push(anchor(
                        &mut rng,
                        &jobs,
                        Injection::LaunchModeFlip { queue_cap },
                    ));
                }
            }
            _ => {
                if devices == 2 && rng.below(2) == 0 {
                    injections.push(anchor(&mut rng, &jobs, Injection::StealStorm));
                }
            }
        }
        // Flush-timing jitter rides along on every second schedule —
        // except node-fault, whose per-node runtimes take no injections
        // (the links are the fault surface), and overload, whose only
        // fault surface is the admission door.
        if !matches!(theme, 6 | 7) && rng.below(2) == 0 {
            let shots = 1 + rng.below(3);
            injections.push(anchor(
                &mut rng,
                &jobs,
                Injection::FlushJitter { shots },
            ));
        }

        let cluster = (theme == 6).then(|| {
            let rounds = jobs[0].rounds;
            ClusterPlan {
                nodes: 2,
                delay: [0, 1, 2][rng.below(3)],
                reorder: rng.below(2) == 0,
                drop_nth_heartbeat: [0, 3][rng.below(2)],
                peer_down_round: (rng.below(2) == 0)
                    .then(|| 1 + rng.below(rounds as usize - 1) as u64),
            }
        });

        let overload = (theme == 7).then(|| OverloadPlan {
            burst: 5 + rng.below(8),
            best_effort_depth: 1,
            pool_depth: 2,
            burst_rounds: 1 + rng.below(2) as u64,
        });

        Schedule {
            seed,
            devices,
            pes,
            families,
            jobs,
            table_slots,
            injections,
            cluster,
            overload,
        }
    }

    /// The schedule's own trace header lines (pure; part of the replay-
    /// identical event trace).
    pub fn describe(&self) -> Vec<String> {
        let mut out = vec![format!(
            "schedule seed={} theme={} devices={} pes={} jobs={} \
             table_slots={}",
            self.seed,
            theme_name(self.seed),
            self.devices,
            self.pes,
            self.jobs.len(),
            self.table_slots
                .map_or("default".into(), |n| n.to_string())
        )];
        for (f, fam) in self.families.iter().enumerate() {
            out.push(format!(
                "family {f} {}: rows={} reuse={} static={:?} cpu_fallback={} \
                 persistent={}",
                fam.name, fam.rows, fam.reuse, fam.static_period,
                fam.cpu_fallback, fam.persistent
            ));
        }
        for (j, job) in self.jobs.iter().enumerate() {
            out.push(format!(
                "plan job{j} fam={} count={} rounds={} chares={} fill={} \
                 fault={:?}",
                job.family, job.count, job.rounds, job.chares, job.fill,
                job.fault
            ));
        }
        for a in &self.injections {
            out.push(format!(
                "plan inject {:?} @ job{} round {}",
                a.inj, a.job, a.round
            ));
        }
        if let Some(c) = &self.cluster {
            out.push(format!(
                "plan cluster nodes={} delay={} reorder={} \
                 drop_nth_heartbeat={} peer_down_round={:?}",
                c.nodes, c.delay, c.reorder, c.drop_nth_heartbeat,
                c.peer_down_round
            ));
        }
        if let Some(o) = &self.overload {
            out.push(format!(
                "plan overload burst={} best_effort_depth={} \
                 pool_depth={} burst_rounds={}",
                o.burst, o.best_effort_depth, o.pool_depth, o.burst_rounds
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        for seed in 0..16u64 {
            assert_eq!(Schedule::from_seed(seed), Schedule::from_seed(seed));
        }
    }

    #[test]
    fn contiguous_corpus_covers_every_theme_twice() {
        let mut seen = [0usize; THEMES];
        for seed in 0..(2 * THEMES as u64) {
            seen[(seed % THEMES as u64) as usize] += 1;
        }
        assert_eq!(seen, [2; THEMES]);
    }

    #[test]
    fn cache_pressure_schedules_starve_the_table() {
        let mut checked = 0;
        for seed in 0..30u64 {
            let s = Schedule::from_seed(seed);
            if seed % THEMES as u64 != 4 {
                assert_eq!(s.table_slots, None, "seed {seed}");
                continue;
            }
            checked += 1;
            let slots = s.table_slots.expect("cache pressure shrinks the table");
            assert_eq!(s.devices, 1, "seed {seed}: one device, one table");
            assert_eq!(s.families.len(), 1, "seed {seed}: shared family");
            assert!(s.families[0].reuse, "seed {seed}: scan needs residency");
            // Hot set fits; every scanning co-tenant overflows the table
            // by itself and stays fault-free (the theme is pressure, not
            // faults).
            assert!(s.jobs[0].nbuf < slots, "seed {seed}");
            for j in &s.jobs[1..] {
                assert!(j.nbuf > slots, "seed {seed}: scan fits the table");
                assert_eq!(j.nbuf, j.count, "seed {seed}: one ref per pass");
                assert_eq!(j.fault, Fault::None, "seed {seed}");
            }
        }
        // seeds = 4 mod THEMES within 0..30: {4, 12, 20, 28}
        assert!(checked >= 4, "corpus sweep missed the theme: {checked}");
    }

    #[test]
    fn node_fault_schedules_run_one_clean_job_on_two_nodes() {
        let mut checked = 0;
        for seed in 0..32u64 {
            let s = Schedule::from_seed(seed);
            if seed % THEMES as u64 != 6 {
                assert_eq!(s.cluster, None, "seed {seed}: cluster off-theme");
                continue;
            }
            checked += 1;
            let c = s.cluster.expect("node-fault plans a cluster");
            assert_eq!(c.nodes, 2, "seed {seed}");
            assert_eq!(s.devices, 1, "seed {seed}: one device per node");
            assert_eq!(s.jobs.len(), 1, "seed {seed}: one SPMD job");
            assert_eq!(s.jobs[0].fault, Fault::None, "seed {seed}");
            assert!(
                s.injections.is_empty(),
                "seed {seed}: links are the only fault surface"
            );
            if let Some(r) = c.peer_down_round {
                assert!(
                    r >= 1 && r < s.jobs[0].rounds,
                    "seed {seed}: peer-down anchor {r} must leave the root \
                     rounds to finish alone"
                );
            }
        }
        // seeds = 6 mod THEMES within 0..32: {6, 14, 22, 30}
        assert!(checked >= 4, "corpus sweep missed the theme: {checked}");
    }

    #[test]
    fn overload_schedules_plan_a_tight_door() {
        let mut checked = 0;
        for seed in 0..32u64 {
            let s = Schedule::from_seed(seed);
            if seed % THEMES as u64 != 7 {
                assert_eq!(s.overload, None, "seed {seed}: overload off-theme");
                continue;
            }
            checked += 1;
            let o = s.overload.expect("overload plans a burst");
            assert_eq!(s.devices, 1, "seed {seed}: saturate one device");
            assert_eq!(s.jobs.len(), 1, "seed {seed}: one latency tenant");
            assert_eq!(s.jobs[0].fault, Fault::None, "seed {seed}");
            assert!(
                s.injections.is_empty(),
                "seed {seed}: the admission door is the only fault surface"
            );
            // The burst must oversubscribe the door so sheds actually
            // happen, and the pool must still have room for the latency
            // tenant plus at least one burst job.
            assert!(o.burst > o.pool_depth, "seed {seed}");
            assert_eq!(o.best_effort_depth, 1, "seed {seed}");
            assert_eq!(o.pool_depth, 2, "seed {seed}");
            assert!(o.burst_rounds >= 1, "seed {seed}");
        }
        // seeds = 7 mod THEMES within 0..32: {7, 15, 23, 31}
        assert!(checked >= 4, "corpus sweep missed the theme: {checked}");
    }

    #[test]
    fn launch_flip_schedules_pin_persistent_and_flip_twice() {
        let mut checked = 0;
        for seed in 0..30u64 {
            let s = Schedule::from_seed(seed);
            let flips: Vec<_> = s
                .injections
                .iter()
                .filter(|a| {
                    matches!(a.inj, Injection::LaunchModeFlip { .. })
                })
                .collect();
            if seed % THEMES as u64 != 5 {
                assert!(flips.is_empty(), "seed {seed}: flip off-theme");
                assert!(
                    s.families.iter().all(|f| !f.persistent),
                    "seed {seed}"
                );
                continue;
            }
            checked += 1;
            assert!(
                s.families.iter().all(|f| f.persistent),
                "seed {seed}: launch-flip starts from a persistent pin"
            );
            assert_eq!(flips.len(), 2, "seed {seed}: two flips alternate");
            for a in &flips {
                let Injection::LaunchModeFlip { queue_cap } = a.inj else {
                    unreachable!()
                };
                assert!(
                    (1..=4).contains(&queue_cap),
                    "seed {seed}: tiny ring caps only"
                );
            }
        }
        assert!(checked >= 4, "corpus sweep missed the theme: {checked}");
    }

    #[test]
    fn anchors_are_always_reachable() {
        for seed in 0..64u64 {
            let s = Schedule::from_seed(seed);
            for a in &s.injections {
                assert!(a.job < s.jobs.len(), "seed {seed}");
                assert!(
                    a.round >= 1
                        && a.round <= s.jobs[a.job].effective_rounds(),
                    "seed {seed}: anchor {a:?} beyond effective rounds"
                );
                if a.inj == Injection::StealStorm {
                    assert!(s.devices >= 2, "seed {seed}: storm needs a pool");
                }
            }
            for j in &s.jobs {
                match j.fault {
                    Fault::None => {}
                    Fault::Cancel { round, .. } | Fault::Panic { round } => {
                        assert!(round >= 1 && round < j.rounds, "seed {seed}");
                    }
                }
                assert!(j.family < s.families.len(), "seed {seed}");
            }
            assert_eq!(s.jobs[0].fault, Fault::None, "seed {seed}: job0 healthy");
        }
    }

    #[test]
    fn round_values_are_exact_integers() {
        for seed in 0..32u64 {
            let s = Schedule::from_seed(seed);
            for j in &s.jobs {
                let v = j.round_value(&s.families[j.family]);
                assert_eq!(v, v.round(), "seed {seed}: non-integer physics");
                assert!(v > 0.0);
            }
        }
    }
}
