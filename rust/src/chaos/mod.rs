//! Deterministic chaos harness for the multi-tenant [`Runtime`].
//!
//! `run_schedule(seed)` derives a full fault plan from the seed alone
//! ([`schedule::Schedule::from_seed`]), executes it against a real
//! runtime — scripted job cancels at chosen quiescence depths, panicking
//! drivers, steal storms, flush-timing jitter, late kernel registration,
//! rejected submissions racing live traffic, launch-mode flips that
//! jitter the persistent work rings mid-job, and node faults that run
//! the job SPMD on a two-node loopback fabric with delayed / reordered
//! / dropped frames and a graceful mid-run peer departure, and
//! saturating best-effort bursts thrown at a `serve::ServeFront` with a
//! deliberately tiny pool (the overload theme, `seed % 8 == 7`) — and
//! checks the cross-cutting invariants at every step:
//!
//! - each healthy job's reduction series equals its exact integer
//!   physics (distinct per-job tile fills: a launch that mixed another
//!   tenant's tiles shifts the sum);
//! - a cancelled job seals `Cancelled` with no blocked
//!   `await_reduction` surviving; a panicking driver seals `Failed`
//!   without taking the runtime down;
//! - no sealed job's residency keys stay resident on any device
//!   ([`Runtime::chaos_resident_jobs`]);
//! - shutdown terminates, and the sealed pool report passes the
//!   accounting sums in [`invariants::accounting_violations`];
//! - a node-fault run's root reduction series equals the exact degraded
//!   cluster physics, and the per-node reports balance the cross-node
//!   steal/request/byte conservation ledger
//!   ([`invariants::cluster_violations`], exact mode);
//! - an overload run's admission ledger closes exactly
//!   (`offered == admitted + rejected + shed`, both the front end's own
//!   counters and the pool-level copy), and the latency co-tenant's
//!   reduction series stays exact under the burst.
//!
//! The event trace is a pure function of the seed (schedule lines plus
//! deterministic outcomes), so `gcharm chaos --seed N` replays a failing
//! corpus entry bit-identically. Compiled only under
//! `#[cfg(any(test, feature = "chaos"))]`: the release hot path carries
//! none of this.

pub mod invariants;
pub mod schedule;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{
    Chare, ChareId, CombinePolicy, Config, Ctx, JobCtx, JobHandle, JobSpec,
    JobStatus, KernelDescriptor, KernelKindId, LaunchMode, Msg, PoolReport,
    Runtime, Tile, WorkDraft, WrResult, METHOD_RESULT,
};
use crate::net::loopback::LinkFault;
use crate::net::{
    Cluster, ClusterHandle, LoopbackFabric, NetConfig, NodeId, Transport,
};
use crate::runtime::kernel::{TileArgSpec, TileKernel};
use crate::runtime::KernelResources;

pub use invariants::{accounting_violations, cluster_violations};
pub use schedule::{
    theme_name, Anchored, CancelKind, ClusterPlan, FamilySpec, Fault,
    Injection, JobPlan, OverloadPlan, Schedule,
};

const METHOD_GO: u32 = 1;
/// Chare collection id for harness chares. Deliberately identical across
/// jobs: chare ids are namespaced per job, and the physics would catch a
/// namespacing regression.
const CHARE_COLL: u32 = 7;
/// Driver-side bound on waiting for a scripted external event (a cancel
/// that the harness fires, an anchor round). Generous: hitting it means
/// the invariant under test failed, and the run reports that instead of
/// hanging the suite.
const EVENT_TIMEOUT: Duration = Duration::from_secs(60);

/// Outcome of one chaos run: the replay-identical event trace and every
/// invariant violation found (empty = the run held).
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub seed: u64,
    pub trace: Vec<String>,
    pub violations: Vec<String>,
}

impl ChaosReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

impl std::fmt::Display for ChaosReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for line in &self.trace {
            writeln!(f, "{line}")?;
        }
        if self.violations.is_empty() {
            write!(f, "seed {}: all invariants held", self.seed)
        } else {
            for v in &self.violations {
                writeln!(f, "VIOLATION: {v}")?;
            }
            write!(f, "seed {}: {} violation(s)", self.seed, self.violations.len())
        }
    }
}

/// Per-slot kernel shared by every chaos family: sum of the tile.
fn sum_slot(args: &[&[f32]], _c: &[f32]) -> Vec<f32> {
    vec![args[0].iter().sum()]
}

/// Registered descriptor for one schedule family. Jobs sharing a family
/// call this with the same spec and resolve to one kind (the cross-job
/// combining hook).
fn descriptor(fam: &FamilySpec) -> KernelDescriptor {
    KernelDescriptor {
        kernel: Arc::new(TileKernel {
            name: Arc::from(fam.name.as_str()),
            args: vec![TileArgSpec {
                name: "tile",
                rows: fam.rows,
                width: 1,
                pad: 0.0,
            }],
            constant: Arc::new(Vec::new()),
            out_rows: 1,
            out_width: 1,
            resources: KernelResources {
                threads_per_block: 128,
                regs_per_thread: 64,
                smem_per_block: 4096,
            },
            items_per_slot: fam.rows as u64,
            reuse_arg: fam.reuse.then_some(0),
            gather_name: fam
                .reuse
                .then(|| Arc::from(format!("{}_gather", fam.name))),
            entry_arg: None,
            slot_fn: sum_slot,
        }),
        combine: fam.static_period.map(CombinePolicy::StaticEvery),
        sort_by_slot: fam.reuse,
        cpu_fallback: fam.cpu_fallback,
        launch_mode: fam.persistent.then_some(LaunchMode::Persistent),
    }
}

/// Harness chare: bursts `count` requests per GO, sums the returned
/// slot outputs, contributes at zero pending. Reuse families cycle
/// `nbuf` buffer ids with id-determined tile values (repeated ids carry
/// identical data — reuse-correct), so the reduction is exact either
/// way.
struct FillBurster {
    id: ChareId,
    rows: usize,
    count: usize,
    reuse: bool,
    nbuf: usize,
    fill: f32,
    pending: usize,
    sum: f64,
}

impl Chare for FillBurster {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        match msg.method {
            METHOD_GO => {
                let kind: KernelKindId = msg.take();
                self.pending = self.count;
                self.sum = 0.0;
                for i in 0..self.count {
                    let (buffer, v) = if self.reuse {
                        let b = (i % self.nbuf) as u64;
                        (Some(b), self.fill + b as f32)
                    } else {
                        (None, self.fill)
                    };
                    ctx.submit(WorkDraft {
                        chare: self.id,
                        kind,
                        buffer,
                        data_items: self.rows,
                        tag: i as u64,
                        payload: Tile::new(vec![vec![v; self.rows]]),
                    })
                    .expect("registered tile shape");
                }
            }
            METHOD_RESULT => {
                let r: WrResult = msg.take();
                self.sum += r.out[0] as f64;
                self.pending -= 1;
                if self.pending == 0 {
                    ctx.contribute(self.sum);
                }
            }
            other => panic!("chaos chare: unknown method {other}"),
        }
    }
}

/// Spin until the harness's scripted cancel lands (bounded: a missed
/// cancel is reported as a Failed seal, not a hung suite).
fn wait_cancelled(ctx: &JobCtx) -> Result<()> {
    let deadline = Instant::now() + EVENT_TIMEOUT;
    while !ctx.cancelled() {
        if Instant::now() > deadline {
            bail!("chaos: scripted cancel never arrived");
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    Ok(())
}

/// Build the `JobSpec` for one planned job. `counter` is the per-job
/// round anchor the harness watches: it is bumped after each fully
/// drained round, so schedule anchors fire at deterministic points of
/// the job's own timeline.
fn job_spec(
    plan: &JobPlan,
    fam: &FamilySpec,
    counter: Arc<AtomicU64>,
) -> JobSpec {
    let mut spec = JobSpec::new(plan.name.clone()).kernel(descriptor(fam));
    for c in 0..plan.chares {
        let id = ChareId::new(CHARE_COLL, c as u32);
        spec = spec.chare(
            id,
            c,
            Box::new(FillBurster {
                id,
                rows: fam.rows,
                count: plan.count,
                reuse: fam.reuse,
                nbuf: plan.nbuf,
                fill: plan.fill,
                pending: 0,
                sum: 0.0,
            }),
        );
    }
    let plan = plan.clone();
    spec.driver(move |ctx| {
        let kind = ctx.kinds()[0];
        let chares = plan.chares as u64;
        let go = |ctx: &JobCtx| {
            for c in 0..plan.chares {
                ctx.send(
                    ChareId::new(CHARE_COLL, c as u32),
                    Msg::new(METHOD_GO, kind),
                );
            }
        };
        let mut series = Vec::new();
        for _ in 0..plan.effective_rounds() {
            go(ctx);
            series.push(ctx.await_reduction(chares)?);
            ctx.await_quiescence();
            counter.fetch_add(1, Ordering::SeqCst);
        }
        match plan.fault {
            Fault::None => Ok(series),
            Fault::Panic { .. } => {
                panic!("chaos: scripted driver panic")
            }
            Fault::Cancel { kind: CancelKind::AtQuiescence, .. } => {
                wait_cancelled(ctx)?;
                Err(anyhow!("chaos: cancelled at quiescence"))
            }
            Fault::Cancel { kind: CancelKind::MidFlight, .. } => {
                // a full un-awaited burst is in flight when the cancel
                // lands; the teardown must drain it
                go(ctx);
                wait_cancelled(ctx)?;
                Err(anyhow!("chaos: cancelled mid-flight"))
            }
            Fault::Cancel { kind: CancelKind::Blocked, .. } => {
                // nothing was sent: only the cancel can release this
                let got = ctx.await_reduction(1)?;
                bail!("chaos: blocked await returned {got} without a cancel")
            }
        }
    })
}

/// Build a standalone `JobSpec` for a plan without wiring a round
/// anchor: for tests that drive the runtime directly (e.g. the
/// id-recycling regression) rather than through [`run_schedule`].
pub fn job_spec_for(plan: &JobPlan, fam: &FamilySpec) -> JobSpec {
    job_spec(plan, fam, Arc::new(AtomicU64::new(0)))
}

/// One submitted job the harness is tracking.
struct Running {
    idx: usize,
    plan: JobPlan,
    fam: FamilySpec,
    counter: Arc<AtomicU64>,
    handle: Option<JobHandle>,
}

/// Execute the seed's schedule against a real runtime and check every
/// invariant. `Err` means the harness itself could not run (coordinator
/// channel down, etc.); invariant failures land in
/// [`ChaosReport::violations`] instead.
pub fn run_schedule(seed: u64) -> Result<ChaosReport> {
    let s = Schedule::from_seed(seed);
    let mut trace = s.describe();
    if let Some(c) = s.cluster {
        // Node-fault theme: the schedule's single job runs SPMD on a
        // faulted loopback fabric instead of one in-process runtime.
        return run_cluster(seed, &s, c, trace);
    }
    if let Some(o) = s.overload {
        // Overload theme: the jobs go through the serving front end's
        // admission door instead of straight into the runtime.
        return run_overload(seed, &s, o, trace);
    }
    let mut violations: Vec<String> = Vec::new();

    let mut cfg = Config {
        pes: s.pes,
        devices: s.devices,
        ..Config::default()
    };
    if let Some(slots) = s.table_slots {
        // Cache-pressure theme: a starved table makes every residency
        // decision (eviction priority, prefetch, namespacing) load-
        // bearing for job 0's exact physics.
        cfg.table_slots = slots;
    }
    let rt = Runtime::new(cfg)?;

    // Submit every planned job up front; drivers pace themselves.
    let mut jobs: Vec<Running> = Vec::new();
    for (idx, plan) in s.jobs.iter().enumerate() {
        let fam = s.families[plan.family].clone();
        let counter = Arc::new(AtomicU64::new(0));
        let handle = rt.submit_job(job_spec(plan, &fam, counter.clone()))?;
        trace.push(format!("submit job{idx} as {}", handle.job()));
        jobs.push(Running {
            idx,
            plan: plan.clone(),
            fam,
            counter,
            handle: Some(handle),
        });
    }

    let wait_round = |jobs: &[Running], j: usize, round: u64| -> Result<()> {
        let deadline = Instant::now() + EVENT_TIMEOUT;
        while jobs[j].counter.load(Ordering::SeqCst) < round {
            if Instant::now() > deadline {
                bail!("anchor job{j} round {round} never reached");
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        Ok(())
    };

    // Fire the scripted injections in schedule order. Every anchor is
    // reachable by construction (round <= effective_rounds), so this
    // loop cannot deadlock.
    for a in &s.injections {
        wait_round(&jobs, a.job, a.round)?;
        match a.inj {
            Injection::StealStorm => {
                // low above any realistic depth + high of 1: every poll
                // sees a steal candidate until the run drains
                rt.chaos_set_watermarks(1 << 20, 1)?;
                trace.push(format!(
                    "inject steal-storm @ job{} round {}",
                    a.job, a.round
                ));
            }
            Injection::FlushJitter { shots } => {
                for _ in 0..shots {
                    rt.chaos_flush_jitter()?;
                }
                trace.push(format!(
                    "inject flush-jitter x{shots} @ job{} round {}",
                    a.job, a.round
                ));
            }
            Injection::LateRegistration => {
                let fam = FamilySpec {
                    name: format!("late_{seed}"),
                    rows: 3,
                    reuse: false,
                    static_period: None,
                    cpu_fallback: false,
                    persistent: false,
                };
                let plan = JobPlan {
                    name: "late".to_string(),
                    family: usize::MAX, // ad-hoc family, not in s.families
                    count: 30,
                    rounds: 1,
                    chares: 1,
                    nbuf: 4,
                    fill: 2.0,
                    fault: Fault::None,
                };
                let counter = Arc::new(AtomicU64::new(0));
                let handle =
                    rt.submit_job(job_spec(&plan, &fam, counter.clone()))?;
                trace.push(format!(
                    "inject late-registration ({}) @ job{} round {}",
                    fam.name, a.job, a.round
                ));
                jobs.push(Running {
                    idx: jobs.len(),
                    plan,
                    fam,
                    counter,
                    handle: Some(handle),
                });
            }
            Injection::LaunchModeFlip { queue_cap } => {
                rt.chaos_launch_mode_flip(queue_cap)?;
                trace.push(format!(
                    "inject launch-mode-flip cap={queue_cap} @ job{} \
                     round {}",
                    a.job, a.round
                ));
            }
            Injection::RejectedSubmit => {
                // same family name, incompatible tile shape: must be
                // rejected and must leave the runtime untouched
                let mut bad = s.families[0].clone();
                bad.rows += 1;
                let spec = JobSpec::new("rejected")
                    .kernel(descriptor(&bad))
                    .driver(|_| Ok(Vec::new()));
                match rt.submit_job(spec) {
                    Err(_) => trace.push(format!(
                        "inject rejected-submit @ job{} round {}: rejected",
                        a.job, a.round
                    )),
                    Ok(h) => {
                        violations.push(
                            "incompatible re-registration was accepted"
                                .to_string(),
                        );
                        let _ = h.wait();
                    }
                }
            }
        }
    }

    // Fire the scripted cancels (after injections: their anchors are
    // independent of cancel timing, the cancel anchors equal each
    // victim's effective rounds).
    for j in 0..jobs.len() {
        if let Fault::Cancel { round, kind } = jobs[j].plan.fault {
            wait_round(&jobs, j, round)?;
            jobs[j].handle.as_ref().expect("not yet waited").cancel();
            trace.push(format!(
                "cancel job{} ({kind:?}) @ round {round}",
                jobs[j].idx
            ));
        }
    }

    // Wait every job out, in submission order, and check its terminal
    // contract. After each seal, audit that its residency keys are gone
    // (unless a later submission recycled the id, which keeps it live).
    for j in 0..jobs.len() {
        let handle = jobs[j].handle.take().expect("waited once");
        while handle.poll() == JobStatus::Running {
            std::thread::sleep(Duration::from_micros(200));
        }
        let status = handle.poll();
        let job_id = handle.job().0;
        let name = handle.name().to_string();
        let result = handle.wait();
        let verdict = match jobs[j].plan.fault {
            Fault::None => match &result {
                Ok(r) => {
                    let fam = &jobs[j].fam;
                    let want = vec![
                        jobs[j].plan.round_value(fam);
                        jobs[j].plan.rounds as usize
                    ];
                    if status != JobStatus::Done {
                        violations.push(format!(
                            "job{j} {name}: healthy job sealed {status:?}"
                        ));
                        "status-mismatch"
                    } else if r.series != want {
                        violations.push(format!(
                            "job{j} {name}: series {:?} != exact physics \
                             {want:?} (tenant isolation broken?)",
                            r.series
                        ));
                        "series-mismatch"
                    } else {
                        "series-exact"
                    }
                }
                Err(e) => {
                    violations
                        .push(format!("job{j} {name}: healthy job failed: {e}"));
                    "unexpected-error"
                }
            },
            Fault::Cancel { .. } => match &result {
                Ok(r) if status == JobStatus::Cancelled
                    && r.series.is_empty() =>
                {
                    "cancelled-clean"
                }
                Ok(r) => {
                    violations.push(format!(
                        "job{j} {name}: cancel sealed {status:?} with {} \
                         series entries",
                        r.series.len()
                    ));
                    "cancel-mismatch"
                }
                Err(e) => {
                    violations.push(format!(
                        "job{j} {name}: cancelled job errored: {e}"
                    ));
                    "cancel-error"
                }
            },
            Fault::Panic { .. } => {
                if result.is_err() && status == JobStatus::Failed {
                    "failed-sealed"
                } else {
                    violations.push(format!(
                        "job{j} {name}: panic sealed {status:?}, wait err: {}",
                        result.is_err()
                    ));
                    "panic-mismatch"
                }
            }
        };
        trace.push(format!("seal job{j} {name}: {status:?} {verdict}"));

        let recycled = jobs
            .iter()
            .any(|o| o.handle.as_ref().map_or(false, |h| h.job().0 == job_id));
        if !recycled {
            let resident = rt.chaos_resident_jobs()?;
            if resident.contains(&job_id) {
                violations.push(format!(
                    "job{j} {name}: residency keys survive its seal \
                     (resident jobs: {resident:?})"
                ));
                trace.push(format!("audit after job{j}: stale"));
            } else {
                trace.push(format!("audit after job{j}: clean"));
            }
        }
    }

    // Final audit: with every tenant sealed, nothing may stay resident.
    let resident = rt.chaos_resident_jobs()?;
    if resident.is_empty() {
        trace.push("final residency audit: clean".to_string());
    } else {
        violations.push(format!(
            "sealed runtime still holds residency for jobs {resident:?}"
        ));
        trace.push("final residency audit: stale".to_string());
    }

    // Shutdown must terminate (watchdog: a hang is a violation, not a
    // hung test suite), and the sealed pool report must pass the
    // accounting invariants.
    let submitted = jobs.len();
    let (tx, rx) = channel();
    std::thread::spawn(move || {
        let _ = tx.send(rt.shutdown());
    });
    match rx.recv_timeout(EVENT_TIMEOUT) {
        Ok(pool) => {
            if pool.jobs.len() != submitted {
                violations.push(format!(
                    "{} sealed job reports for {submitted} submissions",
                    pool.jobs.len()
                ));
            }
            let acc = accounting_violations(&pool);
            trace.push(if acc.is_empty() {
                "accounting: clean".to_string()
            } else {
                format!("accounting: {} violation(s)", acc.len())
            });
            violations.extend(acc);
        }
        Err(_) => {
            violations.push("shutdown did not terminate".to_string());
        }
    }

    Ok(ChaosReport { seed, trace, violations })
}

/// Build one node's `JobSpec` for the node-fault theme: the same
/// [`FillBurster`] physics as the single-runtime themes, but the driver
/// folds each round's local reduction through the cluster tree. Only
/// the root's `reduce` returns totals, so only the root owns a series.
fn cluster_job_spec(
    plan: &JobPlan,
    fam: &FamilySpec,
    my_rounds: u64,
    handle: ClusterHandle,
) -> JobSpec {
    let mut spec = JobSpec::new(plan.name.clone()).kernel(descriptor(fam));
    for c in 0..plan.chares {
        let id = ChareId::new(CHARE_COLL, c as u32);
        spec = spec.chare(
            id,
            c,
            Box::new(FillBurster {
                id,
                rows: fam.rows,
                count: plan.count,
                reuse: fam.reuse,
                nbuf: plan.nbuf,
                fill: plan.fill,
                pending: 0,
                sum: 0.0,
            }),
        );
    }
    let plan = plan.clone();
    spec.driver(move |ctx| {
        let kind = ctx.kinds()[0];
        let chares = plan.chares as u64;
        let mut series = Vec::new();
        for r in 0..my_rounds {
            for c in 0..plan.chares {
                ctx.send(
                    ChareId::new(CHARE_COLL, c as u32),
                    Msg::new(METHOD_GO, kind),
                );
            }
            let local = ctx.await_reduction(chares)?;
            ctx.await_quiescence();
            if let Some((_, total)) = handle.reduce(r as u32, 1, local) {
                series.push(total);
            }
        }
        Ok(series)
    })
}

/// Execute a node-fault schedule: the single planned job runs SPMD on a
/// loopback fabric whose every directed link carries the plan's
/// [`LinkFault`] (frames delayed behind later sends, adjacent pairs
/// swapped, every n-th heartbeat dropped), with node 1 optionally
/// leaving gracefully after `peer_down_round` rounds.
///
/// The root's series stays a pure function of the seed despite the
/// faults and any steal traffic: per-round contributions are exact
/// small-integer sums (order-independent, so steal timing cannot shift
/// them), and links are FIFO with a goodbye that flushes held frames,
/// so every contribution of a departing peer lands before the goodbye
/// that degrades the tree. Steal and heartbeat *counters* are
/// timing-dependent, so the trace never includes them; they are checked
/// against the conservation ledger instead
/// ([`invariants::cluster_violations`], exact mode — the fabric counts
/// every deliberately dropped byte and departures are graceful).
fn run_cluster(
    seed: u64,
    s: &Schedule,
    c: ClusterPlan,
    mut trace: Vec<String>,
) -> Result<ChaosReport> {
    let mut violations: Vec<String> = Vec::new();
    let plan = s.jobs[0].clone();
    let fam = s.families[plan.family].clone();
    let cfg = Config { pes: s.pes, devices: s.devices, ..Config::default() };

    let fault = LinkFault {
        delay: c.delay,
        reorder: c.reorder,
        drop_nth_heartbeat: c.drop_nth_heartbeat,
    };
    let (eps, dropped) = LoopbackFabric::with_faults(c.nodes, fault);
    let transports: Vec<Arc<dyn Transport>> = eps
        .into_iter()
        .map(|t| Arc::new(t) as Arc<dyn Transport>)
        .collect();

    let rounds = plan.rounds;
    let down = c.peer_down_round;
    trace.push(format!(
        "cluster: run {} SPMD on {} nodes, node1 leaves after {} rounds",
        plan.name,
        c.nodes,
        down.unwrap_or(rounds)
    ));

    // Watchdog, same contract as shutdown: a hung collective is a
    // violation, not a hung suite.
    let make_plan = plan.clone();
    let make_fam = fam.clone();
    let (tx, rx) = channel();
    std::thread::spawn(move || {
        let _ = tx.send(Cluster::over(
            transports,
            cfg,
            NetConfig::default(),
            move |node, handle| {
                let my_rounds = if node == NodeId(0) {
                    make_plan.rounds
                } else {
                    down.unwrap_or(make_plan.rounds)
                };
                cluster_job_spec(&make_plan, &make_fam, my_rounds, handle)
            },
        ));
    });
    let reports = match rx.recv_timeout(EVENT_TIMEOUT) {
        Ok(Ok(r)) => r,
        Ok(Err(e)) => {
            violations.push(format!("cluster run failed: {e}"));
            trace.push("cluster: failed".to_string());
            return Ok(ChaosReport { seed, trace, violations });
        }
        Err(_) => {
            violations.push("cluster run did not terminate".to_string());
            trace.push("cluster: hung".to_string());
            return Ok(ChaosReport { seed, trace, violations });
        }
    };

    // Exact physics: every node's total while node 1 is alive, the
    // root's own contribution afterwards.
    let per_round = plan.round_value(&fam);
    let pdr = down.unwrap_or(rounds);
    let want: Vec<f64> = (0..rounds)
        .map(|r| if r < pdr { c.nodes as f64 * per_round } else { per_round })
        .collect();
    if reports[0].series == want {
        trace.push("cluster: root series exact".to_string());
    } else {
        violations.push(format!(
            "root series {:?} != exact cluster physics {want:?} \
             (degraded-tree determinism broken?)",
            reports[0].series
        ));
        trace.push("cluster: root series mismatch".to_string());
    }
    for rep in &reports[1..] {
        if !rep.series.is_empty() {
            violations.push(format!(
                "{} produced {} series entries; only the root owns the \
                 cluster series",
                rep.node,
                rep.series.len()
            ));
        }
    }
    if reports[0].peer_summaries.len() != c.nodes - 1 {
        violations.push(format!(
            "root collected {} peer summaries for {} peers",
            reports[0].peer_summaries.len(),
            c.nodes - 1
        ));
    }

    // Per-node books first, then the cross-node conservation ledger.
    for rep in &reports {
        for v in accounting_violations(&rep.pool) {
            violations.push(format!("{}: {v}", rep.node));
        }
    }
    let pools: Vec<PoolReport> =
        reports.iter().map(|r| r.pool.clone()).collect();
    let acc =
        cluster_violations(&pools, dropped.load(Ordering::SeqCst), true);
    trace.push(if acc.is_empty() {
        "cluster accounting: clean".to_string()
    } else {
        format!("cluster accounting: {} violation(s)", acc.len())
    });
    violations.extend(acc);

    Ok(ChaosReport { seed, trace, violations })
}

/// Execute an overload schedule: a `serve::ServeFront` with the plan's
/// deliberately tiny depths (policy `Shed`) guards a 1-device runtime
/// while the schedule's single healthy tenant runs latency-class; then
/// a saturating burst of best-effort offers slams the door.
///
/// Which individual burst offers land in the free best-effort slot and
/// which shed is timing-dependent (it races earlier burst jobs'
/// seals), so the trace records only the deterministic facts: the
/// latency tenant always admits into an empty pool, nothing may ever
/// preempt it (the burst is strictly lower class), its series stays
/// exact physics, every admitted burst job seals `Done` with exact
/// physics of its own, and the admission ledger closes exactly — the
/// front end's counters, the pool-level copy fed through
/// `Runtime::serve_account`, and the two agreeing with each other.
fn run_overload(
    seed: u64,
    s: &Schedule,
    o: OverloadPlan,
    mut trace: Vec<String>,
) -> Result<ChaosReport> {
    use crate::serve::{
        Admission, AdmissionPolicy, QosClass, ServeConfig, ServeFront,
    };

    let mut violations: Vec<String> = Vec::new();
    let cfg = Config { pes: s.pes, devices: s.devices, ..Config::default() };
    let rt = Runtime::new(cfg)?;
    let front = ServeFront::new(ServeConfig {
        policy: AdmissionPolicy::Shed,
        class_depth: [1, 1, o.best_effort_depth],
        pool_depth: o.pool_depth,
        deadline: Some(0.01),
    })?;

    // The healthy latency tenant goes first: an empty pool always has
    // room for it.
    let plan = s.jobs[0].clone();
    let fam = s.families[plan.family].clone();
    let latency = match front.offer(
        &rt,
        QosClass::LatencySensitive,
        job_spec(&plan, &fam, Arc::new(AtomicU64::new(0))),
    )? {
        Admission::Admitted(h) => h,
        _ => {
            violations
                .push("latency tenant refused by an empty pool".to_string());
            trace.push("overload: latency tenant refused".to_string());
            let _ = rt.shutdown();
            return Ok(ChaosReport { seed, trace, violations });
        }
    };
    trace.push("overload: latency tenant admitted".to_string());

    // The saturating burst: best-effort copies of the same family (so
    // admitted burst jobs cross-job-combine with the latency tenant)
    // offered back-to-back while the latency tenant holds a pool slot.
    // With best_effort_depth 1 at most one runs at a time; a best-effort
    // offer never finds a strictly-lower victim, so the overflow sheds.
    trace.push(format!(
        "overload: burst of {} best-effort offers at pool_depth {}",
        o.burst, o.pool_depth
    ));
    let mut burst_handles = Vec::new();
    let mut shed_n = 0usize;
    for b in 0..o.burst {
        let mut bp = plan.clone();
        bp.name = format!("burst{b}");
        bp.rounds = o.burst_rounds;
        match front.offer(
            &rt,
            QosClass::BestEffort,
            job_spec(&bp, &fam, Arc::new(AtomicU64::new(0))),
        )? {
            Admission::Admitted(h) => burst_handles.push((b, bp, h)),
            Admission::Shed => shed_n += 1,
            Admission::Rejected => {
                violations.push(format!(
                    "burst{b}: Reject verdict under the Shed policy"
                ));
            }
        }
    }

    // Every admitted burst job seals Done with its own exact physics —
    // nothing ever preempts best-effort here (no higher-class offer
    // follows the burst).
    let admitted_n = burst_handles.len();
    for (b, bp, h) in burst_handles {
        let status = h.wait();
        let want =
            vec![bp.round_value(&fam); bp.rounds as usize];
        match status {
            Ok(r) if r.series == want => {}
            Ok(r) => violations.push(format!(
                "burst{b}: series {:?} != exact physics {want:?}",
                r.series
            )),
            Err(e) => violations
                .push(format!("burst{b}: admitted job failed: {e}")),
        }
    }

    // The latency co-tenant's reduction series must be its exact
    // integer physics despite the burst.
    let want =
        vec![plan.round_value(&fam); plan.rounds as usize];
    let status = latency.poll();
    match latency.wait() {
        Ok(r) if r.series == want => {
            trace.push("overload: latency series exact".to_string());
        }
        Ok(r) => {
            violations.push(format!(
                "latency tenant ({status:?}): series {:?} != exact \
                 physics {want:?} (burst broke tenant isolation?)",
                r.series
            ));
            trace.push("overload: latency series mismatch".to_string());
        }
        Err(e) => {
            violations
                .push(format!("latency tenant failed under burst: {e}"));
            trace.push("overload: latency tenant failed".to_string());
        }
    }
    front.drain();

    // The front end's own ledger: closes exactly, with every offer
    // accounted and none rejected under Shed.
    let fs = front.stats();
    if !fs.ledger_closes() {
        violations.push(format!(
            "front ledger open: offered {} != admitted {} + rejected {} \
             + shed {}",
            fs.offered_total(),
            fs.admitted_total(),
            fs.rejected_total(),
            fs.shed_total()
        ));
    }
    if fs.offered_total() != (o.burst + 1) as u64 {
        violations.push(format!(
            "front saw {} offers for {} made",
            fs.offered_total(),
            o.burst + 1
        ));
    }
    trace.push(if fs.ledger_closes() {
        "overload: front ledger closes".to_string()
    } else {
        "overload: front ledger open".to_string()
    });

    // Residency audit + watchdogged shutdown, then the pool-level copy
    // of the ledger: it must both close (accounting_violations) and
    // agree with the front end decision-for-decision.
    let resident = rt.chaos_resident_jobs()?;
    if !resident.is_empty() {
        violations.push(format!(
            "sealed runtime still holds residency for jobs {resident:?}"
        ));
    }
    let (tx, rx) = channel();
    std::thread::spawn(move || {
        let _ = tx.send(rt.shutdown());
    });
    match rx.recv_timeout(EVENT_TIMEOUT) {
        Ok(pool) => {
            if pool.jobs.len() != 1 + admitted_n {
                violations.push(format!(
                    "{} sealed job reports for {} admissions",
                    pool.jobs.len(),
                    1 + admitted_n
                ));
            }
            if (
                pool.serve_offered,
                pool.serve_admitted,
                pool.serve_rejected,
                pool.serve_shed,
            ) != (
                fs.offered_total(),
                fs.admitted_total(),
                fs.rejected_total(),
                fs.shed_total(),
            ) {
                violations.push(format!(
                    "pool serve ledger {}/{}/{}/{} != front ledger \
                     {}/{}/{}/{} (offered/admitted/rejected/shed)",
                    pool.serve_offered,
                    pool.serve_admitted,
                    pool.serve_rejected,
                    pool.serve_shed,
                    fs.offered_total(),
                    fs.admitted_total(),
                    fs.rejected_total(),
                    fs.shed_total()
                ));
            }
            if fs.shed_total() != shed_n as u64 {
                violations.push(format!(
                    "front counted {} sheds, the harness saw {shed_n}",
                    fs.shed_total()
                ));
            }
            let acc = accounting_violations(&pool);
            trace.push(if acc.is_empty() {
                "accounting: clean".to_string()
            } else {
                format!("accounting: {} violation(s)", acc.len())
            });
            violations.extend(acc);
        }
        Err(_) => {
            violations.push("shutdown did not terminate".to_string());
        }
    }

    Ok(ChaosReport { seed, trace, violations })
}
