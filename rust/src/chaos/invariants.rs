//! Cross-cutting accounting invariants over a sealed [`PoolReport`].
//!
//! Pure functions: the harness feeds them the report a chaos run sealed,
//! and the unit tests feed them deliberately corrupted reports to prove
//! the checker actually bites (a checker that cannot fail verifies
//! nothing).
//!
//! The invariants restate the multi-tenant accounting contract
//! (`coordinator::metrics`): per-job request/item/byte counters sum
//! EXACTLY to the pool totals, per-kind counters partition the same
//! totals, every flushed request is accounted on one side of the hybrid
//! split, and launch counts obey the cross-job identity — a launch
//! shared by `k` jobs adds `k` to the per-job launch sum but `1` to the
//! pool, and does the same to the cross-job counters, so the two
//! overcounts must be equal:
//!
//! ```text
//! sum(job.launches) - pool.launches
//!     == sum(job.cross_job_launches) - pool.cross_job_launches
//! ```

use crate::coordinator::PoolReport;

/// Every broken accounting invariant of `pool`, as human-readable
/// strings; empty means the report is consistent. Jobs must be sealed
/// into `pool.jobs` (i.e. this is a post-`shutdown` report).
pub fn accounting_violations(pool: &PoolReport) -> Vec<String> {
    let mut v = Vec::new();
    // A plain fn (not a `v`-capturing closure): the body below also
    // pushes to `v` directly between calls, which a captured `&mut v`
    // would make a second overlapping mutable borrow.
    fn check(v: &mut Vec<String>, what: &str, jobs: u64, total: u64) {
        if jobs != total {
            v.push(format!(
                "{what}: per-job sum {jobs} != pool total {total}"
            ));
        }
    }

    let sum = |f: fn(&crate::coordinator::JobReport) -> u64| -> u64 {
        pool.jobs.iter().map(f).sum()
    };
    check(&mut v, "gpu_requests", sum(|j| j.gpu_requests), pool.gpu_requests);
    check(&mut v, "cpu_requests", sum(|j| j.cpu_requests), pool.cpu_requests);
    check(&mut v, "gpu_items", sum(|j| j.gpu_items), pool.gpu_items);
    check(&mut v, "cpu_items", sum(|j| j.cpu_items), pool.cpu_items);
    check(
        &mut v,
        "transfer_bytes",
        sum(|j| j.transfer_bytes),
        pool.transfer_bytes,
    );

    // Per-kind partition of the same totals.
    let ksum = |f: fn(&crate::coordinator::KindStats) -> u64| -> u64 {
        pool.kind_stats.iter().map(f).sum()
    };
    check(
        &mut v,
        "kind gpu_requests",
        ksum(|k| k.gpu_requests),
        pool.gpu_requests,
    );
    check(
        &mut v,
        "kind cpu_requests",
        ksum(|k| k.cpu_requests),
        pool.cpu_requests,
    );
    check(&mut v, "kind gpu_items", ksum(|k| k.gpu_items), pool.gpu_items);
    check(&mut v, "kind cpu_items", ksum(|k| k.cpu_items), pool.cpu_items);

    // Prefetch staging happens only in the per-family chare tables (the
    // node entry cache never prefetches), so the pool totals must equal
    // the kind sums EXACTLY (ISSUE 7).
    check(
        &mut v,
        "kind prefetch_hits",
        ksum(|k| k.prefetch_hits),
        pool.prefetch_hits,
    );
    check(
        &mut v,
        "kind prefetch_wasted",
        ksum(|k| k.prefetch_wasted),
        pool.prefetch_wasted,
    );
    // The same tables' hit/miss counters are a *subset* of the pool's
    // (the node cache adds its own on top, attributed to no family).
    if ksum(|k| k.table_hits) > pool.table_hits {
        v.push(format!(
            "kind table_hits sum {} exceeds pool total {}",
            ksum(|k| k.table_hits),
            pool.table_hits
        ));
    }
    if ksum(|k| k.table_misses) > pool.table_misses {
        v.push(format!(
            "kind table_misses sum {} exceeds pool total {}",
            ksum(|k| k.table_misses),
            pool.table_misses
        ));
    }
    // A prefetch hit is a residency hit that was staged ahead: per kind
    // it can never outnumber the kind's hits.
    for k in &pool.kind_stats {
        if k.prefetch_hits > k.table_hits {
            v.push(format!(
                "kind {}: {} prefetch hits exceed {} table hits",
                k.name, k.prefetch_hits, k.table_hits
            ));
        }
    }
    // Prefetch bytes are real transfers: a subset of the pool's total.
    if pool.prefetch_bytes > pool.transfer_bytes {
        v.push(format!(
            "prefetch_bytes {} exceed transfer_bytes {}",
            pool.prefetch_bytes, pool.transfer_bytes
        ));
    }

    // Launch-mode partition (ISSUE 8): every combined launch was charged
    // either as a persistent-ring batch or as a per-batch host launch —
    // at the pool and within every family.
    check(
        &mut v,
        "launch-mode partition",
        pool.persistent_batches + pool.per_batch_launches,
        pool.launches,
    );
    for k in &pool.kind_stats {
        if k.persistent_batches + k.per_batch_launches != k.launches {
            v.push(format!(
                "kind {}: {} persistent + {} per-batch != {} launches",
                k.name, k.persistent_batches, k.per_batch_launches, k.launches
            ));
        }
    }

    // Every request flushed from a combiner landed on exactly one side
    // of the hybrid split.
    check(
        &mut v,
        "flushed_requests",
        pool.flushed_requests,
        pool.gpu_requests + pool.cpu_requests,
    );

    // Cross-job launch identity (see module docs). i128: both sides are
    // overcounts and individually fit, but stay honest about subtraction.
    let job_launches: i128 =
        pool.jobs.iter().map(|j| j.launches as i128).sum();
    let job_cross: i128 =
        pool.jobs.iter().map(|j| j.cross_job_launches as i128).sum();
    let lhs = job_launches - pool.launches as i128;
    let rhs = job_cross - pool.cross_job_launches as i128;
    if lhs != rhs {
        v.push(format!(
            "cross-job identity: launch overcount {lhs} != cross-job \
             overcount {rhs}"
        ));
    }
    if lhs < 0 {
        v.push(format!(
            "launches: per-job sum {job_launches} below pool total {}",
            pool.launches
        ));
    }
    for j in &pool.jobs {
        if j.cross_job_launches > j.launches {
            v.push(format!(
                "job {} ({}): {} cross-job launches exceed {} launches",
                j.name, j.job, j.cross_job_launches, j.launches
            ));
        }
    }

    // Cross-node attribution (ISSUE 9): every request drained off this
    // node for remote execution was charged to exactly one job.
    check(
        &mut v,
        "remote_requests",
        sum(|j| j.remote_requests),
        pool.remote_requests_out,
    );

    // Serve admission ledger (ISSUE 10): every offer the serving front
    // end recorded at the pool got exactly one verdict.
    if pool.serve_admitted + pool.serve_rejected + pool.serve_shed
        != pool.serve_offered
    {
        v.push(format!(
            "serve admission ledger: offered {} != admitted {} + \
             rejected {} + shed {}",
            pool.serve_offered,
            pool.serve_admitted,
            pool.serve_rejected,
            pool.serve_shed
        ));
    }
    v
}

/// Cross-node conservation over every node's sealed [`PoolReport`].
///
/// The steal protocol's books must balance cluster-wide: each shipped
/// batch resolves as exactly one of {results accepted at home, requeued
/// at home}, and results for an already-requeued shipment are counted
/// `stale` at the home — so:
///
/// ```text
/// sum(steals_out) + sum(stale_batches) == sum(steals_in) + sum(requeues)
/// sum(requests_out) + sum(stale_results)
///     == sum(requests_in) + sum(requeued_requests)
/// ```
///
/// (a thief counts `steals_in` only at result-ship time, so a batch it
/// declined, dropped, or executed for a dead home never inflates the
/// left side). `dropped_bytes` is what the fabric deliberately dropped
/// (chaos link faults); with `exact` (loopback, graceful exits — the
/// goodbye-is-last-frame protocol) byte conservation is an equality:
///
/// ```text
/// sum(wire_bytes_out) == sum(wire_bytes_in) + dropped_bytes
/// ```
///
/// Under hard faults (a killed TCP peer) frames die in flight with the
/// socket, so only `out >= in + dropped` can be demanded.
pub fn cluster_violations(
    nodes: &[PoolReport],
    dropped_bytes: u64,
    exact: bool,
) -> Vec<String> {
    let mut v = Vec::new();
    let sum = |f: fn(&PoolReport) -> u64| -> u64 { nodes.iter().map(f).sum() };

    let shipped = sum(|p| p.remote_steals_out) + sum(|p| p.remote_stale_batches);
    let resolved = sum(|p| p.remote_steals_in) + sum(|p| p.remote_requeues);
    if shipped != resolved {
        v.push(format!(
            "steal conservation: steals_out + stale_batches {shipped} != \
             steals_in + requeues {resolved}"
        ));
    }
    let req_shipped =
        sum(|p| p.remote_requests_out) + sum(|p| p.remote_stale_results);
    let req_resolved =
        sum(|p| p.remote_requests_in) + sum(|p| p.remote_requeued_requests);
    if req_shipped != req_resolved {
        v.push(format!(
            "request conservation: requests_out + stale_results \
             {req_shipped} != requests_in + requeued_requests {req_resolved}"
        ));
    }
    let out = sum(|p| p.wire_bytes_out);
    let inn = sum(|p| p.wire_bytes_in);
    if exact && out != inn + dropped_bytes {
        v.push(format!(
            "byte conservation: {out} sent != {inn} received + \
             {dropped_bytes} dropped"
        ));
    }
    if out < inn + dropped_bytes {
        v.push(format!(
            "byte conservation: {inn} received + {dropped_bytes} dropped \
             exceed {out} sent"
        ));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{JobId, JobReport, KindStats, PoolReport};

    /// A small self-consistent two-tenant report: 4 launches total, one
    /// of them shared by both jobs (so per-job launches sum to 5).
    fn consistent() -> PoolReport {
        let mut pool = PoolReport {
            launches: 4,
            cross_job_launches: 1,
            gpu_requests: 16,
            cpu_requests: 4,
            gpu_items: 64,
            cpu_items: 16,
            transfer_bytes: 320,
            flushed_requests: 20,
            // Residency: the family's tables saw 6 hits / 14 misses, the
            // node entry cache one extra hit; 2 of the hits were staged
            // ahead, one staged buffer died unused, 64 B staged total.
            table_hits: 7,
            table_misses: 14,
            prefetch_hits: 2,
            prefetch_wasted: 1,
            prefetch_bytes: 64,
            // One launch rode a persistent ring, the rest were host
            // launches (the mode partition the checker enforces).
            persistent_batches: 1,
            per_batch_launches: 3,
            // Serve admission ledger: 3 offers -> 2 admitted + 1 shed.
            serve_offered: 3,
            serve_admitted: 2,
            serve_shed: 1,
            ..PoolReport::default()
        };
        pool.kind_stats.push(KindStats {
            name: "chaos_fam".into(),
            launches: 4,
            gpu_requests: 16,
            cpu_requests: 4,
            gpu_items: 64,
            cpu_items: 16,
            table_hits: 6,
            table_misses: 14,
            prefetch_hits: 2,
            prefetch_wasted: 1,
            persistent_batches: 1,
            per_batch_launches: 3,
        });
        pool.jobs.push(JobReport {
            job: JobId(0),
            name: "a".into(),
            launches: 3,
            cross_job_launches: 1,
            gpu_requests: 10,
            cpu_requests: 2,
            gpu_items: 40,
            cpu_items: 8,
            transfer_bytes: 200,
            ..JobReport::default()
        });
        pool.jobs.push(JobReport {
            job: JobId(1),
            name: "b".into(),
            launches: 2,
            cross_job_launches: 1,
            gpu_requests: 6,
            cpu_requests: 2,
            gpu_items: 24,
            cpu_items: 8,
            transfer_bytes: 120,
            ..JobReport::default()
        });
        pool
    }

    #[test]
    fn consistent_report_is_clean() {
        assert_eq!(accounting_violations(&consistent()), Vec::<String>::new());
    }

    #[test]
    fn broken_request_sum_is_detected() {
        let mut pool = consistent();
        pool.gpu_requests += 1; // the deliberately broken sum
        let v = accounting_violations(&pool);
        assert!(
            v.iter().any(|s| s.contains("gpu_requests")),
            "checker missed the corrupted request sum: {v:?}"
        );
    }

    #[test]
    fn broken_byte_attribution_is_detected() {
        let mut pool = consistent();
        pool.jobs[1].transfer_bytes -= 1;
        let v = accounting_violations(&pool);
        assert!(v.iter().any(|s| s.contains("transfer_bytes")), "{v:?}");
    }

    #[test]
    fn broken_cross_job_identity_is_detected() {
        let mut pool = consistent();
        // claim the shared launch in the pool but strip one participant
        pool.jobs[0].cross_job_launches = 0;
        let v = accounting_violations(&pool);
        assert!(v.iter().any(|s| s.contains("cross-job identity")), "{v:?}");
    }

    #[test]
    fn dropped_flush_accounting_is_detected() {
        let mut pool = consistent();
        pool.flushed_requests -= 3;
        let v = accounting_violations(&pool);
        assert!(v.iter().any(|s| s.contains("flushed_requests")), "{v:?}");
    }

    #[test]
    fn broken_prefetch_partition_is_detected() {
        let mut pool = consistent();
        pool.kind_stats[0].prefetch_hits += 1; // kinds no longer sum to pool
        let v = accounting_violations(&pool);
        assert!(v.iter().any(|s| s.contains("kind prefetch_hits")), "{v:?}");

        let mut pool = consistent();
        pool.prefetch_wasted += 2;
        let v = accounting_violations(&pool);
        assert!(v.iter().any(|s| s.contains("kind prefetch_wasted")), "{v:?}");
    }

    #[test]
    fn prefetch_hits_exceeding_table_hits_are_detected() {
        let mut pool = consistent();
        // a prefetch hit that never showed up as a residency hit
        pool.kind_stats[0].prefetch_hits = pool.kind_stats[0].table_hits + 1;
        pool.prefetch_hits = pool.kind_stats[0].prefetch_hits;
        let v = accounting_violations(&pool);
        assert!(v.iter().any(|s| s.contains("prefetch hits exceed")), "{v:?}");
    }

    #[test]
    fn kind_table_counters_exceeding_pool_are_detected() {
        let mut pool = consistent();
        pool.kind_stats[0].table_hits = pool.table_hits + 3;
        let v = accounting_violations(&pool);
        assert!(v.iter().any(|s| s.contains("table_hits sum")), "{v:?}");
    }

    #[test]
    fn prefetch_bytes_exceeding_transfers_are_detected() {
        let mut pool = consistent();
        pool.prefetch_bytes = pool.transfer_bytes + 1;
        let v = accounting_violations(&pool);
        assert!(v.iter().any(|s| s.contains("prefetch_bytes")), "{v:?}");
    }

    #[test]
    fn broken_launch_mode_partition_is_detected() {
        // pool-level: a launch charged as neither persistent nor per-batch
        let mut pool = consistent();
        pool.per_batch_launches -= 1;
        let v = accounting_violations(&pool);
        assert!(
            v.iter().any(|s| s.contains("launch-mode partition")),
            "{v:?}"
        );

        // kind-level: the family double-counts a persistent batch
        let mut pool = consistent();
        pool.kind_stats[0].persistent_batches += 1;
        pool.persistent_batches += 1; // keep the pool partition intact
        pool.launches += 1;
        let v = accounting_violations(&pool);
        assert!(
            v.iter().any(|s| s.contains("persistent + ")),
            "{v:?}"
        );
    }

    #[test]
    fn per_job_cross_job_bound_is_detected() {
        let mut pool = consistent();
        pool.jobs[0].cross_job_launches = pool.jobs[0].launches + 1;
        let v = accounting_violations(&pool);
        assert!(v.iter().any(|s| s.contains("exceed")), "{v:?}");
    }

    #[test]
    fn broken_serve_ledger_is_detected() {
        let mut pool = consistent();
        pool.serve_shed += 1; // a verdict with no matching offer
        let v = accounting_violations(&pool);
        assert!(
            v.iter().any(|s| s.contains("serve admission ledger")),
            "{v:?}"
        );

        let mut pool = consistent();
        pool.serve_offered += 1; // an offer that never got a verdict
        let v = accounting_violations(&pool);
        assert!(
            v.iter().any(|s| s.contains("serve admission ledger")),
            "{v:?}"
        );
    }

    #[test]
    fn unattributed_remote_drain_is_detected() {
        let mut pool = consistent();
        // a request left the node but no job was charged for it
        pool.remote_requests_out += 1;
        let v = accounting_violations(&pool);
        assert!(v.iter().any(|s| s.contains("remote_requests")), "{v:?}");
    }

    /// A balanced two-node exchange: node 0 shipped 2 batches (5
    /// requests); node 1 executed one (3 requests) and declined one,
    /// which node 0 requeued (2 requests). 100 wire bytes each way.
    fn cluster() -> Vec<PoolReport> {
        let home = PoolReport {
            remote_steals_out: 2,
            remote_requests_out: 5,
            remote_requeues: 1,
            remote_requeued_requests: 2,
            wire_bytes_out: 100,
            wire_bytes_in: 80,
            ..PoolReport::default()
        };
        let thief = PoolReport {
            remote_steals_in: 1,
            remote_requests_in: 3,
            wire_bytes_out: 80,
            wire_bytes_in: 100,
            ..PoolReport::default()
        };
        vec![home, thief]
    }

    #[test]
    fn balanced_cluster_is_clean() {
        assert_eq!(
            cluster_violations(&cluster(), 0, true),
            Vec::<String>::new()
        );
    }

    #[test]
    fn stale_results_keep_the_books_balanced() {
        // the requeued shipment's results straggle home after all:
        // work ran twice, but stale counters absorb the double-count
        let mut nodes = cluster();
        nodes[1].remote_steals_in += 1;
        nodes[1].remote_requests_in += 2;
        nodes[0].remote_stale_batches += 1;
        nodes[0].remote_stale_results += 2;
        assert_eq!(
            cluster_violations(&nodes, 0, true),
            Vec::<String>::new()
        );
    }

    #[test]
    fn lost_shipment_is_detected() {
        let mut nodes = cluster();
        // a shipment left home and was neither executed nor requeued
        nodes[0].remote_steals_out += 1;
        nodes[0].remote_requests_out += 4;
        let v = cluster_violations(&nodes, 0, true);
        assert!(v.iter().any(|s| s.contains("steal conservation")), "{v:?}");
        assert!(v.iter().any(|s| s.contains("request conservation")), "{v:?}");
    }

    #[test]
    fn lost_bytes_are_detected_exactly_and_loosely() {
        let mut nodes = cluster();
        nodes[1].wire_bytes_in -= 7; // 7 bytes vanished silently
        let v = cluster_violations(&nodes, 0, true);
        assert!(v.iter().any(|s| s.contains("byte conservation")), "{v:?}");
        // under hard faults (exact = false) silent loss is tolerated...
        assert!(cluster_violations(&nodes, 0, false).is_empty());
        // ...but bytes appearing from nowhere never are
        nodes[1].wire_bytes_in += 20;
        let v = cluster_violations(&nodes, 0, false);
        assert!(v.iter().any(|s| s.contains("byte conservation")), "{v:?}");
    }

    #[test]
    fn deliberately_dropped_bytes_balance_the_ledger() {
        let mut nodes = cluster();
        // the chaos fabric dropped a 12-byte heartbeat on the floor:
        // charged out, never received, accounted as dropped
        nodes[0].wire_bytes_out += 12;
        assert!(!cluster_violations(&nodes, 0, true).is_empty());
        assert_eq!(
            cluster_violations(&nodes, 12, true),
            Vec::<String>::new()
        );
    }
}
