//! `gcharm` CLI: run the paper's applications and regenerate its figures.
//!
//! Subcommands (hand-rolled parsing; the vendored crate set has no clap):
//!
//! ```text
//! gcharm info                       occupancy/model tables
//! gcharm nbody [opts]               ChaNGa-style N-Body run
//!   --dataset tiny|small|large      (default small)
//!   --pes N --iters N --pieces N    (defaults 4 / 3 / 4 per pe)
//!   --combine adaptive|static[:P]   (default adaptive)
//!   --data noreuse|reuse|sorted     (default sorted)
//!   --devices N --route affinity|rr (default 1 / affinity)
//!   --mode gcharm|cpu|handtuned     (default gcharm)
//! gcharm md [opts]                  2D molecular dynamics run
//!   --particles N --steps N --grid G --pes N
//!   --split static|adaptive         (default adaptive)
//!   --devices N --route affinity|rr (default 1 / affinity)
//!   --mode gcharm|cpu1              (default gcharm)
//! gcharm spmv [opts]                sparse neighbor-update run (the
//!   --rows N --iters N --nnz N      registry-API demo workload)
//!   --pes N --devices N --split static|adaptive
//! gcharm figures [--fig 2|3|4|5|ablation|all] [--full]
//! ```

use std::collections::HashMap;

use anyhow::{bail, Result};

use gcharm::apps::md::{self, MdConfig};
use gcharm::apps::nbody::{self, dataset::DatasetSpec, NbodyConfig};
use gcharm::apps::spmv::{self, SpmvConfig};
use gcharm::bench;
use gcharm::coordinator::{
    CombinePolicy, Config, DataPolicy, RoutePolicy, SplitPolicy,
};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn get<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
    default: T,
) -> T {
    flags
        .get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn combine_policy(s: &str) -> Result<CombinePolicy> {
    if s == "adaptive" {
        Ok(CombinePolicy::Adaptive)
    } else if s == "static" {
        Ok(CombinePolicy::StaticEvery(100))
    } else if let Some(p) = s.strip_prefix("static:") {
        Ok(CombinePolicy::StaticEvery(p.parse()?))
    } else {
        bail!("unknown combine policy {s}")
    }
}

fn data_policy(s: &str) -> Result<DataPolicy> {
    match s {
        "noreuse" => Ok(DataPolicy::NoReuse),
        "reuse" => Ok(DataPolicy::Reuse),
        "sorted" => Ok(DataPolicy::ReuseSorted),
        _ => bail!("unknown data policy {s}"),
    }
}

fn route_policy(s: &str) -> Result<RoutePolicy> {
    match s {
        "affinity" => Ok(RoutePolicy::AffinitySteal),
        "rr" | "roundrobin" => Ok(RoutePolicy::RoundRobin),
        _ => bail!("unknown route policy {s}"),
    }
}

fn cmd_nbody(flags: HashMap<String, String>) -> Result<()> {
    let dataset = match flags.get("dataset").map(|s| s.as_str()) {
        None | Some("small") => DatasetSpec::small(),
        Some("tiny") => DatasetSpec::tiny(),
        Some("large") => DatasetSpec::large(),
        Some("cube300") => DatasetSpec::cube300(),
        Some("lambs") => DatasetSpec::lambs(),
        Some(other) => bail!("unknown dataset {other}"),
    };
    let pes: usize = get(&flags, "pes", 4);
    let mut cfg = NbodyConfig::new(dataset);
    cfg.iters = get(&flags, "iters", 3);
    cfg.pieces_per_pe = get(&flags, "pieces", 4);
    cfg.runtime = Config {
        pes,
        combine: combine_policy(
            flags.get("combine").map(|s| s.as_str()).unwrap_or("adaptive"),
        )?,
        data_policy: data_policy(
            flags.get("data").map(|s| s.as_str()).unwrap_or("sorted"),
        )?,
        devices: get(&flags, "devices", 1),
        route: route_policy(
            flags.get("route").map(|s| s.as_str()).unwrap_or("affinity"),
        )?,
        ..Config::default()
    };

    let mode = flags.get("mode").map(|s| s.as_str()).unwrap_or("gcharm");
    println!(
        "nbody: dataset={} n={} iters={} pes={} devices={} mode={mode}",
        cfg.dataset.name, cfg.dataset.n, cfg.iters, pes, cfg.runtime.devices
    );
    let r = match mode {
        "gcharm" => nbody::run(&cfg)?,
        "cpu" => nbody::run_cpu_only(&cfg)?,
        "handtuned" => nbody::handtuned::run_handtuned(&cfg)?,
        other => bail!("unknown mode {other}"),
    };
    println!("buckets: {}", r.buckets);
    println!(
        "energy: start {:.6e} end {:.6e}",
        r.energies.first().unwrap_or(&0.0),
        r.energies.last().unwrap_or(&0.0)
    );
    println!("{}", r.report);
    Ok(())
}

fn cmd_md(flags: HashMap<String, String>) -> Result<()> {
    let mut cfg = MdConfig::new(get(&flags, "particles", 4096));
    cfg.steps = get(&flags, "steps", 5);
    if let Some(g) = flags.get("grid").and_then(|v| v.parse().ok()) {
        cfg.grid = g;
        cfg.box_l = cfg.grid as f64 * 2.0;
    }
    cfg.runtime = Config {
        pes: get(&flags, "pes", 4),
        split: match flags.get("split").map(|s| s.as_str()) {
            None | Some("adaptive") => SplitPolicy::AdaptiveItems,
            Some("static") => SplitPolicy::StaticCount,
            Some(other) => bail!("unknown split {other}"),
        },
        hybrid: true,
        devices: get(&flags, "devices", 1),
        route: route_policy(
            flags.get("route").map(|s| s.as_str()).unwrap_or("affinity"),
        )?,
        ..Config::default()
    };
    let mode = flags.get("mode").map(|s| s.as_str()).unwrap_or("gcharm");
    println!(
        "md: n={} steps={} grid={} pes={} mode={mode}",
        cfg.n_particles, cfg.steps, cfg.grid, cfg.runtime.pes
    );
    let r = match mode {
        "gcharm" => md::run(&cfg)?,
        "cpu1" => md::run_single_core_cpu(&cfg),
        other => bail!("unknown mode {other}"),
    };
    println!(
        "kinetic energy: start {:.4} end {:.4}",
        r.energies.first().unwrap_or(&0.0),
        r.energies.last().unwrap_or(&0.0)
    );
    println!("{}", r.report);
    Ok(())
}

fn cmd_spmv(flags: HashMap<String, String>) -> Result<()> {
    let mut cfg = SpmvConfig::new(get(&flags, "rows", 2048));
    cfg.iters = get(&flags, "iters", 5);
    cfg.max_row_nnz = get(&flags, "nnz", 512);
    cfg.runtime = Config {
        pes: get(&flags, "pes", 4),
        split: match flags.get("split").map(|s| s.as_str()) {
            None | Some("adaptive") => SplitPolicy::AdaptiveItems,
            Some("static") => SplitPolicy::StaticCount,
            Some(other) => bail!("unknown split {other}"),
        },
        devices: get(&flags, "devices", 1),
        route: route_policy(
            flags.get("route").map(|s| s.as_str()).unwrap_or("affinity"),
        )?,
        ..Config::default()
    };
    println!(
        "spmv: rows={} iters={} max_nnz={} pes={} devices={}",
        cfg.rows, cfg.iters, cfg.max_row_nnz, cfg.runtime.pes,
        cfg.runtime.devices
    );
    let r = spmv::run(&cfg)?;
    println!(
        "residual^2: start {:.4e} end {:.4e}",
        r.residuals.first().unwrap_or(&0.0),
        r.residuals.last().unwrap_or(&0.0)
    );
    println!("{}", r.report);
    Ok(())
}

fn cmd_figures(flags: HashMap<String, String>) -> Result<()> {
    let scale = if flags.contains_key("full") {
        bench::Scale::full()
    } else {
        bench::Scale::quick()
    };
    let which = flags.get("fig").map(|s| s.as_str()).unwrap_or("all");
    bench::print_occupancy_table();
    match which {
        "2" => bench::run_fig2(&scale),
        "3" => bench::run_fig3(&scale),
        "4" => bench::run_fig4(&scale),
        "5" => bench::run_fig5(&scale),
        "ablation" => bench::run_ablation(&scale),
        "all" => {
            bench::run_fig2(&scale);
            bench::run_fig3(&scale);
            bench::run_fig4(&scale);
            bench::run_fig5(&scale);
        }
        other => bail!("unknown figure {other}"),
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    match cmd {
        "info" => {
            bench::print_occupancy_table();
            Ok(())
        }
        "nbody" => cmd_nbody(flags),
        "md" => cmd_md(flags),
        "spmv" => cmd_spmv(flags),
        "figures" => cmd_figures(flags),
        _ => {
            println!(
                "usage: gcharm <info|nbody|md|spmv|figures> [--flags]\n\
                 see rust/src/main.rs header for options"
            );
            Ok(())
        }
    }
}
