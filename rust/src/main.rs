//! `gcharm` CLI: run the paper's applications and regenerate its figures.
//!
//! Subcommands (hand-rolled parsing; the vendored crate set has no clap):
//!
//! ```text
//! gcharm info                       occupancy/model tables
//! gcharm nbody [opts]               ChaNGa-style N-Body run
//!   --dataset tiny|small|large      (default small)
//!   --pes N --iters N --pieces N    (defaults 4 / 3 / 4 per pe)
//!   --combine adaptive|static[:P]   (default adaptive)
//!   --data noreuse|reuse|sorted     (default sorted)
//!   --devices N --route affinity|rr (default 1 / affinity)
//!   --residency lru|reuse           (default reuse: lookahead eviction
//!                                   + ahead-of-flush prefetch)
//!   --launch-mode per-batch|persistent|adaptive  (default adaptive:
//!                                   per-family break-even learner)
//!   --mode gcharm|cpu|handtuned     (default gcharm)
//! gcharm md [opts]                  2D molecular dynamics run
//!   --particles N --steps N --grid G --pes N
//!   --split static|adaptive         (default adaptive)
//!   --devices N --route affinity|rr (default 1 / affinity)
//!   --residency lru|reuse           (default reuse)
//!   --launch-mode per-batch|persistent|adaptive  (default adaptive)
//!   --mode gcharm|cpu1              (default gcharm)
//! gcharm spmv [opts]                sparse neighbor-update run (the
//!   --rows N --iters N --nnz N      registry-API demo workload)
//!   --pes N --devices N --split static|adaptive
//!   --residency lru|reuse           (default reuse)
//!   --launch-mode per-batch|persistent|adaptive  (default adaptive)
//! gcharm serve [opts]               one persistent runtime serving a
//!   --pes N --devices N             mixed nbody+md+2x-spmv workload
//!   --iters N --rows N --particles N  trace concurrently; asserts that
//!   --residency lru|reuse           cross-job combining fired
//!   --launch-mode per-batch|persistent|adaptive  (default adaptive)
//!   --qos latency|throughput|best-effort  spmv-a tenant's class
//!                                   (default latency; spmv-b/md are
//!                                   throughput, nbody best-effort)
//!   --deadline-ms N                 latency-class flush budget (50)
//!   --admission block|reject|shed   front-end policy (default block)
//!   --metrics-addr HOST:PORT        scrapeable plaintext metrics
//!                                   endpoint (port 0 picks a free
//!                                   port; the run self-scrapes once)
//! gcharm figures [--fig 2|3|4|5|ablation|all] [--full]
//! gcharm node [opts]                one TCP cluster node (SPMD: run the
//!   --id N --peers a:p0,b:p1,...    same command on every node; peers[i]
//!   --listen ADDR                   is node i's address, --listen
//!   --app nbody|spmv                overrides the local bind address)
//!   --pes N --devices N --iters N   runs the app cluster-wide with
//!                                   cross-node steal and prints per-node
//!                                   accounting; the root audits the
//!                                   cluster conservation ledger
//! gcharm chaos [--seed N] [--seeds A..B]   deterministic fault-injection
//!                                   run(s) (default corpus 0..16);
//!                                   needs `--features chaos`.
//!                                   Prints the replay-identical event
//!                                   trace; exits nonzero on violations.
//! ```
//!
//! `nbody`, `spmv`, and `serve` also accept `--nodes N`: run N loopback
//! cluster nodes in-process (full wire protocol, zero-copy frames)
//! instead of one runtime — `serve --nodes N` runs the shared-family
//! spmv tenant SPMD with cross-node steal balancing the nodes.

use std::collections::HashMap;

use anyhow::{bail, Result};

use std::sync::{Arc, Mutex};

use gcharm::apps::md::{self, MdConfig};
use gcharm::apps::nbody::{self, dataset::DatasetSpec, NbodyConfig};
use gcharm::apps::spmv::{self, SpmvConfig};
use gcharm::bench;
use gcharm::coordinator::{
    CombinePolicy, Config, DataPolicy, JobSpec, LaunchModePolicy,
    ResidencyPolicy, RoutePolicy, Runtime, SplitPolicy,
};
use gcharm::net::{
    Cluster, ClusterNode, NetConfig, NodeReport, Tcp, Transport,
};
use gcharm::serve::{
    Admission, AdmissionPolicy, MetricsEndpoint, QosClass, ServeConfig,
    ServeFront,
};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn get<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
    default: T,
) -> T {
    flags
        .get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn combine_policy(s: &str) -> Result<CombinePolicy> {
    if s == "adaptive" {
        Ok(CombinePolicy::Adaptive)
    } else if s == "static" {
        Ok(CombinePolicy::StaticEvery(100))
    } else if let Some(p) = s.strip_prefix("static:") {
        Ok(CombinePolicy::StaticEvery(p.parse()?))
    } else {
        bail!("unknown combine policy {s}")
    }
}

fn data_policy(s: &str) -> Result<DataPolicy> {
    match s {
        "noreuse" => Ok(DataPolicy::NoReuse),
        "reuse" => Ok(DataPolicy::Reuse),
        "sorted" => Ok(DataPolicy::ReuseSorted),
        _ => bail!("unknown data policy {s}"),
    }
}

fn route_policy(s: &str) -> Result<RoutePolicy> {
    match s {
        "affinity" => Ok(RoutePolicy::AffinitySteal),
        "rr" | "roundrobin" => Ok(RoutePolicy::RoundRobin),
        _ => bail!("unknown route policy {s}"),
    }
}

/// `--residency lru|reuse` flag (absent = the runtime default).
fn residency_policy(
    flags: &HashMap<String, String>,
) -> Result<ResidencyPolicy> {
    match flags.get("residency").map(|s| s.as_str()) {
        None => Ok(ResidencyPolicy::default()),
        Some("lru") => Ok(ResidencyPolicy::Lru),
        Some("reuse" | "reuse-graph" | "graph") => {
            Ok(ResidencyPolicy::ReuseGraph)
        }
        Some(other) => bail!("unknown residency policy {other}"),
    }
}

/// `--launch-mode per-batch|persistent|adaptive` flag (absent = the
/// runtime default, the adaptive break-even learner).
fn launch_mode_policy(
    flags: &HashMap<String, String>,
) -> Result<LaunchModePolicy> {
    match flags.get("launch-mode").map(|s| s.as_str()) {
        None => Ok(LaunchModePolicy::default()),
        Some("per-batch" | "perbatch") => Ok(LaunchModePolicy::PerBatch),
        Some("persistent") => Ok(LaunchModePolicy::Persistent),
        Some("adaptive") => Ok(LaunchModePolicy::Adaptive),
        Some(other) => bail!("unknown launch mode {other}"),
    }
}

fn cmd_nbody(flags: HashMap<String, String>) -> Result<()> {
    let dataset = match flags.get("dataset").map(|s| s.as_str()) {
        None | Some("small") => DatasetSpec::small(),
        Some("tiny") => DatasetSpec::tiny(),
        Some("large") => DatasetSpec::large(),
        Some("cube300") => DatasetSpec::cube300(),
        Some("lambs") => DatasetSpec::lambs(),
        Some(other) => bail!("unknown dataset {other}"),
    };
    let pes: usize = get(&flags, "pes", 4);
    let mut cfg = NbodyConfig::new(dataset);
    cfg.iters = get(&flags, "iters", 3);
    cfg.pieces_per_pe = get(&flags, "pieces", 4);
    cfg.runtime = Config {
        pes,
        combine: combine_policy(
            flags.get("combine").map(|s| s.as_str()).unwrap_or("adaptive"),
        )?,
        data_policy: data_policy(
            flags.get("data").map(|s| s.as_str()).unwrap_or("sorted"),
        )?,
        devices: get(&flags, "devices", 1),
        route: route_policy(
            flags.get("route").map(|s| s.as_str()).unwrap_or("affinity"),
        )?,
        residency: residency_policy(&flags)?,
        launch_mode: launch_mode_policy(&flags)?,
        ..Config::default()
    };

    let mode = flags.get("mode").map(|s| s.as_str()).unwrap_or("gcharm");
    let nodes: usize = get(&flags, "nodes", 1);
    if nodes > 1 {
        if mode != "gcharm" {
            bail!("--nodes runs the gcharm mode only");
        }
        println!(
            "nbody: dataset={} n={} iters={} nodes={nodes} (loopback \
             cluster)",
            cfg.dataset.name, cfg.dataset.n, cfg.iters
        );
        let rt_cfg = cfg.runtime.clone();
        return run_loopback_cluster(nodes, rt_cfg, move |_, _h| {
            nbody::job_spec(&cfg)
        });
    }
    println!(
        "nbody: dataset={} n={} iters={} pes={} devices={} mode={mode}",
        cfg.dataset.name, cfg.dataset.n, cfg.iters, pes, cfg.runtime.devices
    );
    let r = match mode {
        "gcharm" => nbody::run(&cfg)?,
        "cpu" => nbody::run_cpu_only(&cfg)?,
        "handtuned" => nbody::handtuned::run_handtuned(&cfg)?,
        other => bail!("unknown mode {other}"),
    };
    println!("buckets: {}", r.buckets);
    println!(
        "energy: start {:.6e} end {:.6e}",
        r.energies.first().unwrap_or(&0.0),
        r.energies.last().unwrap_or(&0.0)
    );
    println!("{}", r.report);
    Ok(())
}

fn cmd_md(flags: HashMap<String, String>) -> Result<()> {
    let mut cfg = MdConfig::new(get(&flags, "particles", 4096));
    cfg.steps = get(&flags, "steps", 5);
    if let Some(g) = flags.get("grid").and_then(|v| v.parse().ok()) {
        cfg.grid = g;
        cfg.box_l = cfg.grid as f64 * 2.0;
    }
    cfg.runtime = Config {
        pes: get(&flags, "pes", 4),
        split: match flags.get("split").map(|s| s.as_str()) {
            None | Some("adaptive") => SplitPolicy::AdaptiveItems,
            Some("static") => SplitPolicy::StaticCount,
            Some(other) => bail!("unknown split {other}"),
        },
        hybrid: true,
        devices: get(&flags, "devices", 1),
        route: route_policy(
            flags.get("route").map(|s| s.as_str()).unwrap_or("affinity"),
        )?,
        residency: residency_policy(&flags)?,
        launch_mode: launch_mode_policy(&flags)?,
        ..Config::default()
    };
    let mode = flags.get("mode").map(|s| s.as_str()).unwrap_or("gcharm");
    println!(
        "md: n={} steps={} grid={} pes={} mode={mode}",
        cfg.n_particles, cfg.steps, cfg.grid, cfg.runtime.pes
    );
    let r = match mode {
        "gcharm" => md::run(&cfg)?,
        "cpu1" => md::run_single_core_cpu(&cfg),
        other => bail!("unknown mode {other}"),
    };
    println!(
        "kinetic energy: start {:.4} end {:.4}",
        r.energies.first().unwrap_or(&0.0),
        r.energies.last().unwrap_or(&0.0)
    );
    println!("{}", r.report);
    Ok(())
}

fn cmd_spmv(flags: HashMap<String, String>) -> Result<()> {
    let mut cfg = SpmvConfig::new(get(&flags, "rows", 2048));
    cfg.iters = get(&flags, "iters", 5);
    cfg.max_row_nnz = get(&flags, "nnz", 512);
    cfg.runtime = Config {
        pes: get(&flags, "pes", 4),
        split: match flags.get("split").map(|s| s.as_str()) {
            None | Some("adaptive") => SplitPolicy::AdaptiveItems,
            Some("static") => SplitPolicy::StaticCount,
            Some(other) => bail!("unknown split {other}"),
        },
        devices: get(&flags, "devices", 1),
        route: route_policy(
            flags.get("route").map(|s| s.as_str()).unwrap_or("affinity"),
        )?,
        residency: residency_policy(&flags)?,
        launch_mode: launch_mode_policy(&flags)?,
        ..Config::default()
    };
    let nodes: usize = get(&flags, "nodes", 1);
    if nodes > 1 {
        println!(
            "spmv: rows={} iters={} nodes={nodes} (loopback cluster)",
            cfg.rows, cfg.iters
        );
        let rt_cfg = cfg.runtime.clone();
        return run_loopback_cluster(nodes, rt_cfg, move |_, _h| {
            spmv::job_spec(&cfg)
        });
    }
    println!(
        "spmv: rows={} iters={} max_nnz={} pes={} devices={}",
        cfg.rows, cfg.iters, cfg.max_row_nnz, cfg.runtime.pes,
        cfg.runtime.devices
    );
    let r = spmv::run(&cfg)?;
    println!(
        "residual^2: start {:.4e} end {:.4e}",
        r.residuals.first().unwrap_or(&0.0),
        r.residuals.last().unwrap_or(&0.0)
    );
    println!("{}", r.report);
    Ok(())
}

/// One persistent runtime serving a mixed workload trace: two SpMV jobs
/// (same `spmv_row` family — the cross-job-combining pair), an MD job,
/// and an N-Body job, all offered through the serving front end with
/// per-tenant QoS classes (`--qos` sets spmv-a's; spmv-b and md are
/// throughput, nbody best-effort). Prints per-job reports, the front
/// end's admission ledger, and the pool report, and fails if no flush
/// ever combined tiles from two different jobs. Whether two tenants'
/// bursts overlap inside one combiner window is timing-dependent, so
/// the trace retries — on the SAME warmed runtime (a fresh one would
/// forget the learned fair-share weights and break-even estimates and
/// reset the pool counters), gating each attempt on the *delta* of
/// `cross_job_launches` from a live pool snapshot and logging which
/// attempt passed. CI gates on the exit code.
fn cmd_serve(flags: HashMap<String, String>) -> Result<()> {
    let iters: usize = get(&flags, "iters", 6);
    let rows: usize = get(&flags, "rows", 512);
    let particles: usize = get(&flags, "particles", 2048);
    let attempts: usize = get(&flags, "attempts", 3);
    let runtime_cfg = Config {
        pes: get(&flags, "pes", 4),
        devices: get(&flags, "devices", 1),
        route: route_policy(
            flags.get("route").map(|s| s.as_str()).unwrap_or("affinity"),
        )?,
        residency: residency_policy(&flags)?,
        launch_mode: launch_mode_policy(&flags)?,
        ..Config::default()
    };
    let nodes: usize = get(&flags, "nodes", 1);
    if nodes > 1 {
        // distributed serve: the shared-family spmv tenant runs SPMD,
        // with cross-node steal balancing the loopback nodes
        println!(
            "serve: nodes={nodes} (loopback cluster, spmv tenant SPMD) \
             rows={rows} iters={iters}"
        );
        let mut cfg = SpmvConfig::new(rows);
        cfg.iters = iters;
        return run_loopback_cluster(nodes, runtime_cfg, move |_, _h| {
            spmv::job_spec(&cfg)
        });
    }
    let qos_raw = flags.get("qos").map(|s| s.as_str()).unwrap_or("latency");
    let qos = QosClass::parse(qos_raw).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown --qos class {qos_raw} (latency|throughput|best-effort)"
        )
    })?;
    let adm_raw =
        flags.get("admission").map(|s| s.as_str()).unwrap_or("block");
    let policy = AdmissionPolicy::parse(adm_raw).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown --admission policy {adm_raw} (block|reject|shed)"
        )
    })?;
    let deadline_ms: f64 = get(&flags, "deadline-ms", 50.0);
    println!(
        "serve: pes={} devices={} iters={iters} rows={rows} \
         particles={particles} qos={} admission={} deadline={deadline_ms}ms",
        runtime_cfg.pes,
        runtime_cfg.devices,
        qos.name(),
        policy.name(),
    );

    let rt = Runtime::new(runtime_cfg.clone())?;
    let front = ServeFront::new(ServeConfig {
        policy,
        class_depth: [8, 8, 8],
        pool_depth: 16,
        deadline: Some(deadline_ms / 1e3),
    })?;
    let metrics = match flags.get("metrics-addr") {
        Some(addr) => {
            let ep = MetricsEndpoint::spawn(
                addr,
                rt.shared(),
                rt.snapshot_handle(),
                front.stats_arc(),
            )?;
            println!("metrics: listening on {}", ep.addr());
            Some(ep)
        }
        None => None,
    };

    let mut prev_cross = 0u64;
    let mut passed = None;
    for attempt in 1..=attempts.max(1) {
        serve_trace(
            &rt,
            &front,
            qos,
            runtime_cfg.pes,
            iters,
            rows,
            particles,
        )?;
        let total = rt.pool_snapshot()?.cross_job_launches;
        let delta = total - prev_cross;
        prev_cross = total;
        if delta >= 1 {
            println!(
                "cross-job combining: attempt {attempt}/{attempts} \
                 passed with {delta} shared launches this pass \
                 ({total} since startup)"
            );
            passed = Some(attempt);
            break;
        }
        eprintln!(
            "serve: attempt {attempt}/{attempts}: no launch combined \
             tiles from two different jobs this pass; retrying the \
             trace on the same warmed runtime"
        );
    }
    if let Some(ep) = &metrics {
        let body = MetricsEndpoint::scrape(&ep.addr())?;
        println!(
            "metrics self-scrape from {} ({} lines), serve ledger:",
            ep.addr(),
            body.lines().count()
        );
        for line in
            body.lines().filter(|l| l.starts_with("gcharm_serve_"))
        {
            println!("  {line}");
        }
    }
    drop(metrics);
    println!("{}", front.stats());
    let report = rt.shutdown();
    println!("{report}");
    if passed.is_none() {
        anyhow::bail!(
            "serve: no launch combined tiles from two different jobs in \
             {attempts} attempts (cross_job_launches = {prev_cross}); \
             the runtime failed to multiplex the spmv tenants"
        );
    }
    Ok(())
}

/// Offer the mixed four-tenant trace through the front end and wait for
/// every admitted job. `qos` classes spmv-a; spmv-b and md ride as
/// throughput and nbody as best-effort, so `--admission shed` has a
/// strictly-lower victim ordering to exercise.
fn serve_trace(
    rt: &Runtime,
    front: &ServeFront,
    qos: QosClass,
    pes: usize,
    iters: usize,
    rows: usize,
    particles: usize,
) -> Result<()> {
    // The two SpMV tenants go first so their sweeps race through the
    // shared spmv_row combiners from t0.
    let mut spmv_a = SpmvConfig::new(rows);
    spmv_a.iters = iters;
    let mut spmv_b = SpmvConfig::new(rows);
    spmv_b.iters = iters;
    spmv_b.seed = 1913; // a different matrix, the same kernel family
    // Per-job configs carry only workload shape: the *shared* runtime
    // owns pes/devices/policies for every tenant.
    let mut md_cfg = MdConfig::new(particles);
    md_cfg.steps = iters.min(4);
    let mut nbody_cfg = NbodyConfig::new(DatasetSpec::tiny());
    nbody_cfg.iters = iters.min(2);
    nbody_cfg.pieces_per_pe = 2;
    nbody_cfg.runtime.pes = pes;

    let offers = vec![
        (
            "spmv-a",
            qos,
            spmv::job_spec_with_master(
                &spmv_a,
                "spmv-a",
                Arc::new(Mutex::new(vec![0.0f32; spmv_a.rows])),
            ),
        ),
        (
            "spmv-b",
            QosClass::Throughput,
            spmv::job_spec_with_master(
                &spmv_b,
                "spmv-b",
                Arc::new(Mutex::new(vec![0.0f32; spmv_b.rows])),
            ),
        ),
        ("md", QosClass::Throughput, md::job_spec(&md_cfg)?),
        ("nbody", QosClass::BestEffort, nbody::job_spec(&nbody_cfg)),
    ];

    let mut handles = Vec::new();
    for (name, class, spec) in offers {
        match front.offer(rt, class, spec)? {
            Admission::Admitted(h) => handles.push(h),
            Admission::Rejected => {
                println!("job {name:<8} rejected at admission")
            }
            Admission::Shed => {
                println!("job {name:<8} shed at admission")
            }
        }
    }
    for h in handles {
        let name = h.name().to_string();
        let report = h.wait()?;
        println!("job {name:<8} done: {report}");
    }
    Ok(())
}

/// Print one cluster node's report and check its local books: every
/// job's remote-request count must sum to the node's pool total.
fn print_node_report(rep: &NodeReport) -> Result<()> {
    println!("--- {} ---", rep.node);
    if let (Some(first), Some(last)) =
        (rep.series.first(), rep.series.last())
    {
        println!(
            "series: start {:.6e} end {:.6e} ({} entries)",
            first,
            last,
            rep.series.len()
        );
    }
    println!(
        "remote: steals {} out / {} in, requests {} out / {} in, \
         requeues {}, wire {} B out / {} B in",
        rep.pool.remote_steals_out,
        rep.pool.remote_steals_in,
        rep.pool.remote_requests_out,
        rep.pool.remote_requests_in,
        rep.pool.remote_requeues,
        rep.pool.wire_bytes_out,
        rep.pool.wire_bytes_in,
    );
    println!("{}", rep.pool);
    let per_job: u64 =
        rep.pool.jobs.iter().map(|j| j.remote_requests).sum();
    anyhow::ensure!(
        per_job == rep.pool.remote_requests_out,
        "{}: per-job remote requests ({per_job}) != pool total ({})",
        rep.node,
        rep.pool.remote_requests_out
    );
    Ok(())
}

/// Cross-node conservation over a full set of loopback reports: every
/// shipped batch/request resolves exactly once, and (graceful run,
/// nothing deliberately dropped) wire bytes balance exactly.
fn audit_loopback_cluster(reports: &[NodeReport]) -> Result<()> {
    let sum = |f: fn(&gcharm::coordinator::PoolReport) -> u64| -> u64 {
        reports.iter().map(|r| f(&r.pool)).sum()
    };
    let shipped =
        sum(|p| p.remote_steals_out) + sum(|p| p.remote_stale_batches);
    let resolved =
        sum(|p| p.remote_steals_in) + sum(|p| p.remote_requeues);
    anyhow::ensure!(
        shipped == resolved,
        "cluster steal ledger unbalanced: {shipped} shipped vs \
         {resolved} resolved"
    );
    let rq_shipped =
        sum(|p| p.remote_requests_out) + sum(|p| p.remote_stale_results);
    let rq_resolved = sum(|p| p.remote_requests_in)
        + sum(|p| p.remote_requeued_requests);
    anyhow::ensure!(
        rq_shipped == rq_resolved,
        "cluster request ledger unbalanced: {rq_shipped} vs {rq_resolved}"
    );
    let (out, inn) = (sum(|p| p.wire_bytes_out), sum(|p| p.wire_bytes_in));
    anyhow::ensure!(
        out == inn,
        "cluster byte ledger unbalanced: {out} out vs {inn} in"
    );
    println!(
        "cluster conservation: balanced ({shipped} batches, {out} wire \
         bytes)"
    );
    Ok(())
}

/// `--nodes N` mode shared by nbody/spmv/serve: run `make`'s SPMD spec
/// on an in-process loopback cluster and audit the conservation ledger.
fn run_loopback_cluster<F>(nodes: usize, cfg: Config, make: F) -> Result<()>
where
    F: Fn(gcharm::net::NodeId, gcharm::net::ClusterHandle) -> JobSpec
        + Send
        + Sync
        + 'static,
{
    let reports = Cluster::loopback(nodes, cfg, NetConfig::default(), make)?;
    for rep in &reports {
        print_node_report(rep)?;
    }
    audit_loopback_cluster(&reports)
}

/// One TCP cluster node: join the `--peers` mesh as `--id`, run the app
/// SPMD with cross-node steal, print this node's accounting, and (on
/// the root) audit the cluster ledger from the peers' Summary frames.
fn cmd_node(flags: HashMap<String, String>) -> Result<()> {
    let id: u32 = get(&flags, "id", 0);
    let peers: Vec<String> = flags
        .get("peers")
        .map(|s| {
            s.split(',')
                .map(|p| p.trim().to_string())
                .filter(|p| !p.is_empty())
                .collect()
        })
        .unwrap_or_default();
    if peers.len() < 2 {
        bail!(
            "gcharm node wants --id N and --peers a:p0,b:p1,... \
             (>= 2 addresses; peers[i] is node i's listen address)"
        );
    }
    let app = flags.get("app").map(|s| s.as_str()).unwrap_or("nbody");
    if !matches!(app, "nbody" | "spmv") {
        bail!("unknown app {app} (nbody|spmv)");
    }
    let transport: Arc<dyn Transport> =
        if let Some(listen) = flags.get("listen") {
            let listener = std::net::TcpListener::bind(listen.as_str())?;
            Arc::new(Tcp::with_listener(id, listener, &peers)?)
        } else {
            Arc::new(Tcp::connect(id, &peers)?)
        };
    let cfg = Config {
        pes: get(&flags, "pes", 4),
        devices: get(&flags, "devices", 1),
        ..Config::default()
    };
    let iters: usize = get(&flags, "iters", 2);
    let rows: usize = get(&flags, "rows", 512);
    let pes = cfg.pes;
    println!(
        "node {id}/{}: app={app} pes={} devices={}",
        peers.len(),
        cfg.pes,
        cfg.devices
    );
    let app = app.to_string();
    let report =
        ClusterNode::run(cfg, NetConfig::default(), transport, move |_h| {
            if app == "spmv" {
                let mut c = SpmvConfig::new(rows);
                c.iters = iters;
                spmv::job_spec(&c)
            } else {
                let mut c = NbodyConfig::new(DatasetSpec::tiny());
                c.iters = iters;
                c.pieces_per_pe = 2;
                c.runtime.pes = pes;
                nbody::job_spec(&c)
            }
        })?;
    print_node_report(&report)?;

    if report.node.0 == 0 {
        anyhow::ensure!(
            report.peer_summaries.len() == peers.len() - 1,
            "root collected {} peer summaries for {} peers",
            report.peer_summaries.len(),
            peers.len() - 1
        );
        // Fold the peers' Summary counters into our own pool counters.
        // Summaries carry no stale counts — a graceful run has none
        // (staleness needs a ship timeout), so the ledger still closes.
        let p = &report.pool;
        let mut shipped = p.remote_steals_out + p.remote_stale_batches;
        let mut resolved = p.remote_steals_in + p.remote_requeues;
        let mut rq_shipped =
            p.remote_requests_out + p.remote_stale_results;
        let mut rq_resolved =
            p.remote_requests_in + p.remote_requeued_requests;
        let (mut out, mut inn) = (p.wire_bytes_out, p.wire_bytes_in);
        for (_, c) in &report.peer_summaries {
            // [steals_out, requests_out, steals_in, requests_in,
            //  requeues, requeued_requests, bytes_out, bytes_in]
            shipped += c[0];
            rq_shipped += c[1];
            resolved += c[2];
            rq_resolved += c[3];
            resolved += c[4];
            rq_resolved += c[5];
            out += c[6];
            inn += c[7];
        }
        anyhow::ensure!(
            shipped == resolved,
            "cluster steal ledger unbalanced: {shipped} shipped vs \
             {resolved} resolved"
        );
        anyhow::ensure!(
            rq_shipped == rq_resolved,
            "cluster request ledger unbalanced: {rq_shipped} vs \
             {rq_resolved}"
        );
        anyhow::ensure!(
            out == inn,
            "cluster byte ledger unbalanced: {out} out vs {inn} in"
        );
        println!(
            "cluster conservation: balanced ({shipped} batches, {out} \
             wire bytes)"
        );
    }
    Ok(())
}

fn cmd_figures(flags: HashMap<String, String>) -> Result<()> {
    let scale = if flags.contains_key("full") {
        bench::Scale::full()
    } else {
        bench::Scale::quick()
    };
    let which = flags.get("fig").map(|s| s.as_str()).unwrap_or("all");
    bench::print_occupancy_table();
    match which {
        "2" => bench::run_fig2(&scale),
        "3" => bench::run_fig3(&scale),
        "4" => bench::run_fig4(&scale),
        "5" => bench::run_fig5(&scale),
        "ablation" => bench::run_ablation(&scale),
        "all" => {
            bench::run_fig2(&scale);
            bench::run_fig3(&scale);
            bench::run_fig4(&scale);
            bench::run_fig5(&scale);
        }
        other => bail!("unknown figure {other}"),
    }
    Ok(())
}

/// Replay chaos schedules by seed: `--seed N` for one, `--seeds A..B`
/// for a range (default: the regression corpus 0..16). Exits nonzero if
/// any seed violates an invariant, printing its full event trace.
#[cfg(feature = "chaos")]
fn cmd_chaos(flags: HashMap<String, String>) -> Result<()> {
    use gcharm::chaos::{run_schedule, theme_name};

    let seeds: Vec<u64> = if let Some(s) = flags.get("seed") {
        vec![s.parse()?]
    } else {
        let range =
            flags.get("seeds").map(|s| s.as_str()).unwrap_or("0..16");
        let (a, b) = range
            .split_once("..")
            .ok_or_else(|| anyhow::anyhow!("--seeds wants A..B, got {range}"))?;
        (a.parse()?..b.parse()?).collect()
    };
    let mut failed = 0usize;
    for seed in seeds {
        println!("=== seed {seed} ({}) ===", theme_name(seed));
        let r = run_schedule(seed)?;
        println!("{r}");
        if !r.ok() {
            failed += 1;
        }
    }
    if failed > 0 {
        bail!("{failed} seed(s) violated invariants");
    }
    Ok(())
}

#[cfg(not(feature = "chaos"))]
fn cmd_chaos(_flags: HashMap<String, String>) -> Result<()> {
    bail!(
        "the chaos harness is feature-gated; rebuild with \
         `cargo build --features chaos` (or run \
         `cargo test --features chaos` for the seed corpus)"
    )
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    match cmd {
        "info" => {
            bench::print_occupancy_table();
            Ok(())
        }
        "nbody" => cmd_nbody(flags),
        "md" => cmd_md(flags),
        "spmv" => cmd_spmv(flags),
        "serve" => cmd_serve(flags),
        "figures" => cmd_figures(flags),
        "node" => cmd_node(flags),
        "chaos" => cmd_chaos(flags),
        _ => {
            println!(
                "usage: gcharm \
                 <info|nbody|md|spmv|serve|figures|node|chaos> \
                 [--flags]\n\
                 see rust/src/main.rs header for options"
            );
            Ok(())
        }
    }
}
