//! Tree walks: build per-bucket interaction lists (paper section 4.1).
//!
//! For each bucket, walk the tree with the Barnes-Hut multipole acceptance
//! criterion: a node whose cell subtends less than `theta` from the bucket
//! is accepted as a monopole (one interaction entry); otherwise it is
//! opened; leaves contribute their particles directly. All particles in a
//! bucket share the same list -- exactly the property the 16x8 CUDA block
//! exploits and our Pallas tile mirrors.
//!
//! List lengths vary strongly with local density (the irregularity driving
//! section 3.1's adaptive combining): clustered buckets open many nodes,
//! void buckets accept a handful of monopoles.

use super::tree::{Particle, Tree};

/// One interaction entry: [x, y, z, mass] -- node monopole or particle.
pub type Interaction = [f32; 4];

/// Stable id of an interaction entry within one iteration: tree-node index
/// for monopoles, `nodes.len() + particle index` for particles. The chare
/// table keys device residency of interaction data on these ids (in real
/// ChaNGa the moments/particle arrays live on the GPU and lists reference
/// them; section 3.2's reuse is about exactly this data).
pub type InterId = u32;

/// Walk statistics for tests/benches.
#[derive(Debug, Clone, Copy, Default)]
pub struct WalkStats {
    pub nodes_opened: usize,
    pub monopoles: usize,
    pub particles: usize,
}

/// Build the interaction list for bucket `b`, with entry ids for residency
/// tracking.
pub fn interaction_list_ids(
    tree: &Tree,
    parts: &[Particle],
    b: usize,
    theta: f64,
) -> (Vec<Interaction>, Vec<InterId>, WalkStats) {
    let bucket_node = &tree.nodes[tree.buckets[b].node];
    let bc = bucket_node.center;
    let bh = bucket_node.half;
    let nnodes = tree.nodes.len() as u32;
    let mut out = Vec::with_capacity(256);
    let mut ids = Vec::with_capacity(256);
    let mut stats = WalkStats::default();
    let mut stack: Vec<usize> = vec![0];

    while let Some(ni) = stack.pop() {
        let node = &tree.nodes[ni];
        if node.count == 0 {
            continue;
        }
        let d = (node.com - bc).norm();
        // Opening criterion: cell size over distance (bucket extent
        // included so nearby cells always open).
        let open = d <= (2.0 * node.half + bh) / theta.max(1e-6);
        if !open && ni != tree.buckets[b].node {
            out.push([
                node.com.x as f32,
                node.com.y as f32,
                node.com.z as f32,
                node.mass as f32,
            ]);
            ids.push(ni as u32);
            stats.monopoles += 1;
            continue;
        }
        if node.bucket >= 0 {
            // leaf: particle-particle interactions (including the bucket's
            // own members; Plummer softening keeps self-terms finite and
            // the kernel adds eps2 > 0)
            for &pi in &tree.order[node.start..node.end] {
                let p = &parts[pi as usize];
                out.push([
                    p.pos.x as f32,
                    p.pos.y as f32,
                    p.pos.z as f32,
                    p.mass as f32,
                ]);
                ids.push(nnodes + pi);
                stats.particles += 1;
            }
        } else {
            stats.nodes_opened += 1;
            for &c in &node.children {
                if c >= 0 {
                    stack.push(c as usize);
                }
            }
        }
    }
    (out, ids, stats)
}

/// Interaction list without ids (convenience for tests and the CPU paths).
pub fn interaction_list(
    tree: &Tree,
    parts: &[Particle],
    b: usize,
    theta: f64,
) -> (Vec<Interaction>, WalkStats) {
    let (out, _, stats) = interaction_list_ids(tree, parts, b, theta);
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::nbody::dataset::DatasetSpec;
    use crate::util::Vec3;

    #[test]
    fn theta_zero_gives_all_particles() {
        // theta -> 0 opens everything: the list is exactly all particles
        let ps = DatasetSpec::tiny().generate();
        let tree = Tree::build(&ps);
        let (list, stats) = interaction_list(&tree, &ps, 0, 1e-9);
        assert_eq!(list.len(), ps.len());
        assert_eq!(stats.monopoles, 0);
        assert_eq!(stats.particles, ps.len());
    }

    #[test]
    fn larger_theta_shorter_lists() {
        let ps = DatasetSpec::tiny().generate();
        let tree = Tree::build(&ps);
        let len = |theta: f64| -> usize {
            (0..tree.buckets.len())
                .map(|b| interaction_list(&tree, &ps, b, theta).0.len())
                .sum()
        };
        let strict = len(0.2);
        let loose = len(1.2);
        assert!(
            loose < strict,
            "looser theta must shorten lists: {loose} vs {strict}"
        );
    }

    #[test]
    fn mass_is_conserved_in_list() {
        // monopole + particle masses in the list == total mass
        let ps = DatasetSpec::tiny().generate();
        let tree = Tree::build(&ps);
        for b in [0, tree.buckets.len() / 2, tree.buckets.len() - 1] {
            let (list, _) = interaction_list(&tree, &ps, b, 0.7);
            let m: f64 = list.iter().map(|e| e[3] as f64).sum();
            assert!(
                (m - 1.0).abs() < 1e-3,
                "bucket {b}: list mass {m} != total"
            );
        }
    }

    #[test]
    fn irregular_list_lengths_with_clustering() {
        let ps = DatasetSpec::tiny().generate();
        let tree = Tree::build(&ps);
        let lens: Vec<usize> = (0..tree.buckets.len())
            .map(|b| interaction_list(&tree, &ps, b, 0.7).0.len())
            .collect();
        let min = *lens.iter().min().unwrap();
        let max = *lens.iter().max().unwrap();
        assert!(
            max as f64 > 1.3 * min as f64,
            "expected irregular lists, got {min}..{max}"
        );
    }

    #[test]
    fn far_uniform_pair_approximates_direct_sum() {
        // two distant clumps: monopole force from the walk list must be
        // close to the direct all-pairs force
        let mut ps = Vec::new();
        for i in 0..32 {
            let dx = (i % 4) as f64 * 0.01;
            let dy = ((i / 4) % 4) as f64 * 0.01;
            ps.push(Particle::at_rest(Vec3::new(dx, dy, 0.0), 1.0));
            ps.push(Particle::at_rest(Vec3::new(100.0 + dx, dy, 0.0), 1.0));
        }
        let tree = Tree::build(&ps);
        // bucket containing origin-side particles
        let b = (0..tree.buckets.len())
            .find(|&b| {
                let pi = tree.bucket_particles(b)[0] as usize;
                ps[pi].pos.x < 50.0
            })
            .unwrap();
        let (list, _) = interaction_list(&tree, &ps, b, 0.5);
        // force on first particle of the bucket from the list
        let pi = tree.bucket_particles(b)[0] as usize;
        let p = ps[pi].pos;
        let eps2 = 1e-4;
        let f_list: f64 = list
            .iter()
            .map(|e| {
                let d = Vec3::new(e[0] as f64, e[1] as f64, e[2] as f64) - p;
                let r2 = d.norm2() + eps2;
                e[3] as f64 * d.x / (r2 * r2.sqrt())
            })
            .sum();
        let f_direct: f64 = ps
            .iter()
            .map(|q| {
                let d = q.pos - p;
                let r2 = d.norm2() + eps2;
                q.mass * d.x / (r2 * r2.sqrt())
            })
            .sum();
        let rel = (f_list - f_direct).abs() / f_direct.abs().max(1e-12);
        assert!(rel < 0.02, "monopole error {rel}");
    }
}
