//! TreePiece chares: the message-driven unit of the ChaNGa-style app.
//!
//! Each TreePiece owns a contiguous Morton range of buckets (paper section
//! 4.1: "particles are divided among TreePiece chares"). Per iteration a
//! piece receives START, walks the (shared, read-only) tree for each of its
//! buckets, and submits one Force work request per 128-entry chunk of the
//! interaction list plus one Ewald request per bucket. Results stream back
//! via METHOD_RESULT; once all expected results arrived the piece
//! integrates its particles (leapfrog), writes them back to the master
//! array, and contributes to the iteration reduction.
//!
//! Chunked lists are where *data reuse* comes from: every chunk of a bucket
//! rereads the same particle buffer, so with the chare table enabled only
//! the first chunk transfers it (section 3.2).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::{
    Chare, ChareId, Ctx, KernelKindId, Msg, Tile, WorkDraft, WrResult,
    METHOD_RESULT,
};
use crate::runtime::shapes::{
    INTERACTIONS, INTER_W, OUT_W, PARTICLE_W, PARTS_PER_BUCKET,
};
use crate::util::Vec3;

use super::tree::{Particle, Tree};
use super::walk::interaction_list_ids;

/// Entry method id: begin one iteration.
pub const METHOD_START: u32 = 1;

/// START payload: everything a piece needs for one iteration.
pub struct StartMsg {
    pub tree: Arc<Tree>,
    /// Read-only particle snapshot the tree was built from.
    pub snapshot: Arc<Vec<Particle>>,
    /// Master array to write integrated state back into.
    pub master: Arc<Mutex<Vec<Particle>>>,
    /// Bucket ids assigned to this piece.
    pub buckets: Vec<usize>,
    /// Registered kernel kinds (from `GCharm::register_kernel`) the piece
    /// tags its force and Ewald work requests with.
    pub force_kind: KernelKindId,
    pub ewald_kind: KernelKindId,
    pub theta: f64,
    pub dt: f64,
    pub do_ewald: bool,
    /// Skip the runtime: compute forces inline on the PE (the multi-core
    /// CPU baseline of Fig 4).
    pub cpu_only: bool,
    /// Gravity softening (squared), matching the executor's kernels.
    pub eps2: f32,
    /// Ewald k-table (read in cpu_only mode; the GPU path uses the
    /// executor's copy).
    pub ktab: Arc<Vec<f32>>,
}

/// Per-particle force accumulator.
#[derive(Debug, Clone, Copy, Default)]
struct Accum {
    acc: Vec3,
    pot: f64,
}

/// The TreePiece chare. Knows its own ChareId so work-request results route
/// back to it.
pub struct TreePiece {
    id: ChareId,
    expected: usize,
    received: usize,
    /// particle index -> accumulated acceleration/potential
    accum: HashMap<u32, Accum>,
    /// bucket tag -> particle ids (in kernel row order)
    rows: HashMap<u64, Vec<u32>>,
    iter_state: Option<IterState>,
}

struct IterState {
    master: Arc<Mutex<Vec<Particle>>>,
    snapshot: Arc<Vec<Particle>>,
    dt: f64,
}

impl TreePiece {
    pub fn new(id: ChareId) -> TreePiece {
        TreePiece {
            id,
            expected: 0,
            received: 0,
            accum: HashMap::new(),
            rows: HashMap::new(),
            iter_state: None,
        }
    }

    fn on_start(&mut self, m: StartMsg, ctx: &mut Ctx) {
        self.expected = 0;
        self.received = 0;
        self.accum.clear();
        self.rows.clear();

        let parts = &*m.snapshot;
        let mut kinetic = 0.0f64;

        for &b in &m.buckets {
            let pids = m.tree.bucket_particles(b).to_vec();
            // padded particle buffer for this bucket (the reusable unit)
            let mut pbuf = vec![0.0f32; PARTS_PER_BUCKET * PARTICLE_W];
            for (j, &pi) in pids.iter().enumerate() {
                let p = &parts[pi as usize];
                pbuf[j * PARTICLE_W] = p.pos.x as f32;
                pbuf[j * PARTICLE_W + 1] = p.pos.y as f32;
                pbuf[j * PARTICLE_W + 2] = p.pos.z as f32;
                pbuf[j * PARTICLE_W + 3] = p.mass as f32;
            }
            self.rows.insert(b as u64, pids.clone());
            for &pi in &pids {
                self.accum.insert(pi, Accum::default());
                let p = &parts[pi as usize];
                kinetic += 0.5 * p.mass * p.vel.norm2();
            }

            let (list, list_ids, _) =
                interaction_list_ids(&m.tree, parts, b, m.theta);

            if m.cpu_only {
                // Fig 4 CPU baseline: compute inline on the PE, no runtime.
                let mut inters = vec![0.0f32; list.len() * INTER_W];
                for (k, e) in list.iter().enumerate() {
                    inters[k * INTER_W..k * INTER_W + 4].copy_from_slice(e);
                }
                let real = &pbuf[..pids.len() * PARTICLE_W];
                let out = crate::coordinator::cpu_kernels::cpu_gravity(
                    real, &inters, m.eps2,
                );
                self.fold_rows(&pids, &out);
                if m.do_ewald {
                    let out = crate::coordinator::cpu_kernels::cpu_ewald(
                        real, &m.ktab,
                    );
                    self.fold_rows(&pids, &out);
                }
                continue;
            }

            // chunk the interaction list into I-entry work requests
            for (chunk, ids) in
                list.chunks(INTERACTIONS).zip(list_ids.chunks(INTERACTIONS))
            {
                let mut inters = vec![0.0f32; INTERACTIONS * INTER_W];
                for (k, e) in chunk.iter().enumerate() {
                    inters[k * INTER_W..k * INTER_W + 4].copy_from_slice(e);
                }
                ctx.submit(WorkDraft {
                    chare: self.id,
                    kind: m.force_kind,
                    buffer: Some(b as u64),
                    data_items: chunk.len(),
                    tag: b as u64,
                    payload: Tile::with_entries(
                        vec![pbuf.clone(), inters],
                        ids.to_vec(),
                    ),
                })
                .expect("canonical force tile shapes");
                self.expected += 1;
            }
            if m.do_ewald {
                ctx.submit(WorkDraft {
                    chare: self.id,
                    kind: m.ewald_kind,
                    buffer: None,
                    data_items: pids.len(),
                    tag: b as u64,
                    payload: Tile::new(vec![pbuf.clone()]),
                })
                .expect("canonical ewald tile shape");
                self.expected += 1;
            }
        }

        self.iter_state = Some(IterState {
            master: m.master,
            snapshot: m.snapshot.clone(),
            dt: m.dt,
        });
        if m.cpu_only || self.expected == 0 {
            // everything computed inline: integrate immediately
            self.integrate_and_contribute(ctx, kinetic);
        }
    }

    fn fold_rows(&mut self, pids: &[u32], out: &[f32]) {
        for (j, &pi) in pids.iter().enumerate() {
            let a = self.accum.get_mut(&pi).expect("accumulator exists");
            a.acc += Vec3::new(
                out[j * OUT_W] as f64,
                out[j * OUT_W + 1] as f64,
                out[j * OUT_W + 2] as f64,
            );
            a.pot += out[j * OUT_W + 3] as f64;
        }
    }

    fn on_result(&mut self, r: WrResult, ctx: &mut Ctx) {
        let pids = self
            .rows
            .get(&r.tag)
            .expect("result for unknown bucket")
            .clone();
        self.fold_rows(&pids, &r.out);
        self.received += 1;
        if self.received == self.expected {
            let st = self.iter_state.as_ref().expect("iteration in flight");
            let kinetic: f64 = self
                .accum
                .keys()
                .map(|&pi| {
                    let p = &st.snapshot[pi as usize];
                    0.5 * p.mass * p.vel.norm2()
                })
                .sum();
            self.integrate_and_contribute(ctx, kinetic);
        }
    }

    /// Leapfrog kick+drift, write back to the master array, contribute
    /// kinetic + 1/2 potential (this piece's share of total energy).
    fn integrate_and_contribute(&mut self, ctx: &mut Ctx, kinetic: f64) {
        let st = self.iter_state.take().expect("iteration in flight");
        let mut potential = 0.0f64;
        {
            let mut master = st.master.lock().unwrap();
            for (&pi, a) in &self.accum {
                let p = &mut master[pi as usize];
                p.acc = a.acc;
                p.pot = a.pot;
                potential += 0.5 * p.mass * a.pot;
                p.vel += a.acc * st.dt;
                p.pos += p.vel * st.dt;
            }
        }
        ctx.contribute(kinetic + potential);
    }
}

impl Chare for TreePiece {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        match msg.method {
            METHOD_START => {
                let m: StartMsg = msg.take();
                self.on_start(m, ctx);
            }
            METHOD_RESULT => {
                let r: WrResult = msg.take();
                self.on_result(r, ctx);
            }
            other => panic!("TreePiece: unknown method {other}"),
        }
    }
}
