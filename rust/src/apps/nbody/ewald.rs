//! Ewald summation k-table for periodic boundary conditions.
//!
//! ChaNGa applies force corrections for periodic images via Ewald
//! summation, executed as a separate GPU kernel (paper sections 4.1, 4.3:
//! 31% occupancy, maxSize 65). We precompute the reciprocal-space table --
//! the `KTABLE` lowest non-zero k-vectors of the box with Gaussian-damped
//! coefficients -- once per run; the kernel evaluates the sinusoid sums per
//! particle.

use crate::runtime::shapes::{KTAB_W, KTABLE};

/// Build the k-table for a cubic box of side `l` with splitting parameter
/// `alpha`. Returns KTABLE x 4 row-major [kx, ky, kz, coef]; rows beyond
/// the available vectors carry coef = 0 (inert padding).
pub fn ktable(l: f64, alpha: f64) -> Vec<f32> {
    let two_pi = std::f64::consts::TAU;
    let kunit = two_pi / l;
    // enumerate integer triples by |k|^2, skip 0
    let range = 3i64;
    let mut ks: Vec<(i64, [i64; 3])> = Vec::new();
    for ix in -range..=range {
        for iy in -range..=range {
            for iz in -range..=range {
                let n2 = ix * ix + iy * iy + iz * iz;
                if n2 > 0 {
                    ks.push((n2, [ix, iy, iz]));
                }
            }
        }
    }
    ks.sort_by_key(|&(n2, v)| (n2, v));
    let vol = l * l * l;
    let mut out = vec![0.0f32; KTABLE * KTAB_W];
    for (row, &(n2, v)) in ks.iter().take(KTABLE).enumerate() {
        let k2 = n2 as f64 * kunit * kunit;
        let coef = (4.0 * std::f64::consts::PI / vol)
            * (-k2 / (4.0 * alpha * alpha)).exp()
            / k2;
        out[row * KTAB_W] = (v[0] as f64 * kunit) as f32;
        out[row * KTAB_W + 1] = (v[1] as f64 * kunit) as f32;
        out[row * KTAB_W + 2] = (v[2] as f64 * kunit) as f32;
        out[row * KTAB_W + 3] = coef as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_ktable_rows() {
        let t = ktable(10.0, 0.5);
        assert_eq!(t.len(), KTABLE * KTAB_W);
    }

    #[test]
    fn coefficients_decay_with_k() {
        let t = ktable(10.0, 0.5);
        let first = t[3];
        let last_active = (0..KTABLE)
            .rev()
            .find(|&r| t[r * KTAB_W + 3] != 0.0)
            .unwrap();
        assert!(first > t[last_active * KTAB_W + 3]);
    }

    #[test]
    fn all_coefficients_nonnegative_and_finite() {
        let t = ktable(300.0, 2.0 / 300.0);
        for r in 0..KTABLE {
            let c = t[r * KTAB_W + 3];
            assert!(c.is_finite() && c >= 0.0);
        }
    }

    #[test]
    fn k_vectors_are_multiples_of_kunit() {
        let l = 10.0f64;
        let t = ktable(l, 0.5);
        let kunit = std::f64::consts::TAU / l;
        for r in 0..4 {
            for c in 0..3 {
                let v = t[r * KTAB_W + c] as f64 / kunit;
                assert!((v - v.round()).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn no_zero_vector_included() {
        let t = ktable(10.0, 0.5);
        for r in 0..KTABLE {
            if t[r * KTAB_W + 3] != 0.0 {
                let n: f32 = (0..3).map(|c| t[r * KTAB_W + c].abs()).sum();
                assert!(n > 0.0, "row {r} is the zero vector");
            }
        }
    }
}
